"""Shared benchmark scaffolding: paper-setup clusters, profiles, policies.

Every harness reproduces one paper artifact on the DESIGN.md §4 evaluation
path: real solvers + real routing statistics + the calibrated ground-truth
variability model, replayed through the discrete-event EP simulator.
"""

from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional

import numpy as np

from repro.configs import get
from repro.core import (ClusterTopology, ClusterVariability, DriftConfig,
                        SolveContext, StealConfig, ViBEConfig, ViBEController,
                        get_policy, make_cluster)
from repro.serving import (EPSimulator, SimConfig, WORKLOADS,
                           routing_profile)

MODELS = ("deepseek-v3-671b", "qwen3-moe-235b-a22b")
PROFILE_TOKENS = 16_384            # paper's stressed operating point


def paper_cluster(model_name: str, regime: str = "mi325x", ep: int = 8,
                  seed: int = 0) -> ClusterVariability:
    m = get(model_name)
    return make_cluster(ep, regime, d_model=m.d_model, d_ff=m.moe_d_ff,
                        experts_per_rank=max(m.n_experts // ep, 1),
                        seed=seed)


def profile_W(model_name: str, workload: str, ep: int = 8) -> np.ndarray:
    m = get(model_name)
    prof = routing_profile(WORKLOADS[workload], m._n_moe_layers(),
                           m.n_experts)
    return prof * PROFILE_TOKENS * m.top_k


def placement_for(policy: str, model_name: str, workload: str,
                  cluster: ClusterVariability, ep: int = 8,
                  slots_per_rank=None,
                  topology: Optional[ClusterTopology] = None):
    """Registry-driven solve: capabilities decide what the context carries
    (no per-policy special-casing). The default topology is the explicit
    flat one — bit-identical placements to the pre-topology call sites
    (pinned by tests), while making the topology input first-class."""
    W = profile_W(model_name, workload, ep)
    pol = get_policy(policy)
    caps = pol.capabilities
    if topology is None:
        topology = ClusterTopology.flat(ep, cluster.ici_bw)
    ctx = SolveContext(
        w=W, n_ranks=ep,
        perf_models=cluster.fit_models() if caps.needs_perf_models else None,
        slot_budget=slots_per_rank if caps.accepts_slot_budget else None,
        topology=topology)
    return pol.solve(ctx)


def make_sim(model_name: str, workload: str, policy: str,
             regime: str = "mi325x", ep: int = 8, seed: int = 1,
             adaptive: bool = False, record_layers: bool = False,
             cluster: Optional[ClusterVariability] = None,
             steal: Optional[StealConfig] = None) -> EPSimulator:
    m = get(model_name)
    cluster = cluster or paper_cluster(model_name, regime, ep)
    sim_cfg = SimConfig(ep_degree=ep, seed=seed, max_prefill_tokens=16_384,
                        record_layer_stats=record_layers)
    if adaptive or steal is not None:
        # a controller-backed sim: adaptive recalibration, dispatch-time
        # stealing, or both (stealing works for static controllers too —
        # its whole point is reacting between/without recalibrations)
        perf = cluster.fit_models()
        ctl = ViBEController(
            m._n_moe_layers(), m.n_experts, ep, perf,
            ViBEConfig(policy=policy, adaptive=adaptive, steal=steal,
                       drift=DriftConfig(window=50, interval=10,
                                         cooldown=20),
                       expert_bytes=3 * m.d_model * m.moe_d_ff * 2),
            initial_w=profile_W(model_name, workload, ep))
        return EPSimulator(m, cluster, WORKLOADS[workload], sim_cfg,
                           controller=ctl)
    pl = placement_for(policy, model_name, workload, cluster, ep)
    return EPSimulator(m, cluster, WORKLOADS[workload], sim_cfg,
                       placement=pl)


def qps_grid(model_name: str, workload: str, cluster=None, n: int = 5):
    """Capacity-relative QPS grid bracketing the saturation knee."""
    cluster = cluster or paper_cluster(model_name)
    sim = EPSimulator(get(model_name), cluster, WORKLOADS[workload],
                      SimConfig(ep_degree=cluster.n_devices, seed=0,
                                max_prefill_tokens=16_384),
                      placement=placement_for("eplb", model_name, workload,
                                              cluster,
                                              cluster.n_devices))
    mean_in = WORKLOADS[workload].mean_in
    per_step = max(int(16_384 // mean_in), 1)
    dt = sim.step_time(int(per_step * mean_in), mean_in / 2)
    capacity = per_step / dt
    return tuple(round(capacity * f, 1) for f in
                 np.linspace(0.55, 1.15, n))


def emit(rows: List[Dict], name: str) -> None:
    """CSV to stdout + JSON under results/bench/."""
    os.makedirs("results/bench", exist_ok=True)
    with open(f"results/bench/{name}.json", "w") as f:
        json.dump(rows, f, indent=1, default=float)
    for r in rows:
        for k, v in r.items():
            if k in ("bench", "label"):
                continue
            tag = r.get("label", name)
            if isinstance(v, float):
                print(f"{name},{tag},{k},{v:.6g}")
            elif isinstance(v, (int, str)):
                print(f"{name},{tag},{k},{v}")
    sys.stdout.flush()
