"""Kernel harness: fused MoE FFN + router vs pure-jnp references.

On this CPU host the Pallas kernels execute in interpret mode (correctness,
not speed); the wall-clock numbers reported are for the jitted XLA-CPU
reference path, giving a stable regression metric, plus the kernels'
VMEM/block accounting for the v5e target.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels.moe_ffn import fused_moe_ffn_pallas
from .common import emit

SHAPES = [  # (E_loc, C, D, F) — per-device expert shards of the MoE archs
    ("qwen3", 8, 512, 4096, 1536),
    ("deepseek", 16, 512, 7168, 2048),
    ("granite", 3, 512, 1536, 512),
    ("jamba", 1, 512, 8192, 24576),
]


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run(quick=True):
    rows = []
    jref = jax.jit(ref.moe_ffn_ref)
    for name, E, C, D, F in SHAPES:
        if quick and name in ("jamba", "deepseek"):
            C = 64
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        toks = jax.random.normal(ks[0], (E, C, D)).astype(jnp.bfloat16)
        w1 = (jax.random.normal(ks[1], (E, D, F)) / np.sqrt(D)).astype(jnp.bfloat16)
        w3 = (jax.random.normal(ks[2], (E, D, F)) / np.sqrt(D)).astype(jnp.bfloat16)
        w2 = (jax.random.normal(ks[3], (E, F, D)) / np.sqrt(F)).astype(jnp.bfloat16)
        us = _time(jref, w1, w3, w2, toks) * 1e6
        y_ref = np.asarray(jref(w1, w3, w2, toks), np.float32)
        bm, bf = ops.pick_blocks(D, F)
        # interpret-mode correctness on a small slice (full jamba is slow)
        sl = min(C, 32 if quick else 64)
        y_k = np.asarray(
            fused_moe_ffn_pallas(w1, w3, w2, toks[:, :sl], bm=min(bm, sl),
                                 bf=bf, interpret=True), np.float32)
        err = np.abs(y_k - y_ref[:, :sl]).max() / max(np.abs(y_ref).max(),
                                                      1e-9)
        flops = 2 * E * C * D * F * 3
        resident = (bm * D * 2 + bm * D * 4 + 3 * D * bf * 2 + bm * bf * 4)
        rows.append({
            "bench": "kernels", "label": name,
            "ref_us_per_call": us,
            "rel_err_vs_ref": float(err),
            "gflop": flops / 1e9,
            "block_bm": bm, "block_bf": bf,
            "vmem_resident_mib": resident / 2**20,
            "v5e_ideal_us": flops / 197e12 * 1e6,
        })
    # router
    for T, E, K in ((4096, 128, 8), (4096, 256, 8)):
        logits = jax.random.normal(jax.random.PRNGKey(1), (T, E))
        jr = jax.jit(lambda l: ref.router_topk_ref(l, K))
        us = _time(jr, logits) * 1e6
        w_r, i_r = jr(logits)
        w_k, i_k = ops.router_topk(logits, K)
        rows.append({
            "bench": "kernels", "label": f"router_T{T}_E{E}",
            "ref_us_per_call": us,
            "idx_match": bool((np.asarray(i_k) == np.asarray(i_r)).all()),
        })
    emit(rows, "kernels")
    return rows


if __name__ == "__main__":
    run(quick=False)
