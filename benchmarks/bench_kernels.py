"""Kernel harness: fused + ragged MoE FFN and router vs pure-jnp references.

On this CPU host the Pallas kernels execute in interpret mode (correctness,
not speed); the wall-clock numbers reported are for the jitted XLA-CPU
reference paths, giving a stable regression metric, plus the kernels'
VMEM/block accounting for the v5e target.

The **ragged sweep** is the ISSUE 4 acceptance gate: on the qwen3 expert
shape it routes a fixed token budget with Zipf(α) skew and compares the two
grouped-FFN implementations *dropless to dropless* —

* capacity path: buckets sized to the hottest expert (the only dropless
  fixed capacity), compute = E × max_e(load_e) rows;
* ragged path: flat expert-sorted buffer, compute = realized tokens plus
  per-expert tile padding.

Emitted per α: both FLOP counts, wasted-FLOP fractions, the drop count a
paper-default cf=1.25 bucket would have incurred (the artifact the ragged
path removes — its own drop count is structurally 0), and (at the stressed
α=1.2 point) jitted XLA-CPU wall-clock for both paths with exact
row-by-row agreement checked. The ≥1.5× speedup at α=1.2 is asserted.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels.moe_ffn import fused_moe_ffn_pallas
from repro.kernels.ragged_moe_ffn import (ragged_n_tiles,
                                          ragged_tile_metadata)
from .common import emit

SHAPES = [  # (E_loc, C, D, F) — per-device expert shards of the MoE archs
    ("qwen3", 8, 512, 4096, 1536),
    ("deepseek", 16, 512, 7168, 2048),
    ("granite", 3, 512, 1536, 512),
    ("jamba", 1, 512, 8192, 24576),
]

#: Zipf skew sweep for the ragged-vs-capacity comparison; α=1.2 is the
#: stressed operating point the acceptance criterion pins.
RAGGED_ALPHAS = (0.0, 0.6, 1.2)
RAGGED_SPEEDUP_FLOOR = 1.5


def _time(fn, *args, reps=3):
    """Best-of-reps wall clock after one warmup call (which also compiles).

    Min, not mean: on a shared/loaded host the minimum is the robust
    estimator of the code's actual cost (same convention as
    bench_placement_solve), which keeps the --check regression gate from
    tripping on scheduler noise."""
    jax.block_until_ready(fn(*args))
    best = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _zipf_sizes(E: int, total: int, alpha: float, seed: int = 0) -> np.ndarray:
    """Integer per-expert loads summing to ``total`` with Zipf(α) shares
    (largest-remainder apportionment; hot expert shuffled per seed)."""
    rng = np.random.default_rng(seed)
    share = 1.0 / np.arange(1, E + 1) ** alpha
    share = rng.permutation(share / share.sum())
    exact = share * total
    sizes = np.floor(exact).astype(np.int64)
    rem = total - sizes.sum()
    order = np.argsort(-(exact - sizes), kind="stable")
    sizes[order[:rem]] += 1
    return sizes


def _ragged_vs_capacity(name, E, D, F, A, bm, alpha, timed, reps):
    """One sweep point: build both dropless layouts from the same rows."""
    sizes = _zipf_sizes(E, A, alpha)
    c_cap = int(-(-int(sizes.max()) // 8) * 8)      # dropless fixed bucket
    # the bench scores a *known* realized routing, so the buffer is sized
    # to the exact occupied tile count — the cost the Pallas kernel pays
    # (it skips unoccupied tiles; the in-dispatch jit path instead carries
    # the static worst-case bound ragged_n_tiles(A) = A//bm + E)
    nt = int((-(-sizes // bm)).sum())
    assert nt <= ragged_n_tiles(A, E, bm)
    row_off, tile_group = ragged_tile_metadata(jnp.asarray(sizes), bm, nt)
    off = np.asarray(row_off)
    occupied_rows = int(off[-1])
    assert occupied_rows == nt * bm

    flop_row = 2 * D * F * 3                         # SwiGLU MACs per row
    cap_gflop = E * c_cap * flop_row / 1e9
    ragged_gflop = occupied_rows * flop_row / 1e9
    realized_gflop = A * flop_row / 1e9
    # what a paper-default cf=1.25 bucket would have dropped on this skew
    cap_cf = max(int(np.ceil(A / E * 1.25)), 1)
    dropped_cf = int(np.maximum(sizes - cap_cf, 0).sum())

    row = {
        "bench": "kernels", "label": f"ragged_{name}_a{alpha:g}",
        "zipf_alpha": alpha, "tokens": A, "block_m": bm,
        "capacity_rows": E * c_cap, "ragged_rows": occupied_rows,
        "capacity_gflop": cap_gflop, "ragged_gflop": ragged_gflop,
        "realized_gflop": realized_gflop,
        "wasted_flop_frac_capacity": 1.0 - A / (E * c_cap),
        "wasted_flop_frac_ragged": 1.0 - A / max(occupied_rows, 1),
        "dropped_at_cf1.25_capacity": dropped_cf,
        "dropped_ragged": 0,
    }
    if not timed:
        return row

    rng = np.random.default_rng(1 + int(alpha * 10))
    rows_np = rng.standard_normal((A, D)).astype(np.float32)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    w1 = (jax.random.normal(ks[0], (E, D, F)) / np.sqrt(D)).astype(jnp.bfloat16)
    w3 = (jax.random.normal(ks[1], (E, D, F)) / np.sqrt(D)).astype(jnp.bfloat16)
    w2 = (jax.random.normal(ks[2], (E, F, D)) / np.sqrt(F)).astype(jnp.bfloat16)
    buf = np.zeros((nt * bm, D), np.float32)
    toks = np.zeros((E, c_cap, D), np.float32)
    start = 0
    for e, s in enumerate(sizes):
        seg = rows_np[start:start + s]
        buf[off[e]:off[e] + s] = seg
        toks[e, :s] = seg
        start += s
    buf = jnp.asarray(buf, jnp.bfloat16)
    toks = jnp.asarray(toks, jnp.bfloat16)

    jcap = jax.jit(ref.moe_ffn_ref)
    jrag = jax.jit(ref.ragged_moe_ffn_ref)
    cap_us = _time(jcap, w1, w3, w2, toks, reps=reps) * 1e6
    rag_us = _time(jrag, w1, w3, w2, buf, tile_group, reps=reps) * 1e6
    if alpha >= 1.2 and cap_us / rag_us < RAGGED_SPEEDUP_FLOOR:
        # flake guard mirroring run.py --check: one slow scheduler sample
        # must not abort the acceptance assert — re-measure once, keep the
        # per-path best before the floor is enforced
        cap_us = min(cap_us, _time(jcap, w1, w3, w2, toks,
                                   reps=reps) * 1e6)
        rag_us = min(rag_us, _time(jrag, w1, w3, w2, buf, tile_group,
                                   reps=reps) * 1e6)
    # exactness: same rows through both layouts must agree bit-for-bit in
    # the compute (tolerance covers XLA layout-dependent fusion only)
    y_cap = np.asarray(jcap(w1, w3, w2, toks), np.float32)
    y_rag = np.asarray(jrag(w1, w3, w2, buf, tile_group), np.float32)
    err = 0.0
    for e, s in enumerate(sizes):
        if s:
            seg_err = np.abs(y_rag[off[e]:off[e] + s] - y_cap[e, :s]).max()
            err = max(err, float(seg_err))
    row.update({
        "capacity_us_per_call": cap_us,
        "ragged_us_per_call": rag_us,
        "ragged_speedup": cap_us / rag_us,
        "ragged_vs_capacity_err": err,
    })
    return row


def run(quick=True):
    rows = []
    jref = jax.jit(ref.moe_ffn_ref)
    for name, E, C, D, F in SHAPES:
        if quick and name in ("jamba", "deepseek"):
            C = 64
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        toks = jax.random.normal(ks[0], (E, C, D)).astype(jnp.bfloat16)
        w1 = (jax.random.normal(ks[1], (E, D, F)) / np.sqrt(D)).astype(jnp.bfloat16)
        w3 = (jax.random.normal(ks[2], (E, D, F)) / np.sqrt(D)).astype(jnp.bfloat16)
        w2 = (jax.random.normal(ks[3], (E, F, D)) / np.sqrt(F)).astype(jnp.bfloat16)
        us = _time(jref, w1, w3, w2, toks) * 1e6
        y_ref = np.asarray(jref(w1, w3, w2, toks), np.float32)
        bm, bf = ops.pick_blocks(D, F)
        # interpret-mode correctness on a small slice (full jamba is slow)
        sl = min(C, 32 if quick else 64)
        y_k = np.asarray(
            fused_moe_ffn_pallas(w1, w3, w2, toks[:, :sl], bm=min(bm, sl),
                                 bf=bf, interpret=True), np.float32)
        err = np.abs(y_k - y_ref[:, :sl]).max() / max(np.abs(y_ref).max(),
                                                      1e-9)
        flops = 2 * E * C * D * F * 3
        resident = (bm * D * 2 + bm * D * 4 + 3 * D * bf * 2 + bm * bf * 4)
        rows.append({
            "bench": "kernels", "label": name,
            "ref_us_per_call": us,
            "rel_err_vs_ref": float(err),
            "capacity_gflop": flops / 1e9,
            "ragged_gflop": flops / 1e9,     # balanced fixture: same rows
            "block_bm": bm, "block_bf": bf,
            "vmem_resident_mib": resident / 2**20,
            "v5e_ideal_us": flops / 197e12 * 1e6,
        })

    # ragged vs capacity across Zipf skew (qwen3 expert shape; acceptance)
    name, E, _, D, F = SHAPES[0]
    A, bm = (2048, 128) if quick else (4096, 128)
    reps = 2 if quick else 3
    for alpha in RAGGED_ALPHAS:
        timed = (alpha == 1.2) or not quick
        row = _ragged_vs_capacity(name, E, D, F, A, bm, alpha, timed, reps)
        rows.append(row)
        if alpha == 1.2:
            assert row["ragged_vs_capacity_err"] <= 5e-2, row
            assert row["ragged_speedup"] >= RAGGED_SPEEDUP_FLOOR, (
                f"ragged speedup {row['ragged_speedup']:.2f}× below "
                f"{RAGGED_SPEEDUP_FLOOR}× at α=1.2")

    # router
    for T, E, K in ((4096, 128, 8), (4096, 256, 8)):
        logits = jax.random.normal(jax.random.PRNGKey(1), (T, E))
        jr = jax.jit(lambda l: ref.router_topk_ref(l, K))
        us = _time(jr, logits) * 1e6
        w_r, i_r = jr(logits)
        w_k, i_k = ops.router_topk(logits, K)
        rows.append({
            "bench": "kernels", "label": f"router_T{T}_E{E}",
            "ref_us_per_call": us,
            "idx_match": bool((np.asarray(i_k) == np.asarray(i_r)).all()),
        })
    emit(rows, "kernels")
    return rows


if __name__ == "__main__":
    run(quick=False)
