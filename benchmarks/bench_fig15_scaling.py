"""Fig 15: projected per-MoE-layer tail latency vs EP group size.

Scaling the measured per-device profile to larger EP groups (duplicating
the empirical distribution, as the paper does with its 80-GPU profiles):
larger groups accumulate more spread (straggler probability ↑) but hold
fewer experts per rank (placement freedom ↓) — the paper finds a 16–32
sweet spot and convergence of all policies past 64.

ViBE-R extends the sweep past that convergence point: with one spare slot
per rank for hot-expert replicas, the straggler-vs-freedom trade-off bends
back — replicated copies absorb the skew that singleton placement can no
longer spread once experts-per-rank gets small.

The policy set is *enumerated from the registry* (repro.core.policy):
registering a new placement policy adds it to this sweep — including the
GEM-style and HarMoEny-style related-work baselines — with no per-policy
special-casing here (capability flags decide what each solve consumes).
"""

import numpy as np

from repro.configs import get
from repro.core import (ClusterTopology, SolveContext, get_policy,
                        make_cluster, registered_policies)
from repro.serving import (EPSimulator, SimConfig, WORKLOADS,
                           routing_profile, sample_requests, summarize)
from repro.serving.simulator import rank_latency_matrix
from .common import PROFILE_TOKENS, emit


def run(model="deepseek-v3-671b", workload="sharegpt", quick=True,
        seeds=(0, 1, 2), n_steps=40):
    m = get(model)
    L, E = m._n_moe_layers(), m.n_experts
    spec = WORKLOADS[workload]
    policies = registered_policies()
    rows = []
    for ep in (8, 16, 32, 64, 128, 256):
        if E % ep:
            continue
        tail = {p: [] for p in policies}
        gain, gain_r = [], []
        for seed in (seeds[:1] if quick else seeds):
            cluster = make_cluster(ep, "mi325x", d_model=m.d_model,
                                   d_ff=m.moe_d_ff,
                                   experts_per_rank=E // ep, seed=seed)
            perf = cluster.fit_models()
            prof = routing_profile(spec, L, E)
            W = prof * PROFILE_TOKENS * m.top_k
            rng = np.random.default_rng(seed + 100)
            # paper's projection methodology: static profiled loads +
            # per-invocation jitter, tail over repeated layer executions
            for policy in policies:
                # replication-capable policies run their default slot
                # budget (one spare replica slot per rank)
                pol = get_policy(policy)
                pl = pol.solve(SolveContext(
                    w=W, n_ranks=ep,
                    perf_models=(perf if pol.capabilities.needs_perf_models
                                 else None)))
                rank_load = pl.rank_loads(W)
                maxes = [rank_latency_matrix(cluster, rank_load,
                                             rng=rng).max(1)
                         for _ in range(n_steps // (2 if quick else 1))]
                tail[policy].append(
                    float(np.percentile(np.concatenate(maxes), 99)))
            gain.append(tail["eplb"][-1] / tail["vibe"][-1] - 1)
            gain_r.append(tail["vibe"][-1] / tail["vibe_r"][-1] - 1)
        row = {
            "bench": "fig15", "label": f"EP{ep}",
            "ep": ep, "experts_per_rank": E // ep,
            "vibe_gain_over_eplb_pct": 100 * float(np.mean(gain)),
            "vibe_r_gain_over_vibe_pct": 100 * float(np.mean(gain_r)),
        }
        row.update({f"p99_layer_ms_{p}": 1e3 * float(np.mean(tail[p]))
                    for p in policies})
        rows.append(row)
    emit(rows, "fig15_scaling")
    return rows


def run_hier(model="deepseek-v3-671b", workload="sharegpt", quick=True,
             n_nodes=8, n_requests=16):
    """Fleet-scale 2-level sweep: vibe_h vs vibe_r on the same topology.

    Both policies solve against the *same* 2-level topology (``n_nodes``
    nodes, ICI within / ~8x-slower DCN between) and replay the same
    request trace through :class:`EPSimulator` with the hierarchical a2a
    clock. ``dcn_reduction_x`` (flat vibe_r's cross-node bytes over
    vibe_h's) and ``ttft_ratio`` (vibe_r's P90 TTFT over vibe_h's) are
    the ``--check`` quality gates: vibe_h must keep cutting DCN traffic
    without giving the tail latency back.
    """
    m = get(model)
    L, E = m._n_moe_layers(), m.n_experts
    spec = WORKLOADS[workload]
    rows = []
    for ep in ((64,) if quick else (64, 128, 256)):
        if E % ep or ep % n_nodes:
            continue
        cluster = make_cluster(ep, "mi325x", d_model=m.d_model,
                               d_ff=m.moe_d_ff,
                               experts_per_rank=max(E // ep, 1), seed=0)
        topo = ClusterTopology.uniform(n_nodes, ep // n_nodes,
                                       cluster.ici_bw)
        perf = cluster.fit_models()
        W = routing_profile(spec, L, E) * PROFILE_TOKENS * m.top_k
        arm = {}
        for policy in ("vibe_r", "vibe_h"):
            pl = get_policy(policy).solve(SolveContext(
                w=W, n_ranks=ep, perf_models=perf, topology=topo))
            sim = EPSimulator(m, cluster, spec,
                              SimConfig(ep_degree=ep, seed=1,
                                        max_prefill_tokens=16_384,
                                        topology=topo),
                              placement=pl)
            reqs = sample_requests(spec, n_requests, qps=50.0, seed=2)
            s = summarize(sim.run(reqs))
            arm[policy] = (sim.dcn_bytes, sim.ici_bytes, s["ttft_p90"])
        dcn_r, ici_r, p90_r = arm["vibe_r"]
        dcn_h, ici_h, p90_h = arm["vibe_h"]
        rows.append({
            "bench": "fig15_hier", "label": f"EP{ep}",
            "ep": ep, "n_nodes": n_nodes,
            "dcn_gb_vibe_r": dcn_r / 1e9, "dcn_gb_vibe_h": dcn_h / 1e9,
            "dcn_frac_vibe_r": dcn_r / max(dcn_r + ici_r, 1e-9),
            "dcn_frac_vibe_h": dcn_h / max(dcn_h + ici_h, 1e-9),
            "dcn_reduction_x": dcn_r / max(dcn_h, 1e-9),
            "ttft_p90_ms_vibe_r": 1e3 * p90_r,
            "ttft_p90_ms_vibe_h": 1e3 * p90_h,
            "ttft_ratio": p90_r / max(p90_h, 1e-12),
        })
    emit(rows, "fig15_hier")
    return rows


if __name__ == "__main__":
    run(quick=False)
    run_hier(quick=False)
