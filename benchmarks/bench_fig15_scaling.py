"""Fig 15: projected per-MoE-layer tail latency vs EP group size.

Scaling the measured per-device profile to larger EP groups (duplicating
the empirical distribution, as the paper does with its 80-GPU profiles):
larger groups accumulate more spread (straggler probability ↑) but hold
fewer experts per rank (placement freedom ↓) — the paper finds a 16–32
sweet spot and convergence of all policies past 64.

ViBE-R extends the sweep past that convergence point: with one spare slot
per rank for hot-expert replicas, the straggler-vs-freedom trade-off bends
back — replicated copies absorb the skew that singleton placement can no
longer spread once experts-per-rank gets small.

The policy set is *enumerated from the registry* (repro.core.policy):
registering a new placement policy adds it to this sweep — including the
GEM-style and HarMoEny-style related-work baselines — with no per-policy
special-casing here (capability flags decide what each solve consumes).
"""

import numpy as np

from repro.configs import get
from repro.core import (SolveContext, get_policy, make_cluster,
                        registered_policies)
from repro.serving import WORKLOADS, routing_profile
from repro.serving.simulator import rank_latency_matrix
from .common import PROFILE_TOKENS, emit


def run(model="deepseek-v3-671b", workload="sharegpt", quick=True,
        seeds=(0, 1, 2), n_steps=40):
    m = get(model)
    L, E = m._n_moe_layers(), m.n_experts
    spec = WORKLOADS[workload]
    policies = registered_policies()
    rows = []
    for ep in (8, 16, 32, 64, 128):
        if E % ep:
            continue
        tail = {p: [] for p in policies}
        gain, gain_r = [], []
        for seed in (seeds[:1] if quick else seeds):
            cluster = make_cluster(ep, "mi325x", d_model=m.d_model,
                                   d_ff=m.moe_d_ff,
                                   experts_per_rank=E // ep, seed=seed)
            perf = cluster.fit_models()
            prof = routing_profile(spec, L, E)
            W = prof * PROFILE_TOKENS * m.top_k
            rng = np.random.default_rng(seed + 100)
            # paper's projection methodology: static profiled loads +
            # per-invocation jitter, tail over repeated layer executions
            for policy in policies:
                # replication-capable policies run their default slot
                # budget (one spare replica slot per rank)
                pol = get_policy(policy)
                pl = pol.solve(SolveContext(
                    w=W, n_ranks=ep,
                    perf_models=(perf if pol.capabilities.needs_perf_models
                                 else None)))
                rank_load = pl.rank_loads(W)
                maxes = [rank_latency_matrix(cluster, rank_load,
                                             rng=rng).max(1)
                         for _ in range(n_steps // (2 if quick else 1))]
                tail[policy].append(
                    float(np.percentile(np.concatenate(maxes), 99)))
            gain.append(tail["eplb"][-1] / tail["vibe"][-1] - 1)
            gain_r.append(tail["vibe"][-1] / tail["vibe_r"][-1] - 1)
        row = {
            "bench": "fig15", "label": f"EP{ep}",
            "ep": ep, "experts_per_rank": E // ep,
            "vibe_gain_over_eplb_pct": 100 * float(np.mean(gain)),
            "vibe_r_gain_over_vibe_pct": 100 * float(np.mean(gain_r)),
        }
        row.update({f"p99_layer_ms_{p}": 1e3 * float(np.mean(tail[p]))
                    for p in policies})
        rows.append(row)
    emit(rows, "fig15_scaling")
    return rows


if __name__ == "__main__":
    run(quick=False)
