"""Figs 11+12: drift — static vs adaptive recalibration, both drift kinds.

**Workload drift**: placement is profiled on one dataset and served on
another (SG→SN, SN→SG); adaptive ViBE/EPLB recover most of the lost goodput
at the cost of brief migration stalls (Fig 12's TTFT spikes), with per-event
moved-expert counts and transfer bytes accounted.

**Hardware drift** (the paper's "performance estimates" refresh, §4.2.4):
the ground-truth cluster itself changes over the virtual clock via a
:data:`repro.core.SCENARIOS` event schedule (thermal ramp, fleet power cap,
transient interference, device replacement). Three arms per scenario:

* ``stale``    — placement solved from the t=0 profile, never refreshed;
* ``adaptive`` — ViBE with online perf-drift recalibration (telemetry
  residual watch → refit f_g from the window → re-solve);
* ``oracle``   — placement solved from a post-drift re-profile (the
  upper bound an offline re-sweep would reach).

``recovered`` reports (adaptive − stale) / (oracle − stale) goodput.

Each A/B case profiles the cluster ONCE and shares the fitted models across
its arms — ``fit_models()`` draws from the cluster's jitter RNG, so
profiling per arm would hand each arm a different hardware snapshot.
"""

from repro.configs import get
from repro.core import (DriftConfig, PerfDriftConfig, SCENARIOS, SolveContext,
                        StealConfig, ViBEConfig, ViBEController, get_policy,
                        make_cluster, make_scenario)
from repro.serving import (EPSimulator, PAPER_SLOS, SimConfig, TRACES,
                           WORKLOADS, goodput, sample_requests, sample_trace)
from .common import emit, paper_cluster, profile_W

EXPERT_BYTES = lambda m: 3 * m.d_model * m.moe_d_ff * 2


def _placement(policy, W, cluster, perf, ep=8):
    """Registry solve reusing an already-fitted perf-model set (so A/B arms
    of one case share one hardware snapshot)."""
    pol = get_policy(policy)
    ctx = SolveContext(
        w=W, n_ranks=ep,
        perf_models=perf if pol.capabilities.needs_perf_models else None)
    return pol.solve(ctx)


def _sim(model, profile_wl, serve_wl, policy, adaptive, cluster, perf,
         seed=3):
    m = get(model)
    W0 = profile_W(model, profile_wl)
    cfg = SimConfig(ep_degree=8, seed=seed, max_prefill_tokens=16_384)
    if adaptive:
        ctl = ViBEController(
            m._n_moe_layers(), m.n_experts, 8, perf,
            ViBEConfig(policy=policy, adaptive=True,
                       drift=DriftConfig(window=50, interval=10,
                                         cooldown=20),
                       expert_bytes=EXPERT_BYTES(m)),
            initial_w=W0)
        return EPSimulator(m, cluster, WORKLOADS[serve_wl], cfg,
                           controller=ctl)
    pl = _placement(policy, W0, cluster, perf)
    return EPSimulator(m, cluster, WORKLOADS[serve_wl], cfg, placement=pl)


def run(model="deepseek-v3-671b", quick=True):
    rows = []
    n_req = 200 if quick else 500
    cases = [("sonnet", "sonnet", 20.0), ("sharegpt", "sonnet", 20.0),
             ("sonnet", "sharegpt", 120.0),
             ("sharegpt", "sharegpt", 120.0)]
    for prof_wl, serve_wl, qps in cases:
        slo = PAPER_SLOS[(serve_wl, model)]
        # ONE hardware snapshot per case: every arm below scores against
        # the same fitted models (fit_models() advances the jitter RNG)
        cluster = paper_cluster(model, "mi325x")
        perf = cluster.fit_models()
        for policy in ("eplb", "vibe"):
            for adaptive in ((False, True) if prof_wl != serve_wl
                             else (False,)):
                sim = _sim(model, prof_wl, serve_wl, policy, adaptive,
                           cluster, perf)
                # serving profile differs from the profiled one → the sim's
                # own routing profile is the *serving* workload's
                reqs = sample_requests(WORKLOADS[serve_wl], n_req, qps=qps,
                                       seed=4)
                recs = sim.run(reqs, phase="prefill")
                row = {
                    "bench": "fig11",
                    "label": f"{prof_wl[:2]}->{serve_wl[:2]}/{policy}"
                             + ("/adaptive" if adaptive else "/static"),
                    "goodput": goodput(recs, slo),
                }
                if adaptive and sim.controller is not None:
                    moved = sum(u.moved_experts
                                for u in sim.controller.updates)
                    row.update(
                        recalibrations=len(sim.controller.updates),
                        moved_experts=moved,
                        migration_bytes=sum(
                            u.migration_bytes
                            for u in sim.controller.updates),
                        stall_total_ms=1e3 * sum(
                            s for s, _, _ in sim.migration_stalls),
                    )
                rows.append(row)
    rows += run_hardware(model, quick=quick)
    emit(rows, "fig11_drift")
    return rows


# ---------------------------------------------------------------------------
# hardware drift: stale vs adaptive vs oracle under SCENARIOS schedules
# ---------------------------------------------------------------------------

def _hw_cluster(model, scenario, t0, duration, ep=8):
    m = get(model)
    events = make_scenario(scenario, ep, t0=t0, duration=duration)
    return make_cluster(ep, "mi325x", d_model=m.d_model, d_ff=m.moe_d_ff,
                        experts_per_rank=max(m.n_experts // ep, 1),
                        events=events)


def run_hardware(model="deepseek-v3-671b", quick=True, workload="sonnet",
                 qps=40.0, t0=1.0, duration=2.0):
    # qps sits between the stale arm's post-drift capacity and the
    # re-solved arms' — the regime where a stale f_g actually costs goodput
    m = get(model)
    slo = PAPER_SLOS[(workload, model)]
    n_req = 300 if quick else 500
    W0 = profile_W(model, workload)
    rows = []
    for scenario in sorted(SCENARIOS):
        reqs = sample_requests(WORKLOADS[workload], n_req, qps=qps, seed=4)
        t_end = t0 + duration + 1.0
        gps = {}
        stats = {}
        for arm in ("stale", "adaptive", "oracle"):
            # fresh cluster per arm: identical speeds/schedule (same seed),
            # independent jitter stream — arms see the same hardware, not
            # each other's RNG position
            cluster = _hw_cluster(model, scenario, t0, duration)
            perf = cluster.fit_models(t=t_end if arm == "oracle" else 0.0)
            cfg = SimConfig(ep_degree=8, seed=3, max_prefill_tokens=16_384)
            if arm == "adaptive":
                ctl = ViBEController(
                    m._n_moe_layers(), m.n_experts, 8, perf,
                    ViBEConfig(policy="vibe", adaptive=True,
                               drift=DriftConfig(window=50, interval=10,
                                                 cooldown=20),
                               perf_drift=PerfDriftConfig(
                                   delta_perf=0.08, window=128, interval=5,
                                   cooldown=10, min_samples=16),
                               # minimal-movement refinement: a full
                               # re-solve relocates nearly every slot
                               # (~0.4 s stall at saturation); the paper's
                               # Alg 2 swap path recovers the same capacity
                               # for a few dozen moves
                               full_resolve_on_stress=False,
                               expert_bytes=EXPERT_BYTES(m)),
                    initial_w=W0)
                sim = EPSimulator(m, cluster, WORKLOADS[workload], cfg,
                                  controller=ctl)
            else:
                pl = _placement("vibe", W0, cluster, perf)
                sim = EPSimulator(m, cluster, WORKLOADS[workload], cfg,
                                  placement=pl)
            recs = sim.run(reqs, phase="prefill")
            gps[arm] = goodput(recs, slo)
            if arm == "adaptive" and sim.controller is not None:
                stats = dict(
                    recalibrations=len(sim.controller.updates),
                    perf_recalibrations=sum(
                        1 for u in sim.controller.updates
                        if u.kind == "perf"),
                    stall_total_ms=1e3 * sum(
                        s for s, _, _ in sim.migration_stalls))
        gap = gps["oracle"] - gps["stale"]
        recovered = (gps["adaptive"] - gps["stale"]) / gap if gap > 1e-9 \
            else float("nan")
        rows.append({
            "bench": "fig11_hw",
            "label": f"hw/{scenario}",
            "goodput_stale": gps["stale"],
            "goodput_adaptive": gps["adaptive"],
            "goodput_oracle": gps["oracle"],
            "recovered": recovered,
            **stats,
        })
    return rows


# ---------------------------------------------------------------------------
# dispatch-time work stealing: bursty arrivals on a stale profile
# ---------------------------------------------------------------------------

def run_steal(model="deepseek-v3-671b", quick=True, qps=10.0,
              headroom=0.0, slot_budget=64):
    """Token rescheduling between recalibrations (ISSUE 7 acceptance run).

    The regime placement alone cannot fix: every arm's plan is solved from
    a STALE profile (sonnet) while the served traffic is bursty multi-tenant
    chat (sharegpt-dominated), and no arm recalibrates. Three arms share
    one hardware snapshot and one request trace:

    * ``vibe_r/static`` — pure-placement ViBE-R, shares frozen at the plan;
    * ``vibe_r/steal``  — same plan + TokenRescheduler reweighting copy
      shares from realized tallies each step;
    * ``harmoeny/static`` — load-only replication baseline.

    Stealing must come out strictly ahead of both: it reacts to the
    realized (shifted, bursty) load while the static arms keep splitting
    traffic for a profile that no longer describes it.
    """
    m = get(model)
    slo = PAPER_SLOS[("sharegpt", model)]
    n_req = 200 if quick else 500
    W0 = profile_W(model, "sonnet")            # deliberately stale
    cluster = paper_cluster(model, "mi325x")
    perf = cluster.fit_models()
    reqs = sample_trace(TRACES["bursty"], n_req, qps=qps, seed=4)
    arms = (("vibe_r/static", "vibe_r", None),
            ("vibe_r/steal", "vibe_r",
             StealConfig(headroom=headroom, smoothing=1.0, max_shift=0.5)),
            ("harmoeny/static", "harmoeny", None))
    rows = []
    for label, policy, steal in arms:
        # every arm gets the same slot budget: without replicas there is
        # nothing to steal, and a budget asymmetry would confound the A/B
        ctl = ViBEController(
            m._n_moe_layers(), m.n_experts, 8, perf,
            ViBEConfig(policy=policy, adaptive=False, steal=steal,
                       slot_budget=slot_budget),
            initial_w=W0)
        sim = EPSimulator(m, cluster, WORKLOADS["sharegpt"],
                          SimConfig(ep_degree=8, seed=3,
                                    max_prefill_tokens=16_384),
                          controller=ctl)
        recs = sim.run(reqs, phase="prefill")
        row = {"bench": "fig11_steal", "label": f"steal/{label}",
               "goodput": goodput(recs, slo)}
        if steal is not None:
            rs = ctl.rescheduler
            row.update(steals=rs.steals, steal_updates=sim.steal_updates,
                       share_moved=rs.share_moved)
        assert not ctl.updates                 # every arm truly static
        rows.append(row)
    emit(rows, "fig11_steal")
    return rows


if __name__ == "__main__":
    run(quick=False)
    run_steal(quick=False)
