"""Figs 11+12: cross-workload drift — static vs adaptive recalibration.

Placement is profiled on one dataset and served on another (SG→SN, SN→SG);
adaptive ViBE/EPLB recover most of the lost goodput at the cost of brief
migration stalls (Fig 12's TTFT spikes), with per-event moved-expert counts
and transfer bytes accounted.
"""

import numpy as np

from repro.configs import get
from repro.core import (DriftConfig, ViBEConfig, ViBEController)
from repro.serving import (EPSimulator, PAPER_SLOS, SimConfig, WORKLOADS,
                           goodput, routing_profile, sample_requests)
from .common import emit, paper_cluster, placement_for, profile_W


def _sim(model, profile_wl, serve_wl, policy, adaptive, cluster, seed=3):
    m = get(model)
    perf = cluster.fit_models()
    W0 = profile_W(model, profile_wl)
    cfg = SimConfig(ep_degree=8, seed=seed, max_prefill_tokens=16_384)
    if adaptive:
        ctl = ViBEController(
            m._n_moe_layers(), m.n_experts, 8, perf,
            ViBEConfig(policy=policy, adaptive=True,
                       drift=DriftConfig(window=50, interval=10,
                                         cooldown=20),
                       expert_bytes=3 * m.d_model * m.moe_d_ff * 2),
            initial_w=W0)
        return EPSimulator(m, cluster, WORKLOADS[serve_wl], cfg,
                           controller=ctl)
    pl = placement_for(policy, model, profile_wl, cluster)
    return EPSimulator(m, cluster, WORKLOADS[serve_wl], cfg, placement=pl)


def run(model="deepseek-v3-671b", quick=True):
    cluster = paper_cluster(model, "mi325x")
    m = get(model)
    rows = []
    n_req = 200 if quick else 500
    cases = [("sonnet", "sonnet", 20.0), ("sharegpt", "sonnet", 20.0),
             ("sonnet", "sharegpt", 120.0),
             ("sharegpt", "sharegpt", 120.0)]
    for prof_wl, serve_wl, qps in cases:
        slo = PAPER_SLOS[(serve_wl, model)]
        for policy in ("eplb", "vibe"):
            for adaptive in ((False, True) if prof_wl != serve_wl
                             else (False,)):
                sim = _sim(model, prof_wl, serve_wl, policy, adaptive,
                           cluster)
                # serving profile differs from the profiled one → the sim's
                # own routing profile is the *serving* workload's
                reqs = sample_requests(WORKLOADS[serve_wl], n_req, qps=qps,
                                       seed=4)
                recs = sim.run(reqs, phase="prefill")
                row = {
                    "bench": "fig11",
                    "label": f"{prof_wl[:2]}->{serve_wl[:2]}/{policy}"
                             + ("/adaptive" if adaptive else "/static"),
                    "goodput": goodput(recs, slo),
                }
                if adaptive and sim.controller is not None:
                    moved = sum(u.moved_experts
                                for u in sim.controller.updates)
                    row.update(
                        recalibrations=len(sim.controller.updates),
                        moved_experts=moved,
                        migration_bytes=sum(
                            u.migration_bytes
                            for u in sim.controller.updates),
                        stall_total_ms=1e3 * sum(
                            s for s, _, _ in sim.migration_stalls),
                    )
                rows.append(row)
    emit(rows, "fig11_drift")
    return rows


if __name__ == "__main__":
    run(quick=False)
