"""Figs 13+14: sensitivity to the variability distribution.

Three regimes: MI300X (mild), MI325X (paper main), and the skewed system
(GPU 0 degraded 13% via a modified V-F curve). Reports the kernel-time
spread at 4K tokens/expert-group (Fig 13) and the policy frontier (Fig 14).
"""

import numpy as np

from repro.serving import PAPER_SLOS, WORKLOADS, goodput, sample_requests, \
    slo_frontier
from repro.serving.simulator import rank_latency_matrix
from repro.core import registered_policies

from .common import emit, make_sim, paper_cluster, qps_grid


def run(model="deepseek-v3-671b", workload="sonnet", quick=True):
    rows = []
    slo = PAPER_SLOS[(workload, model)]
    for regime in ("mi300x", "mi325x", "skewed"):
        cluster = paper_cluster(model, regime)
        eq = np.full((1, 8), 16_384.0)
        lat = rank_latency_matrix(cluster, eq)[0]
        rows.append({
            "bench": "fig13", "label": regime,
            "kernel_spread_pct": 100 * float(lat.max() / lat.min() - 1),
        })
        grid = qps_grid(model, workload, cluster)
        frontiers = {}
        for policy in registered_policies():
            g2q = {}
            for qps in grid:
                sim = make_sim(model, workload, policy, regime=regime,
                               seed=1, cluster=cluster)
                recs = sim.run(
                    sample_requests(WORKLOADS[workload],
                                    150 if quick else 400, qps=qps, seed=2),
                    phase="prefill")
                g2q[qps] = goodput(recs, slo)
            frontiers[policy] = slo_frontier(g2q)
            rows.append({"bench": "fig13",
                         "label": f"{regime}/{policy}",
                         "frontier_qps": frontiers[policy]})
        rows.append({
            "bench": "fig13", "label": regime,
            "vibe_vs_eplb_pct": 100 * (frontiers["vibe"]
                                       / max(frontiers["eplb"], 1e-9) - 1),
        })
    emit(rows, "fig13_sensitivity")
    return rows


if __name__ == "__main__":
    run(quick=False)
