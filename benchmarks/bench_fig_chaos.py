"""Chaos drill gate (fig_chaos): degraded goodput under randomized faults.

A controller-driven :class:`~repro.serving.EPSimulator` on a 2-node
topology serves a bursty multi-tenant trace twice with one shared
hardware snapshot: a *healthy* arm, and a *chaos* arm running the same
trace under the seed-deterministic default
:class:`~repro.serving.FaultSchedule` (rank fail → transient stall → DCN
brownout → rank recover, all priced on the virtual clock: the mask/unmask
re-solves charge migration stalls, the stall composes with the
variability model, the brownout shrinks ``dcn_bw``).

The harness asserts the drill's hard invariants itself — every request
finishes, every scheduled fault applies (none skipped), recovery restores
the full rank set — and emits both arms' goodput. The ``--check`` gate
compares the committed baseline per arm (quality direction): the chaos
arm's goodput dropping means the fault path got more expensive or elastic
recovery stopped restoring capacity; the healthy arm pins the no-fault
cost of carrying the injection machinery (zero, by construction).
"""

import numpy as np

from repro.configs import get
from repro.core import (ClusterTopology, ViBEConfig, ViBEController,
                        make_cluster)
from repro.serving import (EPSimulator, FaultSchedule, PAPER_SLOS, SLO,
                           SimConfig, TRACES, WORKLOADS, goodput,
                           sample_trace)
from .common import emit, profile_W

EP = 8
CHAOS_SEED = 7


def _arm(model, topo, inject, n_req, qps):
    """One drill arm: fresh cluster (fixed seed = the shared hardware
    snapshot) + static controller + simulator; ``inject`` arms the
    default chaos schedule. ``adaptive=False`` keeps routing-drift
    recalibration out of the arm, so the A/B difference is *exactly* the
    injected faults (mask/unmask re-solves, the stall, the brownout)."""
    m = get(model)
    cluster = make_cluster(EP, "mi325x", d_model=m.d_model,
                           d_ff=m.moe_d_ff,
                           experts_per_rank=max(m.n_experts // EP, 1),
                           seed=0)
    perf = cluster.fit_models()
    W0 = profile_W(model, "sharegpt", EP)
    ctl = ViBEController(
        m._n_moe_layers(), m.n_experts, EP, perf,
        ViBEConfig(policy="vibe_h", adaptive=False,
                   expert_bytes=3 * m.d_model * m.moe_d_ff * 2,
                   topology=topo),
        initial_w=W0)
    sim = EPSimulator(m, cluster, WORKLOADS["sharegpt"],
                      SimConfig(ep_degree=EP, seed=1,
                                max_prefill_tokens=16_384, topology=topo),
                      controller=ctl)
    if inject:
        sim.inject_faults(FaultSchedule.default(EP, seed=CHAOS_SEED))
    reqs = sample_trace(TRACES["bursty"], n_req, qps=qps, seed=5)
    recs = sim.run(reqs, phase="prefill")
    return sim, recs


#: committed degraded-mode SLO for the chaos arm: the fail/recover
#: full-resolve migrations are priced at several virtual seconds on this
#: operating point, so the paper SLO is unmeetable mid-drill by design —
#: the robustness promise is that recovery restores service fast enough
#: that (nearly) every request still lands within this TTFT. Gated with
#: ~1/48 granularity headroom, unlike the paper-SLO goodput (a handful of
#: pre-fault requests), which is emitted for information only.
DEGRADED_SLO = SLO(ttft=6.0, tpot=1.0)


def run(model="qwen3-moe-235b-a22b", quick=True):
    topo = ClusterTopology.uniform(2, EP // 2, 50e9)
    n_req = 48 if quick else 200
    slo = PAPER_SLOS[("sharegpt", model)]
    rows = []
    for label, inject in (("healthy", False), ("chaos", True)):
        sim, recs = _arm(model, topo, inject, n_req, qps=15.0)
        finished = sum(1 for r in recs if np.isfinite(r.finished_at))
        assert finished == len(recs), \
            f"{label}: {len(recs) - finished} requests never finished"
        row = {"bench": "fig_chaos", "label": label,
               "n_requests": len(recs)}
        if inject:
            skipped = [(s.kind, why) for s, why in sim.fault_log
                       if why != "applied"]
            assert not skipped, f"chaos faults skipped: {skipped}"
            assert sim.controller.dead_ranks == (), \
                "recovery did not restore the full rank set"
            row.update(
                goodput_degraded=goodput(recs, DEGRADED_SLO),
                goodput_paper_slo=goodput(recs, slo),
                faults_applied=sum(1 for _, w in sim.fault_log
                                   if w == "applied"),
                recalibrations=len(sim.controller.updates),
                stall_total_ms=1e3 * sum(s for s, _, _
                                         in sim.migration_stalls))
        else:
            row["goodput"] = goodput(recs, slo)
        rows.append(row)
    emit(rows, "fig_chaos")
    return rows


if __name__ == "__main__":
    run(quick=False)
