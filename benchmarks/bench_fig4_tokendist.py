"""Fig 4: per-GPU token share under vLLM contiguous placement.

Paper: layer 11 prefill — busiest GPU >24% of tokens, lightest <10%.
"""

import numpy as np

from .common import emit, paper_cluster, placement_for, profile_W


def run(model="deepseek-v3-671b", workload="sonnet", quick=True):
    cluster = paper_cluster(model, "mi325x")
    W = profile_W(model, workload)
    pl = placement_for("contiguous", model, workload, cluster)
    shares = pl.rank_loads(W)
    shares = shares / shares.sum(1, keepdims=True)
    worst = int(np.argmax(shares.max(1)))
    rows = [{
        "bench": "fig4", "label": "contiguous",
        "max_share_mean": float(shares.max(1).mean()),
        "min_share_mean": float(shares.min(1).mean()),
        "worst_layer": worst,
        "worst_layer_max_share": float(shares[worst].max()),
        "worst_layer_min_share": float(shares[worst].min()),
    }]
    emit(rows, "fig4_tokendist")
    return rows


if __name__ == "__main__":
    run()
