"""Fig 3: kernel-time breakdown (MoE FFN / all-to-all / attention+other)
for prefill and decode under perfect token balance.

Paper: MoE FFN 49% of prefill, 20% of decode; a2a 24.5% / 22.1%.

Additionally sweeps the dominant MoE-FFN term across Zipf routing skew
under both grouped-FFN implementations (ISSUE 4): ``capacity`` prices the
fixed-bucket kernel (every rank pays slots × capacity rows; overflow
drops), ``ragged`` prices the dropless kernel (realized tokens only) —
emitting the wasted-FLOP fraction and the capacity drop count the ragged
path removes.
"""

import numpy as np

from repro.configs import get
from .common import emit, make_sim

#: skew sweep for the ragged-vs-capacity MoE pricing comparison
SKEW_ALPHAS = (0.0, 0.6, 1.2)


def run(model="deepseek-v3-671b", quick=True):
    m = get(model)
    sim = make_sim(model, "sonnet", "eplb")
    from repro.serving.simulator import rank_latency_matrix
    rows = []
    for phase, tokens, ctx in (("prefill", 16_384, 512), ("decode", 64,
                                                          1024)):
        loads = np.full((sim.L, sim.E),
                        tokens * m.top_k / sim.E)     # perfect balance
        rank_load = sim.placement.rank_loads(loads)
        moe = float(rank_latency_matrix(sim.cluster,
                                        rank_load).max(1).sum())
        a2a = sim.L * sim._a2a_time(tokens)
        attn = m.n_layers * sim._attn_time(tokens, ctx)
        total = moe + a2a + attn
        rows.append({
            "bench": "fig3", "label": phase,
            "moe_ffn_frac": moe / total,
            "a2a_frac": a2a / total,
            "attn_other_frac": attn / total,
            "step_ms": total * 1e3,
        })

    # ragged vs capacity MoE-FFN pricing across routing skew (prefill point)
    tokens = 16_384
    rng = np.random.default_rng(0)
    for alpha in SKEW_ALPHAS:
        z = 1.0 / np.arange(1, sim.E + 1) ** max(alpha, 1e-9)
        prof = np.stack([rng.permutation(z / z.sum())
                         for _ in range(sim.L)])
        loads = prof * tokens * m.top_k
        rank_r = sim.placement.rank_loads(loads)
        moe_r = float(rank_latency_matrix(sim.cluster, rank_r).max(1).sum())
        before = sim.dropped_assignments
        rank_c = sim._capacity_rank_loads(sim.placement, loads, tokens)
        dropped = sim.dropped_assignments - before
        moe_c = float(rank_latency_matrix(sim.cluster, rank_c).max(1).sum())
        realized = float(loads.sum())
        bucket_rows = float(rank_c.sum())
        rows.append({
            "bench": "fig3", "label": f"prefill_moe_a{alpha:g}",
            "zipf_alpha": alpha,
            "moe_ms_capacity": moe_c * 1e3,
            "moe_ms_ragged": moe_r * 1e3,
            "ragged_moe_speedup": moe_c / moe_r,
            # capacity-only: the simulator prices ragged at exactly the
            # realized tokens (no tile model at this level — the true
            # tile-padding fraction lives in bench_kernels' ragged rows)
            "wasted_flop_frac_capacity":
                max(1.0 - (realized - dropped) / bucket_rows, 0.0),
            "dropped_capacity": dropped,
            "dropped_ragged": 0,
        })
    emit(rows, "fig3_breakdown")
    return rows


if __name__ == "__main__":
    run()
