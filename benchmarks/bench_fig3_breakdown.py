"""Fig 3: kernel-time breakdown (MoE FFN / all-to-all / attention+other)
for prefill and decode under perfect token balance.

Paper: MoE FFN 49% of prefill, 20% of decode; a2a 24.5% / 22.1%.
"""

import numpy as np

from repro.configs import get
from .common import emit, make_sim


def run(model="deepseek-v3-671b", quick=True):
    m = get(model)
    sim = make_sim(model, "sonnet", "eplb")
    rows = []
    for phase, tokens, ctx in (("prefill", 16_384, 512), ("decode", 64,
                                                          1024)):
        loads = np.full((sim.L, sim.E),
                        tokens * m.top_k / sim.E)     # perfect balance
        rank_load = sim.placement.rank_loads(loads)
        from repro.serving.simulator import rank_latency_matrix
        moe = float(rank_latency_matrix(sim.cluster,
                                        rank_load).max(1).sum())
        a2a = sim.L * sim._a2a_time(tokens)
        attn = m.n_layers * sim._attn_time(tokens, ctx)
        total = moe + a2a + attn
        rows.append({
            "bench": "fig3", "label": phase,
            "moe_ffn_frac": moe / total,
            "a2a_frac": a2a / total,
            "attn_other_frac": attn / total,
            "step_ms": total * 1e3,
        })
    emit(rows, "fig3_breakdown")
    return rows


if __name__ == "__main__":
    run()
