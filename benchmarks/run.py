"""Benchmark aggregator: one harness per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig8,fig10]

Prints ``bench,label,metric,value`` CSV lines; JSON per harness lands in
results/bench/.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import (bench_fig1_imbalance, bench_fig3_breakdown,
               bench_fig4_tokendist, bench_fig6_assignment, bench_fig8_slo,
               bench_fig10_gap, bench_fig11_drift, bench_fig13_sensitivity,
               bench_fig15_scaling, bench_kernels, bench_placement_solve)

HARNESSES = {
    "fig1": bench_fig1_imbalance.run,
    "fig3": bench_fig3_breakdown.run,
    "fig4": bench_fig4_tokendist.run,
    "fig6": bench_fig6_assignment.run,
    "fig8": bench_fig8_slo.run,
    "fig10": bench_fig10_gap.run,
    "fig11": bench_fig11_drift.run,
    "fig13": bench_fig13_sensitivity.run,
    "fig15": bench_fig15_scaling.run,
    "placement": bench_placement_solve.run,
    "kernels": bench_kernels.run,
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweeps (slower)")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    failures = 0
    for name, fn in HARNESSES.items():
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            fn(quick=not args.full)
        except Exception:
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
