"""Benchmark aggregator: one harness per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig8,fig10]
    PYTHONPATH=src python -m benchmarks.run --check

``--check`` is the CI regression gate: it reruns the quick ``kernels``,
``placement``, ``fig8`` and ``fig11_steal`` harnesses and compares their
gated metrics
against the checked-in JSON baselines under ``results/bench/`` (restored
afterwards — the gate never mutates its own reference). Each spec
declares a direction: ``time`` metrics fail on a >25% slowdown
(``BENCH_CHECK_TOL`` overrides the ratio); ``quality`` metrics (the fig8
goodput frontier) fail when the fresh value drops below baseline/tol
(``BENCH_QUALITY_TOL``, default 1.10 — the simulator sweep is seeded and
deterministic, so the quality gate can be tight). Baselines are
machine-dependent for time metrics — refresh them deliberately
(``--only kernels,placement,fig8`` + commit the JSON) when changing
hardware, not to paper over a regression.

Otherwise prints ``bench,label,metric,value`` CSV lines; JSON per harness
lands in results/bench/.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

from . import (bench_fig1_imbalance, bench_fig3_breakdown,
               bench_fig4_tokendist, bench_fig6_assignment, bench_fig8_slo,
               bench_fig10_gap, bench_fig11_drift, bench_fig13_sensitivity,
               bench_fig15_scaling, bench_fig_chaos, bench_kernels,
               bench_placement_solve)

HARNESSES = {
    "fig1": bench_fig1_imbalance.run,
    "fig3": bench_fig3_breakdown.run,
    "fig4": bench_fig4_tokendist.run,
    "fig6": bench_fig6_assignment.run,
    "fig8": bench_fig8_slo.run,
    "fig10": bench_fig10_gap.run,
    "fig11": bench_fig11_drift.run,
    "fig11_steal": bench_fig11_drift.run_steal,
    "fig13": bench_fig13_sensitivity.run,
    "fig15": bench_fig15_scaling.run,
    "fig15_hier": bench_fig15_scaling.run_hier,
    "fig_chaos": bench_fig_chaos.run,
    "placement": bench_placement_solve.run,
    "kernels": bench_kernels.run,
}


#: --check gate: harness → (baseline JSON stem, keys compared, direction).
#: "time" metrics regress upward (slowdown); "quality" metrics regress
#: downward (the fig8 goodput frontier shrinking means the serving stack
#: sustains less load at the paper SLO). Counts/errors are covered by
#: asserts inside the harnesses themselves.
CHECK_SPECS = {
    "kernels": ("kernels", ("ref_us_per_call", "capacity_us_per_call",
                            "ragged_us_per_call"), "time"),
    "placement": ("placement_solve", ("solve_ms_vibe", "solve_ms_vibe_r"),
                  "time"),
    "fig8": ("fig8_slo", ("frontier_qps",), "quality"),
    "fig11_steal": ("fig11_steal", ("goodput",), "quality"),
    # vibe_h must keep beating flat vibe_r on cross-node (DCN) bytes on a
    # 2-level topology without regressing simulated P90 TTFT (ratios > 1)
    "fig15_hier": ("fig15_hier", ("dcn_reduction_x", "ttft_ratio"),
                   "quality"),
    # chaos drill: degraded-mode goodput under the seeded
    # fail/stall/dcn/recover schedule must stay above the committed
    # baseline (recovery keeps restoring service); the healthy arm's
    # paper-SLO goodput pins the no-fault cost of the injection machinery
    "fig_chaos": ("fig_chaos", ("goodput", "goodput_degraded"), "quality"),
}
#: fail --check when fresh wall-clock exceeds baseline by more than this;
#: override with BENCH_CHECK_TOL (e.g. a noisy shared CI runner may need
#: more headroom than the 1.25 default) — never to absorb a regression.
REGRESSION_TOL = float(os.environ.get("BENCH_CHECK_TOL", "1.25"))
#: fail --check when a quality metric falls below baseline divided by
#: this; the discrete-event sweep behind it is seeded, so 1.10 is slack
#: for float drift across BLAS builds, not for scheduler noise.
QUALITY_TOL = float(os.environ.get("BENCH_QUALITY_TOL", "1.10"))


def _run_restoring_baseline(name: str, path: str, baseline_raw: str):
    """Run a harness, then put the baseline JSON back: the harness's
    emit() overwrites it with the fresh (possibly regressed) numbers, and
    the gate must never destroy its own reference — refreshing a baseline
    is an explicit ``run --only <name>`` + commit, not a side effect."""
    try:
        return HARNESSES[name](quick=True)
    finally:
        with open(path, "w") as f:
            f.write(baseline_raw)


def _compare(name, fresh, base, keys, direction, verbose=True):
    """badness > tol fails: fresh/base for "time" (slower is worse),
    base/fresh for "quality" (smaller is worse)."""
    tol = REGRESSION_TOL if direction == "time" else QUALITY_TOL
    failures = []
    for r in fresh:
        b = base.get(r.get("label"))
        if b is None:
            continue                      # new row: no baseline yet — fine
        for k in keys:
            if k not in r or k not in b or not b[k]:
                continue
            if direction == "time":
                badness = float(r[k]) / float(b[k])
            else:
                badness = float(b[k]) / max(float(r[k]), 1e-12)
            tag = "REGRESSION" if badness > tol else "ok"
            if verbose:
                print(f"# check {name}/{r['label']}/{k}: "
                      f"{float(b[k]):.4g} → {float(r[k]):.4g} "
                      f"({badness:.2f}x {direction} badness) {tag}",
                      flush=True)
            if badness > tol:
                failures.append((name, r["label"], k, badness))
    return failures


def check_lint() -> list:
    """``lint_clean`` gate: the in-repo analyzer must exit clean against
    the committed baseline, and neither the inline-suppression count nor
    the baseline's grandfathered findings may grow past what is committed
    — a "fix" that silently adds a suppression or fattens the baseline is
    a regression with extra steps."""
    from repro.analysis import Baseline, analyze

    bl_path = ".viblint-baseline.json"
    baseline = Baseline.load(bl_path) if os.path.exists(bl_path) \
        else Baseline()
    rep = analyze(["src"], baseline=baseline)
    failures = []
    print(f"# --- check lint_clean (vs {bl_path}) ---", flush=True)
    for f in rep.active:
        print(f"# {f.render()}", flush=True)
    if rep.active:
        failures.append(("lint_clean", "findings",
                         f"{len(rep.active)} unsuppressed", 0.0))
    if rep.suppression_count > baseline.suppression_budget:
        failures.append((
            "lint_clean", "suppressions",
            f"{rep.suppression_count} inline > budget "
            f"{baseline.suppression_budget}", 0.0))
    if rep.stale_baseline:
        failures.append(("lint_clean", "baseline",
                         f"{len(rep.stale_baseline)} stale entr(ies) — "
                         "prune fixed findings", 0.0))
    print(f"# lint_clean: {len(rep.active)} finding(s), "
          f"{rep.suppression_count}/{baseline.suppression_budget} "
          f"suppressions, {len(rep.baselined)} baselined"
          f"{' FAILED' if failures else ' ok'}", flush=True)
    return failures


def check_regressions() -> int:
    failures = check_lint()
    for name, (stem, keys, direction) in CHECK_SPECS.items():
        path = os.path.join("results", "bench", f"{stem}.json")
        if not os.path.exists(path):
            print(f"# --check: missing baseline {path} — run "
                  f"`python -m benchmarks.run --only {name}` and commit it",
                  file=sys.stderr)
            failures.append((name, "<baseline missing>", "", 0.0))
            continue
        with open(path) as f:
            baseline_raw = f.read()
        base = {r["label"]: r for r in json.loads(baseline_raw)}
        print(f"# --- check {name} (vs {path}) ---", flush=True)
        fresh = _run_restoring_baseline(name, path, baseline_raw)
        harness_failures = _compare(name, fresh, base, keys, direction)
        if harness_failures:
            # flake guard: scheduler noise on a loaded host shows up as a
            # one-off bad sample. Re-run the harness once and keep the
            # per-metric best (fastest for time, highest for quality) — a
            # genuine code regression stays bad on both runs; transient
            # noise does not.
            print(f"# {name}: {len(harness_failures)} metric(s) over "
                  "tolerance — re-running once to rule out scheduler "
                  "noise", flush=True)
            retry = {r["label"]: r
                     for r in _run_restoring_baseline(name, path,
                                                      baseline_raw)}
            best = min if direction == "time" else max
            for r in fresh:
                r2 = retry.get(r.get("label"))
                if r2 is None:
                    continue
                for k in keys:
                    if k in r and k in r2:
                        r[k] = best(float(r[k]), float(r2[k]))
            harness_failures = _compare(name, fresh, base, keys, direction)
        failures.extend(harness_failures)
    if failures:
        print("# --check FAILED:", file=sys.stderr)
        for name, label, k, ratio in failures:
            detail = f"{k}: {ratio:.2f}x over baseline" if ratio else k
            print(f"#   {name}/{label}/{detail}", file=sys.stderr)
        return 1
    print("# --check passed: no wall-clock regression "
          f"> {REGRESSION_TOL:.2f}x, no quality regression "
          f"> {QUALITY_TOL:.2f}x", flush=True)
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweeps (slower)")
    ap.add_argument("--only", default="")
    ap.add_argument("--check", action="store_true",
                    help="rerun quick kernels+placement+fig8+fig11_steal "
                         "benches and "
                         f"fail on >{REGRESSION_TOL}x wall-clock or "
                         f">{QUALITY_TOL}x goodput-frontier loss vs the "
                         "checked-in results/bench baselines")
    args = ap.parse_args()
    if args.check:
        return check_regressions()
    only = set(args.only.split(",")) if args.only else None
    failures = 0
    for name, fn in HARNESSES.items():
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            fn(quick=not args.full)
        except Exception:
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
