"""Fig 6: uniform vs variability-informed token assignment, one MoE layer.

The variability-informed split (speed-proportional, training/elastic.py's
``elastic_targets``) aligns per-GPU completion times even though token
counts differ — the paper's core insight in its simplest form. Also applies
to non-MoE archs' DP batch split (DESIGN.md §5 arch-applicability).
"""

import numpy as np

from repro.serving.simulator import rank_latency_matrix
from repro.training import elastic_targets
from .common import emit, paper_cluster


def run(model="deepseek-v3-671b", tokens=16_384, quick=True):
    cluster = paper_cluster(model, "mi325x")
    perf = cluster.fit_models()
    G = cluster.n_devices
    uniform = np.full((1, G), tokens / G)
    informed = elastic_targets(perf, tokens, n_ref=tokens / G)[None, :]
    rows = []
    for label, loads in (("uniform", uniform),
                         ("variability-informed", informed.astype(float))):
        lat = rank_latency_matrix(cluster, loads)[0]
        rows.append({
            "bench": "fig6", "label": label,
            "load_spread": float(loads[0].max() / loads[0].min()),
            "latency_spread": float(lat.max() / lat.min()),
            "completion_ms": float(lat.max() * 1e3),
            "idle_frac": float((lat.max() - lat).mean() / lat.max()),
        })
    emit(rows, "fig6_assignment")
    return rows


if __name__ == "__main__":
    run()
