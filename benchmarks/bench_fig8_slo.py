"""Figs 8+9: SLO attainment + TTFT/TPOT percentiles vs request rate.

Sweeps QPS for each (model × dataset × arrival process × policy),
reporting goodput, the 90%-goodput frontier, and latency percentiles
(the paper's two headline figures share one sweep). Arrival processes
cover homogeneous Poisson traffic and the multi-tenant bursty trace
(doubly-stochastic arrivals, chat + long-context mix) — the frontier
rows are the CI goodput-regression gate's input (run.py --check).
"""

from repro.serving import PAPER_SLOS, TRACES, goodput, sample_requests, \
    sample_trace, slo_frontier, summarize, WORKLOADS
from repro.core import StealConfig, registered_policies

from .common import MODELS, emit, make_sim, qps_grid

#: arrival processes swept per combo: "poisson" draws i.i.d. exponential
#: gaps from one workload family; anything else is a TRACES key replayed
#: via sample_trace (multi-tenant, time-varying rate).
ARRIVALS = ("poisson", "bursty")

#: trace arrivals mix long-context tenants and concentrate load in bursts,
#: so the sustainable mean rate is far below the homogeneous-Poisson
#: capacity the qps_grid brackets; shrink the grid so the 90%-goodput
#: frontier lands inside it instead of reading 0 at every point.
TRACE_GRID_SCALE = 0.2


def _requests(arrival, workload, n_req, qps):
    if arrival == "poisson":
        return sample_requests(WORKLOADS[workload], n_req, qps=qps, seed=2)
    return sample_trace(TRACES[arrival], n_req, qps=qps, seed=2)


def run(quick=True, phase="prefill"):
    rows = []
    combos = ([("deepseek-v3-671b", "sonnet")] if quick else
              [(m, w) for m in MODELS for w in ("sonnet", "sharegpt")])
    n_req = 250 if quick else 600
    for model, workload in combos:
        slo = PAPER_SLOS[(workload, model)]
        grid = qps_grid(model, workload)
        for arrival in ARRIVALS:
            agrid = (grid if arrival == "poisson" else
                     tuple(round(q * TRACE_GRID_SCALE, 1) for q in grid))
            frontiers = {}
            # trace arrivals get a dispatch-time work-stealing arm on top of
            # the pure-placement sweep: bursts between recalibrations are
            # exactly the regime the rescheduler targets
            policies = registered_policies() + (
                ("vibe_r+steal",) if arrival != "poisson" else ())
            for policy in policies:
                base_policy, _, variant = policy.partition("+")
                steal = StealConfig() if variant == "steal" else None
                g2q = {}
                for qps in agrid:
                    sim = make_sim(model, workload, base_policy, seed=1,
                                   steal=steal)
                    recs = sim.run(_requests(arrival, workload, n_req, qps),
                                   phase=phase)
                    g2q[qps] = goodput(recs, slo)
                    s = summarize(recs)
                    rows.append({
                        "bench": "fig8",
                        "label": f"{model[:8]}/{workload[:6]}/{arrival}"
                                 f"/{policy}",
                        "qps": qps, "goodput": g2q[qps],
                        "ttft_p50_ms": s["ttft_p50"] * 1e3,
                        "ttft_p90_ms": s["ttft_p90"] * 1e3,
                        "ttft_p99_ms": s["ttft_p99"] * 1e3,
                    })
                frontiers[policy] = slo_frontier(g2q)
                rows.append({
                    "bench": "fig8",
                    "label": f"{model[:8]}/{workload[:6]}/{arrival}"
                             f"/{policy}",
                    "frontier_qps": frontiers[policy],
                })
            if frontiers["eplb"] > 0:
                rows.append({
                    "bench": "fig8",
                    "label": f"{model[:8]}/{workload[:6]}/{arrival}",
                    "vibe_vs_eplb_frontier_pct":
                        100 * (frontiers["vibe"] / frontiers["eplb"] - 1),
                    "vibe_vs_vllm_frontier_pct":
                        100 * (frontiers["vibe"]
                               / max(frontiers["contiguous"], 1e-9) - 1),
                })
    emit(rows, "fig8_slo")
    return rows


if __name__ == "__main__":
    run(quick=False)
