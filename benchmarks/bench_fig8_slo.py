"""Figs 8+9: SLO attainment + TTFT/TPOT percentiles vs request rate.

Sweeps QPS for each (model × dataset × policy), reporting goodput, the
90%-goodput frontier, and latency percentiles (the paper's two headline
figures share one sweep).
"""

from repro.serving import PAPER_SLOS, goodput, sample_requests, \
    slo_frontier, summarize, WORKLOADS
from repro.core import registered_policies

from .common import MODELS, emit, make_sim, qps_grid


def run(quick=True, phase="prefill"):
    rows = []
    combos = ([("deepseek-v3-671b", "sonnet")] if quick else
              [(m, w) for m in MODELS for w in ("sonnet", "sharegpt")])
    n_req = 250 if quick else 600
    for model, workload in combos:
        slo = PAPER_SLOS[(workload, model)]
        grid = qps_grid(model, workload)
        frontiers = {}
        for policy in registered_policies():
            g2q = {}
            for qps in grid:
                sim = make_sim(model, workload, policy, seed=1)
                recs = sim.run(sample_requests(WORKLOADS[workload], n_req,
                                               qps=qps, seed=2),
                               phase=phase)
                g2q[qps] = goodput(recs, slo)
                s = summarize(recs)
                rows.append({
                    "bench": "fig8",
                    "label": f"{model[:8]}/{workload[:6]}/{policy}",
                    "qps": qps, "goodput": g2q[qps],
                    "ttft_p50_ms": s["ttft_p50"] * 1e3,
                    "ttft_p90_ms": s["ttft_p90"] * 1e3,
                    "ttft_p99_ms": s["ttft_p99"] * 1e3,
                })
            frontiers[policy] = slo_frontier(g2q)
            rows.append({
                "bench": "fig8",
                "label": f"{model[:8]}/{workload[:6]}/{policy}",
                "frontier_qps": frontiers[policy],
            })
        if frontiers["eplb"] > 0:
            rows.append({
                "bench": "fig8",
                "label": f"{model[:8]}/{workload[:6]}",
                "vibe_vs_eplb_frontier_pct":
                    100 * (frontiers["vibe"] / frontiers["eplb"] - 1),
                "vibe_vs_vllm_frontier_pct":
                    100 * (frontiers["vibe"]
                           / max(frontiers["contiguous"], 1e-9) - 1),
            })
    emit(rows, "fig8_slo")
    return rows


if __name__ == "__main__":
    run(quick=False)
