"""Fig 1: token vs latency imbalance across MoE layers, per policy.

Each MoE layer contributes one point (token max/min ratio across ranks,
latency max/min ratio). EPLB collapses token imbalance but leaves latency
imbalance; ViBE targets the latency-balanced regime.
"""

import numpy as np

from repro.serving.simulator import rank_latency_matrix
from repro.core import registered_policies

from .common import emit, paper_cluster, placement_for, profile_W


def run(model="deepseek-v3-671b", workload="sonnet", quick=True):
    cluster = paper_cluster(model, "mi325x")
    W = profile_W(model, workload)
    rows = []
    for policy in registered_policies():
        pl = placement_for(policy, model, workload, cluster)
        loads = pl.rank_loads(W)
        lat = rank_latency_matrix(cluster, loads)
        tok_ratio = loads.max(1) / np.maximum(loads.min(1), 1e-9)
        lat_ratio = lat.max(1) / lat.min(1)
        rows.append({
            "bench": "fig1", "label": policy,
            "token_ratio_mean": float(tok_ratio.mean()),
            "token_ratio_p95": float(np.percentile(tok_ratio, 95)),
            "latency_ratio_mean": float(lat_ratio.mean()),
            "latency_ratio_p95": float(np.percentile(lat_ratio, 95)),
        })
    emit(rows, "fig1_imbalance")
    return rows


if __name__ == "__main__":
    run()
