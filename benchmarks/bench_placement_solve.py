"""ViBE-R solver benchmark: wall-clock vs (G, E, L) + quality on Zipf skew.

Two questions the placement subsystem must answer at cluster scale:

1. **Does the solve itself scale?** The per-layer Python greedy is O(L·E·G)
   with Python-loop constants; the vectorized solvers advance all layers
   simultaneously (argsort/segment ops), so the DeepSeek-scale operating
   point (G=64, L=58, E=256) must finish in well under a second — fast
   enough to re-solve inside a serving-loop recalibration window.
2. **Does replication buy latency?** On a Zipf-skewed activation matrix the
   hottest expert pins whichever rank holds it; ViBE-R splits that expert
   over several ranks (speed-proportional shares), so its predicted
   max-layer latency must drop below singleton ViBE's.

Run:  PYTHONPATH=src:. python -m benchmarks.bench_placement_solve
"""

import time

import numpy as np

from repro.core import (default_slots_per_rank, layer_latency_span,
                        make_cluster, vibe_r_placement)
from repro.core.placement import (_greedy_target_assign, _speed_targets,
                                  vibe_placement)
from .common import emit

#: (G, E, L) sweep; the 64×256×58 point is DeepSeek-V3 on a 64-rank fleet.
SWEEP = ((8, 64, 4), (16, 128, 16), (32, 256, 32), (64, 256, 58),
         (128, 512, 58))


def zipf_activation(L: int, E: int, tokens: float = 500_000.0,
                    alpha: float = 1.2, seed: int = 0) -> np.ndarray:
    """Zipf(alpha) expert popularity, hot-expert identity shuffled per layer."""
    rng = np.random.default_rng(seed)
    z = 1.0 / np.arange(1, E + 1) ** alpha
    prof = np.stack([rng.permutation(z) for _ in range(L)])
    return prof / prof.sum(axis=1, keepdims=True) * tokens


def _time(fn, repeats: int = 3) -> float:
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick=True, seed=0):
    rows = []
    for G, E, L in (SWEEP[:4] if quick else SWEEP):
        cluster = make_cluster(G, "mi325x", d_model=7168, d_ff=2048,
                               experts_per_rank=max(E // G, 1), seed=seed)
        perf = cluster.fit_models()
        W = zipf_activation(L, E, seed=seed)
        s_loc = default_slots_per_rank(E, G)   # one replica slot per rank

        t_vibe = _time(lambda: vibe_placement(W, perf))
        t_vibe_r = _time(lambda: vibe_r_placement(W, perf,
                                                  slots_per_rank=s_loc))

        # per-layer reference greedy (the pre-vectorization code path)
        def legacy():
            _, targets = _speed_targets(W, perf, "rank")
            for l in range(L):
                _greedy_target_assign(W[l], targets[l].copy(), G)
        t_legacy = _time(legacy, repeats=1)

        pv = vibe_placement(W, perf)
        pr = vibe_r_placement(W, perf, slots_per_rank=s_loc)
        span_v = layer_latency_span(pv, W, perf)[:, 0]
        span_r = layer_latency_span(pr, W, perf)[:, 0]
        rows.append({
            "bench": "placement_solve", "label": f"G{G}_E{E}_L{L}",
            "G": G, "E": E, "L": L, "slots_per_rank_vibe_r": s_loc,
            "solve_ms_vibe": 1e3 * t_vibe,
            "solve_ms_vibe_r": 1e3 * t_vibe_r,
            "solve_ms_perlayer_greedy": 1e3 * t_legacy,
            "vec_speedup_x": t_legacy / max(t_vibe, 1e-9),
            "pred_max_layer_ms_vibe": 1e3 * float(span_v.mean()),
            "pred_max_layer_ms_vibe_r": 1e3 * float(span_r.mean()),
            "vibe_r_latency_reduction_pct":
                100 * (1 - float(span_r.mean()) / float(span_v.mean())),
            "max_copies": int(pr.n_copies().max()),
        })
        if (G, E, L) == (64, 256, 58):
            assert t_vibe_r < 1.0, \
                f"acceptance: vibe_r solve took {t_vibe_r:.2f}s (≥1s)"
            assert span_r.mean() < span_v.mean(), \
                "acceptance: vibe_r did not beat vibe on Zipf skew"
    emit(rows, "placement_solve")
    return rows


if __name__ == "__main__":
    run(quick=False)
