"""Fig 10: per-layer MoE latency gap distribution + utilization spread.

Paper: EPLB cuts the median gap 63.9% vs vLLM; ViBE a further 19.6%; the
per-GPU busy-share (frequency proxy) tightens under ViBE.
"""

import numpy as np

from repro.serving import sample_requests, WORKLOADS
from repro.core import registered_policies

from .common import emit, make_sim


def run(model="deepseek-v3-671b", workload="sonnet", quick=True):
    rows = []
    med_gap = {}
    avg_moe = {}
    for policy in registered_policies():
        sim = make_sim(model, workload, policy, seed=1, record_layers=True)
        reqs = sample_requests(WORKLOADS[workload], 120 if quick else 400,
                               qps=20.0, seed=2)
        sim.run(reqs, phase="prefill")
        gaps = np.concatenate([ls.latency_gap for ls in sim.layer_stats])
        layer_t = np.concatenate([ls.layer_time for ls in sim.layer_stats])
        util = sim.utilization_spread()
        med_gap[policy] = float(np.median(gaps))
        avg_moe[policy] = float(layer_t.mean())
        rows.append({
            "bench": "fig10", "label": policy,
            "gap_median_ms": med_gap[policy] * 1e3,
            "gap_p90_ms": float(np.percentile(gaps, 90)) * 1e3,
            "avg_moe_layer_ms": avg_moe[policy] * 1e3,
            "barrier_idle_s": sim.total_barrier_idle,
            "util_spread": float(util.max() / util.min()),
        })
    rows.append({
        "bench": "fig10", "label": "reductions",
        "eplb_gap_cut_pct": 100 * (1 - med_gap["eplb"]
                                   / max(med_gap["contiguous"], 1e-12)),
        "vibe_extra_gap_cut_pct": 100 * (1 - med_gap["vibe"]
                                         / max(med_gap["eplb"], 1e-12)),
        "vibe_vs_vllm_moe_latency_pct":
            100 * (1 - avg_moe["vibe"] / avg_moe["contiguous"]),
        "vibe_vs_eplb_moe_latency_pct":
            100 * (1 - avg_moe["vibe"] / avg_moe["eplb"]),
    })
    emit(rows, "fig10_gap")
    return rows


if __name__ == "__main__":
    run(quick=False)
