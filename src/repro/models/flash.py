"""Chunked (flash-style) attention in pure JAX.

The quadratic reference in ``attention.py`` materializes (B, S, S) scores —
fine as an oracle, impossible at prefill_32k / train_4k full configs. This
module implements the online-softmax algorithm with both query and key/value
chunking via ``lax.scan`` so peak memory is O(Cq · Ckv) per (batch, head)
instead of O(S²), while producing bit-comparable results (fp32 accumulation).

GQA layout: q (B, Sq, KV, G, hd), k/v (B, Skv, KV, hd) where G = H / KV.

Sliding-window and causal masking are data (position arrays + scalar window),
not structure, so local/global gemma3 layers share one compiled body.
"""

from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["flash_attention", "flash_decode"]

_NEG = -0.7 * float(jnp.finfo(jnp.float32).max)

# §Perf knobs (read at import; see EXPERIMENTS.md §Perf):
#  REPRO_FLASH_BF16=1  — store softmax probabilities in bf16 (the dominant
#    HBM tensor at 32k context is the (Cq, Ckv) score/prob block; flash
#    kernels feed the MXU bf16 p anyway). Max/sum statistics stay fp32.
#  REPRO_FLASH_KV_CHUNK — kv chunk length (default 1024); accumulator
#    rewrite traffic scales with S/kv_chunk.
_P_BF16 = os.environ.get("REPRO_FLASH_BF16", "") == "1"
_KV_CHUNK = int(os.environ.get("REPRO_FLASH_KV_CHUNK", "1024"))


def _chunk(x: jnp.ndarray, axis: int, size: int) -> jnp.ndarray:
    """Split ``axis`` into (n_chunks, size) and move n_chunks to the front."""
    n = x.shape[axis] // size
    shape = x.shape[:axis] + (n, size) + x.shape[axis + 1:]
    x = x.reshape(shape)
    return jnp.moveaxis(x, axis, 0)


def flash_attention(
    q: jnp.ndarray,                   # (B, Sq, KV, G, hd)
    k: jnp.ndarray,                   # (B, Skv, KV, hd)
    v: jnp.ndarray,                   # (B, Skv, KV, hd)
    *,
    causal: bool = True,
    window: Optional[jnp.ndarray] = None,    # scalar; 0/None = full
    q_positions: Optional[jnp.ndarray] = None,   # (Sq,)
    kv_positions: Optional[jnp.ndarray] = None,  # (Skv,)
    kv_valid: Optional[jnp.ndarray] = None,      # (Skv,) bool — cache fill mask
    q_chunk: int = 512,
    kv_chunk: int = _KV_CHUNK,
) -> jnp.ndarray:
    """Online-softmax attention, O(Cq·Ckv) live scores. Returns (B,Sq,KV,G,hd)."""
    B, Sq, KV, G, hd = q.shape
    Skv = k.shape[1]
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    # pad to chunk multiples (padded kv masked out; padded q discarded)
    pq = (-Sq) % q_chunk
    pk = (-Skv) % kv_chunk
    if q_positions is None:
        q_positions = jnp.arange(Sq)
    if kv_positions is None:
        kv_positions = jnp.arange(Skv)
    if kv_valid is None:
        kv_valid = jnp.ones((Skv,), bool)
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pq), constant_values=q_positions[-1])
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pk))
        kv_valid = jnp.pad(kv_valid, (0, pk), constant_values=False)

    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qc = _chunk(q, 1, q_chunk)                     # (Nq, B, Cq, KV, G, hd)
    kc = _chunk(k, 1, kv_chunk)                    # (Nk, B, Ck, KV, hd)
    vc = _chunk(v, 1, kv_chunk)
    qpos_c = _chunk(q_positions, 0, q_chunk)       # (Nq, Cq)
    kpos_c = _chunk(kv_positions, 0, kv_chunk)     # (Nk, Ck)
    kval_c = _chunk(kv_valid, 0, kv_chunk)

    def one_q_chunk(_, q_in):
        qi, qpos = q_in                            # (B,Cq,KV,G,hd), (Cq,)

        # flash backward: recompute scores per chunk pair instead of letting
        # the scan VJP store a (B,KV,G,Cq,Ckv) residual for every pair
        @jax.checkpoint
        def one_kv_chunk(carry, kv_in):
            m, l, acc = carry
            kj, vj, kpos, kval = kv_in
            s = jnp.einsum("bqkgh,bskh->bkgqs", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            mask = kval[None, :]
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if window is not None:
                w = jnp.asarray(window)
                in_win = (qpos[:, None] - kpos[None, :]) < w
                mask = mask & jnp.where(w > 0, in_win, True)
            s = jnp.where(mask[None, None, None, :, :], s, _NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            if _P_BF16:
                p = p.astype(jnp.bfloat16)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(vj.dtype), vj)
            acc_new = acc * corr[..., None] + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, q_chunk), _NEG, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(one_kv_chunk, (m0, l0, a0),
                                      (kc, vc, kpos_c, kval_c))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)           # (B,KV,G,Cq,hd)

    _, out = jax.lax.scan(one_q_chunk, None, (qc, qpos_c))
    # (Nq, B, KV, G, Cq, hd) → (B, Sq_pad, KV, G, hd)
    out = jnp.moveaxis(out, 0, 3).reshape(B, KV, G, Sq + pq, hd)
    out = jnp.moveaxis(out, 3, 1)
    return out[:, :Sq] if pq else out


def flash_decode(
    q: jnp.ndarray,                   # (B, KV, G, hd) — one new token
    k_cache: jnp.ndarray,             # (B, S_max, KV, hd)
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,                 # (B,) per-sequence positions
    *,
    window: Optional[jnp.ndarray] = None,
    kv_chunk: int = 2048,
    kpos_offset=0,                    # global position of cache row 0
    return_stats: bool = False,       # (acc, m, l) for cross-shard merge
):
    """Single-token decode against a long cache, chunked over the cache.

    Equivalent to flash_attention with Sq=1 but avoids the q-chunk padding
    and keeps the (B, S_max) score row in chunks. ``pos`` is per-sequence —
    continuous batching serves sequences at different positions in one step.
    """
    B, S_max, KV, hd = k_cache.shape
    G = q.shape[2]
    pos = jnp.broadcast_to(jnp.asarray(pos), (B,))
    kv_chunk = min(kv_chunk, S_max)
    while S_max % kv_chunk:            # keep the cache unpadded/uncopied
        kv_chunk //= 2
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    n = S_max // kv_chunk

    # §Perf iteration: scan over the chunk *index* and dynamic-slice the
    # cache in place — the previous reshape/moveaxis pre-chunking
    # materialized a transposed copy of the entire cache every decode step.
    def one_chunk(carry, j):
        m, l, acc = carry
        start = j * kv_chunk
        kj = jax.lax.dynamic_slice_in_dim(k_cache, start, kv_chunk, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v_cache, start, kv_chunk, axis=1)
        kp = kpos_offset + start + jnp.arange(kv_chunk)
        s = jnp.einsum("bkgh,bskh->bkgs", q, kj,
                       preferred_element_type=jnp.float32) * scale
        valid = kp[None, :] <= pos[:, None]                  # (B, Ck)
        if window is not None:
            w = jnp.asarray(window)
            valid = valid & jnp.where(w > 0,
                                      (pos[:, None] - kp[None, :]) < w, True)
        s = jnp.where(valid[:, None, None, :], s, _NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgs,bskh->bkgh", p.astype(vj.dtype), vj)
        acc_new = acc * corr[..., None] + pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G), _NEG, jnp.float32)
    l0 = jnp.zeros((B, KV, G), jnp.float32)
    a0 = jnp.zeros((B, KV, G, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(one_chunk, (m0, l0, a0),
                                  jnp.arange(n, dtype=jnp.int32))
    if return_stats:
        return acc, m, l
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
