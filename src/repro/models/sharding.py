"""Sharding rules threaded through model code (DESIGN.md §5).

One :class:`ShardingRules` instance describes how a model maps onto a mesh:
which axes carry data parallelism, tensor parallelism, expert parallelism and
FSDP weight sharding, plus per-phase MoE dispatch choices. Model code only
consumes the rules — the launcher builds them per (arch × mesh × phase).

With ``mesh=None`` every constraint is a no-op and MoE uses the dense
reference dispatch: the same model code runs on a bare CPU device (smoke
tests) and on the production mesh (dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.placement import copy_share_cdf

__all__ = ["ShardingRules", "build_slots_of", "build_copy_cdf"]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """How one model maps onto one mesh.

    ``ep``   — mesh axes forming the EP group for a2a dispatch (paper: the
               TP/"model" axis; dense layers TP, MoE layers EP — §5.1).
    ``ep_all`` — axes forming the EP group for replicated-dispatch decode
               (all axes: one expert slot per device, tokens replicated).
    ``fsdp`` — axis weights are additionally sharded over (ZeRO-3 style);
               gathered per layer inside the scan body.
    """

    mesh: Optional[Mesh] = None
    dp: Tuple[str, ...] = ("pod", "data")
    tp: str = "model"
    ep: Tuple[str, ...] = ("model",)
    ep_all: Tuple[str, ...] = ("pod", "data", "model")
    fsdp: Optional[str] = "data"
    attn_mode: str = "heads"            # "heads" | "context"
    moe_dispatch: str = "auto"          # "auto" | "a2a" | "replicated" | "dense"
    moe_impl: str = "auto"              # "auto" | "capacity" | "ragged"
    # "capacity" — fixed per-slot buckets (cf-bounded buffers, overflow
    # drops, FFN cost = E_loc × capacity regardless of skew); "ragged" —
    # sort-based dropless dispatch (flat expert-sorted buffer, grouped FFN
    # over occupied tiles only, FFN cost tracks realized tokens, tally's
    # drop column is structurally zero). "auto" resolves to ragged: it is
    # never worse than a dropless capacity and never drops; capacity stays
    # as the regression baseline. Caveats of ragged (see README "Kernels"):
    # the a2a exchange frames are sized to the dropless worst case
    # (ep × t_loc·top_k rows, ep/cf× capacity's receive memory), and only
    # the Pallas kernel path (use_kernel=True) skips unoccupied tiles —
    # the jnp fallback computes the padded buffer, so at large scale off-
    # TPU prefer moe_impl="capacity" if FLOPs matter more than drops.
    moe_block_m: int = 128              # ragged row tile (MXU-aligned on TPU)
    capacity_factor: float = 1.25
    remat: bool = True                  # checkpoint each scanned layer block
    use_kernel: bool = False            # Pallas fused MoE FFN (TPU target)
    decode_expert_tp: bool = False      # big experts: slots over `ep` only,
    # per-expert F sharded over the dp axes (partial-sum psum combine) —
    # avoids both weight replication and per-layer weight gathering.

    # -- mesh helpers -----------------------------------------------------

    def _names(self) -> set:
        return set(self.mesh.axis_names) if self.mesh is not None else set()

    def axis_size(self, axes) -> int:
        if self.mesh is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        size = 1
        for a in axes:
            if a in self.mesh.axis_names:
                size *= self.mesh.shape[a]
        return size

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.dp if a in self._names())

    @property
    def ep_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.ep if a in self._names())

    @property
    def ep_all_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.ep_all if a in self._names())

    @property
    def moe_impl_resolved(self) -> str:
        """The dispatch implementation ``"auto"`` resolves to (ragged)."""
        return "ragged" if self.moe_impl == "auto" else self.moe_impl

    @property
    def ep_size(self) -> int:
        return self.axis_size(self.ep_axes)

    @property
    def ep_all_size(self) -> int:
        return self.axis_size(self.ep_all_axes)

    def spec(self, *parts) -> P:
        """PartitionSpec with axis names filtered to the active mesh."""
        names = self._names()

        def keep(part):
            if part is None:
                return None
            if isinstance(part, (tuple, list)):
                kept = tuple(x for x in part if x in names)
                return kept if kept else None
            return part if part in names else None

        return P(*[keep(p) for p in parts])

    def constrain(self, x, *parts):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.spec(*parts))


def build_slots_of(perm: np.ndarray, n_experts: int, n_slots: int,
                   r_max: Optional[int] = None
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Logical-expert → physical-slot lookup tables from a slot permutation.

    ``perm``: (L, n_slots) int — logical expert held in each physical slot
    (entries ≥ n_experts are phantom padding; entries may repeat = replicas).
    ``r_max`` optionally pins the copy-axis width (≥ the actual maximum
    replica count) so successive placements with different replication
    degrees keep identical table shapes — no recompile on recalibration.
    Returns ``slots_of`` (L, E, r_max) int32 (padded with the first copy so
    any hash lands on a valid slot) and ``n_copies`` (L, E) int32.
    """
    perm = np.atleast_2d(perm)
    L = perm.shape[0]
    counts = np.zeros((L, n_experts), dtype=np.int32)
    for l in range(L):
        for p in range(n_slots):
            e = perm[l, p]
            if e < n_experts:
                counts[l, e] += 1
    if np.any(counts == 0):
        raise ValueError("some logical expert has no physical slot")
    if r_max is None:
        r_max = int(counts.max())
    elif r_max < int(counts.max()):
        raise ValueError(f"r_max={r_max} < max replica count {counts.max()}")
    slots_of = np.zeros((L, n_experts, r_max), dtype=np.int32)
    fill = np.zeros((L, n_experts), dtype=np.int32)
    for l in range(L):
        for p in range(n_slots):
            e = perm[l, p]
            if e < n_experts:
                slots_of[l, e, fill[l, e]] = p
                fill[l, e] += 1
        for e in range(n_experts):
            slots_of[l, e, counts[l, e]:] = slots_of[l, e, 0]
    return slots_of, counts


def build_copy_cdf(perm: np.ndarray, n_experts: int, n_slots: int,
                   share: Optional[np.ndarray] = None,
                   r_max: Optional[int] = None) -> np.ndarray:
    """Per-(layer, expert) cumulative copy-share table for weighted dispatch.

    ``share``: (L, n_slots) per-slot traffic fraction aligned with ``perm``
    (a ``ReplicatedPlacement.share``); None = uniform over each expert's
    copies. Copies are enumerated in slot order — the same order
    :func:`build_slots_of` lays them out, so ``cdf[l, e, r]`` is the
    cumulative share of the copy held in ``slots_of[l, e, r]``; phantom
    slots (ids ≥ E) take no share. Entries past the last copy are 1.0, so
    inverse-CDF selection never lands on padding. Returns (L, E, r_max)
    float32. Thin wrapper over the canonical
    :func:`repro.core.placement.copy_share_cdf` so the solver and the
    model seam share one table construction.
    """
    perm = np.atleast_2d(perm)
    if perm.shape[1] != n_slots:
        raise ValueError(f"perm has {perm.shape[1]} slots != {n_slots}")
    return copy_share_cdf(perm, n_experts, share=share, r_max=r_max)
