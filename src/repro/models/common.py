"""Shared model building blocks (pure JAX, pytree params).

Conventions:
* params are nested dicts of jnp arrays; stacked-layer weights carry a
  leading L dim and are consumed by ``lax.scan``.
* compute dtype is bf16, accumulation/normalization in fp32.
* sharding is expressed with ``maybe_constrain`` — a no-op outside a mesh
  context, a ``with_sharding_constraint`` inside one (so the same model code
  runs on 1 CPU device and on the production mesh).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = [
    "maybe_constrain", "rms_norm", "rope_tables", "apply_rope",
    "dense_init", "mlp", "mlp_init", "softmax_xent_chunked", "cast",
]


def maybe_constrain(x: jnp.ndarray, spec: Optional[P]):
    """with_sharding_constraint when a mesh is active, identity otherwise."""
    if spec is None:
        return x
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return x
    if mesh is None or mesh.empty:
        return x
    # drop axis names the current mesh doesn't have (e.g. "pod" on 2-D mesh)
    names = set(mesh.axis_names)

    def keep(part):
        if part is None:
            return None
        if isinstance(part, tuple):
            kept = tuple(p for p in part if p in names)
            return kept if kept else None
        return part if part in names else None

    spec2 = P(*[keep(p) for p in spec])
    return jax.lax.with_sharding_constraint(x, spec2)


def cast(x: jnp.ndarray, dtype) -> jnp.ndarray:
    return x.astype(dtype) if x.dtype != dtype else x


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def rope_tables(positions: jnp.ndarray, head_dim: int,
                theta: float = 10000.0):
    """cos/sin tables for the given positions → ((..., hd/2), (..., hd/2))."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, hd); cos/sin: (..., S, hd/2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(jnp.float32)
    s = sin[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16) -> jnp.ndarray:
    scale = 1.0 / jnp.sqrt(jnp.asarray(d_in, jnp.float32))
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def mlp_init(key, d: int, f: int, gated: bool, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    p = {"w1": dense_init(ks[0], d, f, dtype), "w2": dense_init(ks[1], f, d, dtype)}
    if gated:
        p["w3"] = dense_init(ks[2], d, f, dtype)
    return p


def mlp(p, x: jnp.ndarray, gated: bool, tp_spec: Optional[P] = None) -> jnp.ndarray:
    """SwiGLU (gated) or GELU (2-matrix) MLP; hidden optionally TP-sharded."""
    h = jnp.einsum("...d,df->...f", x, p["w1"])
    if gated:
        h = jax.nn.silu(h) * jnp.einsum("...d,df->...f", x, p["w3"])
    else:
        h = jax.nn.gelu(h)
    if tp_spec is not None:
        h = maybe_constrain(h, tp_spec)
    return jnp.einsum("...f,fd->...d", h, p["w2"])


def softmax_xent_chunked(hidden: jnp.ndarray, w_unemb: jnp.ndarray,
                         labels: jnp.ndarray, n_chunks: int = 8,
                         logits_spec: Optional[P] = None) -> jnp.ndarray:
    """Mean token cross-entropy without materializing (B,S,V) at once.

    The sequence axis is processed in ``n_chunks`` scan steps so peak logits
    memory is (B, S/n_chunks, V) — the production trick that keeps the
    262k-vocab archs inside HBM at train_4k (DESIGN.md §5).
    """
    B, S, D = hidden.shape
    if S % n_chunks != 0:
        n_chunks = 1
    C = S // n_chunks
    h = hidden.reshape(B, n_chunks, C, D).swapaxes(0, 1)     # (n, B, C, D)
    y = labels.reshape(B, n_chunks, C).swapaxes(0, 1)        # (n, B, C)

    def chunk_loss(carry, hc_yc):
        hc, yc = hc_yc
        logits = jnp.einsum("bcd,dv->bcv", hc, w_unemb).astype(jnp.float32)
        if logits_spec is not None:
            logits = maybe_constrain(logits, logits_spec)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(chunk_loss, jnp.float32(0.0), (h, y))
    return total / (B * S)
