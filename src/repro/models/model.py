"""Model assembly: every assigned architecture as one scanned-block program.

The model is a ``lax.scan`` over *super-blocks*. A super-block is the
smallest repeating structural unit of the architecture:

* dense / moe / audio / vlm : 1 layer  (gemma3's local/global pattern is
  *data* — a per-layer window array — not structure)
* jamba  : 8 layers (1 attention + 7 Mamba; MoE on odd positions)
* xlstm  : ``slstm_every`` layers (1 sLSTM + rest mLSTM)

All per-block params carry a leading ``n_blocks`` axis, so XLA compiles one
block body regardless of depth — essential for 94-layer dry-run compiles.

Three entry points (the dry-run lowers exactly these):

* :func:`loss_fn`     — training forward → (loss, (tallies, aux))
* :func:`prefill_fn`  — (tokens → last-position logits, filled cache)
* :func:`decode_fn`   — (one token + cache → logits, cache)  [serve_step]

MoE placement enters as the ``moe_tables`` *input* (slot lookup arrays), so
ViBE recalibration never recompiles — see models/moe.py.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ArchConfig
from .attention import attn_init
from .common import apply_rope, dense_init, mlp, mlp_init, rms_norm, \
    rope_tables, softmax_xent_chunked
from .flash import flash_attention, flash_decode
from .moe import (default_perm_a2a, default_perm_replicated, moe_init,
                  moe_layer, n_slots_a2a)
from .sharding import ShardingRules, build_copy_cdf, build_slots_of
from . import ssm

__all__ = [
    "LayerSpec", "block_layout", "init_params", "make_moe_tables",
    "loss_fn", "prefill_fn", "prefill_chunk_fn", "decode_fn", "init_cache",
    "moe_perm_shape", "count_params",
]


# ---------------------------------------------------------------------------
# structural layout
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str                       # attn | mamba | mlstm | slstm
    ffn: str                         # dense | moe | none


def block_layout(cfg: ArchConfig) -> Tuple[int, List[LayerSpec]]:
    """(n_blocks, per-position layer specs)."""
    if cfg.family == "ssm":
        bs = cfg.slstm_every or 1
    elif cfg.attn_every:
        bs = math.lcm(cfg.attn_every, cfg.moe_every if cfg.is_moe else 1)
    else:
        bs = 1
    if cfg.n_layers % bs:
        raise ValueError(f"{cfg.name}: n_layers={cfg.n_layers} % block={bs}")
    specs = []
    for i in range(bs):
        if cfg.family == "ssm":
            mixer = "slstm" if (cfg.slstm_every and i % cfg.slstm_every == 0) \
                else "mlstm"
        elif cfg.attn_every and i % cfg.attn_every != 0:
            mixer = "mamba"
        else:
            mixer = "attn"
        if cfg.is_moe and i % cfg.moe_every == cfg.moe_offset:
            ffn = "moe"
        elif cfg.d_ff:
            ffn = "dense"
        else:
            ffn = "none"
        specs.append(LayerSpec(mixer, ffn))
    return cfg.n_layers // bs, specs


def _windows(cfg: ArchConfig) -> Optional[np.ndarray]:
    """(n_blocks, block_size) sliding-window sizes (0 = full attention)."""
    nb, specs = block_layout(cfg)
    if cfg.window <= 0:
        return None
    win = np.zeros((cfg.n_layers,), np.int32)
    for l in range(cfg.n_layers):
        is_global = cfg.global_every and (l % cfg.global_every
                                          == cfg.global_every - 1)
        win[l] = 0 if is_global else cfg.window
    return win.reshape(nb, len(specs))


def moe_perm_shape(cfg: ArchConfig, rules: Optional[ShardingRules],
                   phase: str) -> Tuple[int, int]:
    """(n_moe_layers, n_slots) for building placement permutations."""
    nb, specs = block_layout(cfg)
    n_moe = nb * sum(1 for s in specs if s.ffn == "moe")
    if rules is None or rules.mesh is None:
        return n_moe, cfg.n_experts
    if phase == "decode":
        fleet = (rules.ep_size if rules.decode_expert_tp
                 else rules.ep_all_size)
        e_loc = max(1, -(-cfg.n_experts // max(fleet, 1)))
        return n_moe, e_loc * max(fleet, 1)
    return n_moe, n_slots_a2a(cfg.n_experts, rules.ep_size)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: ArchConfig, key, rules: Optional[ShardingRules] = None,
                phase: str = "train", dtype=jnp.bfloat16) -> Dict[str, Any]:
    nb, specs = block_layout(cfg)
    _, n_slots = moe_perm_shape(cfg, rules, phase) if cfg.is_moe else (0, 0)
    d, hd = cfg.d_model, cfg.hd
    keys = jax.random.split(key, 8 + len(specs))

    def stacked(init_one, k):
        ks = jax.random.split(k, nb)
        return jax.vmap(init_one)(ks)

    layers = []
    for i, spec in enumerate(specs):
        ki = keys[8 + i]

        def init_layer(k, spec=spec):
            sub = dict(ln1=jnp.zeros((d,), jnp.float32))
            kk = jax.random.split(k, 3)
            if spec.mixer == "attn":
                sub["mixer"] = attn_init(kk[0], d, cfg.n_heads,
                                         cfg.n_kv_heads, hd, dtype)
            elif spec.mixer == "mamba":
                sub["mixer"] = ssm.mamba_init(
                    kk[0], d, expand=cfg.ssm_expand, d_state=cfg.ssm_d_state,
                    d_conv=cfg.ssm_conv, dtype=dtype)
            elif spec.mixer == "mlstm":
                sub["mixer"] = ssm.mlstm_init(
                    kk[0], d, n_heads=cfg.n_heads, expand=cfg.ssm_expand,
                    dtype=dtype)
            else:
                sub["mixer"] = ssm.slstm_init(
                    kk[0], d, n_heads=cfg.n_heads, expand=cfg.ssm_expand,
                    dtype=dtype)
            if spec.ffn != "none":
                sub["ln2"] = jnp.zeros((d,), jnp.float32)
            if spec.ffn == "dense":
                sub["ffn"] = mlp_init(kk[1], d, cfg.d_ff, cfg.mlp_gated, dtype)
            elif spec.ffn == "moe":
                sub["ffn"] = moe_init(kk[1], d=d, f=cfg.moe_d_ff,
                                      n_experts=cfg.n_experts,
                                      n_slots=n_slots, dtype=dtype)
                if cfg.n_shared_experts:
                    sub["shared"] = mlp_init(
                        kk[2], d, cfg.n_shared_experts * cfg.moe_d_ff,
                        cfg.mlp_gated, dtype)
            return sub

        layers.append(stacked(init_layer, ki))

    params: Dict[str, Any] = {
        "embed": dense_init(keys[0], cfg.vocab, d, dtype),
        "final_norm": jnp.zeros((d,), jnp.float32),
        "blocks": layers,
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(keys[1], d, cfg.vocab, dtype)
    if cfg.frontend_dim:
        params["frontend"] = dense_init(keys[2], cfg.frontend_dim, d, dtype)
    return params


def count_params(params) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))


def make_moe_tables(cfg: ArchConfig, rules: Optional[ShardingRules],
                    perm: Optional[np.ndarray] = None,
                    phase: str = "train",
                    n_slots: Optional[int] = None,
                    share: Optional[np.ndarray] = None,
                    r_max: Optional[int] = None):
    """Build the (slots_of, n_copies, copy_cdf) scan inputs from a placement.

    ``perm``: (n_moe_layers, n_slots) — logical expert per physical slot
    (from a ViBE/EPLB/contiguous/ViBE-R placement; repeated entries are
    replicas); None = contiguous default. ``n_slots`` overrides the
    arch-derived slot count when the caller runs an expanded ViBE-R slot
    budget (extra replica slots beyond one-per-expert).

    ``share``: (n_moe_layers, n_slots) per-slot traffic fractions (a
    ``ReplicatedPlacement.share``) — folded into the cumulative-share table
    the dispatch uses for inverse-CDF replica selection; None = uniform
    split over copies. ``r_max`` pins the copy-axis width so placements
    with different replication degrees keep identical table shapes (the
    no-recompile discipline — tables are jit *inputs*, never statics).

    Returns arrays shaped (n_blocks, moe_per_block, E, r) / (…, E) /
    (…, E, r), or None for non-MoE archs.
    """
    if not cfg.is_moe:
        return None
    nb, specs = block_layout(cfg)
    m = sum(1 for s in specs if s.ffn == "moe")
    n_moe, default_slots = moe_perm_shape(cfg, rules, phase)
    n_slots = default_slots if n_slots is None else int(n_slots)
    if perm is None:
        if rules is not None and rules.mesh is not None and phase == "decode":
            fleet = (rules.ep_size if rules.decode_expert_tp
                     else rules.ep_all_size)
            perm = default_perm_replicated(n_moe, cfg.n_experts, fleet)
        else:
            ep = rules.ep_size if (rules and rules.mesh is not None) else 1
            perm = default_perm_a2a(n_moe, cfg.n_experts, ep)
    perm = np.atleast_2d(perm)
    if perm.shape != (n_moe, n_slots):
        raise ValueError(f"perm shape {perm.shape} != {(n_moe, n_slots)}")
    slots_of, n_copies = build_slots_of(perm, cfg.n_experts, n_slots,
                                        r_max=r_max)
    r = slots_of.shape[-1]
    copy_cdf = build_copy_cdf(perm, cfg.n_experts, n_slots, share=share,
                              r_max=r)
    return (jnp.asarray(slots_of.reshape(nb, m, cfg.n_experts, r)),
            jnp.asarray(n_copies.reshape(nb, m, cfg.n_experts)),
            jnp.asarray(copy_cdf.reshape(nb, m, cfg.n_experts, r)))


def refresh_moe_share_tables(cfg: ArchConfig, moe_tables,
                             perm: np.ndarray, share: np.ndarray):
    """Rebuild only the ``copy_cdf`` entry of ``moe_tables`` for new shares.

    The fast path for dispatch-time share updates (work stealing,
    :mod:`repro.core.steal`): the slot table is unchanged, so ``slots_of``
    and ``n_copies`` — the expensive per-slot enumeration in
    :func:`~repro.models.sharding.build_slots_of` — are reused as-is, and
    only the cumulative-share table is recomputed. The returned tuple has
    identical shapes/dtypes to the input (copy-axis width taken from the
    existing ``slots_of``), so swapping it into a jitted step function
    never recompiles.
    """
    if moe_tables is None:
        return None
    slots_of, n_copies, old_cdf = moe_tables
    nb, m, E, r = old_cdf.shape
    perm = np.atleast_2d(perm)
    copy_cdf = build_copy_cdf(perm, cfg.n_experts, perm.shape[1],
                              share=share, r_max=r)
    return (slots_of, n_copies,
            jnp.asarray(copy_cdf.reshape(nb, m, E, r)))


# ---------------------------------------------------------------------------
# block body
# ---------------------------------------------------------------------------

def _attn_specs(cfg, rules: ShardingRules):
    """(q_spec, kv_spec) activation constraints for the chosen TP mode."""
    if rules is None:
        return None, None
    if rules.attn_mode == "heads" and cfg.n_heads % max(rules.axis_size(rules.tp), 1) == 0 \
            and cfg.n_kv_heads % max(rules.axis_size(rules.tp), 1) == 0:
        return (P(rules.dp, None, rules.tp, None),
                P(rules.dp, None, rules.tp, None))
    # context mode: sequence-sharded q, replicated kv (flash gathers chunks)
    return (P(rules.dp, rules.tp, None, None),
            P(rules.dp, None, None, None))


def _run_attention(p, x, cfg, rules, window, positions, cache=None,
                   pos=None, kv_valid=None):
    """Returns (out, (k, v)) for prefill/train or (out, new_cache) decode."""
    B, S, D = x.shape
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    G = H // KV
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, KV, G, hd)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(B, S, KV, hd)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(B, S, KV, hd)
    if cache is None:
        cos, sin = rope_tables(positions[None, :], hd, cfg.rope_theta)
        q = apply_rope(q.reshape(B, S, KV * G, hd), cos, sin) \
            .reshape(B, S, KV, G, hd)
        k = apply_rope(k, cos, sin)
        tp_size = 1 if rules is None else rules.axis_size(rules.tp)
        use_cp = (rules is not None and rules.mesh is not None
                  and rules.attn_mode == "context" and S % tp_size == 0
                  and tp_size > 1)
        if use_cp:
            # context-parallel flash (§Perf): each TP rank holds a q
            # sequence shard and the (small, GQA) kv replicated — fully
            # local attention. Constraining alone does NOT survive the
            # chunking reshapes (XLA re-replicates q → S² score traffic).
            dp_sz = max(rules.axis_size(rules.dp), 1)
            b_ax = rules.dp if B % dp_sz == 0 else None
            qspec = rules.spec(b_ax, rules.tp, None, None, None)
            kvspec = rules.spec(b_ax, None, None, None)
            win = window if window is not None else jnp.int32(0)

            def body(q, k, v, qpos, kpos, win):
                return flash_attention(q, k, v, causal=cfg.causal,
                                       window=win, q_positions=qpos,
                                       kv_positions=kpos)

            out = compat.shard_map(
                body, mesh=rules.mesh,
                in_specs=(qspec, kvspec, kvspec, rules.spec(rules.tp),
                          P(), P()),
                out_specs=qspec,
            )(q, k, v, positions, positions, win)
        else:
            if rules is not None:
                qs, kvs = _attn_specs(cfg, rules)
                if qs is not None:
                    q = rules.constrain(q.reshape(B, S, H, hd), *qs)\
                        .reshape(B, S, KV, G, hd)
                    k = rules.constrain(k, *kvs)
                    v = rules.constrain(v, *kvs)
            out = flash_attention(q, k, v, causal=cfg.causal, window=window,
                                  q_positions=positions,
                                  kv_positions=positions)
        out = out.reshape(B, S, H * hd)
        return jnp.einsum("bsh,hd->bsd", out, p["wo"]), (k, v)
    # decode: single token per sequence at per-sequence positions (B,)
    k_cache, v_cache = cache
    S_max = k_cache.shape[1]
    pos = jnp.broadcast_to(jnp.asarray(pos), (B,))
    cos, sin = rope_tables(pos[:, None], hd, cfg.rope_theta)    # (B,1,hd/2)
    q = apply_rope(q.reshape(B, S, KV * G, hd), cos, sin) \
        .reshape(B, KV, G, hd)
    k = apply_rope(k, cos, sin)
    tp_size = 1 if rules is None else rules.axis_size(rules.tp)
    use_cp = (rules is not None and rules.mesh is not None
              and rules.attn_mode == "context" and tp_size > 1
              and S_max % tp_size == 0)
    if use_cp:
        # context-parallel flash-decode (§Perf): the cache stays
        # sequence-sharded; each TP rank updates/attends its shard and a
        # psum merges the online-softmax stats — no cache gather/halo.
        dp_sz = max(rules.axis_size(rules.dp), 1)
        b_ax = rules.dp if B % dp_sz == 0 else None
        cspec = rules.spec(b_ax, rules.tp, None, None)
        qspec = rules.spec(b_ax, None, None, None)
        s_loc = S_max // tp_size

        def body(q, k1, v1, kc, vc, pos):
            rank = jax.lax.axis_index(rules.tp)
            off = rank * s_loc
            upd = pos - off
            owned = (upd >= 0) & (upd < s_loc)
            safe = jnp.clip(upd, 0, s_loc - 1)
            bi = jnp.arange(q.shape[0])
            kc = kc.at[bi, safe].set(
                jnp.where(owned[:, None, None], k1[:, 0], kc[bi, safe]))
            vc = vc.at[bi, safe].set(
                jnp.where(owned[:, None, None], v1[:, 0], vc[bi, safe]))
            acc, m, l = flash_decode(q, kc, vc, pos, window=window,
                                     kpos_offset=off, return_stats=True)
            m_g = jax.lax.pmax(m, rules.tp)
            scale = jnp.exp(m - m_g)
            num = jax.lax.psum(acc * scale[..., None], rules.tp)
            den = jax.lax.psum(l * scale, rules.tp)
            out = (num / jnp.maximum(den, 1e-30)[..., None]).astype(q.dtype)
            return out, kc, vc

        out, k_cache, v_cache = compat.shard_map(
            body, mesh=rules.mesh,
            in_specs=(qspec, qspec, qspec, cspec, cspec,
                      rules.spec(b_ax)),
            out_specs=(qspec, cspec, cspec),
        )(q, k, v, k_cache, v_cache, pos)
    else:
        k_cache = k_cache.at[jnp.arange(B), pos].set(k[:, 0])
        v_cache = v_cache.at[jnp.arange(B), pos].set(v[:, 0])
        if rules is not None:
            cspec = P(rules.dp, None, rules.tp, None)
            k_cache = rules.constrain(k_cache, *cspec)
            v_cache = rules.constrain(v_cache, *cspec)
        out = flash_decode(q, k_cache, v_cache, pos, window=window)
    out = out.reshape(B, 1, H * hd)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]), (k_cache, v_cache)


def _run_attention_chunk(p, x, cfg, window, cache, positions, lane, offset,
                         n_valid, row_valid):
    """Chunked-prefill attention: one prompt chunk of one sequence against
    its lane in the full (batch, S_max) cache.

    ``row_valid`` masks the tail chunk's padding: padded rows never reach
    the cache (masked write) and unwritten cache rows never reach the
    scores (``kv_valid``), so a chunked prefill accumulates exactly the
    rows a whole-prompt prefill would.
    """
    B, C, D = x.shape                    # B == 1: one sequence's chunk
    hd, H, KV = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    G = H // KV
    k_cache, v_cache = cache
    S_max = k_cache.shape[1]
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, C, KV, G, hd)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(B, C, KV, hd)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(B, C, KV, hd)
    cos, sin = rope_tables(positions[None, :], hd, cfg.rope_theta)
    q = apply_rope(q.reshape(B, C, KV * G, hd), cos, sin) \
        .reshape(B, C, KV, G, hd)
    k = apply_rope(k, cos, sin)
    lane = jnp.asarray(lane, jnp.int32)
    offset = jnp.asarray(offset, jnp.int32)

    def write(cbuf, new):
        # masked in-place write at (lane, offset): padded rows keep the
        # old cache contents (offset + C <= S_max by EngineConfig
        # validation, so dynamic_slice never clamps/shifts the window)
        old = jax.lax.dynamic_slice(cbuf, (lane, offset, 0, 0),
                                    (1, C, KV, hd))
        upd = jnp.where(row_valid[None, :, None, None],
                        new.astype(cbuf.dtype), old)
        return jax.lax.dynamic_update_slice(cbuf, upd, (lane, offset, 0, 0))

    k_cache = write(k_cache, k)
    v_cache = write(v_cache, v)
    k_lane = jax.lax.dynamic_slice(k_cache, (lane, 0, 0, 0),
                                   (1, S_max, KV, hd))
    v_lane = jax.lax.dynamic_slice(v_cache, (lane, 0, 0, 0),
                                   (1, S_max, KV, hd))
    kv_valid = jnp.arange(S_max) < offset + n_valid
    out = flash_attention(q, k_lane, v_lane, causal=cfg.causal,
                          window=window, q_positions=positions,
                          kv_positions=jnp.arange(S_max), kv_valid=kv_valid)
    out = out.reshape(B, C, H * hd)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]), (k_cache, v_cache)


def _block_body(cfg, rules, specs, bp, x, *, windows_blk, moe_tables_blk,
                positions, phase, cache_blk=None, pos=None, chunk_ctx=None):
    """One super-block forward. Returns (x, tallies, aux, new_cache_blk).

    ``chunk_ctx`` — (lane, offset, n_valid, row_valid) for the chunked-
    prefill phase: attention routes through :func:`_run_attention_chunk`
    and MoE layers get the padding mask so telemetry stays honest.
    """
    tallies, aux_total = [], jnp.float32(0.0)
    new_cache = []
    moe_i = 0
    for i, spec in enumerate(specs):
        sub = bp[i]
        h = rms_norm(x, sub["ln1"], cfg.norm_eps)
        if spec.mixer == "attn":
            window = None
            if windows_blk is not None:
                window = windows_blk[i]
            cache = None if cache_blk is None else cache_blk[i]
            if phase == "chunk":
                lane, offset, n_valid, row_valid = chunk_ctx
                h, st = _run_attention_chunk(
                    sub["mixer"], h, cfg, window, cache, positions,
                    lane, offset, n_valid, row_valid)
            else:
                h, st = _run_attention(sub["mixer"], h, cfg, rules, window,
                                       positions, cache=cache, pos=pos)
            new_cache.append(st)
        else:
            st_in = None if cache_blk is None else cache_blk[i]
            fn = {"mamba": ssm.mamba_seq, "mlstm": ssm.mlstm_seq,
                  "slstm": ssm.slstm_seq}[spec.mixer]
            if phase == "decode":
                fn = {"mamba": ssm.mamba_step, "mlstm": ssm.mlstm_step,
                      "slstm": ssm.slstm_step}[spec.mixer]
            h, st = fn(sub["mixer"], h, st_in)
            new_cache.append(st)
        x = x + h
        if spec.ffn != "none":
            h2 = rms_norm(x, sub["ln2"], cfg.norm_eps)
            if spec.ffn == "dense":
                tp = None if rules is None else P(rules.dp, None, rules.tp)
                h2 = mlp(sub["ffn"], h2, cfg.mlp_gated, tp_spec=tp)
            else:
                so = nc = cdf = None
                if moe_tables_blk is not None:
                    so = moe_tables_blk[0][moe_i]
                    nc = moe_tables_blk[1][moe_i]
                    if len(moe_tables_blk) > 2:     # pre-share-table callers
                        cdf = moe_tables_blk[2][moe_i]
                # position-derived salt: decode positions advance every
                # step, so tiny batches re-draw their replica-selection
                # uniforms instead of replaying one fixed set forever
                seed = jnp.sum(positions).astype(jnp.int32)
                rv = None
                if chunk_ctx is not None:
                    rv = jnp.broadcast_to(chunk_ctx[3][None, :],
                                          h2.shape[:2]).reshape(-1)
                y, tally, aux = moe_layer(
                    sub["ffn"], h2, top_k=cfg.top_k,
                    n_experts=cfg.n_experts, rules=rules,
                    slots_of=so, n_copies=nc, copy_cdf=cdf,
                    route_seed=seed, phase=phase, row_valid=rv)
                if cfg.n_shared_experts:
                    tp = None if rules is None else P(rules.dp, None, rules.tp)
                    y = y + mlp(sub["shared"], h2, cfg.mlp_gated, tp_spec=tp)
                tallies.append(tally)
                aux_total = aux_total + aux
                moe_i += 1
                h2 = y
            x = x + h2
    tall = (jnp.stack(tallies) if tallies
            else jnp.zeros((0, cfg.n_experts + 1 if cfg.is_moe else 1),
                           jnp.float32))
    return x, tall, aux_total, new_cache


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def _embed(cfg, params, batch, rules):
    """Token/feature embedding → (x (B,S,D), labels_offset)."""
    if cfg.frontend == "audio":
        x = jnp.einsum("bsf,fd->bsd", batch["feats"],
                       params["frontend"])
        return x, 0
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.frontend == "vision" and "patches" in batch:    # decode: text only
        patches = jnp.einsum("bpf,fd->bpd", batch["patches"],
                             params["frontend"])
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        off = cfg.n_patches
    else:
        off = 0
    if rules is not None:
        x = rules.constrain(x, rules.dp, None, None)
    return x, off


def _unembed_w(cfg, params):
    return params["embed"].T if cfg.tie_embeddings else params["head"]


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def _scan_blocks(cfg, rules, params, x, *, phase, moe_tables, positions,
                 cache=None, pos=None, chunk_ctx=None):
    nb, specs = block_layout(cfg)
    win = _windows(cfg)
    win = None if win is None else jnp.asarray(win)

    # sequence parallelism: the residual stream (and the remat-saved block
    # inputs) live sequence-sharded over the TP axis; attention/MLP gather
    # internally (Megatron-SP). Decode has S=1 — skip.
    seq_ok = (rules is not None and phase != "decode"
              and x.shape[1] % max(rules.axis_size(rules.tp), 1) == 0)

    def body(x, xs):
        bp, wb, mt, cb = xs
        if seq_ok:
            x = rules.constrain(x, rules.dp, rules.tp, None)
        fn = lambda x_: _block_body(cfg, rules, specs, bp, x_,
                                    windows_blk=wb, moe_tables_blk=mt,
                                    positions=positions, phase=phase,
                                    cache_blk=cb, pos=pos,
                                    chunk_ctx=chunk_ctx)
        if rules is not None and rules.remat and phase == "train":
            x, tall, aux, nc = jax.checkpoint(fn)(x)
        else:
            x, tall, aux, nc = fn(x)
        if seq_ok:
            x = rules.constrain(x, rules.dp, rules.tp, None)
        if phase == "train":
            nc = []        # don't materialize stacked states during training
        return x, (tall, aux, nc)

    xs = (params["blocks"], win, moe_tables, cache)
    x, (tallies, aux, new_cache) = jax.lax.scan(body, x, xs)
    # tallies (nb, m, E+1) → (n_moe_layers, E+1): per-layer logical-expert
    # routing counts plus a final capacity-dropped-assignment column
    # (see moe_layer); aux summed
    tallies = tallies.reshape(-1, tallies.shape[-1])
    return x, tallies, aux.sum(), new_cache


def loss_fn(cfg: ArchConfig, rules: Optional[ShardingRules] = None,
            aux_weight: float = 0.01):
    """Training loss: mean token xent + MoE load-balance aux."""

    def fn(params, batch, moe_tables=None):
        x, off = _embed(cfg, params, batch, rules)
        S = x.shape[1]
        positions = jnp.arange(S)
        x, tallies, aux, _ = _scan_blocks(
            cfg, rules, params, x, phase="train", moe_tables=moe_tables,
            positions=positions)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        if off:
            x = x[:, off:]
        logits_spec = None
        if rules is not None and cfg.vocab % max(
                rules.axis_size(rules.tp), 1) == 0:
            logits_spec = P(rules.dp, None, rules.tp)
        loss = softmax_xent_chunked(x, _unembed_w(cfg, params),
                                    batch["labels"], logits_spec=logits_spec)
        return loss + aux_weight * aux, (tallies, aux)

    return fn


def prefill_fn(cfg: ArchConfig, rules: Optional[ShardingRules] = None):
    """(params, batch) → (last-position logits, cache, tallies)."""

    def fn(params, batch, moe_tables=None):
        x, off = _embed(cfg, params, batch, rules)
        S = x.shape[1]
        positions = jnp.arange(S)
        x, tallies, _, cache = _scan_blocks(
            cfg, rules, params, x, phase="prefill", moe_tables=moe_tables,
            positions=positions)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                            _unembed_w(cfg, params).astype(jnp.float32))
        return logits, cache, tallies

    return fn


def prefill_chunk_fn(cfg: ArchConfig, rules: Optional[ShardingRules] = None):
    """Chunked prefill: one fixed-width prompt chunk into one cache lane.

    ``(params, tokens (1, C), cache, lane, offset, n_valid)`` →
    ``(logits (1, V) at the chunk's last valid row, new cache, tallies)``.

    ``lane``/``offset``/``n_valid`` are traced scalars, so one compilation
    serves every lane, every chunk index and every tail length — the
    engine pays one compile per chunk width, not per request. The caller
    guarantees ``offset + C <= max_seq`` (``EngineConfig`` validates
    ``max_seq % prefill_chunk == 0``); padded tail rows are masked out of
    the cache write, the attention scores and the MoE tallies, so the
    final chunk's logits and cache state match a whole-prompt prefill.
    Logits are only meaningful on the chunk that completes the prompt.
    """
    _, specs = block_layout(cfg)
    if any(s.mixer != "attn" for s in specs):
        raise NotImplementedError(
            f"{cfg.name}: chunked prefill needs a resumable per-position "
            "cache; SSM/hybrid mixers carry recurrent state and are not "
            "supported")
    if rules is not None and rules.mesh is not None:
        raise NotImplementedError(
            "chunked prefill is single-device (the serving engine's "
            "configuration); mesh sharding is not supported")

    def fn(params, tokens, cache, lane, offset, n_valid, moe_tables=None):
        x, _ = _embed(cfg, params, {"tokens": tokens}, rules)
        C = x.shape[1]
        positions = offset + jnp.arange(C)
        row_valid = jnp.arange(C) < n_valid
        x, tallies, _, new_cache = _scan_blocks(
            cfg, rules, params, x, phase="chunk", moe_tables=moe_tables,
            positions=positions, cache=cache,
            chunk_ctx=(lane, offset, n_valid, row_valid))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        last = jnp.take(x[0], jnp.maximum(n_valid - 1, 0), axis=0)
        logits = jnp.einsum("d,dv->v", last.astype(jnp.float32),
                            _unembed_w(cfg, params).astype(jnp.float32))
        return logits[None], new_cache, tallies

    return fn


def decode_fn(cfg: ArchConfig, rules: Optional[ShardingRules] = None):
    """(params, token (B,1), cache, pos) → (logits, new cache, tallies)."""

    def fn(params, token, cache, pos, moe_tables=None):
        """``pos``: (B,) per-sequence positions (continuous batching)."""
        x, _ = _embed(cfg, params, {"tokens": token}, rules)
        pos = jnp.broadcast_to(jnp.asarray(pos), (token.shape[0],))
        x, tallies, _, new_cache = _scan_blocks(
            cfg, rules, params, x, phase="decode", moe_tables=moe_tables,
            positions=pos, cache=cache, pos=pos)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                            _unembed_w(cfg, params).astype(jnp.float32))
        return logits, new_cache, tallies

    return fn


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               rules: Optional[ShardingRules] = None, dtype=jnp.bfloat16):
    """Stacked per-block cache pytree matching the scan layout."""
    nb, specs = block_layout(cfg)
    per_pos = []
    for spec in specs:
        if spec.mixer == "attn":
            shape = (nb, batch, max_seq, cfg.n_kv_heads, cfg.hd)
            per_pos.append((jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)))
        elif spec.mixer == "mamba":
            st = ssm.mamba_state_init(batch, cfg.d_model,
                                      expand=cfg.ssm_expand,
                                      d_state=cfg.ssm_d_state,
                                      d_conv=cfg.ssm_conv, dtype=dtype)
            per_pos.append(jax.tree.map(
                lambda a: jnp.zeros((nb,) + a.shape, a.dtype), st))
        elif spec.mixer == "mlstm":
            st = ssm.mlstm_state_init(batch, cfg.d_model,
                                      n_heads=cfg.n_heads,
                                      expand=cfg.ssm_expand)
            per_pos.append(jax.tree.map(
                lambda a: jnp.zeros((nb,) + a.shape, a.dtype), st))
        else:
            st = ssm.slstm_state_init(batch, cfg.d_model,
                                      n_heads=cfg.n_heads,
                                      expand=cfg.ssm_expand)
            per_pos.append(jax.tree.map(
                lambda a: jnp.zeros((nb,) + a.shape, a.dtype), st))
    return per_pos
