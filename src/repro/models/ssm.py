"""Recurrent sequence mixers: Mamba (S6) for jamba, mLSTM/sLSTM for xLSTM.

Training/prefill use chunk-parallel forms so the backward pass saves only
chunk-boundary states (O(S/W) not O(S)); decode is a single-step recurrence
against a tiny carried state — this is what makes the ``long_500k`` shape
tractable for these families (DESIGN.md §5 skip matrix).

Sharding: every state tensor is per-channel (d_inner) or per-head, so TP
shards the channel/head axis and the scan carries stay local; the only
cross-device reductions are the in/out projections (GSPMD-inserted).

Numerics: states and gate accumulations in fp32, activations bf16.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .common import dense_init, rms_norm

__all__ = [
    "mamba_init", "mamba_seq", "mamba_step", "mamba_state_init",
    "mlstm_init", "mlstm_seq", "mlstm_step", "mlstm_state_init",
    "slstm_init", "slstm_seq", "slstm_step", "slstm_state_init",
]


# ---------------------------------------------------------------------------
# Mamba (S6) — selective state space, as used by Jamba
# ---------------------------------------------------------------------------

def mamba_init(key, d: int, *, expand: int = 2, d_state: int = 16,
               d_conv: int = 4, dtype=jnp.bfloat16):
    di = expand * d
    dt_rank = max(16, d // 16)
    ks = jax.random.split(key, 7)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, di), jnp.float32)
                   / np.sqrt(d_conv)).astype(dtype),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * d_state, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, di, jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[4], (di,), jnp.float32,
                                        1e-3, 1e-1), 1e-4))),
        "A_log": jnp.log(jnp.tile(
            jnp.arange(1, d_state + 1, dtype=jnp.float32)[None, :], (di, 1))),
        "D_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, d, dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv over sequence. x (B,S,di), w (k,di).

    ``state``: previous (B, k-1, di) tail for decode continuation. Returns
    (y, new_state).
    """
    B, S, di = x.shape
    k = w.shape[0]
    pad = (jnp.zeros((B, k - 1, di), x.dtype) if state is None
           else state.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)                    # (B, S+k-1, di)
    y = sum(xp[:, i:i + S, :] * w[i][None, None, :] for i in range(k))
    return y, xp[:, -(k - 1):, :] if k > 1 else jnp.zeros((B, 0, di), x.dtype)


def _ssm_comb(l, r):
    """Associative element for h_t = a_t·h_{t-1} + b_t."""
    return (r[0] * l[0], r[0] * l[1] + r[1])


def mamba_state_init(batch: int, d: int, *, expand: int = 2,
                     d_state: int = 16, d_conv: int = 4, dtype=jnp.bfloat16):
    di = expand * d
    return {"h": jnp.zeros((batch, di, d_state), jnp.float32),
            "conv": jnp.zeros((batch, d_conv - 1, di), dtype)}


def _mamba_core(p, x):
    """Shared pre-scan computation. x (B,S,D) → (u, z, dt, Bm, Cm, conv_tail)."""
    di = p["conv_w"].shape[1]
    ds = p["A_log"].shape[1]
    uz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    u, z = jnp.split(uz, 2, axis=-1)
    return u, z, di, ds


def mamba_seq(p, x: jnp.ndarray, state=None, chunk: int = 128):
    """Full-sequence Mamba mixer. Returns (y (B,S,D), new_state).

    The (B, W, di, ds) discretized tensors exist only *inside* the chunk
    scan body (checkpointed) — materializing them for the full sequence is
    ~TBs at jamba scale.
    """
    B, S, D = x.shape
    u, z, di, ds = _mamba_core(p, x)
    conv_state = None if state is None else state["conv"]
    u, conv_tail = _causal_conv(u, p["conv_w"], conv_state)
    u = jax.nn.silu(u)
    A = -jnp.exp(p["A_log"])                                  # (di, ds)
    dt_rank = p["dt_proj"].shape[0]

    W = min(chunk, S)
    while S % W:
        W //= 2
    n = S // W
    u_c = jnp.moveaxis(u.reshape(B, n, W, di), 1, 0)          # (n,B,W,di)

    @jax.checkpoint
    def one_chunk(h, u_w):
        proj = jnp.einsum("bwi,ie->bwe", u_w,
                          p["x_proj"]).astype(jnp.float32)
        dt_in, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
        dt = jax.nn.softplus(
            jnp.einsum("bwr,ri->bwi", dt_in, p["dt_proj"]) + p["dt_bias"])
        a = jnp.exp(dt[..., None] * A[None, None])            # (B,W,di,ds)
        bx = (dt[..., None] * Bm[:, :, None, :]
              * u_w.astype(jnp.float32)[..., None])
        aa, bb = jax.lax.associative_scan(_ssm_comb, (a, bx), axis=1)
        h_all = aa * h[:, None] + bb                          # (B,W,di,ds)
        y_w = (h_all * Cm[:, :, None, :]).sum(-1)             # (B,W,di)
        y_w = y_w + p["D_skip"][None, None, :] * u_w.astype(jnp.float32)
        return h_all[:, -1], y_w.astype(x.dtype)

    h0 = (jnp.zeros((B, di, ds), jnp.float32) if state is None
          else state["h"])
    h_last, y = jax.lax.scan(one_chunk, h0, u_c)              # (n,B,W,di)
    y = jnp.moveaxis(y, 0, 1).reshape(B, S, di)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return out, {"h": h_last, "conv": conv_tail}


def mamba_step(p, x: jnp.ndarray, state):
    """Single-token decode. x (B,1,D) → (y (B,1,D), new_state)."""
    out, new_state = mamba_seq(p, x, state, chunk=1)
    return out, new_state


# ---------------------------------------------------------------------------
# mLSTM — matrix-memory LSTM (xLSTM), chunkwise-parallel form
# ---------------------------------------------------------------------------

def mlstm_init(key, d: int, *, n_heads: int, expand: int = 2,
               dtype=jnp.bfloat16):
    di = expand * d
    ks = jax.random.split(key, 7)
    return {
        "up": dense_init(ks[0], d, 2 * di, dtype),
        "wq": dense_init(ks[1], di, di, dtype),
        "wk": dense_init(ks[2], di, di, dtype),
        "wv": dense_init(ks[3], di, di, dtype),
        "w_if": dense_init(ks[4], di, 2 * n_heads, jnp.float32),
        "ln_scale": jnp.zeros((di,), jnp.float32),
        "down": dense_init(ks[5], di, d, dtype),
    }


def mlstm_state_init(batch: int, d: int, *, n_heads: int, expand: int = 2):
    di = expand * d
    hd = di // n_heads
    return {"C": jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, n_heads, hd), jnp.float32),
            "m": jnp.full((batch, n_heads), -1e30, jnp.float32)}


def _mlstm_chunk(q, k, v, log_i, log_f, C0, n0, m0):
    """One chunk of stabilized chunkwise mLSTM.

    q/k/v: (B,H,W,hd); log_i/log_f: (B,H,W) fp32. State (C0,n0,m0).
    Returns (h (B,H,W,hd), C1, n1, m1).
    """
    B, H, W, hd = q.shape
    scale = 1.0 / np.sqrt(hd)
    b = jnp.cumsum(log_f, axis=-1)                            # (B,H,W) inclusive
    # intra-chunk log-weights: A[t,s] = b_t − b_s + ι_s for s ≤ t
    A = b[..., :, None] - b[..., None, :] + log_i[..., None, :]
    mask = jnp.tril(jnp.ones((W, W), bool))
    A = jnp.where(mask, A, -jnp.inf)
    m_intra = A.max(axis=-1)                                  # (B,H,W)
    m_inter = b + m0[..., None]
    m_t = jnp.maximum(m_intra, m_inter)                       # running stabilizer
    # intra scores
    S = jnp.einsum("bhwd,bhsd->bhws", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    P = jnp.where(mask, S * jnp.exp(A - m_t[..., None]), 0.0)
    h_intra = jnp.einsum("bhws,bhsd->bhwd", P, v.astype(jnp.float32))
    # inter-chunk: decayed state contribution
    dec = jnp.exp(m_inter - m_t)[..., None]                   # (B,H,W,1)
    h_inter = jnp.einsum("bhwd,bhde->bhwe", q.astype(jnp.float32) * scale,
                         C0) * dec
    n_q = (jnp.einsum("bhwd,bhd->bhw", q.astype(jnp.float32) * scale, n0)
           [..., None] * dec)
    num = h_intra + h_inter
    # normalizer: q·n_t = Σ_s exp(A−m)·(q·k_s·scale) = row-sum of P (intra)
    # + decayed q·n0 (inter) — consistent across chunk boundaries
    den_vec = P.sum(-1, keepdims=True) + n_q
    den = jnp.maximum(jnp.abs(den_vec), jnp.exp(-m_t)[..., None])
    h = num / den
    # state update to chunk end
    bW = b[..., -1:]
    m1 = jnp.maximum(bW + m0[..., None], (bW - b + log_i).max(-1, keepdims=True))
    w_upd = jnp.exp(bW - b + log_i - m1)                      # (B,H,W)
    dec1 = jnp.exp(bW + m0[..., None] - m1)                   # (B,H,1)
    C1 = (dec1[..., None] * C0
          + jnp.einsum("bhw,bhwd,bhwe->bhde", w_upd,
                       k.astype(jnp.float32), v.astype(jnp.float32)))
    n1 = dec1 * n0 + jnp.einsum("bhw,bhwd->bhd", w_upd,
                                k.astype(jnp.float32))
    return h, C1, n1, m1[..., -1]


def mlstm_seq(p, x: jnp.ndarray, state=None, chunk: int = 128):
    """Full-sequence mLSTM block. x (B,S,D) → (y (B,S,D), new_state)."""
    B, S, D = x.shape
    di = p["down"].shape[0]
    H = p["w_if"].shape[1] // 2
    hd = di // H
    uz = jnp.einsum("bsd,de->bse", x, p["up"])
    u, z = jnp.split(uz, 2, axis=-1)
    q = jnp.einsum("bsi,ij->bsj", u, p["wq"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsi,ij->bsj", u, p["wk"]).reshape(B, S, H, hd)
    v = jnp.einsum("bsi,ij->bsj", u, p["wv"]).reshape(B, S, H, hd)
    gates = jnp.einsum("bsi,ig->bsg", u.astype(jnp.float32), p["w_if"])
    log_i, log_f = gates[..., :H], gates[..., H:]
    log_f = -jax.nn.softplus(-log_f)                          # log sigmoid

    W = min(chunk, S)
    while S % W:
        W //= 2
    n = S // W
    # layout (B,H,S,hd): heads first, then chunk the sequence
    qh = jnp.moveaxis(q, 2, 1)                                # (B,H,S,hd)
    kh = jnp.moveaxis(k, 2, 1)
    vh = jnp.moveaxis(v, 2, 1)
    gi = jnp.moveaxis(log_i, 2, 1)                            # (B,H,S)
    gf = jnp.moveaxis(log_f, 2, 1)
    ch = lambda t: jnp.moveaxis(
        t.reshape(B, H, n, W, *t.shape[3:]), 2, 0)

    st = (mlstm_state_init(B, D, n_heads=H, expand=di // D) if state is None
          else state)

    @jax.checkpoint
    def one_chunk(carry, inp):
        C0, n0, m0 = carry
        qw, kw, vw, iw, fw = inp
        h, C1, n1, m1 = _mlstm_chunk(qw, kw, vw, iw, fw, C0, n0, m0)
        return (C1, n1, m1), h

    (C1, n1, m1), h = jax.lax.scan(
        one_chunk, (st["C"], st["n"], st["m"]),
        (ch(qh), ch(kh), ch(vh), ch(gi), ch(gf)))
    h = jnp.moveaxis(h, 0, 2).reshape(B, H, S, hd)            # (B,H,S,hd)
    h = jnp.moveaxis(h, 1, 2).reshape(B, S, di)
    h = rms_norm(h.astype(x.dtype), p["ln_scale"])
    y = h * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["down"])
    return out, {"C": C1, "n": n1, "m": m1}


def mlstm_step(p, x: jnp.ndarray, state):
    return mlstm_seq(p, x, state, chunk=1)


# ---------------------------------------------------------------------------
# sLSTM — scalar-memory LSTM with exponential gating (recurrent only)
# ---------------------------------------------------------------------------

def slstm_init(key, d: int, *, n_heads: int, expand: int = 2,
               dtype=jnp.bfloat16):
    di = expand * d
    hd = di // n_heads
    ks = jax.random.split(key, 4)
    return {
        "up": dense_init(ks[0], d, di, dtype),
        "w_gates": dense_init(ks[1], di, 4 * di, dtype),      # i, f, z, o
        "r_gates": (jax.random.normal(ks[2], (n_heads, hd, 4 * hd), jnp.float32)
                    / np.sqrt(hd)).astype(dtype),             # recurrent, per head
        "down": dense_init(ks[3], di, d, dtype),
    }


def slstm_state_init(batch: int, d: int, *, n_heads: int, expand: int = 2):
    di = expand * d
    hd = di // n_heads
    z = lambda: jnp.zeros((batch, n_heads, hd), jnp.float32)
    return {"c": z(), "n": z(), "h": z(),
            "m": jnp.full((batch, n_heads, hd), -1e30, jnp.float32)}


def _slstm_cell(p, u_t, st, n_heads, hd):
    """One sLSTM step. u_t (B, di); state pytree of (B,H,hd)."""
    B = u_t.shape[0]
    gx = jnp.einsum("bi,ig->bg", u_t, p["w_gates"]).reshape(B, n_heads, 4 * hd)
    gh = jnp.einsum("bhe,heg->bhg", st["h"].astype(u_t.dtype), p["r_gates"])
    g = (gx + gh).astype(jnp.float32)
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)                 # (B,H,hd) each
    log_f = -jax.nn.softplus(-gf)
    m_new = jnp.maximum(log_f + st["m"], gi)
    i = jnp.exp(gi - m_new)
    f = jnp.exp(log_f + st["m"] - m_new)
    c = f * st["c"] + i * jnp.tanh(gz)
    n = f * st["n"] + i
    h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_seq(p, x: jnp.ndarray, state=None, chunk: int = 256):
    """Sequential sLSTM (non-linear recurrence has no parallel form)."""
    B, S, D = x.shape
    di = p["down"].shape[0]
    H, hd4 = p["r_gates"].shape[0], p["r_gates"].shape[2]
    hd = hd4 // 4
    u = jnp.einsum("bsd,di->bsi", x, p["up"])
    st = slstm_state_init(B, D, n_heads=H, expand=di // D) if state is None \
        else state

    W = min(chunk, S)
    while S % W:
        W //= 2
    n_chunks = S // W
    u_c = jnp.moveaxis(u.reshape(B, n_chunks, W, di), 1, 0)

    @jax.checkpoint
    def one_chunk(carry, u_w):
        def cell(c, u_t):
            c2 = _slstm_cell(p, u_t, c, H, hd)
            return c2, c2["h"]
        carry2, hs = jax.lax.scan(cell, carry, jnp.moveaxis(u_w, 1, 0))
        return carry2, hs                                      # (W, B, H, hd)

    st_fin, hs = jax.lax.scan(one_chunk, st, u_c)              # (n, W, B, H, hd)
    h = jnp.moveaxis(hs.reshape(S, B, H, hd), 0, 1).reshape(B, S, di)
    out = jnp.einsum("bsi,id->bsd", h.astype(x.dtype), p["down"])
    return out, st_fin


def slstm_step(p, x: jnp.ndarray, state):
    return slstm_seq(p, x, state, chunk=1)
