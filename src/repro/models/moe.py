"""Mixture-of-Experts layer with expert-parallel dispatch (paper Fig 2b).

Three dispatch paths, all semantically identical (modulo capacity drops):

* ``dense``      — reference: every expert computed on every token, masked
                   combine. Differentiable oracle for tests; used when no
                   mesh is active (CPU smoke).
* ``a2a``        — production train/prefill path: tokens sharded over the EP
                   axis, capacity-bucketed per physical expert slot, two
                   ``lax.all_to_all`` exchanges around the grouped expert FFN
                   inside ``jax.shard_map`` — the paper's synchronized EP
                   execution, layer latency = slowest rank (§2).
* ``replicated`` — production decode path: with one token per sequence the
                   token tensor is tiny, so tokens are replicated across the
                   *full* device fleet, each device computes only the tokens
                   routed to its local expert slot(s), and a single ``psum``
                   combines. Experts are *replicated* across slots when the
                   fleet is larger than E (the paper's §5.5 "selective expert
                   duplication" future work, realized here as uniform
                   round-robin duplication).

Each path additionally comes in two **implementations**
(``ShardingRules.moe_impl``): ``capacity`` — the legacy fixed per-slot
buckets (cf-bounded buffers, overflow assignments dropped and surfaced in
``tally[E]``, grouped-FFN cost ``E_loc × capacity`` regardless of skew) —
and ``ragged`` (the ``auto`` default) — sort-based dropless dispatch:
assignments are stable-argsorted by physical slot (``_sort_by_slot``,
O(A log A) vs the old one-hot/cumsum O(A × n_slots)), packed into a flat
expert-sorted buffer whose per-slot segments are tile-aligned
(``_ragged_plan``), and the grouped FFN (``kernels.ragged_moe_ffn``)
executes only occupied (bm, D) tiles — compute tracks *realized* routed
tokens, hot experts never drop, cold experts burn nothing.

**Placement is positional** (DESIGN.md §3): the stacked expert weights live
in *physical slot* order; the router produces *logical* expert ids; the
``slots_of`` lookup (built from a ViBE/EPLB/contiguous ``Placement``) maps
logical → physical at runtime. Replicated experts additionally carry a
``copy_cdf`` cumulative-share table (ViBE-R solver phase 3): each
assignment picks among an expert's copies by inverse CDF over a
deterministic per-assignment uniform, so realized per-copy traffic matches
the solver's speed-proportional shares (see ``_select_slots``). Because
``slots_of``/``copy_cdf`` are plain array inputs, recalibration changes
placement *and* traffic shares *without recompilation* — only the weight
migration gather (:func:`apply_placement`) touches the expert tensors.

Phantom padding: when E does not divide the EP degree (granite: 40 experts,
16 ranks) the slot count is padded to the next multiple (48); phantom slots
never receive tokens. This keeps the full ViBE placement freedom at any mesh
instead of degrading to expert-TP.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat

from .common import dense_init
from .sharding import ShardingRules

__all__ = [
    "moe_init", "moe_layer", "route", "expert_ffn_ref",
    "default_perm_a2a", "default_perm_replicated", "n_slots_a2a",
    "apply_placement", "placement_gather_indices", "expand_experts",
]


# ---------------------------------------------------------------------------
# init / slot layout helpers
# ---------------------------------------------------------------------------

def n_slots_a2a(n_experts: int, ep_size: int) -> int:
    """Physical slot count for a2a dispatch: E padded to a multiple of EP."""
    return ((n_experts + ep_size - 1) // ep_size) * ep_size


def default_perm_a2a(n_layers: int, n_experts: int, ep_size: int) -> np.ndarray:
    """Identity (contiguous) slot permutation; phantoms at the tail."""
    ns = n_slots_a2a(n_experts, ep_size)
    return np.tile(np.arange(ns, dtype=np.int32), (n_layers, 1))


def default_perm_replicated(n_layers: int, n_experts: int,
                            fleet: int) -> np.ndarray:
    """Round-robin replication: slot p holds logical expert p % E."""
    e_loc = max(1, -(-n_experts // max(fleet, 1)))
    ns = e_loc * max(fleet, 1)
    return np.tile(np.arange(ns, dtype=np.int32) % n_experts, (n_layers, 1))


def moe_init(key, *, d: int, f: int, n_experts: int, n_slots: int,
             dtype=jnp.bfloat16):
    """Router (logical order) + stacked expert weights (physical slot order)."""
    ks = jax.random.split(key, 4)
    shape = lambda a, b: (n_slots, a, b)
    init = lambda k, a, b: (jax.random.normal(k, shape(a, b), jnp.float32)
                            / np.sqrt(a)).astype(dtype)
    return {
        "router": dense_init(ks[0], d, n_experts, jnp.float32),
        "w1": init(ks[1], d, f),
        "w3": init(ks[2], d, f),
        "w2": init(ks[3], f, d),
    }


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------

def route(router_w: jnp.ndarray, xf: jnp.ndarray, top_k: int):
    """Softmax-then-top-k routing (Mixtral/Qwen convention).

    Returns gate weights (t, K) f32 renormalized over the selected experts,
    indices (t, K) i32 (logical), and mean full-softmax probs (E,) f32 for
    the load-balance aux loss.
    """
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights, idx.astype(jnp.int32), probs.mean(axis=0)


def expert_ffn_ref(w1, w3, w2, toks):
    """Grouped SwiGLU FFN: toks (E_loc, C, D) → (E_loc, C, D). Pure jnp."""
    h = jnp.einsum("ecd,edf->ecf", toks, w1)
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", toks, w3)
    return jnp.einsum("ecf,efd->ecd", h, w2)


def _get_ffn(rules: Optional[ShardingRules]) -> Callable:
    if rules is not None and rules.use_kernel:
        from repro.kernels import ops
        return ops.fused_moe_ffn
    return expert_ffn_ref


def _get_ragged_ffn(rules: Optional[ShardingRules]) -> Callable:
    """Grouped FFN over a flat expert-sorted buffer + per-tile expert ids."""
    if rules is not None and rules.use_kernel:
        from repro.kernels import ops
        return ops.ragged_moe_ffn
    from repro.kernels.ref import ragged_moe_ffn_ref
    return ragged_moe_ffn_ref


def _sort_by_slot(slot_flat: jnp.ndarray, n_slots: int,
                  active: Optional[jnp.ndarray] = None):
    """Sort-based bucketing core shared by every dispatch path.

    Stable-argsorts the (A,) assignment→slot map (inactive assignments get
    the sentinel key ``n_slots`` so they sort past every real slot) and
    finds each slot's segment boundaries with ``searchsorted`` — O(A log A)
    instead of the old one-hot/cumsum O(A × n_slots).

    Returns ``(order, sorted_key, starts, pos_sorted)``:

    * ``order`` (A,) — assignment index in slot-sorted order (stable, so
      within a slot the original arrival order is preserved);
    * ``sorted_key`` (A,) — slot id per sorted assignment (``n_slots`` =
      inactive);
    * ``starts`` (n_slots + 1,) — segment start per slot;
      ``starts[n_slots]`` is where the inactive tail begins;
    * ``pos_sorted`` (A,) — arrival position within the slot's segment.
    """
    key = slot_flat.astype(jnp.int32)
    if active is not None:
        key = jnp.where(active, key, n_slots)
    order = jnp.argsort(key)
    sorted_key = key[order]
    starts = jnp.searchsorted(
        sorted_key, jnp.arange(n_slots + 1, dtype=jnp.int32),
        side="left").astype(jnp.int32)
    pos_sorted = (jnp.arange(slot_flat.shape[0], dtype=jnp.int32)
                  - starts[sorted_key])
    return order, sorted_key, starts, pos_sorted


def _bucket_positions(slot_flat: jnp.ndarray, n_slots: int,
                      active: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Arrival position of each assignment within its slot's bucket.

    ``slot_flat``: (A,) slot id per assignment; ``active``: (A,) bool mask —
    inactive assignments consume no capacity. Sort-based (``_sort_by_slot``);
    the stable sort preserves arrival order, so positions are bit-identical
    to the old one-hot/cumsum build at O(A log A) instead of O(A × n_slots).
    Positions of inactive assignments are meaningless (callers mask them).
    """
    order, _, _, pos_sorted = _sort_by_slot(slot_flat, n_slots, active)
    return jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)


def _ragged_plan(slot_flat: jnp.ndarray, n_slots: int, bm: int,
                 active: Optional[jnp.ndarray] = None):
    """Sort-based dropless dispatch plan (the ragged hot path's metadata).

    Lays every assignment into a flat expert-sorted buffer whose per-slot
    segments are padded to multiples of the row tile ``bm`` (group-aligned:
    each (bm, D) tile belongs to exactly one slot; empty slots own zero
    tiles). All shapes are static worst-case bounds; the data-dependent part
    is *values only*, so the plan jits.

    Returns ``(order, rows, tile_group, n_rows)``:

    * ``order`` (A,) — assignment index in slot-sorted order;
    * ``rows`` (A,) — buffer row per *sorted* assignment; inactive
      assignments get ``n_rows`` (out of bounds → scatters drop them,
      gathers clamp and callers mask them);
    * ``tile_group`` (n_tiles,) — owning slot per tile, sentinel
      ``n_slots`` for unoccupied tiles (the grouped FFN skips those);
    * ``n_rows`` — static buffer row count (``ragged_n_tiles(A) × bm``).
    """
    from repro.kernels.ragged_moe_ffn import (ragged_n_tiles,
                                              ragged_tile_metadata)
    A = slot_flat.shape[0]
    order, sorted_key, starts, pos_sorted = _sort_by_slot(
        slot_flat, n_slots, active)
    sizes = jnp.diff(starts)                         # (n_slots,)
    n_tiles = ragged_n_tiles(A, n_slots, bm)
    n_rows = n_tiles * bm
    row_off, tile_group = ragged_tile_metadata(sizes, bm, n_tiles)
    rows = jnp.where(
        sorted_key < n_slots,
        row_off[jnp.minimum(sorted_key, n_slots - 1)] + pos_sorted,
        n_rows)
    return order, rows, tile_group, n_rows


#: Knuth multiplicative-hash constant: odd, so ``i * KNUTH mod 2^32`` is an
#: equidistributed (Weyl) sequence over uint32 — successive assignment
#: positions cover [0, 1) with low discrepancy, decorrelated from position.
_HASH_MULT = np.uint32(2654435761)
#: odd stride for the per-step salt: for a fixed assignment index, varying
#: the seed walks its own Weyl sequence, so traffic aggregated *across*
#: steps converges too (a decode batch has only t·K ≈ tens of assignments
#: per step — without the salt those few uniforms would repeat forever and
#: quantize the realized shares).
_SEED_MULT = np.uint32(2246822519)


def _assignment_uniforms(t: int, K: int, seed=None) -> jnp.ndarray:
    """Deterministic per-assignment uniforms u ∈ [0, 1) → (t, K) f32.

    Top 24 bits of a multiplicative hash of the flat assignment index
    (offset by ``seed``, an int32 scalar that callers vary per step), so
    every value is exactly representable in float32 and strictly < 1.
    """
    i = jnp.arange(t * K, dtype=jnp.uint32)
    if seed is not None:
        i = i + jnp.asarray(seed).astype(jnp.uint32) * _SEED_MULT
    h = i * _HASH_MULT
    u = (h >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0 ** -24)
    return u.reshape(t, K)


def _select_slots(idx: jnp.ndarray, slots_of: jnp.ndarray,
                  n_copies: jnp.ndarray,
                  copy_cdf: Optional[jnp.ndarray] = None,
                  route_seed=None) -> jnp.ndarray:
    """Map logical ids (t, K) to physical slots across replicas.

    With ``copy_cdf`` (E, r_max) — the cumulative per-copy traffic shares
    from the placement solver — each assignment draws a deterministic,
    position-decorrelated uniform and picks its copy by inverse CDF, so
    realized per-copy traffic converges to the solver's shares (ViBE-R
    phase 3 honored by the actual dispatch, not just the objective).
    ``route_seed`` (int32 scalar) salts the hash; the model threads a
    step-varying value through so tiny decode batches converge across
    steps rather than replaying one fixed set of uniforms.
    ``copy_cdf=None`` keeps the legacy uniform ``% n_copies`` hash (the
    share-oblivious path the parity suite uses as its regression tripwire).
    """
    t, K = idx.shape
    r_max = slots_of.shape[-1]
    if r_max == 1:
        return slots_of[:, 0][idx]
    if copy_cdf is None:
        copy = (jnp.arange(t * K, dtype=jnp.int32).reshape(t, K)) \
            % n_copies[idx]
    else:
        u = _assignment_uniforms(t, K, route_seed)
        # smallest r with u < cdf[r]; trailing entries are 1.0 > u, and the
        # min() guards f32 round-up of a copy's cumulative share past u
        copy = jnp.sum(u[:, :, None] >= copy_cdf[idx], axis=-1,
                       dtype=jnp.int32)
        copy = jnp.minimum(copy, n_copies[idx] - 1)
    return slots_of[idx, copy]


# ---------------------------------------------------------------------------
# dense (reference) dispatch
# ---------------------------------------------------------------------------

def _dense_dispatch(p, xf, route_seed, *, top_k, n_experts, slots_of,
                    n_copies, copy_cdf, row_valid=None):
    weights, idx, mean_prob = route(p["router"], xf, top_k)
    if row_valid is not None:
        # padded rows (chunked prefill): no gate weight, no tally — they
        # must be invisible to both the output and the routing telemetry
        weights = weights * row_valid[:, None].astype(weights.dtype)
    slots = _select_slots(idx, slots_of, n_copies, copy_cdf,
                          route_seed)                   # (t, K) physical
    n_slots = p["w1"].shape[0]
    # scatter gate weights into a (t, n_slots) combine matrix
    comb = jnp.zeros((xf.shape[0], n_slots), jnp.float32).at[
        jnp.arange(xf.shape[0])[:, None], slots].add(weights)
    y = expert_ffn_ref(p["w1"], p["w3"], p["w2"],
                       jnp.broadcast_to(xf, (n_slots,) + xf.shape))
    out = jnp.einsum("te,etd->td", comb, y.astype(jnp.float32))
    tally = _masked_tally(idx, n_experts, row_valid)
    aux = _aux_loss(tally, mean_prob, n_experts)
    # dense computes every expert on every token: nothing can be dropped
    tally = jnp.concatenate([tally, jnp.zeros((1,), jnp.float32)])
    return out.astype(xf.dtype), tally, aux


def _masked_tally(idx, n_experts, row_valid=None):
    oh = jax.nn.one_hot(idx, n_experts, dtype=jnp.float32)
    if row_valid is not None:
        oh = oh * row_valid[:, None, None].astype(jnp.float32)
    return oh.sum((0, 1))


def _aux_loss(tally, mean_prob, n_experts):
    frac = tally / jnp.maximum(tally.sum(), 1.0)
    return n_experts * jnp.dot(frac, mean_prob)


# ---------------------------------------------------------------------------
# ragged (dropless) dispatch
# ---------------------------------------------------------------------------

def _ragged_local_ffn(xf, tok_flat, wgt_flat, slot_flat, active, n_groups,
                      bm, ffn, w1, w3, w2):
    """Sorted-buffer grouped FFN + weighted combine for local assignments.

    Builds the ragged plan over ``slot_flat``, scatters each (active)
    assignment's token row into the flat expert-sorted buffer, runs the
    grouped FFN over occupied tiles, and scatter-adds the gate-weighted
    results back per token. Inactive assignments land out of bounds (their
    scatters drop, their gathers clamp and are zero-weighted). Returns the
    (t, D) f32 partial output — dropless by construction.
    """
    t, D = xf.shape
    order, rows, tile_group, n_rows = _ragged_plan(slot_flat, n_groups, bm,
                                                   active)
    tok_s = tok_flat[order]
    buf = jnp.zeros((n_rows, D), xf.dtype).at[rows].set(
        xf[tok_s], mode="drop")
    y_buf = ffn(w1, w3, w2, buf, tile_group)
    wgt_s = wgt_flat[order]
    if active is not None:
        wgt_s = wgt_s * active[order].astype(wgt_s.dtype)
    contrib = (y_buf[jnp.minimum(rows, n_rows - 1)].astype(jnp.float32)
               * wgt_s[:, None])
    return jnp.zeros((t, D), jnp.float32).at[tok_s].add(contrib)


def _dense_dispatch_ragged(p, xf, route_seed, *, top_k, n_experts, slots_of,
                           n_copies, copy_cdf, bm, ffn, row_valid=None):
    """Single-device ragged dispatch: compute each assignment exactly once
    (A = t·top_k rows) instead of the dense oracle's every-expert-on-every-
    token broadcast. Same return contract as ``_dense_dispatch``."""
    weights, idx, mean_prob = route(p["router"], xf, top_k)
    if row_valid is not None:
        weights = weights * row_valid[:, None].astype(weights.dtype)
    slots = _select_slots(idx, slots_of, n_copies, copy_cdf, route_seed)
    n_slots = p["w1"].shape[0]
    t = xf.shape[0]
    tok_flat = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)
    out = _ragged_local_ffn(xf, tok_flat, weights.reshape(-1),
                            slots.reshape(-1), None, n_slots, bm, ffn,
                            p["w1"], p["w3"], p["w2"])
    tally = _masked_tally(idx, n_experts, row_valid)
    aux = _aux_loss(tally, mean_prob, n_experts)
    tally = jnp.concatenate([tally, jnp.zeros((1,), jnp.float32)])
    return out.astype(xf.dtype), tally, aux


def _a2a_body_ragged(xb, router_w, w1, w3, w2, slots_of, n_copies, copy_cdf,
                     route_seed, *, top_k, n_experts, n_slots, bm, ep,
                     ep_axes, dp_axes, fsdp_axes, ffn):
    """Dropless a2a dispatch: sorted per-destination frames + ragged FFN.

    The exchange cannot be ragged itself (``lax.all_to_all`` needs equal
    splits), so instead of per-*slot* capacity buckets the send buffer holds
    one fixed frame of A = t_loc·top_k rows per destination rank — the
    worst case (every local assignment routed to one rank), so nothing can
    ever overflow. Assignments are slot-sorted (slots are rank-major, so
    one sort orders by destination rank *and* groups by slot), packed into
    their destination frame, and their local-slot ids ride along in a
    parallel int frame. The receiver re-sorts the ep·A incoming rows by
    local slot and runs the grouped FFN over occupied tiles only; results
    return through the mirror-image exchange. Memory trades against the
    capacity path: frames total ep·A rows vs ``n_slots·capacity ≈ A·cf``
    on the send side, but the FFN computes only realized tokens and the
    tally's drop column is structurally zero.
    """
    Bl, Sl, D = xb.shape
    e_loc = n_slots // ep
    if fsdp_axes:
        w1 = jax.lax.all_gather(w1, fsdp_axes, axis=1, tiled=True)
        w3 = jax.lax.all_gather(w3, fsdp_axes, axis=1, tiled=True)
        w2 = jax.lax.all_gather(w2, fsdp_axes, axis=1, tiled=True)

    xf = xb.reshape(Bl * Sl, D)
    t = xf.shape[0]
    weights, idx, mean_prob = route(router_w, xf, top_k)
    slots = _select_slots(idx, slots_of, n_copies, copy_cdf, route_seed)
    slot_flat = slots.reshape(-1)
    wgt_flat = weights.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)
    A = t * top_k

    # sorted send: slot-major order == (dest rank, local slot) order, so
    # one shared sort plan yields the rank segments too (slots are
    # rank-major: rank r's segment starts where slot r·e_loc does)
    order, ss, starts, _ = _sort_by_slot(slot_flat, n_slots)
    rank_sorted = ss // e_loc
    rank_starts = starts[jnp.arange(ep + 1, dtype=jnp.int32) * e_loc]
    pos_in_rank = jnp.arange(A, dtype=jnp.int32) - rank_starts[rank_sorted]
    send_row = rank_sorted * A + pos_in_rank
    send = jnp.zeros((ep * A, D), xf.dtype).at[send_row].set(
        xf[tok_flat[order]])
    # local-slot ids per frame row; e_loc = padding sentinel
    loc_ids = jnp.full((ep * A,), e_loc, jnp.int32).at[send_row].set(
        ss % e_loc)

    a2a_axes = ep_axes[0] if len(ep_axes) == 1 else ep_axes
    recv = jax.lax.all_to_all(send.reshape(ep, A, D), a2a_axes,
                              split_axis=0, concat_axis=0)
    rloc = jax.lax.all_to_all(loc_ids.reshape(ep, A), a2a_axes,
                              split_axis=0, concat_axis=0).reshape(-1)

    # receiver: compact ep·A frame rows into the slot-sorted ragged buffer
    R = ep * A
    order2, rows2, tile_group, n_rows = _ragged_plan(
        rloc, e_loc, bm, active=rloc < e_loc)
    buf = jnp.zeros((n_rows, D), xf.dtype).at[rows2].set(
        recv.reshape(R, D)[order2], mode="drop")
    y_buf = ffn(w1, w3, w2, buf, tile_group)
    # un-sort back into frame layout (padding rows stay zero) and return
    row_of_recv = jnp.full((R,), n_rows, jnp.int32).at[order2].set(rows2)
    y_recv = (y_buf[jnp.minimum(row_of_recv, n_rows - 1)]
              * (rloc < e_loc)[:, None].astype(y_buf.dtype))
    back = jax.lax.all_to_all(y_recv.reshape(ep, A, D), a2a_axes,
                              split_axis=0, concat_axis=0).reshape(R, D)

    contrib = (back[send_row].astype(jnp.float32)
               * wgt_flat[order][:, None])
    out = jnp.zeros((t, D), jnp.float32).at[tok_flat[order]].add(contrib)

    tally = jax.nn.one_hot(idx, n_experts, dtype=jnp.float32).sum((0, 1))
    tally = jnp.concatenate([tally, jnp.zeros((1,))])   # dropless: tally[E]=0
    tally = jax.lax.psum(tally, ep_axes + dp_axes)
    mean_prob = jax.lax.pmean(mean_prob, ep_axes + dp_axes)
    aux = _aux_loss(tally[:n_experts], mean_prob, n_experts)
    return out.astype(xb.dtype).reshape(Bl, Sl, D), tally, aux


def _replicated_body_ragged(xb, router_w, w1, w3, w2, slots_of, n_copies,
                            copy_cdf, route_seed, *, top_k, n_experts,
                            n_slots, bm, ep_axes, ep_sizes, ffn,
                            psum_axes=None):
    """Dropless decode path: each device ragged-computes its own slots.

    Same replication scheme as ``_replicated_body`` (tokens fleet-wide,
    psum combine), but local assignments go through the sorted ragged
    buffer instead of fixed capacity buckets — the buffer's static bound
    covers *all* A assignments landing on one device, so nothing drops.
    """
    B, S, D = xb.shape
    e_loc = w1.shape[0]
    psum_axes = psum_axes or ep_axes
    my_rank = jnp.int32(0)
    for a, sz in zip(ep_axes, ep_sizes):
        my_rank = my_rank * sz + jax.lax.axis_index(a)

    xf = xb.reshape(B * S, D)
    t = xf.shape[0]
    weights, idx, mean_prob = route(router_w, xf, top_k)
    slots = _select_slots(idx, slots_of, n_copies, copy_cdf, route_seed)
    slot_flat = slots.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)

    mine = (slot_flat // e_loc) == my_rank
    out = _ragged_local_ffn(xf, tok_flat, weights.reshape(-1),
                            slot_flat % e_loc, mine, e_loc, bm, ffn,
                            w1, w3, w2)
    out = jax.lax.psum(out, psum_axes)

    tally = jax.nn.one_hot(idx, n_experts, dtype=jnp.float32).sum((0, 1))
    aux = _aux_loss(tally, mean_prob, n_experts)
    tally = jnp.concatenate([tally, jnp.zeros((1,))])   # dropless: tally[E]=0
    return out.astype(xb.dtype).reshape(B, S, D), tally, aux


# ---------------------------------------------------------------------------
# a2a dispatch (train / prefill)
# ---------------------------------------------------------------------------

def _a2a_body(xb, router_w, w1, w3, w2, slots_of, n_copies, copy_cdf,
              route_seed, *, top_k, n_experts, n_slots, capacity, ep,
              ep_axes, dp_axes, fsdp_axes, ffn):
    """Per-device block of the a2a EP MoE layer.

    xb: (B_loc, S_loc, D). Expert weights arrive sharded (E_loc, D/f, F)
    with axis 1 FSDP-sharded; gathered here (ZeRO-3, transposes to
    reduce-scatter in the backward). ``ep`` is the static EP group size
    (mesh shape is known at trace time; old JAX has no lax.axis_size).
    """
    Bl, Sl, D = xb.shape
    e_loc = n_slots // ep
    if fsdp_axes:
        w1 = jax.lax.all_gather(w1, fsdp_axes, axis=1, tiled=True)
        w3 = jax.lax.all_gather(w3, fsdp_axes, axis=1, tiled=True)
        w2 = jax.lax.all_gather(w2, fsdp_axes, axis=1, tiled=True)

    xf = xb.reshape(Bl * Sl, D)
    t = xf.shape[0]
    weights, idx, mean_prob = route(router_w, xf, top_k)
    slots = _select_slots(idx, slots_of, n_copies, copy_cdf,
                          route_seed)                   # (t, K)
    slot_flat = slots.reshape(-1)
    wgt_flat = weights.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)

    pos = _bucket_positions(slot_flat, n_slots)
    keep = pos < capacity
    dest = slot_flat * capacity + jnp.where(keep, pos, 0)
    send = jnp.zeros((n_slots * capacity, D), xf.dtype)
    send = send.at[dest].add(xf[tok_flat] * keep[:, None].astype(xf.dtype))

    # dispatch: (ep, E_loc, C, D) — chunk i goes to EP rank i
    send = send.reshape(ep, e_loc, capacity, D)
    a2a_axes = ep_axes[0] if len(ep_axes) == 1 else ep_axes
    recv = jax.lax.all_to_all(send, a2a_axes, split_axis=0, concat_axis=0)
    # recv[j] = tokens from source rank j for my local experts
    toks = jnp.moveaxis(recv, 0, 1).reshape(e_loc, ep * capacity, D)
    y = ffn(w1, w3, w2, toks)                                # (E_loc, ep·C, D)
    y = jnp.moveaxis(y.reshape(e_loc, ep, capacity, D), 1, 0)
    back = jax.lax.all_to_all(y, a2a_axes, split_axis=0, concat_axis=0)
    back = back.reshape(n_slots * capacity, D)               # my sends, processed

    contrib = (back[dest].astype(jnp.float32)
               * (wgt_flat * keep)[:, None])
    out = jnp.zeros((t, D), jnp.float32).at[tok_flat].add(contrib)

    tally = jax.nn.one_hot(idx, n_experts, dtype=jnp.float32).sum((0, 1))
    # capacity-overflow accounting: assignments past a slot's bucket are
    # zeroed above; surface the count instead of dropping them silently
    dropped = jnp.sum(1.0 - keep.astype(jnp.float32))[None]
    tally = jnp.concatenate([tally, dropped])
    tally = jax.lax.psum(tally, ep_axes + dp_axes)
    mean_prob = jax.lax.pmean(mean_prob, ep_axes + dp_axes)
    aux = _aux_loss(tally[:n_experts], mean_prob, n_experts)
    return out.astype(xb.dtype).reshape(Bl, Sl, D), tally, aux


# ---------------------------------------------------------------------------
# replicated dispatch (decode)
# ---------------------------------------------------------------------------

def _replicated_body(xb, router_w, w1, w3, w2, slots_of, n_copies, copy_cdf,
                     route_seed, *, top_k, n_experts, n_slots, capacity,
                     ep_axes, ep_sizes, ffn, psum_axes=None):
    """Tokens replicated fleet-wide; each device computes its slots only.

    With expert-TP (big experts) the local w1/w3 carry an F-slice and w2 the
    matching rows: y is a partial sum over F, folded in by the wider psum.
    ``ep_sizes`` are the static mesh sizes of ``ep_axes`` (same order).
    """
    B, S, D = xb.shape
    e_loc = w1.shape[0]
    psum_axes = psum_axes or ep_axes
    my_rank = jnp.int32(0)
    for a, sz in zip(ep_axes, ep_sizes):
        my_rank = my_rank * sz + jax.lax.axis_index(a)

    xf = xb.reshape(B * S, D)
    t = xf.shape[0]
    weights, idx, mean_prob = route(router_w, xf, top_k)
    slots = _select_slots(idx, slots_of, n_copies, copy_cdf, route_seed)
    slot_flat = slots.reshape(-1)
    wgt_flat = weights.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)

    mine = (slot_flat // e_loc) == my_rank
    loc = slot_flat % e_loc
    pos = _bucket_positions(loc, e_loc, active=mine)
    keep = mine & (pos >= 0) & (pos < capacity)
    dest = loc * capacity + jnp.where(keep, pos, 0)
    buckets = jnp.zeros((e_loc * capacity, D), xf.dtype)
    buckets = buckets.at[dest].add(xf[tok_flat] * keep[:, None].astype(xf.dtype))

    y = ffn(w1, w3, w2, buckets.reshape(e_loc, capacity, D))
    y = y.reshape(e_loc * capacity, D)
    contrib = y[dest].astype(jnp.float32) * (wgt_flat * keep)[:, None]
    out = jnp.zeros((t, D), jnp.float32).at[tok_flat].add(contrib)
    out = jax.lax.psum(out, psum_axes)

    tally = jax.nn.one_hot(idx, n_experts, dtype=jnp.float32).sum((0, 1))
    aux = _aux_loss(tally, mean_prob, n_experts)
    # local capacity overflow (each device drops its own bucket excess);
    # psum over the slot axes only — expert-TP ranks see duplicate drops
    dropped = jnp.sum((mine & (pos >= capacity)).astype(jnp.float32))[None]
    dropped = jax.lax.psum(dropped, ep_axes)
    tally = jnp.concatenate([tally, dropped])
    return out.astype(xb.dtype).reshape(B, S, D), tally, aux


# ---------------------------------------------------------------------------
# public layer
# ---------------------------------------------------------------------------

def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def moe_layer(
    p,
    x: jnp.ndarray,                    # (B, S, D)
    *,
    top_k: int,
    n_experts: int,
    rules: Optional[ShardingRules] = None,
    slots_of: Optional[jnp.ndarray] = None,     # (E, r_max) physical lookup
    n_copies: Optional[jnp.ndarray] = None,     # (E,)
    copy_cdf: Optional[jnp.ndarray] = None,     # (E, r_max) cumulative shares
    route_seed=None,                   # int32 scalar salt (varies per step)
    phase: str = "train",              # "train" | "prefill" | "decode"
    row_valid: Optional[jnp.ndarray] = None,    # (B·S,) bool — chunk padding
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,S,D), tally (E+1,), aux_loss).

    ``row_valid`` masks padded token rows (a chunked-prefill tail chunk):
    masked rows get zero gate weight and contribute nothing to the tally,
    so the routing telemetry the virtual clock prices stays honest.
    Supported on the single-device dense paths only (the serving engine's
    configuration); mesh dispatch with a row mask is not implemented.

    ``tally[:E]`` — logical-expert routing counts (pre-capacity, so each
    token contributes exactly top_k); ``tally[E]`` — assignments dropped by
    the capacity buckets this pass (0 on the dense path).

    ``copy_cdf`` carries the placement solver's per-copy traffic shares
    (cumulative, from ``make_moe_tables``/``build_copy_cdf``); replicas are
    then traffic-weighted by inverse-CDF selection. None = uniform split
    over copies — correct for round-robin duplication, share-oblivious for
    ViBE-R placements. ``route_seed`` decorrelates the selection across
    steps (the model passes a position-derived salt) so small decode
    batches don't replay one fixed uniform set forever.
    """
    B, S, D = x.shape
    n_slots = p["w1"].shape[0]
    if slots_of is None:
        slots_of = jnp.arange(n_experts, dtype=jnp.int32)[:, None]
    if n_copies is None:
        n_copies = jnp.ones((n_experts,), jnp.int32)
    if copy_cdf is None:
        # uniform fallback: copy r of expert e covers ((r+1)/n_copies[e])
        r_pad = slots_of.shape[-1]
        copy_cdf = jnp.minimum(
            jnp.arange(1, r_pad + 1, dtype=jnp.float32)[None, :]
            / jnp.maximum(n_copies[:, None].astype(jnp.float32), 1.0), 1.0)
    if route_seed is None:
        route_seed = jnp.int32(0)
    route_seed = jnp.asarray(route_seed).astype(jnp.int32)

    mode = "dense"
    impl = "capacity" if rules is None else rules.moe_impl_resolved
    if rules is not None and rules.mesh is not None:
        if rules.moe_dispatch in ("a2a", "replicated", "dense"):
            mode = rules.moe_dispatch
        elif phase == "decode":
            mode = "replicated"
        else:
            mode = "a2a"
        if mode == "a2a" and S % max(rules.ep_size, 1) != 0:
            mode = "replicated"

    if mode == "dense":
        if rules is not None and impl == "ragged":
            out, tally, aux = _dense_dispatch_ragged(
                p, x.reshape(B * S, D), route_seed, top_k=top_k,
                n_experts=n_experts, slots_of=slots_of, n_copies=n_copies,
                copy_cdf=copy_cdf, bm=rules.moe_block_m,
                ffn=_get_ragged_ffn(rules), row_valid=row_valid)
        else:
            out, tally, aux = _dense_dispatch(
                p, x.reshape(B * S, D), route_seed, top_k=top_k,
                n_experts=n_experts, slots_of=slots_of, n_copies=n_copies,
                copy_cdf=copy_cdf, row_valid=row_valid)
        return out.reshape(B, S, D), tally, aux

    if row_valid is not None:
        raise NotImplementedError(
            "row_valid (chunked-prefill padding mask) is only supported on "
            "the single-device dense dispatch paths")

    cf = rules.capacity_factor
    bm = rules.moe_block_m
    ffn = _get_ragged_ffn(rules) if impl == "ragged" else _get_ffn(rules)
    mesh = rules.mesh
    if mode == "a2a":
        ep_axes, dp_axes = rules.ep_axes, rules.dp_axes
        fsdp_axes = tuple(a for a in ((rules.fsdp,) if isinstance(rules.fsdp, str)
                                      else (rules.fsdp or ()))
                          if a in mesh.axis_names)
        ep = rules.ep_size
        t_loc = (B // max(rules.axis_size(dp_axes), 1)) * (S // ep)
        capacity = _round_up(max(int(np.ceil(t_loc * top_k / n_slots * cf)), 1), 4)
        x = rules.constrain(x, rules.dp, rules.ep[0] if len(rules.ep) == 1 else rules.ep, None)
        if impl == "ragged":
            body = functools.partial(
                _a2a_body_ragged, top_k=top_k, n_experts=n_experts,
                n_slots=n_slots, bm=bm, ep=ep, ep_axes=ep_axes,
                dp_axes=dp_axes, fsdp_axes=fsdp_axes, ffn=ffn)
        else:
            body = functools.partial(
                _a2a_body, top_k=top_k, n_experts=n_experts, n_slots=n_slots,
                capacity=capacity, ep=ep, ep_axes=ep_axes, dp_axes=dp_axes,
                fsdp_axes=fsdp_axes, ffn=ffn)
        ep_spec = ep_axes[0] if len(ep_axes) == 1 else ep_axes
        w_spec = P(ep_spec, fsdp_axes if fsdp_axes else None, None)
        out, tally, aux = compat.shard_map(
            body, mesh=mesh,
            in_specs=(P(dp_axes if dp_axes else None, ep_spec, None),
                      P(None, None), w_spec, w_spec,
                      P(ep_spec, fsdp_axes if fsdp_axes else None, None),
                      P(None, None), P(None), P(None, None), P()),
            out_specs=(P(dp_axes if dp_axes else None, ep_spec, None),
                       P(None), P()),
        )(x, p["router"], p["w1"], p["w3"], p["w2"], slots_of, n_copies,
          copy_cdf, route_seed)
        return out, tally, aux

    # replicated decode: one-or-few slots per device across the whole fleet
    # (expert-TP variant: slots over `ep` only, F sliced over the dp axes)
    if rules.decode_expert_tp:
        ep_axes = rules.ep_axes
        ftp_axes = tuple(a for a in rules.ep_all_axes if a not in ep_axes)
    else:
        ep_axes = rules.ep_all_axes
        ftp_axes = ()
    fleet = rules.axis_size(ep_axes)
    t = B * S
    capacity = _round_up(
        max(int(np.ceil(t * top_k / n_slots * max(cf, 2.0))), 4), 4)
    ep_spec = ep_axes if len(ep_axes) > 1 else (ep_axes[0] if ep_axes else None)
    ftp_spec = (ftp_axes if len(ftp_axes) > 1 else
                (ftp_axes[0] if ftp_axes else None))
    if impl == "ragged":
        body = functools.partial(
            _replicated_body_ragged, top_k=top_k, n_experts=n_experts,
            n_slots=n_slots, bm=bm, ep_axes=ep_axes,
            ep_sizes=tuple(rules.axis_size(a) for a in ep_axes), ffn=ffn,
            psum_axes=ep_axes + ftp_axes)
    else:
        body = functools.partial(
            _replicated_body, top_k=top_k, n_experts=n_experts,
            n_slots=n_slots, capacity=capacity, ep_axes=ep_axes,
            ep_sizes=tuple(rules.axis_size(a) for a in ep_axes), ffn=ffn,
            psum_axes=ep_axes + ftp_axes)
    out, tally, aux = compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None, None), P(None, None),
                  P(ep_spec, None, ftp_spec), P(ep_spec, None, ftp_spec),
                  P(ep_spec, ftp_spec, None), P(None, None), P(None),
                  P(None, None), P()),
        out_specs=(P(None, None, None), P(None), P()),
    )(x, p["router"], p["w1"], p["w3"], p["w2"], slots_of, n_copies,
      copy_cdf, route_seed)
    return out, tally, aux


# ---------------------------------------------------------------------------
# placement application (weight migration)
# ---------------------------------------------------------------------------

def _first_slot_of(perm: np.ndarray, n_ids: int) -> np.ndarray:
    """inv[l, e] = first (lowest) slot in ``perm[l]`` holding id e, -1 if
    absent. Vectorized first-occurrence build: numpy fancy assignment lets
    the *last* write win, so feeding slots in descending order makes slot 0
    the survivor — identical to the old per-slot Python scan."""
    L, NS = perm.shape
    inv = np.full((L, n_ids), -1, dtype=np.int32)
    desc = np.arange(NS - 1, -1, -1, dtype=np.int32)
    inv[np.arange(L)[:, None], perm[:, ::-1]] = desc[None, :]
    return inv


def placement_gather_indices(old_perm: np.ndarray,
                             new_perm: np.ndarray) -> np.ndarray:
    """gather_idx[l, p] = old slot whose weights must land in new slot p.

    Fully vectorized (scatter-build of the expert→first-slot inverse plus
    one gather); runs on every engine recalibration, so no Python O(L·NS)
    loops. Bit-identical to the historical loop build (tests pin this).
    """
    old_perm = np.atleast_2d(old_perm)
    new_perm = np.atleast_2d(new_perm)
    L, NS = old_perm.shape
    n_ids = int(max(old_perm.max(), new_perm.max())) + 1
    inv = _first_slot_of(old_perm, n_ids)
    src = inv[np.arange(L)[:, None], new_perm]                  # (L, NS)
    return np.where(src >= 0, src,
                    np.arange(NS, dtype=np.int32)[None, :]).astype(np.int32)


@functools.partial(jax.jit, donate_argnums=0)
def _gather_experts(leaf: jnp.ndarray, gather_idx: jnp.ndarray) -> jnp.ndarray:
    # leaf (L, n_slots, ...) ← leaf[l, gather_idx[l]]
    return jnp.take_along_axis(
        leaf, gather_idx.reshape(gather_idx.shape + (1,) * (leaf.ndim - 2)),
        axis=1)


def apply_placement(expert_params: dict, old_perm: np.ndarray,
                    new_perm: np.ndarray) -> Tuple[dict, int]:
    """Migrate stacked expert weights from one slot permutation to another.

    Returns (new params, number of (layer, slot) tensors that moved) — the
    paper's weight-transfer volume; the incremental solver's swap list makes
    this O(#swaps) instead of O(L·E).
    """
    gi = placement_gather_indices(old_perm, new_perm)
    moved = int((gi != np.arange(gi.shape[1])[None, :]).sum())
    out = dict(expert_params)
    for k in ("w1", "w2", "w3"):
        if k in out:
            out[k] = _gather_experts(out[k], jnp.asarray(gi))
    return out, moved


def expand_experts(expert_params: dict, perm_a2a: np.ndarray,
                   perm_dec: np.ndarray) -> dict:
    """Build decode-fleet expert tensors (replicated slots) from the a2a
    layout: decode slot p holds logical expert perm_dec[l, p], fetched from
    the a2a slot holding that expert. Vectorized like
    :func:`placement_gather_indices` (the old dict build also kept the
    first a2a slot per expert); a decode expert absent from the a2a layout
    is an error, as before."""
    perm_dec = np.atleast_2d(perm_dec)
    perm_a2a = np.atleast_2d(perm_a2a)
    L, ns_dec = perm_dec.shape
    n_ids = int(max(perm_a2a.max(), perm_dec.max())) + 1
    inv = _first_slot_of(perm_a2a, n_ids)
    gi = inv[np.arange(L)[:, None], perm_dec]
    if (gi < 0).any():
        missing = sorted(set(perm_dec[gi < 0].tolist()))
        raise KeyError(f"decode experts absent from a2a layout: {missing}")
    gi = gi.astype(np.int32)
    out = dict(expert_params)
    for k in ("w1", "w2", "w3"):
        if k in out:
            out[k] = jnp.take_along_axis(
                out[k], jnp.asarray(gi).reshape(gi.shape + (1,) * (out[k].ndim - 2)),
                axis=1)
    return out
