# Pure-JAX model zoo: shared blocks, attention (quadratic oracle + flash),
# MoE with EP dispatch, recurrent mixers, and the per-arch assembly.
from .sharding import ShardingRules, build_copy_cdf, build_slots_of
from .model import (block_layout, decode_fn, init_cache, init_params,
                    loss_fn, make_moe_tables, moe_perm_shape,
                    prefill_chunk_fn, prefill_fn, count_params,
                    refresh_moe_share_tables)

__all__ = [
    "ShardingRules", "build_copy_cdf", "build_slots_of",
    "block_layout", "decode_fn", "init_cache", "init_params", "loss_fn",
    "make_moe_tables", "moe_perm_shape", "prefill_chunk_fn", "prefill_fn",
    "count_params", "refresh_moe_share_tables",
]
