"""GQA attention: train/prefill (optionally sliding-window) + KV-cache decode.

Sharding modes (DESIGN.md §5) are expressed as GSPMD constraints so the same
code lowers on 1 device and on the production mesh:

* ``heads``   — q/kv heads sharded over the TP axis (divisible archs).
* ``context`` — q sharded over sequence, K/V gathered (non-divisible heads:
  smollm 15H, gemma3 8H, starcoder2 36H, granite 24H). XLA inserts the
  all-gather; decode shards the KV *cache* over sequence and the softmax
  reductions become cross-shard psums (flash-decode structure, GSPMD-native).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import apply_rope, dense_init, maybe_constrain, rope_tables

__all__ = ["AttnSharding", "attn_init", "attention", "decode_attention",
           "init_kv_cache"]


@dataclasses.dataclass(frozen=True)
class AttnSharding:
    mode: str = "none"               # "heads" | "context" | "none"
    dp: tuple = ("pod", "data")      # batch axes
    tp: str = "model"                # head/TP axis

    @property
    def q_spec(self):                # (B, S, H, hd)
        if self.mode == "heads":
            return P(self.dp, None, self.tp, None)
        if self.mode == "context":
            return P(self.dp, self.tp, None, None)
        return P(self.dp, None, None, None)

    @property
    def kv_spec(self):               # (B, S, KV, hd) — gathered in context mode
        if self.mode == "heads":
            return P(self.dp, None, self.tp, None)
        return P(self.dp, None, None, None)

    @property
    def cache_spec(self):            # (B, S_max, KV, hd): decode KV cache
        if self.mode == "heads":
            return P(self.dp, None, self.tp, None)
        if self.mode == "context":
            return P(self.dp, self.tp, None, None)   # seq-sharded cache
        return P(self.dp, None, None, None)


def attn_init(key, d: int, n_heads: int, n_kv: int, hd: int,
              dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, n_heads * hd, dtype),
        "wk": dense_init(ks[1], d, n_kv * hd, dtype),
        "wv": dense_init(ks[2], d, n_kv * hd, dtype),
        "wo": dense_init(ks[3], n_heads * hd, d, dtype),
    }


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def attention(p, x: jnp.ndarray, *, n_heads: int, n_kv: int, hd: int,
              rope_theta: float, causal: bool = True,
              window: Optional[jnp.ndarray] = None,
              sharding: AttnSharding = AttnSharding(),
              positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full-sequence attention (train / prefill).

    ``window``: scalar (possibly traced) sliding-window size; None/0 = full.
    Window is data, not structure, so local/global gemma3 layers share one
    scanned HLO body.
    """
    B, S, D = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]                    # (1, S)
    cos, sin = rope_tables(positions, hd, rope_theta)

    q = _split_heads(jnp.einsum("bsd,dh->bsh", x, p["wq"]), n_heads, hd)
    k = _split_heads(jnp.einsum("bsd,dh->bsh", x, p["wk"]), n_kv, hd)
    v = _split_heads(jnp.einsum("bsd,dh->bsh", x, p["wv"]), n_kv, hd)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = maybe_constrain(q, sharding.q_spec)
    k = maybe_constrain(k, sharding.kv_spec)
    v = maybe_constrain(v, sharding.kv_spec)

    group = n_heads // n_kv
    qg = q.reshape(B, S, n_kv, group, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(hd, jnp.float32))

    qpos = positions[:, :, None] if positions.ndim == 2 else positions[..., None]
    kpos = positions[:, None, :] if positions.ndim == 2 else positions[..., None, :]
    mask = jnp.ones((B if positions.shape[0] == B else 1, S, S), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        w = jnp.asarray(window)
        mask = mask & jnp.where(w > 0, (qpos - kpos) < w, True)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)

    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v).reshape(B, S, n_heads * hd)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"])


def init_kv_cache(n_layers: int, batch: int, max_seq: int, n_kv: int,
                  hd: int, dtype=jnp.bfloat16):
    shape = (n_layers, batch, max_seq, n_kv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_attention(p, x: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, pos: jnp.ndarray, *,
                     n_heads: int, n_kv: int, hd: int, rope_theta: float,
                     window: Optional[jnp.ndarray] = None,
                     sharding: AttnSharding = AttnSharding()):
    """One-token decode against a (B, S_max, KV, hd) cache at position ``pos``.

    Returns (out (B, 1, D), new_k, new_v). The new K/V row is written with a
    dynamic_update_slice; masking handles the not-yet-filled tail. In
    ``context`` mode the cache is sequence-sharded and the softmax reductions
    lower to cross-shard psums (flash-decode).
    """
    B, one, D = x.shape
    S_max = k_cache.shape[1]
    cos, sin = rope_tables(pos[None, None], hd, rope_theta)   # (1,1,hd/2)

    q = _split_heads(jnp.einsum("bsd,dh->bsh", x, p["wq"]), n_heads, hd)
    k_new = _split_heads(jnp.einsum("bsd,dh->bsh", x, p["wk"]), n_kv, hd)
    v_new = _split_heads(jnp.einsum("bsd,dh->bsh", x, p["wv"]), n_kv, hd)
    q = apply_rope(q, cos, sin)
    k_new = apply_rope(k_new, cos, sin)

    k_cache = jax.lax.dynamic_update_slice(k_cache, k_new, (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_new, (0, pos, 0, 0))
    k_cache = maybe_constrain(k_cache, sharding.cache_spec)
    v_cache = maybe_constrain(v_cache, sharding.cache_spec)

    group = n_heads // n_kv
    qg = q.reshape(B, n_kv, group, hd)                        # (B,KV,G,hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(hd, jnp.float32))

    kpos = jnp.arange(S_max)
    valid = kpos <= pos
    if window is not None:
        w = jnp.asarray(window)
        valid = valid & jnp.where(w > 0, (pos - kpos) < w, True)
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, v_cache)
    out = out.reshape(B, 1, n_heads * hd)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"]), k_cache, v_cache
