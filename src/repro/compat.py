"""JAX version compatibility layer.

The codebase targets the modern mesh/shard_map surface (``jax.shard_map``
with ``check_vma``, ``jax.set_mesh``, ``jax.sharding.AxisType``); CI and
some dev containers pin older JAX (0.4.x) where those names either do not
exist or are spelled differently. Every version-sensitive call goes through
this module so the rest of the tree stays on one idiom:

* :func:`shard_map`          — ``jax.shard_map(check_vma=False)`` on new JAX,
                               ``jax.experimental.shard_map.shard_map(check_rep=False)``
                               on 0.4.x (same semantics: skip the replication
                               / varying-manual-axes check).
* :func:`use_mesh`           — ``jax.set_mesh(mesh)`` context on new JAX;
                               on 0.4.x ``Mesh`` itself is the context
                               manager that installs the resource env.
* :func:`make_mesh`          — ``jax.make_mesh`` with ``axis_types`` only
                               where the kwarg (and ``AxisType``) exist;
                               0.4.x meshes are implicitly Auto.
* :func:`cost_analysis_dict` — ``Compiled.cost_analysis()`` returns a dict
                               on new JAX but a one-element list of dicts on
                               0.4.x; normalize to a dict.

``jax.lax.axis_size`` also does not exist on 0.4.x; shard_map bodies that
need axis sizes receive them statically from the caller (the mesh shape is
always known at trace time) instead of querying the axis env.
"""

from __future__ import annotations

import contextlib
from typing import Dict, Sequence

import jax

__all__ = ["shard_map", "use_mesh", "make_mesh", "cost_analysis_dict"]


def shard_map(f, *, mesh, in_specs, out_specs):
    """Version-portable ``shard_map`` with replication checking disabled."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def use_mesh(mesh):
    """Context manager installing ``mesh`` for named sharding constraints."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh
    return contextlib.nullcontext(mesh)  # pragma: no cover - AbstractMesh


def make_mesh(shape: Sequence[int], axes: Sequence[str], devices=None):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    kw = {}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kw["axis_types"] = (axis_type.Auto,) * len(axes)
    if devices is not None:
        kw["devices"] = devices
    return jax.make_mesh(tuple(shape), tuple(axes), **kw)


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """Normalize ``Compiled.cost_analysis()`` across JAX versions.

    Old versions return ``[{...}]`` (one dict per device program); new ones
    return ``{...}`` directly. Missing/empty analyses normalize to ``{}``.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}
