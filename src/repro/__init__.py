"""ViBE reproduction: variability-aware MoE serving (control plane + JAX
data plane). See README.md for the layout and ROADMAP.md for direction."""
