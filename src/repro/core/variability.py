"""Hardware variability models (paper §2, §3 Challenge 2, §5.5).

The paper measures per-GPU throughput asymmetry on real AMD nodes: up to 7%
fused-MoE kernel-time spread on MI325X, milder on MI300X, and a synthetic
"skewed" regime with one device degraded 13% via a modified V-F curve. The
defining property (Fig 5) is *stress dependence*: variability is latent at low
utilization (decode) and activates when the workload pushes devices to their
power envelope (prefill).

This module provides the cluster-level stand-in used by the discrete-event
simulator and the benchmarks: a :class:`ClusterVariability` that yields one
ground-truth latency function per device, with presets matching the paper's
measured regimes plus a conservative TPU projection.

Ground-truth per-device latency (seconds) for token load n:

    lat_g(n) = t_base + max(w_bytes/BW, 2*n*d*f*3 / (PEAK * speed_g(n)))

where ``speed_g(n)`` interpolates between 1.0 (unstressed) and the device's
intrinsic speed factor as utilization approaches the power envelope:

    speed_g(n) = 1 - (1 - base_speed_g) * stress(n)
    stress(n)  = clip(n / n_tdp, 0, 1) ** stress_gamma

so at low load all devices look identical (paper Fig 5 decode) and at high
load the full process-variation spread is exposed (prefill). ViBE never sees
this ground truth — it only observes profiled (n, latency) samples, exactly
like the real system.

**Time-varying hardware (§4.2.4 "performance estimates" refresh):** the
cluster additionally carries a schedule of :class:`VariabilityEvent`\\ s, so
the ground truth itself can drift: a thermal throttle ramping one device
down, a fleet-wide power-cap step, transient neighbor interference, or a
device replacement that changes a rank's intrinsic speed bin. ``latency``
(and the simulator's vectorized twin) take the virtual-clock time ``t``;
with no events the cluster is static and behaves exactly as before. Named
scenario presets (:data:`SCENARIOS`, :func:`make_scenario`) back the
hardware-drift benchmarks and the ``serve --variability-scenario`` flag.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from .perf_model import DeviceProfile, PerfModel, fit_perf_model, profile_device

__all__ = [
    "VariabilityRegime",
    "VariabilityEvent",
    "ClusterVariability",
    "REGIMES",
    "SCENARIOS",
    "make_cluster",
    "make_scenario",
]


@dataclasses.dataclass(frozen=True)
class VariabilityRegime:
    """Distribution of per-device intrinsic speed factors."""

    name: str
    # intrinsic speed factors are sampled as 1 - |N(0, sigma)| truncated,
    # optionally with explicit per-device overrides (e.g. skewed GPU 0).
    sigma: float
    max_slowdown: float              # truncation: slowest device speed
    overrides: Optional[Dict[int, float]] = None
    stress_gamma: float = 2.0        # how sharply variability activates
    throttle: float = 0.30          # fleet-wide frequency drop at full stress
    # Paper Fig 5: "sustained power saturation reduces GPU frequency by 38%
    # on average for MoE layers" — that base throttle hits every device; the
    # device-specific sigma spread rides on top of it. Effective speed:
    #   speed_g(n) = 1 − (throttle + (1 − base_speed_g)) · stress(n)

    def sample_speeds(self, n_devices: int, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        draw = np.abs(rng.normal(0.0, self.sigma, size=n_devices))
        speeds = np.clip(1.0 - draw, self.max_slowdown, 1.0)
        if self.overrides:
            for dev, s in self.overrides.items():
                if dev < n_devices:
                    speeds[dev] = s
        return speeds


@dataclasses.dataclass(frozen=True)
class VariabilityEvent:
    """One scheduled change to the cluster's ground-truth behaviour.

    ``kind``:

    * ``"ramp"``      — gradual slowdown: the device's effective speed is
      multiplied by a factor going linearly 1 → (1 − magnitude) over
      [t_start, t_start + duration], then holding (thermal throttle).
    * ``"step"``      — instantaneous permanent slowdown by ``magnitude``
      from ``t_start`` on (power-cap change).
    * ``"transient"`` — slowdown by ``magnitude`` only during
      [t_start, t_start + duration) (neighbor interference, shared-fabric
      contention), full recovery afterwards.
    * ``"replace"``   — the device's *intrinsic* speed bin (its entry in
      ``ClusterVariability.speeds``) becomes ``magnitude`` from ``t_start``
      on: a swapped part from a different process-variation bin. Unlike the
      multiplicative kinds this only shows under stress, exactly like the
      static spread.

    ``device`` is an EP rank index, or None for the whole fleet (only
    meaningful for the multiplicative kinds).
    """

    kind: str                        # "ramp" | "step" | "transient" | "replace"
    t_start: float
    magnitude: float                 # fractional slowdown; "replace": new speed
    device: Optional[int] = None     # None = every device
    duration: float = 0.0            # ramp length / transient length

    def __post_init__(self):
        if self.kind not in ("ramp", "step", "transient", "replace"):
            raise ValueError(f"unknown VariabilityEvent kind {self.kind!r}")
        if self.kind == "replace":
            if self.device is None:
                raise ValueError("replace events need a specific device")
            if not 0.0 < self.magnitude <= 1.0:
                raise ValueError("replace magnitude is the new intrinsic "
                                 f"speed in (0, 1], got {self.magnitude}")
        elif not 0.0 <= self.magnitude < 1.0:
            raise ValueError(f"{self.kind} magnitude must be a fractional "
                             f"slowdown in [0, 1), got {self.magnitude}")

    def multiplier(self, t: float) -> float:
        """Effective-speed multiplier this event contributes at time ``t``
        (1.0 = inactive; "replace" events always return 1.0 here)."""
        if self.kind == "replace" or t < self.t_start:
            return 1.0
        if self.kind == "step":
            return 1.0 - self.magnitude
        if self.kind == "transient":
            return (1.0 - self.magnitude
                    if t < self.t_start + self.duration else 1.0)
        # ramp
        if self.duration <= 0.0 or t >= self.t_start + self.duration:
            return 1.0 - self.magnitude
        frac = (t - self.t_start) / self.duration
        return 1.0 - self.magnitude * frac


#: Named hardware-drift scenarios for benchmarks / ``serve``. Each maps to a
#: builder ``f(n_devices, t0, duration) -> List[VariabilityEvent]``; default
#: magnitudes are calibrated to be clearly detectable (≫ jitter_sigma) while
#: staying within the paper's measured throttling range.
SCENARIOS: Dict[str, Callable[..., List[VariabilityEvent]]] = {}


def _scenario(name):
    def reg(fn):
        SCENARIOS[name] = fn
        return fn
    return reg


@_scenario("thermal-ramp")
def _thermal_ramp(n_devices, t0, duration, magnitude=0.30):
    # one device gradually throttles (clogged heatsink / thermal paste aging)
    return [VariabilityEvent("ramp", t0, magnitude, device=0,
                             duration=duration)]


@_scenario("power-cap")
def _power_cap(n_devices, t0, duration, magnitude=0.15):
    # facility lowers the fleet power cap: every device steps down at once
    return [VariabilityEvent("step", t0, magnitude, device=None)]


@_scenario("interference")
def _interference(n_devices, t0, duration, magnitude=0.35):
    # a co-located tenant hammers shared fabric next to the last rank,
    # then goes away
    return [VariabilityEvent("transient", t0, magnitude,
                             device=n_devices - 1, duration=duration)]


@_scenario("device-replace")
def _device_replace(n_devices, t0, duration, magnitude=0.86):
    # rank 0's board is swapped for a part from a slower V-F bin
    return [VariabilityEvent("replace", t0, magnitude, device=0)]


def make_scenario(name: str, n_devices: int, t0: float = 1.0,
                  duration: float = 4.0, **kw) -> List[VariabilityEvent]:
    """Build the event schedule for a named hardware-drift scenario."""
    try:
        builder = SCENARIOS[name]
    except KeyError:
        raise ValueError(f"unknown variability scenario {name!r}; known: "
                         f"{', '.join(sorted(SCENARIOS))}") from None
    return builder(n_devices, t0, duration, **kw)


#: Paper-measured regimes (§3, §5.5) + TPU projection (DESIGN.md §3).
REGIMES: Dict[str, VariabilityRegime] = {
    # MI325X node: up to ~7% kernel-time variation under balanced load
    # (§1, §3). Calibrated so an 8-device draw yields ≈7% spread in *kernel
    # time* at full stress: max|N(0, .035)| over 8 ≈ .046 deviation,
    # ratio (1−.30)/(1−.30−.046) ≈ 1.07. See benchmarks/bench_fig13.
    "mi325x": VariabilityRegime("mi325x", sigma=0.035, max_slowdown=0.94,
                                throttle=0.30),
    # MI300X node: lower variability (§5.5 Fig 13a)
    "mi300x": VariabilityRegime("mi300x", sigma=0.012, max_slowdown=0.97,
                                throttle=0.25),
    # Skewed: GPU 0 degraded 13% via modified V-F curve (§5.5 Fig 13b)
    "skewed": VariabilityRegime("skewed", sigma=0.028, max_slowdown=0.93,
                                overrides={0: 0.87}, throttle=0.30),
    # Conservative TPU v5e projection: narrower binning spread, mild thermal
    "tpu-v5e": VariabilityRegime("tpu-v5e", sigma=0.012, max_slowdown=0.965,
                                 throttle=0.05),
    # Homogeneous control (EPLB's implicit assumption): throttling still
    # happens, identically on every device
    "uniform": VariabilityRegime("uniform", sigma=0.0, max_slowdown=1.0,
                                 throttle=0.30),
}

#: Per-platform hardware magnitudes (effective, not peak-datasheet):
#: peak FLOP/s at serving dtype, HBM bandwidth, scale-up link bandwidth,
#: and the per-rank token load where the power envelope binds (paper §3:
#: 1024-in × bs16 ⇒ ~2k tokens/rank holds MI325X at TDP 82.8% of the time).
#: ici_bw is the per-device *aggregate* scale-up bandwidth (all links used
#: concurrently by an all-to-all): MI3xx full-mesh xGMI ≈ 7×64 GB/s,
#: v5e 2-D torus ≈ 4 usable × 45 GB/s. peak_flops is *effective sustained*
#: FP8 throughput for the fused MoE GEMMs (datasheet peak × ~0.4 MoE-shape
#: MXU efficiency), so simulated step times land at the paper's absolute
#: scale (sonnet saturation ~2–3.5 QPS/GPU on 8×MI325X).
HW_PRESETS: Dict[str, Dict[str, float]] = {
    "mi325x": dict(peak_flops=0.55e15, hbm_bw=6.0e12, ici_bw=448e9,
                   n_tdp=2048.0),
    "mi300x": dict(peak_flops=0.45e15, hbm_bw=5.3e12, ici_bw=448e9,
                   n_tdp=2048.0),
    "skewed": dict(peak_flops=0.55e15, hbm_bw=6.0e12, ici_bw=448e9,
                   n_tdp=2048.0),
    "tpu-v5e": dict(peak_flops=100e12, hbm_bw=819e9, ici_bw=180e9,
                    n_tdp=4096.0),
    "uniform": dict(peak_flops=0.55e15, hbm_bw=6.0e12, ici_bw=448e9,
                    n_tdp=2048.0),
}


@dataclasses.dataclass
class ClusterVariability:
    """Ground-truth latency oracle for a cluster of ``n_devices`` EP ranks.

    Parameters mirror an MoE expert shard: d_model, d_ff, n local experts —
    these set the compute/memory magnitudes so simulated latencies have
    realistic scale and a realistic memory-bound floor.
    """

    n_devices: int
    speeds: np.ndarray               # (G,) intrinsic speed factors in (0,1]
    events: List[VariabilityEvent] = dataclasses.field(default_factory=list)
    # time-varying drift schedule; empty = static cluster (historical
    # behaviour; every ``t`` parameter below is then irrelevant)
    d_model: int = 7168
    d_ff: int = 2048
    experts_per_rank: int = 32
    peak_flops: float = 197e12       # effective FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s
    ici_bw: float = 50e9             # scale-up link bytes/s (a2a path)
    t_base: float = 8e-6             # dispatch overhead
    n_tdp: float = 4096.0            # token load where power envelope binds
    stress_gamma: float = 2.0
    throttle: float = 0.30           # fleet-wide frequency drop at full stress
    jitter_sigma: float = 0.01       # per-invocation measurement noise
    _rng: np.random.Generator = dataclasses.field(
        default_factory=lambda: np.random.default_rng(1234))

    @property
    def weight_bytes(self) -> float:
        # SwiGLU expert: 3 matrices d_model×d_ff, bf16
        return self.experts_per_rank * 3 * self.d_model * self.d_ff * 2.0

    def stress(self, n: float) -> float:
        return float(np.clip(n / self.n_tdp, 0.0, 1.0) ** self.stress_gamma)

    # -- time-varying ground truth ------------------------------------------

    def base_speeds_at(self, t: float = 0.0) -> np.ndarray:
        """(G,) intrinsic speed bins at time ``t`` ("replace" events)."""
        sp = np.asarray(self.speeds, dtype=np.float64)
        if not self.events:
            return sp
        sp = sp.copy()
        # by t_start, not list order: the most recent replacement wins
        for ev in sorted(self.events, key=lambda e: e.t_start):
            if ev.kind == "replace" and t >= ev.t_start:
                sp[ev.device] = ev.magnitude
        return sp

    def multipliers_at(self, t: float = 0.0) -> np.ndarray:
        """(G,) product of active events' effective-speed multipliers."""
        m = np.ones(self.n_devices, dtype=np.float64)
        for ev in self.events:
            f = ev.multiplier(t)
            if f == 1.0:
                continue
            if ev.device is None:
                m *= f
            else:
                m[ev.device] *= f
        return m

    def effective_speed(self, device_id: int, n: float,
                        t: float = 0.0) -> float:
        """1 at rest; (1 − throttle − device deviation) at full stress,
        further scaled by whatever drift events are active at time ``t``."""
        base = float(self.base_speeds_at(t)[device_id])
        mult = float(self.multipliers_at(t)[device_id])
        speed = (1.0 - (self.throttle + (1.0 - base)) * self.stress(n)) * mult
        return max(speed, 0.1)

    def latency(self, device_id: int, n: float, t: float = 0.0,
                jitter: bool = False) -> float:
        """Ground-truth fused-MoE latency for n tokens on one rank at
        virtual-clock time ``t``.

        DVFS throttling divides the *whole* kernel by the effective speed —
        a frequency drop slows the fabric and scheduling as well as the MXU,
        matching the paper's observation of whole-kernel-time spread (§3).
        """
        n = float(max(n, 0.0))
        flops = 2.0 * n * self.d_model * self.d_ff * 3.0  # 3 GEMMs (SwiGLU)
        t_mem = self.weight_bytes / self.hbm_bw
        t_cmp = flops / self.peak_flops
        lat = (self.t_base
               + max(t_mem, t_cmp) / self.effective_speed(device_id, n, t))
        if jitter and self.jitter_sigma > 0:
            lat *= float(1.0 + self._rng.normal(0.0, self.jitter_sigma))
        return max(lat, 1e-9)

    # -- profiling interface (what ViBE is allowed to see) ------------------

    def profile_all(self, token_counts=(64, 128, 256, 512, 1024, 2048, 4096,
                                         8192, 16384),
                    repeats: int = 3, t: float = 0.0) -> List[DeviceProfile]:
        fn = lambda g, n: self.latency(g, n, t=t, jitter=True)
        return [profile_device(fn, g, token_counts, repeats)
                for g in range(self.n_devices)]

    def fit_models(self, t: float = 0.0, **kw) -> List[PerfModel]:
        """Profile-and-fit at virtual-clock time ``t`` (Phase 1; an oracle
        re-profile of a drifted cluster passes the post-drift time)."""
        return [fit_perf_model(p, **kw)
                for p in self.profile_all(t=t, **kw_pop(kw))]


def kw_pop(kw):
    # profile_all kwargs pass-through helper (fit_perf_model takes n_knots)
    out = {}
    for k in ("token_counts", "repeats"):
        if k in kw:
            out[k] = kw.pop(k)
    return out


def make_cluster(
    n_devices: int,
    regime: str = "mi325x",
    seed: int = 0,
    **overrides,
) -> ClusterVariability:
    """Build a ground-truth cluster for a named variability regime.

    The regime name also selects the platform's hardware magnitudes
    (HW_PRESETS); any explicit keyword overrides them.
    """
    r = REGIMES[regime]
    speeds = r.sample_speeds(n_devices, seed=seed)
    kw = dict(HW_PRESETS.get(regime, {}))
    kw.update(overrides)
    return ClusterVariability(
        n_devices=n_devices,
        speeds=speeds,
        stress_gamma=r.stress_gamma,
        throttle=r.throttle,
        **kw,
    )
