"""ViBE end-to-end controller (paper Algorithm 1, Appendix A.1).

Ties the four components together across the three phases:

  Phase 1 (offline):  profile each EP rank → f_g(n); run representative
                      workload → activation matrix W.
  Phase 2 (initial):  vibe_placement(W, {f_g}).
  Phase 3 (online):   every H forward passes check drift; on trigger refresh
                      W from recent routing, run the incremental solver,
                      snapshot the reference, cool down.

The controller is engine-agnostic: the serving engine feeds it per-step
routing tallies + observed batch token counts and asks for the current
placement; when a recalibration fires, the controller returns a
:class:`PlacementUpdate` whose swap list doubles as the weight-migration
plan (bytes accounted for the paper's transfer-volume comparison).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from .activation import ActivationProfiler
from .drift import DriftConfig, DriftDetector, DriftEvent
from .incremental import (IncrementalResult, incremental_update,
                          incremental_update_replicated)
from .perf_model import PerfModel
from .placement import Placement, ReplicatedPlacement, solve_model_placement

__all__ = ["ViBEConfig", "PlacementUpdate", "ViBEController"]

#: policies that consume per-device performance models
_PERF_POLICIES = ("vibe", "vibe_r")


@dataclasses.dataclass(frozen=True)
class ViBEConfig:
    policy: str = "vibe"              # "vibe" | "vibe_r" | "eplb" | "contiguous"
    adaptive: bool = True             # Phase 3 on/off (paper: static vs adaptive)
    drift: DriftConfig = dataclasses.field(default_factory=DriftConfig)
    epsilon: float = 0.03             # incremental solver tolerance
    expert_bytes: int = 0             # per-expert weight bytes (migration cost)
    full_resolve_on_stress: bool = True
    # stress drift changes f_g's operating point → re-solve from scratch is
    # allowed there (the paper's magnitude-aware recalibration); routing-only
    # drift uses the minimal-movement incremental solver.
    slots_per_rank: Optional[int] = None
    # vibe_r only: physical slot budget per rank (≥ ceil(E/G)); the excess
    # slots hold hot-expert replicas. None = placement.default_slots_per_rank.
    reweight_shares: bool = False
    # vibe_r only: after an incremental (swap-based) recalibration,
    # re-proportion each expert's copy shares to the speeds of the ranks its
    # copies landed on (placement.reweight_shares_by_speed) so the weighted
    # dispatch keeps steering traffic toward fast copies.


@dataclasses.dataclass(frozen=True)
class PlacementUpdate:
    step: int
    event: DriftEvent
    placement: Placement
    moved_experts: int
    migration_bytes: int
    swaps_per_layer: Optional[np.ndarray] = None
    full_resolve: bool = False


class ViBEController:
    def __init__(
        self,
        n_layers: int,
        n_experts: int,
        n_ranks: int,
        perf_models: Sequence[PerfModel],
        config: ViBEConfig = ViBEConfig(),
        initial_w: Optional[np.ndarray] = None,
    ):
        if len(perf_models) != n_ranks:
            raise ValueError("one perf model per EP rank required")
        self.cfg = config
        self.L, self.E, self.G = n_layers, n_experts, n_ranks
        self.perf_models = list(perf_models)
        self.profiler = ActivationProfiler(n_layers, n_experts,
                                           window=config.drift.window)
        self.detector = DriftDetector(n_layers, n_experts, config.drift)
        w0 = (np.atleast_2d(initial_w) if initial_w is not None
              else np.full((n_layers, n_experts), 1.0 / n_experts))
        self.placement = self._solve(w0)
        self._step = 0
        self.updates: List[PlacementUpdate] = []

    # ------------------------------------------------------------------
    def _solve(self, w: np.ndarray):
        """Full placement solve with this controller's policy and knobs."""
        return solve_model_placement(
            self.cfg.policy, w, self.G,
            perf_models=(self.perf_models
                         if self.cfg.policy in _PERF_POLICIES else None),
            slots_per_rank=self.cfg.slots_per_rank)

    # ------------------------------------------------------------------
    @property
    def step(self) -> int:
        return self._step

    def observe(self, step_counts: np.ndarray,
                tokens: Optional[float] = None) -> Optional[PlacementUpdate]:
        """Feed one forward pass; returns an update when recalibration fires.

        ``step_counts``: (L, E) routing tallies for this pass.
        ``tokens``: batch token count (defaults to layer-0 tally sum).
        """
        self._step += 1
        step_counts = np.asarray(step_counts, dtype=np.float64)
        self.profiler.update(step_counts)
        if tokens is None:
            tokens = float(step_counts[0].sum())
        if not self.cfg.adaptive or self.cfg.policy == "contiguous":
            # still track (so static-vs-adaptive comparisons share stats)
            self.detector.observe(step_counts, tokens)
            return None
        event = self.detector.observe(step_counts, tokens)
        if event is None:
            return None
        return self._recalibrate(event)

    # ------------------------------------------------------------------
    def _recalibrate(self, event: DriftEvent) -> PlacementUpdate:
        w = self.profiler.window_matrix()
        old = self.placement
        if event.kind == "stress" and self.cfg.full_resolve_on_stress:
            # magnitude shift: operating point of every f_g moved → full
            # re-solve at the new stress level (still same machinery).
            # ``moved_experts`` counts changed (layer, slot) residents, so
            # for vibe_r every migrated *copy* is charged expert_bytes.
            new = self._solve(w)
            moved = new.moved_experts(old)
            upd = PlacementUpdate(
                step=self._step, event=event, placement=new,
                moved_experts=moved,
                migration_bytes=moved * self.cfg.expert_bytes,
                full_resolve=True)
        elif self.cfg.policy in _PERF_POLICIES:
            if self.cfg.policy == "vibe_r":
                res: IncrementalResult = incremental_update_replicated(
                    old, w, self.perf_models, epsilon=self.cfg.epsilon,
                    reweight_shares=self.cfg.reweight_shares)
            else:
                res = incremental_update(
                    old, w, self.perf_models, epsilon=self.cfg.epsilon)
            new, moved = res.placement, res.moved_expert_count()
            upd = PlacementUpdate(
                step=self._step, event=event, placement=new,
                moved_experts=moved,
                migration_bytes=moved * self.cfg.expert_bytes,
                swaps_per_layer=res.per_layer_swaps)
        else:  # eplb-style full greedy re-solve (the paper's contrast)
            new = self._solve(w)
            moved = new.moved_experts(old)
            upd = PlacementUpdate(
                step=self._step, event=event, placement=new,
                moved_experts=moved,
                migration_bytes=moved * self.cfg.expert_bytes,
                full_resolve=True)
        self.placement = upd.placement
        self.detector.snapshot()
        self.updates.append(upd)
        return upd
