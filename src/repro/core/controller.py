"""ViBE end-to-end controller (paper Algorithm 1, Appendix A.1).

Ties the four components together across the three phases:

  Phase 1 (offline):  profile each EP rank → f_g(n); run representative
                      workload → activation matrix W.
  Phase 2 (initial):  registry policy solve over the SolveContext.
  Phase 3 (online):   every H forward passes check drift; on trigger refresh
                      W from recent routing, recalibrate (capability-gated:
                      policies advertising ``supports_incremental`` refine
                      with minimal-movement swaps, others re-solve in full),
                      snapshot the reference, cool down.

Phase 3 watches **both** halves of the paper's recalibration story: routing
drift over the activation matrix (``observe``) and *performance* drift over
the fitted f_g models (``observe_latency`` — per-rank (load, latency)
telemetry fed back from the serving virtual clock or real kernel timers).
A perf-drift event refits the affected ranks' models from the telemetry
window (:func:`~repro.core.perf_model.refit_from_samples`), rebuilds the
SolveContext with the refreshed models, and recalibrates; on the
incremental path ``reweight_shares_by_speed`` then consumes the refreshed
speeds, so traffic shares chase the hardware's *current* behaviour.

The controller is engine-agnostic: the serving engine feeds it per-step
routing tallies + observed batch token counts and asks for the current
placement; when a recalibration fires, the controller returns a
:class:`PlacementUpdate` whose swap list doubles as the weight-migration
plan (bytes accounted for the paper's transfer-volume comparison).

The placement policy is resolved from the registry
(:mod:`repro.core.policy`) by name — the controller never compares policy
names itself; every branch reads :class:`PolicyCapabilities` flags, so a
newly registered policy works here unchanged. Placements are always the
unified :class:`ReplicatedPlacement` representation (singleton policies
yield the r_max = 1 degenerate).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from .activation import ActivationProfiler
from .drift import (DriftConfig, DriftDetector, DriftEvent, PerfDriftConfig,
                    PerfDriftDetector, PerfDriftEvent)
from .incremental import IncrementalResult
from .perf_model import PerfModel
from .placement import ReplicatedPlacement
from .policy import PlacementPolicy, SolveContext, get_policy
from .steal import StealConfig, TokenRescheduler
from .topology import ClusterTopology

__all__ = ["ViBEConfig", "PlacementUpdate", "FailEvent", "ViBEController"]


@dataclasses.dataclass(frozen=True)
class ViBEConfig:
    policy: str = "vibe"              # any name in repro.core.policy registry
    adaptive: bool = True             # Phase 3 on/off (paper: static vs adaptive)
    drift: DriftConfig = dataclasses.field(default_factory=DriftConfig)
    perf_drift: Optional[PerfDriftConfig] = None
    # None disables performance-drift monitoring (routing-only Phase 3, the
    # pre-drift-refresh behaviour). Set a PerfDriftConfig to watch observed
    # per-rank latencies against the fitted f_g models and refit + recalibrate
    # when the relative residual exceeds delta_perf on any rank.
    epsilon: float = 0.03             # incremental solver tolerance
    expert_bytes: int = 0             # per-expert weight bytes (migration cost)
    full_resolve_on_stress: bool = True
    # stress drift changes f_g's operating point → re-solve from scratch is
    # allowed there (the paper's magnitude-aware recalibration); routing-only
    # drift uses the minimal-movement incremental solver.
    slot_budget: Union[None, int, Sequence[int], np.ndarray] = None
    # physical slot budget per rank for replication-capable policies: a
    # scalar (uniform) or a (G,) array (non-uniform, device memory
    # headroom). None = the policy's default. Only valid when the policy's
    # capabilities report accepts_slot_budget.
    slots_per_rank: Union[None, int, Sequence[int], np.ndarray] = None
    # deprecated alias of slot_budget (the published pre-registry kwarg);
    # the two are merged in __post_init__ and read identically afterwards.
    reweight_shares: bool = False
    # replicated policies only: after an incremental (swap-based)
    # recalibration, re-proportion each expert's copy shares to the speeds
    # of the ranks its copies landed on (placement.reweight_shares_by_speed)
    # so the weighted dispatch keeps steering traffic toward fast copies.
    steal: Optional[StealConfig] = None
    # dispatch-time token rescheduling (core/steal.py): between
    # recalibrations, shift bounded traffic shares away from the rank whose
    # predicted latency exceeds the fleet mean by the configured headroom,
    # toward sibling replica copies on faster ranks. Operates even with
    # adaptive=False (it is orthogonal to recalibration — exactly the
    # stale-profile regime it exists for). Requires a replication-capable
    # policy: without copies there is nowhere to shift share.
    topology: Optional[ClusterTopology] = None
    # fleet topology (core/topology.py): node structure + ICI/DCN link
    # asymmetry, threaded into every SolveContext so topology-aware
    # policies (vibe_h) bin experts by node. None = flat cluster — every
    # pre-existing policy behaves identically either way.

    # -- validated against the registered policy's capabilities -----------
    def __post_init__(self):
        if self.slots_per_rank is not None:
            if self.slot_budget is not None and not np.array_equal(
                    np.asarray(self.slot_budget),
                    np.asarray(self.slots_per_rank)):
                raise ValueError("pass slot_budget or its deprecated alias "
                                 "slots_per_rank, not conflicting both")
            object.__setattr__(self, "slot_budget", self.slots_per_rank)
        else:
            object.__setattr__(self, "slots_per_rank", self.slot_budget)
        caps = get_policy(self.policy).capabilities   # raises on unknown name
        if self.perf_drift is not None and not caps.needs_perf_models:
            # such a policy never reads f_g — refitting the models could
            # never change its placement, so the monitor would be inert
            raise ValueError(
                f"perf_drift set, but policy {self.policy!r} has "
                "capabilities.needs_perf_models=False — refreshed perf "
                "models would never influence its placement")
        if self.slot_budget is not None and not caps.accepts_slot_budget:
            raise ValueError(
                f"slot_budget set, but policy {self.policy!r} has "
                "capabilities.accepts_slot_budget=False — the budget would "
                "be silently ignored")
        if self.reweight_shares and not (caps.supports_replication
                                         and caps.supports_incremental):
            # the reweight only executes on the incremental refine path, so
            # accepting it for a policy that never refines (or has no copy
            # shares at all) would be silently inert
            raise ValueError(
                f"reweight_shares=True, but policy {self.policy!r} lacks "
                "supports_replication+supports_incremental — the flag "
                "would never take effect")
        if self.steal is not None and not caps.supports_replication:
            # stealing reweights *copy* shares; a singleton placement has
            # one copy per expert, so every steal would cancel — inert
            raise ValueError(
                f"steal set, but policy {self.policy!r} has "
                "capabilities.supports_replication=False — a singleton "
                "placement has no replica copies to shift share between")


@dataclasses.dataclass(frozen=True)
class FailEvent:
    """A rank (or several) left the fleet — device failure, scheduled
    replace, or elastic shrink. Triggers a topology-masked full re-solve
    through :meth:`ViBEController.mask_ranks`."""

    ranks: Tuple[int, ...]
    kind: str = "fail"


@dataclasses.dataclass(frozen=True)
class PlacementUpdate:
    step: int
    event: Union[DriftEvent, PerfDriftEvent, FailEvent]
    placement: ReplicatedPlacement
    moved_experts: int
    migration_bytes: int
    swaps_per_layer: Optional[np.ndarray] = None
    full_resolve: bool = False
    refit_ranks: Tuple[int, ...] = ()   # ranks whose f_g was refreshed
    #                                     ("perf" events only)

    @property
    def kind(self) -> str:
        """Which signal triggered this update:
        "routing" | "stress" | "perf" | "fail"."""
        return self.event.kind


class ViBEController:
    def __init__(
        self,
        n_layers: int,
        n_experts: int,
        n_ranks: int,
        perf_models: Sequence[PerfModel],
        config: ViBEConfig = ViBEConfig(),
        initial_w: Optional[np.ndarray] = None,
    ):
        if len(perf_models) != n_ranks:
            raise ValueError("one perf model per EP rank required")
        if config.topology is not None \
                and config.topology.n_ranks != n_ranks:
            raise ValueError(f"topology has {config.topology.n_ranks} ranks "
                             f"but the controller manages {n_ranks}")
        self.cfg = config
        self.policy: PlacementPolicy = get_policy(config.policy)
        self.L, self.E, self.G = n_layers, n_experts, n_ranks
        self.dead_ranks: Tuple[int, ...] = ()
        self.perf_models = list(perf_models)
        self.profiler = ActivationProfiler(n_layers, n_experts,
                                           window=config.drift.window)
        self.detector = DriftDetector(n_layers, n_experts, config.drift)
        # perf-drift detector shares self.perf_models BY REFERENCE: its
        # refit() replaces entries in place, so _context() always reads the
        # freshest f_g without a copy protocol
        self.perf_detector = (
            PerfDriftDetector(n_ranks, self.perf_models, config.perf_drift)
            if config.perf_drift is not None else None)
        w0 = (np.atleast_2d(initial_w) if initial_w is not None
              else np.full((n_layers, n_experts), 1.0 / n_experts))
        self.placement: ReplicatedPlacement = self._solve(w0)
        # dispatch-time work stealing: shares self.perf_models BY REFERENCE
        # (like perf_detector) so online refits retune the steal trigger
        self.rescheduler = (TokenRescheduler(config.steal, self.perf_models)
                            if config.steal is not None else None)
        if self.rescheduler is not None:
            self.rescheduler.reset(self.placement)
        self._step = 0
        self.updates: List[PlacementUpdate] = []

    # ------------------------------------------------------------------
    def _context(self, w: np.ndarray) -> SolveContext:
        """SolveContext carrying this controller's knobs and profiles."""
        caps = self.policy.capabilities
        return SolveContext(
            w=w, n_ranks=self.G,
            perf_models=self.perf_models if caps.needs_perf_models else None,
            slot_budget=self.cfg.slot_budget,
            epsilon=self.cfg.epsilon,
            reweight_shares=self.cfg.reweight_shares,
            topology=self.cfg.topology,
            dead_ranks=self.dead_ranks or None)

    def _solve(self, w: np.ndarray) -> ReplicatedPlacement:
        """Full placement solve with this controller's policy and knobs."""
        return self.policy.solve(self._context(w))

    # ------------------------------------------------------------------
    @property
    def step(self) -> int:
        return self._step

    @property
    def dispatch_placement(self) -> ReplicatedPlacement:
        """What dispatch should route against *right now*: the responsive
        (steal-adjusted) placement when stealing is on, else the plan.
        Same slot table either way — only traffic shares differ."""
        if self.rescheduler is not None:
            return self.rescheduler.placement
        return self.placement

    def observe(self, step_counts: np.ndarray,
                tokens: Optional[float] = None) -> Optional[PlacementUpdate]:
        """Feed one forward pass; returns an update when recalibration fires.

        ``step_counts``: (L, E) routing tallies for this pass.
        ``tokens``: batch token count (defaults to layer-0 tally sum).
        """
        self._step += 1
        step_counts = np.asarray(step_counts, dtype=np.float64)
        self.profiler.update(step_counts)
        if self.rescheduler is not None:
            # BEFORE the adaptive gate: stealing is dispatch-time and
            # orthogonal to recalibration — it must run for static
            # controllers too (the stale-profile regime it exists for)
            self.rescheduler.observe(step_counts)
        if tokens is None:
            tokens = float(step_counts[0].sum())
        if not self.cfg.adaptive \
                or not self.policy.capabilities.workload_aware:
            # static layouts can't react to routing — still track (so
            # static-vs-adaptive comparisons share stats)
            self.detector.observe(step_counts, tokens)
            return None
        event = self.detector.observe(step_counts, tokens)
        if event is None:
            return None
        return self._recalibrate(event)

    def observe_latency(self, rank_loads: np.ndarray,
                        rank_latencies: np.ndarray
                        ) -> Optional[PlacementUpdate]:
        """Feed one step's per-rank (token load, observed MoE latency).

        Arrays are (G,) or (L, G) — the engine/simulator virtual clocks
        produce the per-layer form. When the windowed relative residual
        against the fitted f_g exceeds δ_perf on any rank, the affected
        models are refit from the telemetry window and a recalibration runs
        with the refreshed estimates (the paper's performance-refresh half
        of §4.2.4). Returns the resulting update, or None.

        Telemetry is tracked even for static controllers so static-vs-
        adaptive comparisons share drift statistics, mirroring ``observe``.
        """
        if self.rescheduler is not None:
            # BEFORE the perf-drift gate: measured latencies retune the
            # dispatch-time steal trigger even when perf-drift monitoring
            # (refits) is disabled — stealing reacts to hardware drift
            # *between* refits, which is exactly its job
            self.rescheduler.observe_latency(rank_loads, rank_latencies)
        if self.perf_detector is None:
            return None
        event = self.perf_detector.observe(rank_loads, rank_latencies)
        if event is None or not self.cfg.adaptive:
            return None
        refit = self.perf_detector.refit(event.ranks)
        if not refit:
            return None                    # not enough samples to refresh
        return self._recalibrate(event, refit_ranks=refit)

    # ------------------------------------------------------------------
    def mask_ranks(self, dead: Sequence[int]) -> PlacementUpdate:
        """Mark ranks dead and re-solve over the survivors (elastic fail
        path — ``serving/elastic.py`` routes rank-loss events here).

        ``dead`` is the *complete* dead set (replaces any previous mask;
        pass ``()`` to restore a recovered fleet). The re-solve is always
        full: dead ranks come back as all-phantom zero-share windows
        (``SolveContext.dead_ranks``), so dispatch stops sending them
        tokens while the slot-table geometry stays put.
        """
        dead_set = tuple(sorted(set(int(g) for g in dead)))
        for g in dead_set:
            if not 0 <= g < self.G:
                raise ValueError(f"rank {g} outside [0, {self.G})")
        if len(dead_set) >= self.G:
            raise ValueError("cannot mask every rank — no survivors")
        return self._set_dead(dead_set, FailEvent(dead_set, kind="fail"))

    def unmask_ranks(self, ranks: Sequence[int]) -> PlacementUpdate:
        """Bring recovered ranks back into the fleet (elastic *grow*, the
        inverse of :meth:`mask_ranks` — ``serving/elastic.recover_rank``
        routes rank-recovery events here).

        ``ranks`` are the ranks to unmask; each must currently be dead.
        The re-solve is full over the enlarged survivor set, so traffic
        shares flow back onto the recovered ranks and the weight
        rehydration shows up as ``moved_experts``/``migration_bytes`` on
        the returned update (event kind ``"recover"``). A fail→recover
        round trip with no interleaved observations restores the healthy
        placement bit-identically (pinned by property test).
        """
        up_set = tuple(sorted(set(int(g) for g in ranks)))
        if not up_set:
            raise ValueError("no ranks to unmask")
        dead = set(self.dead_ranks)
        for g in up_set:
            if not 0 <= g < self.G:
                raise ValueError(f"rank {g} outside [0, {self.G})")
            if g not in dead:
                raise ValueError(f"rank {g} is not dead — nothing to unmask")
        new_dead = tuple(sorted(dead - set(up_set)))
        return self._set_dead(new_dead, FailEvent(up_set, kind="recover"))

    def _set_dead(self, dead_set: Tuple[int, ...],
                  event: FailEvent) -> PlacementUpdate:
        """Shared rank-lifecycle transition: install the new dead set,
        full re-solve over the survivors, account the migration, reset the
        rescheduler and cool down both drift monitors."""
        self.dead_ranks = dead_set
        w = self.profiler.window_matrix()
        old = self.placement
        new = self._solve(w)
        moved = new.moved_experts(old)
        upd = PlacementUpdate(
            step=self._step, event=event, placement=new,
            moved_experts=moved,
            migration_bytes=moved * self.cfg.expert_bytes,
            full_resolve=True)
        self.placement = new
        if self.rescheduler is not None:
            self.rescheduler.reset(new)
        self.detector.snapshot()
        if self.perf_detector is not None:
            self.perf_detector.snapshot()
        self.updates.append(upd)
        return upd

    # ------------------------------------------------------------------
    def _recalibrate(self, event: Union[DriftEvent, PerfDriftEvent],
                     refit_ranks: Tuple[int, ...] = ()) -> PlacementUpdate:
        w = self.profiler.window_matrix()
        old = self.placement
        if event.kind in ("stress", "perf") \
                and self.cfg.full_resolve_on_stress:
            # stress: the operating point of every f_g moved; perf: the
            # f_g curves themselves moved → full re-solve with the fresh
            # estimates (still same machinery).
            incremental = False
        else:
            incremental = self.policy.capabilities.supports_incremental
        if self.dead_ranks:
            # swap-based refinement is blind to the mask — it would happily
            # move copies back onto a dead rank. Full re-solves go through
            # the masked path in policy.solve.
            incremental = False
        if incremental:
            res: IncrementalResult = self.policy.refine(old, self._context(w))
            new, moved = res.placement, res.moved_expert_count()
            upd = PlacementUpdate(
                step=self._step, event=event, placement=new,
                moved_experts=moved,
                migration_bytes=moved * self.cfg.expert_bytes,
                swaps_per_layer=res.per_layer_swaps,
                refit_ranks=refit_ranks)
        else:
            # full greedy re-solve (the paper's contrast for eplb-style
            # policies; also the stress/perf-event path for every policy).
            # ``moved_experts`` counts changed (layer, slot) residents, so
            # every migrated *copy* is charged expert_bytes.
            new = self._solve(w)
            moved = new.moved_experts(old)
            upd = PlacementUpdate(
                step=self._step, event=event, placement=new,
                moved_experts=moved,
                migration_bytes=moved * self.cfg.expert_bytes,
                full_resolve=True, refit_ranks=refit_ranks)
        self.placement = upd.placement
        if self.rescheduler is not None:
            # recalibration restarts the responsive shares from the fresh
            # plan — post-migration tallies reflect the new layout
            self.rescheduler.reset(upd.placement)
        # cool down BOTH monitors: the rearrangement perturbs routing and
        # latency telemetry alike (transient migration burst, Appendix A.1)
        self.detector.snapshot()
        if self.perf_detector is not None:
            self.perf_detector.snapshot()
        self.updates.append(upd)
        return upd
