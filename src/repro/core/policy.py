"""Pluggable placement-policy registry: the baseline family as plugins.

The paper's evaluation matrix (and the related-work baselines — GEM's
variability-aware expert-to-GPU mapping, HarMoEny's redundant-sharding load
balancing) is a *family* of placement strategies. This module makes that
family open-ended: a placement policy is any object satisfying the
:class:`PlacementPolicy` protocol —

* ``name`` — the registry key (what ``--policy`` accepts end to end),
* ``capabilities`` — :class:`PolicyCapabilities` flags consumers branch on
  (instead of comparing policy name strings),
* ``solve(ctx) -> ReplicatedPlacement`` — full solve from a
  :class:`SolveContext` (activation matrix, perf models, per-rank slot
  budgets). Always returns the *unified* replicated representation;
  singleton strategies return the r_max = 1 degenerate.
* optional ``refine(placement, ctx) -> IncrementalResult`` — minimal-
  movement recalibration (Algorithm 2), advertised via
  ``capabilities.supports_incremental``.

Registering a policy (one file, no core edits) exposes it everywhere at
once: ``ViBEConfig``/``ViBEController`` recalibration, the serving engine,
``launch/serve.py --policy`` choices, ``training/elastic.py`` re-planning,
and every benchmark sweep that enumerates :func:`registered_policies`.

    from repro.core.policy import (PolicyCapabilities, SolveContext,
                                   register_policy)

    @register_policy
    class RandomPolicy:
        name = "random"
        capabilities = PolicyCapabilities()
        def solve(self, ctx):
            rng = np.random.default_rng(0)
            assign = np.stack([rng.permutation(ctx.n_experts) % ctx.n_ranks
                               for _ in range(ctx.n_layers)])
            return ReplicatedPlacement.from_singleton(
                Placement(assign, ctx.n_ranks))
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Protocol, Sequence, Tuple, runtime_checkable

import numpy as np

from .incremental import IncrementalResult, incremental_update_replicated
from .perf_model import PerfModel
from .placement import (Placement, ReplicatedPlacement, contiguous_placement,
                        eplb_placement, gem_placement, harmoeny_placement,
                        inflate_placement, normalize_slot_budget,
                        vibe_placement, vibe_r_placement)
from .topology import ClusterTopology, vibe_h_placement

__all__ = [
    "PolicyCapabilities",
    "SolveContext",
    "PlacementPolicy",
    "UnknownPolicyError",
    "register_policy",
    "get_policy",
    "registered_policies",
]


@dataclasses.dataclass(frozen=True)
class PolicyCapabilities:
    """What a placement policy consumes and supports.

    Consumers branch on these flags — never on the policy name:

    * ``workload_aware``     — the solve reads the activation matrix; if
      False (static layouts like ``contiguous``) the controller skips
      drift-triggered recalibration entirely.
    * ``needs_perf_models``  — the solve requires per-rank f_g(n) latency
      models (:class:`SolveContext.perf_models` must be set).
    * ``supports_replication`` — the solve may place multiple copies of an
      expert (returns a genuinely replicated placement; the engine must
      budget physical slots beyond one-per-expert).
    * ``supports_incremental`` — the policy implements ``refine`` (swap-
      based minimal-movement recalibration); the controller uses it for
      routing-drift events instead of a full re-solve.
    * ``accepts_slot_budget`` — the solve honours
      :class:`SolveContext.slot_budget` (per-rank physical slot counts,
      possibly non-uniform). Setting a budget for a policy without this
      capability is a configuration error.
    """

    workload_aware: bool = True
    needs_perf_models: bool = False
    supports_replication: bool = False
    supports_incremental: bool = False
    accepts_slot_budget: bool = False


@dataclasses.dataclass(frozen=True)
class SolveContext:
    """Everything a placement solve may consume, in one validated bundle.

    ``w``            — (L, E) activation matrix (per-layer expert token
                       loads from the profiler window).
    ``n_ranks``      — EP group size G.
    ``perf_models``  — per-rank f_g(n) latency models (len == G), or None
                       for hardware-oblivious policies.
    ``slot_budget``  — per-rank physical slot counts: None (policy
                       default), a scalar (uniform budget), or a (G,) array
                       (non-uniform, e.g. device memory headroom). Arrays
                       are first-class: the replicated solvers pad ranks
                       below the maximum with phantom slots.
    ``n_ref_mode``   — operating point for speed estimates ("rank" |
                       "expert", see :func:`~repro.core.placement.
                       vibe_placement`).
    ``epsilon``      — incremental-refinement convergence tolerance.
    ``reweight_shares`` — re-proportion copy shares to rank speeds after a
                       swap-based refinement (replicated policies only).
    ``topology``     — optional :class:`~repro.core.topology.ClusterTopology`
                       (node structure + ICI/DCN link asymmetry). ``None``
                       and flat topologies are equivalent for every
                       built-in policy; only topology-aware solvers
                       (``vibe_h``) read the node structure.
    ``dead_ranks``   — ranks currently lost to the fleet (elastic fail
                       path). When set, the built-in policies solve over
                       the survivors only (with a masked topology) and
                       re-inflate the result so dead ranks hold
                       all-phantom zero-share slot windows — dispatch
                       sends them nothing while the global slot-table
                       geometry stays put.
    """

    w: np.ndarray
    n_ranks: int
    perf_models: Optional[Sequence[PerfModel]] = None
    slot_budget: Optional[np.ndarray] = None
    n_ref_mode: str = "rank"
    epsilon: float = 0.03
    reweight_shares: bool = False
    topology: Optional[ClusterTopology] = None
    dead_ranks: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        w = np.atleast_2d(np.asarray(self.w, dtype=np.float64))
        if w.ndim != 2 or w.size == 0:
            raise ValueError(f"activation matrix must be (L, E), got {w.shape}")
        object.__setattr__(self, "w", w)
        if self.n_ranks < 1:
            raise ValueError(f"n_ranks must be >= 1, got {self.n_ranks}")
        if self.perf_models is not None:
            pm = tuple(self.perf_models)
            if len(pm) != self.n_ranks:
                raise ValueError("need one perf model per rank "
                                 f"({len(pm)} != {self.n_ranks})")
            object.__setattr__(self, "perf_models", pm)
        if self.slot_budget is not None:
            # one validation path with the solvers: scalar → (G,),
            # shape/min checks, and feasibility (Σ ≥ E, max ≤ E) — so
            # infeasible budgets fail here at the boundary, before any
            # policy (including third-party ones) reads the context
            object.__setattr__(
                self, "slot_budget",
                normalize_slot_budget(self.slot_budget, self.n_experts,
                                      self.n_ranks))
        if self.topology is not None \
                and self.topology.n_ranks != self.n_ranks:
            raise ValueError(f"topology has {self.topology.n_ranks} ranks "
                             f"but n_ranks={self.n_ranks}")
        if self.dead_ranks is not None:
            dead = tuple(sorted(set(int(g) for g in self.dead_ranks)))
            if dead and (dead[0] < 0 or dead[-1] >= self.n_ranks):
                raise ValueError(f"dead_ranks {dead} outside "
                                 f"[0, {self.n_ranks})")
            if len(dead) >= self.n_ranks:
                raise ValueError("cannot mark every rank dead")
            object.__setattr__(self, "dead_ranks", dead or None)

    @property
    def n_layers(self) -> int:
        return self.w.shape[0]

    @property
    def n_experts(self) -> int:
        return self.w.shape[1]


@runtime_checkable
class PlacementPolicy(Protocol):
    """Protocol every registered placement policy satisfies."""

    name: str
    capabilities: PolicyCapabilities

    def solve(self, ctx: SolveContext) -> ReplicatedPlacement:
        """Full placement solve → unified replicated representation."""
        ...


class UnknownPolicyError(ValueError):
    """Raised for a policy name absent from the registry."""


_REGISTRY: Dict[str, PlacementPolicy] = {}


def register_policy(policy, *, replace: bool = False):
    """Add a policy to the registry; usable as a class decorator.

    Accepts a :class:`PlacementPolicy` instance or a zero-arg class (which
    is instantiated). Duplicate names raise unless ``replace=True``.
    Returns the argument unchanged so decorated classes stay usable.
    """
    inst = policy() if isinstance(policy, type) else policy
    name = getattr(inst, "name", "")
    if not name or not isinstance(name, str):
        raise ValueError("placement policy needs a non-empty string .name")
    if not isinstance(inst, PlacementPolicy):
        raise TypeError(f"{name!r} does not satisfy the PlacementPolicy "
                        "protocol (name/capabilities/solve)")
    if inst.capabilities.supports_incremental \
            and not callable(getattr(inst, "refine", None)):
        raise TypeError(
            f"{name!r} advertises supports_incremental but implements no "
            "refine(placement, ctx) — the controller would crash on the "
            "first routing-drift recalibration")
    if name in _REGISTRY and not replace:
        raise ValueError(f"placement policy {name!r} already registered "
                         "(pass replace=True to override)")
    _REGISTRY[name] = inst
    return policy


def get_policy(name: str) -> PlacementPolicy:
    """Registry lookup; unknown names list what *is* registered."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownPolicyError(
            f"unknown placement policy {name!r}; registered policies: "
            f"{', '.join(registered_policies())}") from None


def registered_policies() -> Tuple[str, ...]:
    """Sorted names of all registered policies (drives CLI choices and
    benchmark sweeps)."""
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# built-in policies
# ---------------------------------------------------------------------------

class _BuiltinPolicy:
    """Shared context validation + capability-gated refine plumbing."""

    name: str = ""
    capabilities = PolicyCapabilities()

    def validate(self, ctx: SolveContext) -> None:
        caps = self.capabilities
        if caps.needs_perf_models and ctx.perf_models is None:
            raise ValueError(f"{self.name} placement requires perf_models")
        if ctx.slot_budget is not None and not caps.accepts_slot_budget:
            raise ValueError(
                f"policy {self.name!r} does not accept a slot budget "
                "(capabilities.accepts_slot_budget=False)")

    def solve(self, ctx: SolveContext) -> ReplicatedPlacement:
        self.validate(ctx)
        if ctx.dead_ranks:
            return self._solve_masked(ctx)
        return self._solve(ctx)

    def _solve_masked(self, ctx: SolveContext) -> ReplicatedPlacement:
        """Solve over the surviving ranks only and re-inflate: dead ranks
        come back as all-phantom zero-share windows (dispatch sends them
        nothing), so the global slot-table geometry the engine pinned at
        init survives the failure whenever the per-rank budget does."""
        from .placement import default_slots_per_rank
        dead = set(ctx.dead_ranks)
        survivors = [g for g in range(ctx.n_ranks) if g not in dead]
        Gs, E = len(survivors), ctx.n_experts
        if not self.capabilities.supports_replication and E % Gs:
            raise ValueError(
                f"policy {self.name!r} places one expert per slot and "
                f"cannot spread E={E} experts over {Gs} surviving ranks "
                "(E % survivors != 0) — elastic fail-over needs a "
                "replication-capable policy (e.g. vibe_r / vibe_h)")
        if ctx.slot_budget is not None:
            budget = ctx.slot_budget[survivors]
        else:
            # per-rank memory budgets don't change because a peer died:
            # keep the default budget of the *original* group size, bumped
            # only if the survivors can no longer hold every expert
            b = max(default_slots_per_rank(E, ctx.n_ranks),
                    -(-E // Gs))
            budget = np.full(Gs, min(b, E), dtype=np.int64)
        sub = SolveContext(
            w=ctx.w, n_ranks=Gs,
            perf_models=(tuple(ctx.perf_models[g] for g in survivors)
                         if ctx.perf_models is not None else None),
            slot_budget=(budget if self.capabilities.accepts_slot_budget
                         else None),
            n_ref_mode=ctx.n_ref_mode, epsilon=ctx.epsilon,
            reweight_shares=ctx.reweight_shares,
            topology=(ctx.topology.mask(sorted(dead))
                      if ctx.topology is not None else None))
        return inflate_placement(self._solve(sub), survivors, ctx.n_ranks)

    def refine(self, placement: ReplicatedPlacement,
               ctx: SolveContext) -> IncrementalResult:
        """Swap-based minimal-movement recalibration (Algorithm 2 at slot
        granularity; the r_max = 1 degenerate reduces to expert swaps)."""
        if not self.capabilities.supports_incremental:
            raise NotImplementedError(
                f"policy {self.name!r} has no incremental refinement "
                "(capabilities.supports_incremental=False)")
        self.validate(ctx)
        if ctx.perf_models is None:
            raise ValueError(f"{self.name} refine requires perf_models "
                             "(swap scoring evaluates f_g latency curves)")
        return incremental_update_replicated(
            placement, ctx.w, ctx.perf_models, epsilon=ctx.epsilon,
            reweight_shares=ctx.reweight_shares)

    def __repr__(self) -> str:
        return f"<{type(self).__name__} {self.name!r}>"


@register_policy
class ContiguousPolicy(_BuiltinPolicy):
    """vLLM default: expert e on rank e // (E/G). No workload or hardware
    awareness — the static lower bound of the sweep."""

    name = "contiguous"
    capabilities = PolicyCapabilities(workload_aware=False)

    def _solve(self, ctx: SolveContext) -> ReplicatedPlacement:
        return ReplicatedPlacement.from_singleton(
            contiguous_placement(ctx.n_layers, ctx.n_experts, ctx.n_ranks))


@register_policy
class EplbPolicy(_BuiltinPolicy):
    """EPLB baseline: greedy token-count balancing (assumes f_g(n) = n)."""

    name = "eplb"
    capabilities = PolicyCapabilities()

    def _solve(self, ctx: SolveContext) -> ReplicatedPlacement:
        return ReplicatedPlacement.from_singleton(
            eplb_placement(ctx.w, ctx.n_ranks))


@register_policy
class GemPolicy(_BuiltinPolicy):
    """GEM-style variability-aware greedy: hottest experts to the rank with
    the lowest predicted completion time f_g(n_g + w_e); no replication."""

    name = "gem"
    capabilities = PolicyCapabilities(needs_perf_models=True)

    def _solve(self, ctx: SolveContext) -> ReplicatedPlacement:
        return ReplicatedPlacement.from_singleton(
            gem_placement(ctx.w, ctx.perf_models))


@register_policy
class HarmoenyPolicy(_BuiltinPolicy):
    """HarMoEny-style baseline: redundant sharding for pure load balance —
    ViBE-R's replication machinery with uniform speeds and shares."""

    name = "harmoeny"
    capabilities = PolicyCapabilities(supports_replication=True,
                                      accepts_slot_budget=True)

    def _solve(self, ctx: SolveContext) -> ReplicatedPlacement:
        return harmoeny_placement(ctx.w, ctx.n_ranks,
                                  slots_per_rank=ctx.slot_budget)


@register_policy
class VibePolicy(_BuiltinPolicy):
    """The paper's contribution: speed-proportional token targets from the
    profiled f_g curves, greedy descending-load fill (Alg 1 Phase 2)."""

    name = "vibe"
    capabilities = PolicyCapabilities(needs_perf_models=True,
                                      supports_incremental=True)

    def _solve(self, ctx: SolveContext) -> ReplicatedPlacement:
        return ReplicatedPlacement.from_singleton(
            vibe_placement(ctx.w, ctx.perf_models, ctx.n_ref_mode))


@register_policy
class VibeRPolicy(_BuiltinPolicy):
    """ViBE-R: slot-budget hot-expert replication + speed-proportional copy
    shares (cluster-scale extension; accepts non-uniform budgets)."""

    name = "vibe_r"
    capabilities = PolicyCapabilities(needs_perf_models=True,
                                      supports_replication=True,
                                      supports_incremental=True,
                                      accepts_slot_budget=True)

    def _solve(self, ctx: SolveContext) -> ReplicatedPlacement:
        return vibe_r_placement(ctx.w, ctx.perf_models,
                                slots_per_rank=ctx.slot_budget,
                                n_ref_mode=ctx.n_ref_mode)


@register_policy
class VibeHPolicy(_BuiltinPolicy):
    """ViBE-H: two-level node-aware solve — experts binned across nodes to
    minimize cross-node (DCN) token traffic, then the full ViBE-R
    replication solve within each node against that node's per-rank perf
    models (see :func:`repro.core.topology.vibe_h_placement`). Without a
    (multi-node) ``SolveContext.topology`` it delegates to ``vibe_r``
    exactly. No incremental refine: swap-based refinement is blind to node
    boundaries, so routing drift triggers a full (cheap, vectorized)
    re-solve instead."""

    name = "vibe_h"
    capabilities = PolicyCapabilities(needs_perf_models=True,
                                      supports_replication=True,
                                      accepts_slot_budget=True)

    def _solve(self, ctx: SolveContext) -> ReplicatedPlacement:
        return vibe_h_placement(ctx.w, ctx.perf_models, ctx.topology,
                                slots_per_rank=ctx.slot_budget,
                                n_ref_mode=ctx.n_ref_mode)
