"""Device-specific performance models (paper §4.2.1).

Each EP rank g gets a model ``f_g(n)`` mapping token load ``n`` to expected
fused-MoE kernel latency. The paper profiles each GPU once with the fused MoE
kernel across a token-count sweep (Phase 1); under *performance drift*
(thermal throttling, power-cap changes, device replacement — §4.2.4) the
fitted model goes stale, so this module also provides the online side: a
:class:`TelemetryBuffer` of observed per-rank ``(n, latency)`` samples from
serving itself, and :func:`refit_from_samples` which rebuilds f_g from the
recent window with the same fitting machinery — no offline sweep required.

We model the physically-motivated two-regime shape observed on both GPUs and
TPUs:

  latency(n) = max(t_mem(n), t_compute(n)) + t_base

* ``t_base``    — kernel launch / dispatch overhead (device-independent-ish).
* ``t_mem``     — weight + activation traffic; for small n the expert weights
                  dominate and latency is ~flat in n (memory-bound floor).
* ``t_compute`` — MXU/SIMD time, linear in n, with a device-specific speed
                  factor; near the power envelope the effective slope grows
                  (DVFS throttling), which we capture with a piecewise-linear
                  fit rather than a single slope.

The public surface is small:

  * :class:`PerfModel` — immutable fitted model; ``__call__(n) -> seconds``;
    ``speed(n_ref)`` = 1/f_g(n_ref) (the paper's s_g).
  * :func:`fit_perf_model` — least-squares piecewise-linear fit from
    (token_count, latency) samples, as produced by the profiling harness.
  * :class:`DeviceProfile` — the profiling sweep record for one device.
  * :class:`TelemetryBuffer` — per-rank rolling window of serving-observed
    ``(n, latency)`` samples (the perf-drift detector's raw signal).
  * :func:`refit_from_samples` — rebuild one rank's f_g from such a window.

Everything here is plain numpy — this is control-plane code that runs on the
host next to the serving engine, exactly as in the paper.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "PerfModel",
    "DeviceProfile",
    "TelemetryBuffer",
    "fit_perf_model",
    "profile_device",
    "refit_from_samples",
]


@dataclasses.dataclass(frozen=True)
class PerfModel:
    """Piecewise-linear token-load → latency model for one device.

    ``knots``  — increasing token counts (K,), first is 0.
    ``lat``    — latency (seconds) at each knot (K,).
    Between knots latency is linear; beyond the last knot it extrapolates
    with the final segment's slope. This representation subsumes the paper's
    "assume f_g monotone" requirement and captures the memory-bound floor +
    power-throttled steep region without committing to a parametric form.
    """

    knots: np.ndarray
    lat: np.ndarray
    device_id: int = 0

    def __post_init__(self):
        k = np.asarray(self.knots, dtype=np.float64)
        l = np.asarray(self.lat, dtype=np.float64)
        if k.ndim != 1 or k.shape != l.shape or k.size < 2:
            raise ValueError("knots/lat must be matching 1-D arrays, >=2 points")
        if not np.all(np.diff(k) > 0):
            raise ValueError("knots must be strictly increasing")
        if np.any(l <= 0):
            raise ValueError("latencies must be positive")
        object.__setattr__(self, "knots", k)
        object.__setattr__(self, "lat", l)

    def __call__(self, n) -> np.ndarray:
        """Predicted latency (seconds) at token load ``n`` (scalar or array)."""
        n = np.asarray(n, dtype=np.float64)
        k, l = self.knots, self.lat
        # linear extrapolation beyond last knot using the final slope
        out = np.interp(n, k, l)
        last_slope = (l[-1] - l[-2]) / (k[-1] - k[-2])
        over = n > k[-1]
        out = np.where(over, l[-1] + (n - k[-1]) * last_slope, out)
        return out if out.ndim else float(out)

    def speed(self, n_ref: float) -> float:
        """Paper's s_g = 1 / f_g(n_ref)."""
        return 1.0 / float(self(n_ref))

    def throughput(self, n: float) -> float:
        """Tokens per second at load n (marginal, from local slope)."""
        eps = max(1.0, 0.01 * n)
        return 2 * eps / (float(self(n + eps)) - float(self(n - eps)) + 1e-30)

    def scaled(self, factor: float) -> "PerfModel":
        """A copy with all latencies scaled (e.g. to model degradation)."""
        return PerfModel(self.knots.copy(), self.lat * factor, self.device_id)


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Raw profiling sweep for one device: latency at each token count."""

    device_id: int
    token_counts: np.ndarray  # (S,)
    latencies: np.ndarray     # (S,) seconds

    def __post_init__(self):
        object.__setattr__(self, "token_counts",
                           np.asarray(self.token_counts, dtype=np.float64))
        object.__setattr__(self, "latencies",
                           np.asarray(self.latencies, dtype=np.float64))


def fit_perf_model(profile: DeviceProfile, n_knots: int = 8) -> PerfModel:
    """Fit a monotone piecewise-linear model to a profiling sweep.

    Knots are placed at quantiles of the sampled token counts; latency at
    each knot comes from an isotonic-regularized *local regression* over the
    knot's nearest-knot bin — a line fit through the bin's samples evaluated
    at the knot itself — guaranteeing the fitted f_g is monotone
    non-decreasing (physical requirement — more tokens never finish faster).
    A bin mean (the pre-fix estimator) answered "average latency near this
    knot", not "latency *at* this knot": around the stress knee, where
    curvature is largest, samples on the steep side pulled the mean
    systematically off the knee value (~10% error at the documented profile
    densities). Evaluating the local line at the knot removes that bias
    while degenerating gracefully — single-sample or zero-spread bins fall
    back to the mean. A 0-knot is always anchored at the memory-bound floor
    (the smallest-load bin's latency — at decode-scale loads the expert
    weights dominate and latency is flat in n), honouring the
    :class:`PerfModel` contract that the first knot is 0 even when the
    sweep starts at, say, 64 tokens.
    """
    tc, lt = profile.token_counts, profile.latencies
    order = np.argsort(tc)
    tc, lt = tc[order], lt[order]
    if tc.size < 2:
        raise ValueError("need at least 2 profile samples")
    n_knots = int(min(n_knots, tc.size))
    qs = np.linspace(0.0, 1.0, n_knots)
    knots = np.quantile(tc, qs)
    # de-duplicate knots (quantiles of few samples can repeat)
    knots = np.unique(knots)
    if knots.size < 2:
        knots = np.array([tc.min(), tc.max() + 1.0])
    # local latency per knot: nearest-knot binning, then a per-knot local
    # regression (line through the bin evaluated AT the knot) instead of
    # the bin mean, which sat ~10% off the stress knee (bins straddling
    # the knee average the steep side into the knot value)
    idx = np.abs(tc[:, None] - knots[None, :]).argmin(axis=1)
    lat = np.full(knots.size, np.nan)
    for i in range(knots.size):
        x, y = tc[idx == i], lt[idx == i]
        if x.size == 0:
            continue
        if x.size == 1 or np.ptp(x) == 0.0:
            lat[i] = y.mean()
            continue
        slope, icpt = np.polyfit(x, y, 1)
        lat[i] = icpt + slope * knots[i]
    # fill empty bins by interpolation
    bad = np.isnan(lat)
    if bad.any():
        lat[bad] = np.interp(knots[bad], knots[~bad], lat[~bad])
    # isotonic pass (pool adjacent violators, simple O(K^2) is fine for K<=16)
    lat = _pava(lat)
    # strictly positive floor
    lat = np.maximum(lat, 1e-9)
    if knots[0] > 0.0:
        # anchor the promised 0-knot at the memory-bound floor: loads below
        # the smallest profiled count see the flat floor explicitly instead
        # of relying on interp's silent clamp
        knots = np.concatenate([[0.0], knots])
        lat = np.concatenate([[lat[0]], lat])
    return PerfModel(knots, lat, device_id=profile.device_id)


def _pava(y: np.ndarray) -> np.ndarray:
    """Pool-adjacent-violators: smallest monotone non-decreasing fit."""
    y = y.astype(np.float64).copy()
    n = y.size
    w = np.ones(n)
    # classic stack-based PAVA
    vals = [y[0]]
    wts = [w[0]]
    for i in range(1, n):
        vals.append(y[i])
        wts.append(w[i])
        while len(vals) > 1 and vals[-2] > vals[-1]:
            v = (vals[-2] * wts[-2] + vals[-1] * wts[-1]) / (wts[-2] + wts[-1])
            wt = wts[-2] + wts[-1]
            vals = vals[:-2] + [v]
            wts = wts[:-2] + [wt]
    out = []
    for v, wt in zip(vals, wts):
        out.extend([v] * int(round(wt)))
    return np.asarray(out[:n])


def profile_device(
    latency_fn,
    device_id: int,
    token_counts: Sequence[int] = (64, 128, 256, 512, 1024, 2048, 4096, 8192),
    repeats: int = 3,
) -> DeviceProfile:
    """Run the profiling sweep: call ``latency_fn(device_id, n)`` (seconds).

    In production ``latency_fn`` times the fused MoE kernel on the real
    device (after a warm-up to steady-state thermals, per the paper); in this
    repo the serving simulator and tests inject synthetic device behaviour.
    The median over ``repeats`` is recorded per token count.
    """
    tc, lat = [], []
    for n in token_counts:
        samples = [float(latency_fn(device_id, int(n))) for _ in range(repeats)]
        tc.append(float(n))
        lat.append(float(np.median(samples)))
    return DeviceProfile(device_id=device_id,
                         token_counts=np.asarray(tc),
                         latencies=np.asarray(lat))


# ---------------------------------------------------------------------------
# online telemetry (perf-drift recalibration, §4.2.4)
# ---------------------------------------------------------------------------

class TelemetryBuffer:
    """Per-rank rolling window of serving-observed ``(n, latency)`` samples.

    Serving produces these for free: the engine's virtual clock (or a real
    deployment's kernel timers) yields per-rank token load and measured MoE
    latency every step. The buffer keeps the last ``window`` samples per
    rank — enough load diversity (prefill chunks + decode batches) to refit
    a piecewise-linear f_g without any offline sweep.
    """

    def __init__(self, n_ranks: int, window: int = 128):
        if n_ranks < 1 or window < 1:
            raise ValueError("n_ranks and window must be >= 1")
        self.n_ranks = int(n_ranks)
        self.window = int(window)
        self._buf: List[Deque[Tuple[float, float]]] = [
            collections.deque(maxlen=window) for _ in range(self.n_ranks)]

    def add(self, rank_loads: np.ndarray, rank_latencies: np.ndarray) -> None:
        """Record one step's observations.

        ``rank_loads`` / ``rank_latencies``: matching (G,) or (L, G) arrays
        — the per-layer rows the virtual clock computes are each a genuine
        (n, f_g(n)) sample, so they all go in.
        """
        loads = np.atleast_2d(np.asarray(rank_loads, dtype=np.float64))
        lats = np.atleast_2d(np.asarray(rank_latencies, dtype=np.float64))
        if loads.shape != lats.shape or loads.shape[1] != self.n_ranks:
            raise ValueError(f"loads {loads.shape} / latencies {lats.shape} "
                             f"must match and have {self.n_ranks} columns")
        for g in range(self.n_ranks):
            self._buf[g].extend(zip(loads[:, g], lats[:, g]))

    def count(self, rank: int) -> int:
        return len(self._buf[rank])

    def samples(self, rank: int) -> Tuple[np.ndarray, np.ndarray]:
        """(n, latency) arrays of the rank's current window (oldest first)."""
        if not self._buf[rank]:
            return np.empty(0), np.empty(0)
        arr = np.asarray(self._buf[rank], dtype=np.float64)
        return arr[:, 0], arr[:, 1]

    def relative_residuals(self, models: Sequence[PerfModel],
                           min_samples: int = 1) -> np.ndarray:
        """(G,) windowed mean relative residual |observed − f_g(n)| / f_g(n).

        Ranks with fewer than ``min_samples`` observations report NaN (the
        detector treats that as "no signal yet").
        """
        if len(models) != self.n_ranks:
            raise ValueError("one model per rank required")
        out = np.full(self.n_ranks, np.nan)
        for g, model in enumerate(models):
            if self.count(g) < max(min_samples, 1):
                continue
            n, lat = self.samples(g)
            pred = np.maximum(np.asarray(model(n), dtype=np.float64), 1e-12)
            out[g] = float(np.mean(np.abs(lat - pred) / pred))
        return out

    def clear(self, rank: Optional[int] = None) -> None:
        for g in ([rank] if rank is not None else range(self.n_ranks)):
            self._buf[g].clear()


def refit_from_samples(token_loads: np.ndarray, latencies: np.ndarray,
                       device_id: int = 0, n_knots: int = 8,
                       prior: Optional[PerfModel] = None,
                       min_span: float = 4.0) -> PerfModel:
    """Rebuild one rank's f_g from a telemetry window (online refresh).

    Reuses :func:`fit_perf_model` — quantile knots over the *observed* load
    range, isotonic latencies, 0-knot anchored at the memory-bound floor —
    so the refreshed model has exactly the same shape guarantees as the
    Phase 1 fit, just sourced from recent serving telemetry instead of an
    offline sweep.

    Serving windows rarely look like an offline sweep, so a ``prior`` model
    (the one being replaced) disciplines the refit where the window is
    uninformative:

    * narrow window (max/min < ``min_span``, e.g. a saturated server seeing
      the same full prefill chunk every step): the window identifies at
      most a scale and a trend, so the unseen region is extrapolated from
      the prior. Two physically distinct drifts are modelled separately —
      **throttle** (DVFS-style power capping divides the whole kernel, so
      the observed/predicted ratio is flat in load → rescale the prior's
      entire curve by the median ratio) vs **deviation** (a stress-gated
      shift, e.g. a replaced device with a weaker variability bin, inflates
      only the load-dependent region → preserve the prior's zero-load
      floor and rescale only the excess above it, so low-load predictions
      are not dragged up by a drift that never touched them). The split is
      decided by the ratio's trend across the window's load median.
    * diverse window: the shape is refit from the samples, and the prior's
      knots *above* the observed range ride along, rescaled to match at
      the seam — linear extrapolation from a low-load window would
      otherwise wildly mispredict stressed loads the rank sees later.
    """
    tc = np.asarray(token_loads, dtype=np.float64)
    lt = np.asarray(latencies, dtype=np.float64)
    if tc.size < 2:
        raise ValueError("need at least 2 telemetry samples to refit")
    span = (float(tc.max()) + 1.0) / (float(tc.min()) + 1.0)
    if prior is not None and span < min_span:
        pred = np.maximum(np.asarray(prior(tc), dtype=np.float64), 1e-12)
        ratio = lt / pred
        factor = float(np.median(ratio))
        # throttle vs deviation: split the window at its median load and
        # compare the ratio's halves. A flat trend (or a single-point
        # window, where hi is empty) is the throttle signature.
        n_med = float(np.median(tc))
        lo, hi = ratio[tc <= n_med], ratio[tc > n_med]
        trend = (float(np.median(hi)) - float(np.median(lo))
                 if lo.size and hi.size else 0.0)
        floor = float(prior.lat[0])
        excess = np.maximum(pred - floor, 1e-12)
        deviation = (trend > 0.25 * max(abs(factor - 1.0), 0.02)
                     and float(np.median(pred - floor))
                     > 0.25 * float(np.median(pred)))
        if not deviation:
            return PerfModel(prior.knots.copy(),
                             np.maximum(prior.lat * factor, 1e-9), device_id)
        # deviation: latency = floor + k * (prior - floor); monotone and
        # floor-preserving by construction
        k = max(float(np.median((lt - floor) / excess)), 0.0)
        return PerfModel(prior.knots.copy(),
                         np.maximum(floor + k * (prior.lat - floor), 1e-9),
                         device_id)
    fitted = fit_perf_model(DeviceProfile(device_id, tc, lt),
                            n_knots=n_knots)
    if prior is None:
        return fitted
    n_hi = float(tc.max())
    tail = prior.knots > n_hi * 1.25
    if not tail.any():
        return fitted
    ratio = float(fitted(n_hi)) / max(float(prior(n_hi)), 1e-12)
    knots = np.concatenate([fitted.knots, prior.knots[tail]])
    lat = np.concatenate([fitted.lat,
                          np.maximum(prior.lat[tail] * ratio, 1e-9)])
    # the seam is continuous by construction (both sides equal ~fitted(n_hi)
    # at n_hi); accumulate guards monotonicity against bin noise
    return PerfModel(knots, np.maximum.accumulate(lat), device_id)
