"""Device-specific performance models (paper §4.2.1).

Each EP rank g gets a model ``f_g(n)`` mapping token load ``n`` to expected
fused-MoE kernel latency. The paper profiles each GPU once with the fused MoE
kernel across a token-count sweep and notes the load→latency relationship is
stable over time, so a fitted model can be retained for the serving lifetime.

We model the physically-motivated two-regime shape observed on both GPUs and
TPUs:

  latency(n) = max(t_mem(n), t_compute(n)) + t_base

* ``t_base``    — kernel launch / dispatch overhead (device-independent-ish).
* ``t_mem``     — weight + activation traffic; for small n the expert weights
                  dominate and latency is ~flat in n (memory-bound floor).
* ``t_compute`` — MXU/SIMD time, linear in n, with a device-specific speed
                  factor; near the power envelope the effective slope grows
                  (DVFS throttling), which we capture with a piecewise-linear
                  fit rather than a single slope.

The public surface is small:

  * :class:`PerfModel` — immutable fitted model; ``__call__(n) -> seconds``;
    ``speed(n_ref)`` = 1/f_g(n_ref) (the paper's s_g).
  * :func:`fit_perf_model` — least-squares piecewise-linear fit from
    (token_count, latency) samples, as produced by the profiling harness.
  * :class:`DeviceProfile` — the profiling sweep record for one device.

Everything here is plain numpy — this is control-plane code that runs on the
host next to the serving engine, exactly as in the paper.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "PerfModel",
    "DeviceProfile",
    "fit_perf_model",
    "profile_device",
]


@dataclasses.dataclass(frozen=True)
class PerfModel:
    """Piecewise-linear token-load → latency model for one device.

    ``knots``  — increasing token counts (K,), first is 0.
    ``lat``    — latency (seconds) at each knot (K,).
    Between knots latency is linear; beyond the last knot it extrapolates
    with the final segment's slope. This representation subsumes the paper's
    "assume f_g monotone" requirement and captures the memory-bound floor +
    power-throttled steep region without committing to a parametric form.
    """

    knots: np.ndarray
    lat: np.ndarray
    device_id: int = 0

    def __post_init__(self):
        k = np.asarray(self.knots, dtype=np.float64)
        l = np.asarray(self.lat, dtype=np.float64)
        if k.ndim != 1 or k.shape != l.shape or k.size < 2:
            raise ValueError("knots/lat must be matching 1-D arrays, >=2 points")
        if not np.all(np.diff(k) > 0):
            raise ValueError("knots must be strictly increasing")
        if np.any(l <= 0):
            raise ValueError("latencies must be positive")
        object.__setattr__(self, "knots", k)
        object.__setattr__(self, "lat", l)

    def __call__(self, n) -> np.ndarray:
        """Predicted latency (seconds) at token load ``n`` (scalar or array)."""
        n = np.asarray(n, dtype=np.float64)
        k, l = self.knots, self.lat
        # linear extrapolation beyond last knot using the final slope
        out = np.interp(n, k, l)
        last_slope = (l[-1] - l[-2]) / (k[-1] - k[-2])
        over = n > k[-1]
        out = np.where(over, l[-1] + (n - k[-1]) * last_slope, out)
        return out if out.ndim else float(out)

    def speed(self, n_ref: float) -> float:
        """Paper's s_g = 1 / f_g(n_ref)."""
        return 1.0 / float(self(n_ref))

    def throughput(self, n: float) -> float:
        """Tokens per second at load n (marginal, from local slope)."""
        eps = max(1.0, 0.01 * n)
        return 2 * eps / (float(self(n + eps)) - float(self(n - eps)) + 1e-30)

    def scaled(self, factor: float) -> "PerfModel":
        """A copy with all latencies scaled (e.g. to model degradation)."""
        return PerfModel(self.knots.copy(), self.lat * factor, self.device_id)


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Raw profiling sweep for one device: latency at each token count."""

    device_id: int
    token_counts: np.ndarray  # (S,)
    latencies: np.ndarray     # (S,) seconds

    def __post_init__(self):
        object.__setattr__(self, "token_counts",
                           np.asarray(self.token_counts, dtype=np.float64))
        object.__setattr__(self, "latencies",
                           np.asarray(self.latencies, dtype=np.float64))


def fit_perf_model(profile: DeviceProfile, n_knots: int = 8) -> PerfModel:
    """Fit a monotone piecewise-linear model to a profiling sweep.

    Knots are placed at quantiles of the sampled token counts; latency at
    each knot is an isotonic-regularized local mean, guaranteeing the fitted
    f_g is monotone non-decreasing (physical requirement — more tokens never
    finish faster).
    """
    tc, lt = profile.token_counts, profile.latencies
    order = np.argsort(tc)
    tc, lt = tc[order], lt[order]
    if tc.size < 2:
        raise ValueError("need at least 2 profile samples")
    n_knots = int(min(n_knots, tc.size))
    qs = np.linspace(0.0, 1.0, n_knots)
    knots = np.quantile(tc, qs)
    # de-duplicate knots (quantiles of few samples can repeat)
    knots = np.unique(knots)
    if knots.size < 2:
        knots = np.array([tc.min(), tc.max() + 1.0])
    # local mean latency per knot via nearest-knot binning
    idx = np.abs(tc[:, None] - knots[None, :]).argmin(axis=1)
    lat = np.array([lt[idx == i].mean() if np.any(idx == i) else np.nan
                    for i in range(knots.size)])
    # fill empty bins by interpolation
    bad = np.isnan(lat)
    if bad.any():
        lat[bad] = np.interp(knots[bad], knots[~bad], lat[~bad])
    # isotonic pass (pool adjacent violators, simple O(K^2) is fine for K<=16)
    lat = _pava(lat)
    # strictly positive floor
    lat = np.maximum(lat, 1e-9)
    return PerfModel(knots, lat, device_id=profile.device_id)


def _pava(y: np.ndarray) -> np.ndarray:
    """Pool-adjacent-violators: smallest monotone non-decreasing fit."""
    y = y.astype(np.float64).copy()
    n = y.size
    w = np.ones(n)
    # classic stack-based PAVA
    vals = [y[0]]
    wts = [w[0]]
    for i in range(1, n):
        vals.append(y[i])
        wts.append(w[i])
        while len(vals) > 1 and vals[-2] > vals[-1]:
            v = (vals[-2] * wts[-2] + vals[-1] * wts[-1]) / (wts[-2] + wts[-1])
            wt = wts[-2] + wts[-1]
            vals = vals[:-2] + [v]
            wts = wts[:-2] + [wt]
    out = []
    for v, wt in zip(vals, wts):
        out.extend([v] * int(round(wt)))
    return np.asarray(out[:n])


def profile_device(
    latency_fn,
    device_id: int,
    token_counts: Sequence[int] = (64, 128, 256, 512, 1024, 2048, 4096, 8192),
    repeats: int = 3,
) -> DeviceProfile:
    """Run the profiling sweep: call ``latency_fn(device_id, n)`` (seconds).

    In production ``latency_fn`` times the fused MoE kernel on the real
    device (after a warm-up to steady-state thermals, per the paper); in this
    repo the serving simulator and tests inject synthetic device behaviour.
    The median over ``repeats`` is recorded per token count.
    """
    tc, lat = [], []
    for n in token_counts:
        samples = [float(latency_fn(device_id, int(n))) for _ in range(repeats)]
        tc.append(float(n))
        lat.append(float(np.median(samples)))
    return DeviceProfile(device_id=device_id,
                         token_counts=np.asarray(tc),
                         latencies=np.asarray(lat))
