# The paper's primary contribution: ViBE — Variability-Informed Binning of
# Experts. Hardware-aware expert placement for distributed MoE serving.
from .activation import ActivationProfiler, routing_tally
from .controller import PlacementUpdate, ViBEConfig, ViBEController
from .drift import (DriftConfig, DriftDetector, DriftEvent, PerfDriftConfig,
                    PerfDriftDetector, PerfDriftEvent, cosine_distance)
from .incremental import (IncrementalResult, SlotSwap, Swap,
                          incremental_update, incremental_update_replicated)
from .perf_model import (DeviceProfile, PerfModel, TelemetryBuffer,
                         fit_perf_model, profile_device, refit_from_samples)
from .placement import (Placement, ReplicatedPlacement, compact_placement,
                        contiguous_placement, default_slots_per_rank,
                        eplb_placement, gem_placement, harmoeny_placement,
                        inflate_placement, layer_latency_span,
                        normalize_slot_budget, pad_phantom_column,
                        placement_to_permutation, permutation_to_placement,
                        predicted_layer_latency, predicted_rank_latencies,
                        reweight_shares_by_speed, solve_model_placement,
                        vibe_placement, vibe_r_placement)
from .policy import (PlacementPolicy, PolicyCapabilities, SolveContext,
                     UnknownPolicyError, get_policy, register_policy,
                     registered_policies)
from .steal import StealConfig, TokenRescheduler
from .topology import ClusterTopology, parse_topology, vibe_h_placement
from .variability import (REGIMES, SCENARIOS, ClusterVariability,
                          VariabilityEvent, VariabilityRegime, make_cluster,
                          make_scenario)

__all__ = [
    "ActivationProfiler", "routing_tally",
    "PlacementUpdate", "ViBEConfig", "ViBEController",
    "DriftConfig", "DriftDetector", "DriftEvent", "cosine_distance",
    "PerfDriftConfig", "PerfDriftDetector", "PerfDriftEvent",
    "IncrementalResult", "SlotSwap", "Swap", "incremental_update",
    "incremental_update_replicated",
    "DeviceProfile", "PerfModel", "TelemetryBuffer", "fit_perf_model",
    "profile_device", "refit_from_samples",
    "Placement", "ReplicatedPlacement", "compact_placement",
    "contiguous_placement",
    "default_slots_per_rank", "eplb_placement", "gem_placement",
    "harmoeny_placement", "inflate_placement", "layer_latency_span",
    "normalize_slot_budget",
    "pad_phantom_column", "placement_to_permutation",
    "permutation_to_placement",
    "predicted_layer_latency", "predicted_rank_latencies",
    "reweight_shares_by_speed", "solve_model_placement", "vibe_placement",
    "vibe_r_placement",
    "PlacementPolicy", "PolicyCapabilities", "SolveContext",
    "UnknownPolicyError", "get_policy", "register_policy",
    "registered_policies",
    "StealConfig", "TokenRescheduler",
    "ClusterTopology", "parse_topology", "vibe_h_placement",
    "REGIMES", "SCENARIOS", "ClusterVariability", "VariabilityEvent",
    "VariabilityRegime", "make_cluster", "make_scenario",
]
