# The paper's primary contribution: ViBE — Variability-Informed Binning of
# Experts. Hardware-aware expert placement for distributed MoE serving.
from .activation import ActivationProfiler, routing_tally
from .controller import PlacementUpdate, ViBEConfig, ViBEController
from .drift import DriftConfig, DriftDetector, DriftEvent, cosine_distance
from .incremental import IncrementalResult, Swap, incremental_update
from .perf_model import (DeviceProfile, PerfModel, fit_perf_model,
                         profile_device)
from .placement import (Placement, contiguous_placement, eplb_placement,
                        layer_latency_span, placement_to_permutation,
                        permutation_to_placement, predicted_layer_latency,
                        solve_model_placement, vibe_placement)
from .variability import (REGIMES, ClusterVariability, VariabilityRegime,
                          make_cluster)

__all__ = [
    "ActivationProfiler", "routing_tally",
    "PlacementUpdate", "ViBEConfig", "ViBEController",
    "DriftConfig", "DriftDetector", "DriftEvent", "cosine_distance",
    "IncrementalResult", "Swap", "incremental_update",
    "DeviceProfile", "PerfModel", "fit_perf_model", "profile_device",
    "Placement", "contiguous_placement", "eplb_placement",
    "layer_latency_span", "placement_to_permutation",
    "permutation_to_placement", "predicted_layer_latency",
    "solve_model_placement", "vibe_placement",
    "REGIMES", "ClusterVariability", "VariabilityRegime", "make_cluster",
]
