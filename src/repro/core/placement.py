"""Expert placement solvers (paper §4.2.3, Table 2c, Alg 1 Phase 2).

Three policies, matching the paper's evaluation matrix:

* :func:`contiguous_placement` — vLLM baseline: logical experts partitioned
  contiguously, expert e → rank e // (E/G). No workload or hardware awareness.
* :func:`eplb_placement` — EPLB baseline: greedy token-count balancing.
  Identical machinery to ViBE but with the implicit assumption f_g(n) = n
  (paper: "EPLB implicitly assumes f_g(n)=n, so it cannot compensate for
  hardware throughput differences").
* :func:`vibe_placement` — the paper's contribution. Per layer:
    1. speed estimate  s_g = 1 / f_g(n_ref),  n_ref = N / E (mean per-expert
       token load),
    2. token target    τ_g = N · s_g / Σ_h s_h,
    3. experts assigned in descending load order to the rank farthest below
       its target (most remaining target capacity), subject to the uniform
       slot constraint (same #experts per rank — paper §5.1 keeps memory
       uniform; non-uniform allocation is future work there, optional here).

A placement for one layer is an integer array ``assign`` of shape (E,) with
``assign[e] = rank``; for the whole model a (L, E) matrix. Helpers convert to
the logical→physical permutation used by the JAX MoE layer (models/moe.py).

All solvers are pure numpy host code (control plane).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from .perf_model import PerfModel

__all__ = [
    "Placement",
    "contiguous_placement",
    "eplb_placement",
    "vibe_placement",
    "solve_model_placement",
    "placement_to_permutation",
    "permutation_to_placement",
    "predicted_layer_latency",
    "layer_latency_span",
]


@dataclasses.dataclass(frozen=True)
class Placement:
    """Expert→rank assignment for every MoE layer.

    ``assign``: (L, E) int array, assign[l, e] = EP rank of logical expert e.
    ``perm``:   (L, E) int array, perm[l, p] = logical expert held in physical
                slot p (slots are rank-major: rank g owns slots
                [g*E_loc, (g+1)*E_loc)). This is what the JAX layer consumes.
    """

    assign: np.ndarray
    n_ranks: int

    def __post_init__(self):
        a = np.asarray(self.assign, dtype=np.int32)
        if a.ndim == 1:
            a = a[None, :]
        object.__setattr__(self, "assign", a)
        L, E = a.shape
        if E % self.n_ranks != 0:
            raise ValueError(f"E={E} not divisible by n_ranks={self.n_ranks}")
        counts = np.apply_along_axis(np.bincount, 1, a, minlength=self.n_ranks)
        if not np.all(counts == E // self.n_ranks):
            raise ValueError("placement violates uniform slots-per-rank")

    @property
    def n_layers(self) -> int:
        return self.assign.shape[0]

    @property
    def n_experts(self) -> int:
        return self.assign.shape[1]

    @property
    def experts_per_rank(self) -> int:
        return self.n_experts // self.n_ranks

    @property
    def perm(self) -> np.ndarray:
        return placement_to_permutation(self.assign, self.n_ranks)

    def rank_loads(self, w: np.ndarray) -> np.ndarray:
        """Per-rank token loads (L, G) given per-expert loads w (L, E)."""
        w = np.atleast_2d(np.asarray(w, dtype=np.float64))
        L, E = self.assign.shape
        out = np.zeros((L, self.n_ranks))
        for l in range(L):
            np.add.at(out[l], self.assign[l], w[l])
        return out

    def moved_experts(self, other: "Placement") -> int:
        """Number of (layer, expert) pairs whose rank differs vs ``other``."""
        return int(np.sum(self.assign != other.assign))


def placement_to_permutation(assign: np.ndarray, n_ranks: int) -> np.ndarray:
    """(L,E) assign → (L,E) perm with perm[l,p] = logical expert in slot p.

    Slots are rank-major; within a rank, logical experts are ordered by id
    (deterministic so repeated solves with equal assignment produce identical
    physical layouts — minimizes spurious weight movement).
    """
    assign = np.atleast_2d(assign)
    L, E = assign.shape
    e_loc = E // n_ranks
    perm = np.empty((L, E), dtype=np.int32)
    for l in range(L):
        for g in range(n_ranks):
            experts = np.flatnonzero(assign[l] == g)
            perm[l, g * e_loc:(g + 1) * e_loc] = experts
    return perm


def permutation_to_placement(perm: np.ndarray, n_ranks: int) -> np.ndarray:
    perm = np.atleast_2d(perm)
    L, E = perm.shape
    e_loc = E // n_ranks
    assign = np.empty((L, E), dtype=np.int32)
    for l in range(L):
        for p in range(E):
            assign[l, perm[l, p]] = p // e_loc
    return assign


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------

def contiguous_placement(n_layers: int, n_experts: int, n_ranks: int) -> Placement:
    """vLLM default: expert e on rank e // (E/G), identical at every layer."""
    e_loc = n_experts // n_ranks
    row = np.arange(n_experts, dtype=np.int32) // e_loc
    return Placement(np.tile(row, (n_layers, 1)), n_ranks)


def _greedy_target_assign(
    w_layer: np.ndarray,           # (E,) per-expert token load
    targets: np.ndarray,           # (G,) token targets τ_g
    n_ranks: int,
) -> np.ndarray:
    """Paper Alg 1 Phase 2 inner loop with the uniform-slot constraint.

    Experts in descending load order go to argmax_g (τ_g − n_g) among ranks
    with free slots.
    """
    E = w_layer.size
    e_loc = E // n_ranks
    order = np.argsort(-w_layer, kind="stable")
    load = np.zeros(n_ranks)
    slots = np.full(n_ranks, e_loc, dtype=np.int64)
    assign = np.empty(E, dtype=np.int32)
    for e in order:
        gap = targets - load
        gap[slots == 0] = -np.inf
        g = int(np.argmax(gap))
        assign[e] = g
        load[g] += w_layer[e]
        slots[g] -= 1
    return assign


def eplb_placement(
    w: np.ndarray,                 # (L, E) activation matrix
    n_ranks: int,
) -> Placement:
    """EPLB: equalize token counts. τ_g = N/G for all g (f_g(n)=n)."""
    w = np.atleast_2d(np.asarray(w, dtype=np.float64))
    L, E = w.shape
    assign = np.empty((L, E), dtype=np.int32)
    for l in range(L):
        N = w[l].sum()
        targets = np.full(n_ranks, N / n_ranks)
        assign[l] = _greedy_target_assign(w[l], targets, n_ranks)
    return Placement(assign, n_ranks)


def vibe_placement(
    w: np.ndarray,                 # (L, E) activation matrix
    perf_models: Sequence[PerfModel],
    n_ref_mode: str = "rank",
) -> Placement:
    """ViBE (paper Alg 1 Phase 2): speed-proportional targets, greedy fill.

    ``n_ref_mode`` picks the operating point for the speed estimate
    s_g = 1/f_g(n_ref):

    * ``"rank"`` (default) — n_ref = N/G, the mean per-*rank* token load.
      f_g maps whole-device kernel load to latency, so this evaluates each
      device at the load it will actually run — where power-limited
      variability is expressed (paper Fig 5).
    * ``"expert"`` — n_ref = N/E, Algorithm 1's literal text. At low
      per-expert loads f_g sits in the unstressed regime where all devices
      look identical, degenerating to EPLB (see DESIGN.md §3 fidelity note).
    """
    w = np.atleast_2d(np.asarray(w, dtype=np.float64))
    L, E = w.shape
    G = len(perf_models)
    assign = np.empty((L, E), dtype=np.int32)
    for l in range(L):
        N = float(w[l].sum())
        n_ref = max(N / (G if n_ref_mode == "rank" else E), 1.0)
        s = np.array([m.speed(n_ref) for m in perf_models])
        targets = N * s / s.sum()
        assign[l] = _greedy_target_assign(w[l], targets, n_ranks=G)
    return Placement(assign, G)


def solve_model_placement(
    policy: str,
    w: np.ndarray,
    n_ranks: int,
    perf_models: Optional[Sequence[PerfModel]] = None,
) -> Placement:
    """Uniform entry point used by the serving engine and benchmarks."""
    w = np.atleast_2d(w)
    if policy == "contiguous":
        return contiguous_placement(w.shape[0], w.shape[1], n_ranks)
    if policy == "eplb":
        return eplb_placement(w, n_ranks)
    if policy == "vibe":
        if perf_models is None:
            raise ValueError("vibe placement requires perf_models")
        if len(perf_models) != n_ranks:
            raise ValueError("need one perf model per rank")
        return vibe_placement(w, perf_models)
    raise ValueError(f"unknown policy {policy!r}")


# ---------------------------------------------------------------------------
# Objective evaluation (paper §4.2.3 problem formulation)
# ---------------------------------------------------------------------------

def predicted_layer_latency(
    assign_layer: np.ndarray,      # (E,)
    w_layer: np.ndarray,           # (E,)
    perf_models: Sequence[PerfModel],
) -> np.ndarray:
    """Per-rank predicted latencies f_g(n_g) for one layer → (G,)."""
    G = len(perf_models)
    load = np.zeros(G)
    np.add.at(load, assign_layer, w_layer)
    return np.array([perf_models[g](load[g]) for g in range(G)])


def layer_latency_span(
    placement: Placement,
    w: np.ndarray,
    perf_models: Sequence[PerfModel],
) -> np.ndarray:
    """Per-layer (T_max, T_mean, T_min) → (L, 3). T = max is layer latency."""
    w = np.atleast_2d(w)
    out = np.empty((placement.n_layers, 3))
    for l in range(placement.n_layers):
        lat = predicted_layer_latency(placement.assign[l], w[l], perf_models)
        out[l] = (lat.max(), lat.mean(), lat.min())
    return out
