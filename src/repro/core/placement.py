"""Expert placement solvers (paper §4.2.3, Table 2c, Alg 1 Phase 2 + ViBE-R).

Four policies, matching the paper's evaluation matrix plus the cluster-scale
replication extension:

* :func:`contiguous_placement` — vLLM baseline: logical experts partitioned
  contiguously, expert e → rank e // (E/G). No workload or hardware awareness.
* :func:`eplb_placement` — EPLB baseline: greedy token-count balancing.
  Identical machinery to ViBE but with the implicit assumption f_g(n) = n
  (paper: "EPLB implicitly assumes f_g(n)=n, so it cannot compensate for
  hardware throughput differences").
* :func:`vibe_placement` — the paper's contribution. Per layer:
    1. speed estimate  s_g = 1 / f_g(n_ref),  n_ref = N / E (mean per-expert
       token load),
    2. token target    τ_g = N · s_g / Σ_h s_h,
    3. experts assigned in descending load order to the rank farthest below
       its target (most remaining target capacity), subject to the uniform
       slot constraint (same #experts per rank — paper §5.1 keeps memory
       uniform; non-uniform allocation is future work there, optional here).
* :func:`vibe_r_placement` — **ViBE-R**: replication-aware co-optimization
  of workload skew and hardware variability at cluster scale (paper Fig 15
  regime; HarMoEny-style redundant sharding). Under a slot budget of
  ``slots_per_rank × G`` physical slots it (a) grants extra *copies* to the
  hottest experts (greedy largest-per-copy-load splitting), (b) spreads each
  expert's traffic over its copies speed-proportionally (fast devices absorb
  a larger share), and (c) runs the whole solve vectorized across layers —
  a 64-rank × 58-layer × 256-expert model solves in milliseconds.

Singleton placements are an integer array ``assign`` of shape (E,) with
``assign[e] = rank`` per layer ((L, E) for the model); replicated placements
are a *slot table* ``slot_expert`` of shape (L, S) (logical expert held in
each physical slot, entries repeat for replicas) plus per-copy traffic
shares. Both convert to the logical→physical permutation consumed by the
JAX MoE layer (models/moe.py ``build_slots_of``).

All solvers are pure numpy host code (control plane). The greedy fills are
vectorized across layers: a Python loop runs only over the E (or S) item
*positions*, with every layer advanced simultaneously via argmax/scatter
ops — the per-layer reference implementations are kept for the equivalence
tests.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Union

import numpy as np

from .perf_model import PerfModel

__all__ = [
    "Placement",
    "ReplicatedPlacement",
    "copy_enumeration",
    "copy_share_cdf",
    "contiguous_placement",
    "eplb_placement",
    "vibe_placement",
    "vibe_r_placement",
    "solve_model_placement",
    "reweight_shares_by_speed",
    "placement_to_permutation",
    "permutation_to_placement",
    "predicted_layer_latency",
    "predicted_rank_latencies",
    "layer_latency_span",
    "default_slots_per_rank",
]


@dataclasses.dataclass(frozen=True)
class Placement:
    """Expert→rank assignment for every MoE layer (one copy per expert).

    ``assign``: (L, E) int array, assign[l, e] = EP rank of logical expert e.
    ``perm``:   (L, E) int array, perm[l, p] = logical expert held in physical
                slot p (slots are rank-major: rank g owns slots
                [g*E_loc, (g+1)*E_loc)). This is what the JAX layer consumes.
    """

    assign: np.ndarray
    n_ranks: int

    def __post_init__(self):
        a = np.asarray(self.assign, dtype=np.int32)
        if a.ndim == 1:
            a = a[None, :]
        object.__setattr__(self, "assign", a)
        L, E = a.shape
        if E % self.n_ranks != 0:
            raise ValueError(f"E={E} not divisible by n_ranks={self.n_ranks}")
        counts = np.apply_along_axis(np.bincount, 1, a, minlength=self.n_ranks)
        if not np.all(counts == E // self.n_ranks):
            raise ValueError("placement violates uniform slots-per-rank")

    @property
    def n_layers(self) -> int:
        return self.assign.shape[0]

    @property
    def n_experts(self) -> int:
        return self.assign.shape[1]

    @property
    def n_slots(self) -> int:
        return self.assign.shape[1]

    @property
    def experts_per_rank(self) -> int:
        return self.n_experts // self.n_ranks

    @property
    def perm(self) -> np.ndarray:
        return placement_to_permutation(self.assign, self.n_ranks)

    def rank_loads(self, w: np.ndarray) -> np.ndarray:
        """Per-rank token loads (L, G) given per-expert loads w (L, E)."""
        w = np.atleast_2d(np.asarray(w, dtype=np.float64))
        L, E = self.assign.shape
        G = self.n_ranks
        flat = (np.arange(L)[:, None] * G + self.assign).ravel()
        return np.bincount(flat, weights=w.ravel(),
                           minlength=L * G).reshape(L, G)

    def moved_experts(self, other: "Placement") -> int:
        """Number of (layer, expert) pairs whose rank differs vs ``other``."""
        return int(np.sum(self.assign != other.assign))


@dataclasses.dataclass(frozen=True)
class ReplicatedPlacement:
    """(expert, copy)→slot placement with per-copy traffic shares (ViBE-R).

    ``slot_expert``: (L, S) int array — logical expert whose weights occupy
        physical slot s. Slots are rank-major (rank g owns
        [g*S_loc, (g+1)*S_loc)); entries *repeat* when an expert is
        replicated. Every logical expert holds ≥ 1 slot per layer.
    ``share``: (L, S) float array — fraction of the expert's token traffic
        dispatched to this copy; sums to 1 over the copies of each
        (layer, expert). The model layer approximates fractional shares by
        hashing assignments across copies; the solver's shares are what the
        latency objective (and the simulator) score.
    """

    slot_expert: np.ndarray
    share: np.ndarray
    n_ranks: int
    n_experts: int

    def __post_init__(self):
        se = np.atleast_2d(np.asarray(self.slot_expert, dtype=np.int32))
        sh = np.atleast_2d(np.asarray(self.share, dtype=np.float64))
        if se.shape != sh.shape:
            raise ValueError(f"slot_expert {se.shape} != share {sh.shape}")
        L, S = se.shape
        if S % self.n_ranks != 0:
            raise ValueError(f"S={S} not divisible by n_ranks={self.n_ranks}")
        if se.min() < 0 or se.max() >= self.n_experts:
            raise ValueError("slot_expert ids outside [0, n_experts)")
        counts = _replica_counts(se, self.n_experts)
        if np.any(counts == 0):
            raise ValueError("some logical expert has no physical slot")
        if sh.min() < -1e-12:
            raise ValueError("negative copy share")
        sums = np.zeros((L, self.n_experts))
        np.add.at(sums, (np.arange(L)[:, None], se), sh)
        if not np.allclose(sums, 1.0, atol=1e-6):
            raise ValueError("copy shares must sum to 1 per (layer, expert)")
        object.__setattr__(self, "slot_expert", se)
        object.__setattr__(self, "share", sh)

    @property
    def n_layers(self) -> int:
        return self.slot_expert.shape[0]

    @property
    def n_slots(self) -> int:
        return self.slot_expert.shape[1]

    @property
    def slots_per_rank(self) -> int:
        return self.n_slots // self.n_ranks

    @property
    def perm(self) -> np.ndarray:
        """Slot table consumed by models/moe.py (entries repeat = replicas)."""
        return self.slot_expert

    def n_copies(self) -> np.ndarray:
        """(L, E) replica count per logical expert."""
        return _replica_counts(self.slot_expert, self.n_experts)

    def copy_shares(self, r_max: Optional[int] = None) -> np.ndarray:
        """(L, E, r_max) per-copy traffic shares, copies in slot order.

        The copy axis matches the enumeration ``build_slots_of`` uses for
        its ``slots_of`` table (ascending physical slot), so index r here
        is the share of the copy living in ``slots_of[l, e, r]``. Entries
        past an expert's replica count are zero. ``r_max`` pads the copy
        axis (must be ≥ the actual maximum replica count).
        """
        se = self.slot_expert
        L, S = se.shape
        counts = self.n_copies()
        rm = int(counts.max()) if r_max is None else int(r_max)
        if rm < int(counts.max()):
            raise ValueError(f"r_max={rm} < max replica count {counts.max()}")
        order, e_sorted, occ = copy_enumeration(se)
        sh_sorted = np.take_along_axis(self.share, order, axis=1)
        out = np.zeros((L, self.n_experts, rm))
        rows = np.repeat(np.arange(L), S)
        out[rows, e_sorted.ravel(), occ.ravel()] = sh_sorted.ravel()
        return out

    def copy_cdf(self, r_max: Optional[int] = None) -> np.ndarray:
        """(L, E, r_max) cumulative copy-share table for weighted dispatch.

        This is what the model layer consumes (via ``make_moe_tables``) for
        inverse-CDF replica selection: assignment with uniform u picks the
        first copy r with u < cdf[l, e, r]. Delegates to the canonical
        :func:`copy_share_cdf` builder — one implementation for the solver
        and the model seam.
        """
        return copy_share_cdf(self.slot_expert, self.n_experts,
                              share=self.share, r_max=r_max)

    def rank_loads(self, w: np.ndarray) -> np.ndarray:
        """Per-rank token loads (L, G): expert loads split over copies."""
        w = np.atleast_2d(np.asarray(w, dtype=np.float64))
        L, S = self.slot_expert.shape
        slot_load = np.take_along_axis(w, self.slot_expert, axis=1) * self.share
        return slot_load.reshape(L, self.n_ranks, self.slots_per_rank).sum(2)

    def moved_experts(self, other: "ReplicatedPlacement") -> int:
        """(layer, slot) pairs whose resident expert differs vs ``other`` —
        the weight-migration volume in expert-tensor units."""
        return int(np.sum(self.slot_expert != other.slot_expert))


AnyPlacement = Union[Placement, ReplicatedPlacement]


def _replica_counts(slot_expert: np.ndarray, n_experts: int) -> np.ndarray:
    """(L, S) slot table → (L, E) copies per logical expert."""
    return np.apply_along_axis(np.bincount, 1, slot_expert,
                               minlength=n_experts)


def copy_enumeration(slot_table: np.ndarray):
    """Canonical copy enumeration of a (L, S) slot table, vectorized.

    Groups each layer's slots by resident id — stable, so slot-ascending
    within an id — and indexes each slot's occurrence within its run:
    returns ``(order, id_sorted, occ)``, all (L, S), where ``order`` maps
    sorted position → physical slot, ``id_sorted`` is the resident id at
    that position, and ``occ`` says "this is the id's occ-th copy".

    This ordering is THE copy axis: ``build_slots_of`` (models/sharding)
    lays out ``slots_of[l, e, r]`` in it, and every share/CDF table must
    enumerate copies identically or solver-side shares and model-side
    dispatch silently disagree — which is why all of them call this one
    helper.
    """
    slot_table = np.atleast_2d(slot_table)
    L, S = slot_table.shape
    order = np.argsort(slot_table, axis=1, kind="stable")
    id_sorted = np.take_along_axis(slot_table, order, axis=1)
    pos = np.arange(S)[None, :]
    new_run = np.concatenate(
        [np.ones((L, 1), bool), id_sorted[:, 1:] != id_sorted[:, :-1]],
        axis=1)
    run_start = np.maximum.accumulate(np.where(new_run, pos, 0), axis=1)
    return order, id_sorted, pos - run_start


def copy_share_cdf(slot_table: np.ndarray, n_experts: int,
                   share: Optional[np.ndarray] = None,
                   r_max: Optional[int] = None) -> np.ndarray:
    """THE cumulative copy-share table: (L, S) slot table → (L, E, r_max).

    The single normalization behind ``ReplicatedPlacement.copy_cdf`` and
    ``models.sharding.build_copy_cdf`` — solver-side scoring and
    model-side dispatch must agree bit-for-bit on this table, so there is
    exactly one implementation. Entries ≥ ``n_experts`` are phantom
    padding and take no share; ``share=None`` means a uniform split over
    each expert's copies; trailing (padding) entries along the copy axis
    are 1.0 so inverse-CDF selection can never land outside an expert's
    real copies. Experts whose shares sum to zero (fully starved) fall
    back to a uniform split. Returns float32.
    """
    slot_table = np.atleast_2d(slot_table)
    L, S = slot_table.shape
    if share is not None:
        share = np.atleast_2d(np.asarray(share, dtype=np.float64))
        if share.shape != slot_table.shape:
            raise ValueError(
                f"share shape {share.shape} != table {slot_table.shape}")
    clipped = np.minimum(slot_table, n_experts)      # phantoms → sentinel E
    counts = np.apply_along_axis(np.bincount, 1, clipped,
                                 minlength=n_experts + 1)[:, :n_experts]
    rm = int(counts.max()) if r_max is None else int(r_max)
    if rm < int(counts.max()):
        raise ValueError(f"r_max={rm} < max replica count {counts.max()}")
    order, e_sorted, occ = copy_enumeration(clipped)
    sh_sorted = (np.ones((L, S))
                 if share is None else np.take_along_axis(share, order, 1))
    acc = np.zeros((L, n_experts, rm), dtype=np.float64)
    li, si = np.nonzero(e_sorted < n_experts)
    acc[li, e_sorted[li, si], occ[li, si]] = sh_sorted[li, si]
    totals = acc.sum(-1)
    dead = totals <= 0.0
    if dead.any():
        uniform = (np.arange(rm)[None, None, :] < counts[..., None]) * 1.0
        acc = np.where(dead[..., None], uniform, acc)
        totals = acc.sum(-1)
    cdf = np.cumsum(acc, axis=-1) / totals[..., None]
    return np.minimum(cdf, 1.0).astype(np.float32)


def placement_to_permutation(assign: np.ndarray, n_ranks: int) -> np.ndarray:
    """(L,E) assign → (L,E) perm with perm[l,p] = logical expert in slot p.

    Slots are rank-major; within a rank, logical experts are ordered by id
    (deterministic so repeated solves with equal assignment produce identical
    physical layouts — minimizes spurious weight movement). Implemented as a
    single stable argsort per layer: sorting expert ids by rank keeps the
    ascending-id order within each rank.
    """
    assign = np.atleast_2d(assign)
    return np.argsort(assign, axis=1, kind="stable").astype(np.int32)


def permutation_to_placement(perm: np.ndarray, n_ranks: int) -> np.ndarray:
    perm = np.atleast_2d(perm)
    L, E = perm.shape
    e_loc = E // n_ranks
    rank_of_slot = (np.arange(E, dtype=np.int32) // e_loc)[None, :]
    assign = np.empty((L, E), dtype=np.int32)
    np.put_along_axis(assign, perm, np.broadcast_to(rank_of_slot, (L, E)),
                      axis=1)
    return assign


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------

def contiguous_placement(n_layers: int, n_experts: int, n_ranks: int) -> Placement:
    """vLLM default: expert e on rank e // (E/G), identical at every layer."""
    e_loc = n_experts // n_ranks
    row = np.arange(n_experts, dtype=np.int32) // e_loc
    return Placement(np.tile(row, (n_layers, 1)), n_ranks)


def _greedy_target_assign(
    w_layer: np.ndarray,           # (E,) per-expert token load
    targets: np.ndarray,           # (G,) token targets τ_g
    n_ranks: int,
) -> np.ndarray:
    """Paper Alg 1 Phase 2 inner loop with the uniform-slot constraint.

    Experts in descending load order go to argmax_g (τ_g − n_g) among ranks
    with free slots. Per-layer reference implementation — production solves
    go through :func:`_greedy_target_assign_vec`; an equivalence test pins
    the two to identical output.
    """
    E = w_layer.size
    e_loc = E // n_ranks
    order = np.argsort(-w_layer, kind="stable")
    load = np.zeros(n_ranks)
    slots = np.full(n_ranks, e_loc, dtype=np.int64)
    assign = np.empty(E, dtype=np.int32)
    for e in order:
        gap = targets - load
        gap[slots == 0] = -np.inf
        g = int(np.argmax(gap))
        assign[e] = g
        load[g] += w_layer[e]
        slots[g] -= 1
    return assign


def _greedy_target_assign_vec(
    w: np.ndarray,                 # (L, E) per-expert token loads
    targets: np.ndarray,           # (L, G) token targets τ_{l,g}
) -> np.ndarray:
    """Vectorized greedy fill: all layers advance one item per iteration.

    The Python loop runs over the E item *positions* (descending-load order
    within each layer); each iteration is O(L·G) numpy work, so DeepSeek
    scale (L=58, E=256, G=64) completes in milliseconds instead of the
    seconds the per-layer double loop needs. Produces exactly the per-layer
    reference's output (same float ops in the same order, same argmax
    tie-breaking).
    """
    w = np.asarray(w, dtype=np.float64)
    L, E = w.shape
    G = targets.shape[1]
    e_loc = E // G
    order = np.argsort(-w, axis=1, kind="stable")                # (L, E)
    rows = np.arange(L)
    load = np.zeros((L, G))
    slots = np.full((L, G), e_loc, dtype=np.int64)
    assign = np.empty((L, E), dtype=np.int32)
    for i in range(E):
        item = order[:, i]                                       # (L,)
        gap = targets - load
        gap[slots == 0] = -np.inf
        g = np.argmax(gap, axis=1)                               # (L,)
        assign[rows, item] = g
        load[rows, g] += w[rows, item]
        slots[rows, g] -= 1
    return assign


def eplb_placement(
    w: np.ndarray,                 # (L, E) activation matrix
    n_ranks: int,
) -> Placement:
    """EPLB: equalize token counts. τ_g = N/G for all g (f_g(n)=n)."""
    w = np.atleast_2d(np.asarray(w, dtype=np.float64))
    L, E = w.shape
    targets = np.repeat(w.sum(axis=1, keepdims=True) / n_ranks, n_ranks,
                        axis=1)
    return Placement(_greedy_target_assign_vec(w, targets), n_ranks)


def _speed_targets(
    w: np.ndarray,                 # (L, E)
    perf_models: Sequence[PerfModel],
    n_ref_mode: str,
) -> tuple:
    """Per-layer speeds s_{l,g} and token targets τ_{l,g} → ((L,G), (L,G))."""
    L, E = w.shape
    G = len(perf_models)
    N = w.sum(axis=1)                                            # (L,)
    n_ref = np.maximum(N / (G if n_ref_mode == "rank" else E), 1.0)
    s = np.empty((L, G))
    for g, m in enumerate(perf_models):
        s[:, g] = 1.0 / np.asarray(m(n_ref), dtype=np.float64)
    targets = N[:, None] * s / s.sum(axis=1, keepdims=True)
    return s, targets


def vibe_placement(
    w: np.ndarray,                 # (L, E) activation matrix
    perf_models: Sequence[PerfModel],
    n_ref_mode: str = "rank",
) -> Placement:
    """ViBE (paper Alg 1 Phase 2): speed-proportional targets, greedy fill.

    ``n_ref_mode`` picks the operating point for the speed estimate
    s_g = 1/f_g(n_ref):

    * ``"rank"`` (default) — n_ref = N/G, the mean per-*rank* token load.
      f_g maps whole-device kernel load to latency, so this evaluates each
      device at the load it will actually run — where power-limited
      variability is expressed (paper Fig 5).
    * ``"expert"`` — n_ref = N/E, Algorithm 1's literal text. At low
      per-expert loads f_g sits in the unstressed regime where all devices
      look identical, degenerating to EPLB (see DESIGN.md §3 fidelity note).
    """
    w = np.atleast_2d(np.asarray(w, dtype=np.float64))
    _, targets = _speed_targets(w, perf_models, n_ref_mode)
    return Placement(_greedy_target_assign_vec(w, targets),
                     len(perf_models))


# ---------------------------------------------------------------------------
# ViBE-R: replication-aware placement
# ---------------------------------------------------------------------------

def default_slots_per_rank(n_experts: int, n_ranks: int) -> int:
    """Default ViBE-R slot budget: the singleton footprint rounded up, plus
    one spare slot per rank when E divides G evenly (otherwise the phantom
    padding slots already provide replication headroom)."""
    base = -(-n_experts // n_ranks)                  # ceil(E/G)
    return base + (1 if base * n_ranks == n_experts else 0)


def _replication_degrees(
    w: np.ndarray,                 # (L, E)
    n_extra: int,                  # copies beyond one-per-expert
    max_copies: int,
) -> np.ndarray:
    """Greedy hot-expert splitting, vectorized across layers.

    Start from one copy each; repeatedly grant a copy to the expert with the
    largest *per-copy* load w_e / c_e (the straggler bound a replica buys
    down the most). ``n_extra`` iterations of O(L·E) work.
    """
    L, E = w.shape
    rows = np.arange(L)
    copies = np.ones((L, E), dtype=np.int64)
    q = w.astype(np.float64).copy()                  # per-copy load
    for _ in range(n_extra):
        q_masked = np.where(copies >= max_copies, -np.inf, q)
        e_star = np.argmax(q_masked, axis=1)
        copies[rows, e_star] += 1
        q[rows, e_star] = w[rows, e_star] / copies[rows, e_star]
    return copies


def vibe_r_placement(
    w: np.ndarray,                 # (L, E) activation matrix
    perf_models: Sequence[PerfModel],
    slots_per_rank: Optional[int] = None,
    n_ref_mode: str = "rank",
) -> ReplicatedPlacement:
    """ViBE-R: co-optimize replication degree with per-device speed.

    Three phases, all vectorized across layers:

    1. **Replicate** — under the slot budget S = slots_per_rank × G, grant
       the S − E spare slots to the hottest experts (largest per-copy load
       first), capped at one copy per rank.
    2. **Place** — greedy speed-target fill over the (expert, copy) items in
       descending per-copy load order, to the rank farthest below its ViBE
       token target τ_g; a copy avoids ranks already holding a copy of the
       same expert (a colocated replica absorbs no skew).
    3. **Share** — split each expert's traffic over its copies
       proportionally to the *speed* of the rank each copy landed on, so
       the share lands where f_g is fastest.
    """
    w = np.atleast_2d(np.asarray(w, dtype=np.float64))
    L, E = w.shape
    G = len(perf_models)
    s_loc = (default_slots_per_rank(E, G) if slots_per_rank is None
             else int(slots_per_rank))
    S = s_loc * G
    if S < E:
        raise ValueError(
            f"slot budget {S} (= {s_loc}×{G}) cannot hold {E} experts")
    if s_loc > E:
        raise ValueError(f"slots_per_rank={s_loc} > E={E}: every rank would "
                         "hold the full expert set")
    rows = np.arange(L)
    speeds, targets = _speed_targets(w, perf_models, n_ref_mode)

    # Phase 1: replication degrees (S − E spare copies, ≤ G copies each)
    copies = _replication_degrees(w, S - E, max_copies=G)

    # Expand to per-copy items: ce (L, S) expert id, cl (L, S) per-copy load
    # (uniform split at placement time; phase 3 reweights by speed).
    cum = np.cumsum(copies, axis=1)                              # (L, E)
    ce = (np.arange(S)[None, :, None] >= cum[:, None, :]).sum(2) \
        .astype(np.int32)                                        # (L, S)
    cl = np.take_along_axis(w, ce, axis=1) \
        / np.take_along_axis(copies, ce, axis=1)

    # Phase 2: vectorized greedy fill over copies (descending per-copy load)
    order = np.argsort(-cl, axis=1, kind="stable")
    load = np.zeros((L, G))
    slots_free = np.full((L, G), s_loc, dtype=np.int64)
    on_rank = np.zeros((L, G, E), dtype=bool)
    copy_rank = np.empty((L, S), dtype=np.int32)
    for i in range(S):
        item = order[:, i]                                       # (L,)
        e_item = ce[rows, item]                                  # (L,)
        gap = targets - load
        invalid = (slots_free == 0) | on_rank[rows, :, e_item]
        # rows where the dedup constraint is unsatisfiable fall back to the
        # slot constraint alone (can only happen when copies ≥ free ranks)
        stuck = invalid.all(axis=1)
        if stuck.any():
            invalid[stuck] = (slots_free[stuck] == 0)
        gap[invalid] = -np.inf
        g = np.argmax(gap, axis=1)                               # (L,)
        copy_rank[rows, item] = g
        load[rows, g] += cl[rows, item]
        slots_free[rows, g] -= 1
        on_rank[rows, g, e_item] = True

    # Phase 3: speed-proportional copy shares
    sp = speeds[rows[:, None], copy_rank]                        # (L, S)
    denom = np.zeros((L, E))
    np.add.at(denom, (rows[:, None], ce), sp)
    share = sp / np.take_along_axis(denom, ce, axis=1)

    # Lay out rank-major slots, copies ordered by expert id within a rank
    key = copy_rank.astype(np.int64) * (E + 1) + ce
    lay = np.argsort(key, axis=1, kind="stable")
    return ReplicatedPlacement(
        slot_expert=np.take_along_axis(ce, lay, axis=1),
        share=np.take_along_axis(share, lay, axis=1),
        n_ranks=G, n_experts=E)


def reweight_shares_by_speed(
    placement: ReplicatedPlacement,
    w: np.ndarray,                 # (L, E) activation matrix
    perf_models: Sequence[PerfModel],
    n_ref_mode: str = "rank",
) -> ReplicatedPlacement:
    """Re-proportion each expert's copy shares to its ranks' current speeds.

    Solver phase 3 applied to an *existing* slot table: after slot-granular
    swaps (incremental updates) move copies between ranks, the shares riding
    with them still reflect the ranks they came from. This recomputes
    share ∝ s_g = 1/f_g(n_ref) for the rank each copy now occupies, keeping
    per-expert sums at 1 and the slot table untouched — so the weighted
    dispatch keeps steering traffic toward the fast copies.
    """
    w = np.atleast_2d(np.asarray(w, dtype=np.float64))
    se = placement.slot_expert
    L, S = se.shape
    if w.shape != (L, placement.n_experts):
        raise ValueError(f"w shape {w.shape} != {(L, placement.n_experts)}")
    speeds, _ = _speed_targets(w, perf_models, n_ref_mode)
    rank_of = np.arange(S) // placement.slots_per_rank
    sp = speeds[:, rank_of]                                      # (L, S)
    rows = np.arange(L)
    denom = np.zeros((L, placement.n_experts))
    np.add.at(denom, (rows[:, None], se), sp)
    share = sp / np.take_along_axis(denom, se, axis=1)
    return ReplicatedPlacement(se.copy(), share, placement.n_ranks,
                               placement.n_experts)


def solve_model_placement(
    policy: str,
    w: np.ndarray,
    n_ranks: int,
    perf_models: Optional[Sequence[PerfModel]] = None,
    slots_per_rank: Optional[int] = None,
) -> AnyPlacement:
    """Uniform entry point used by the serving engine and benchmarks.

    ``slots_per_rank`` only applies to the ``"vibe_r"`` policy: the physical
    slot budget per rank (≥ ceil(E/G); the excess becomes hot-expert
    replicas). Other policies keep the paper's uniform one-slot-per-expert
    memory footprint.
    """
    w = np.atleast_2d(w)
    if policy == "contiguous":
        return contiguous_placement(w.shape[0], w.shape[1], n_ranks)
    if policy == "eplb":
        return eplb_placement(w, n_ranks)
    if policy in ("vibe", "vibe_r"):
        if perf_models is None:
            raise ValueError(f"{policy} placement requires perf_models")
        if len(perf_models) != n_ranks:
            raise ValueError("need one perf model per rank")
        if policy == "vibe":
            return vibe_placement(w, perf_models)
        return vibe_r_placement(w, perf_models, slots_per_rank=slots_per_rank)
    raise ValueError(f"unknown policy {policy!r}")


# ---------------------------------------------------------------------------
# Objective evaluation (paper §4.2.3 problem formulation)
# ---------------------------------------------------------------------------

def predicted_layer_latency(
    assign_layer: np.ndarray,      # (E,)
    w_layer: np.ndarray,           # (E,)
    perf_models: Sequence[PerfModel],
) -> np.ndarray:
    """Per-rank predicted latencies f_g(n_g) for one layer → (G,)."""
    G = len(perf_models)
    load = np.zeros(G)
    np.add.at(load, assign_layer, w_layer)
    return np.array([perf_models[g](load[g]) for g in range(G)])


def predicted_rank_latencies(
    placement: AnyPlacement,
    w: np.ndarray,                 # (L, E)
    perf_models: Sequence[PerfModel],
) -> np.ndarray:
    """Predicted f_g(n_{l,g}) → (L, G); replica-aware via ``rank_loads``."""
    load = placement.rank_loads(np.atleast_2d(w))
    lat = np.empty_like(load)
    for g, m in enumerate(perf_models):
        lat[:, g] = m(load[:, g])
    return lat


def layer_latency_span(
    placement: AnyPlacement,
    w: np.ndarray,
    perf_models: Sequence[PerfModel],
) -> np.ndarray:
    """Per-layer (T_max, T_mean, T_min) → (L, 3). T = max is layer latency."""
    lat = predicted_rank_latencies(placement, w, perf_models)
    return np.stack([lat.max(1), lat.mean(1), lat.min(1)], axis=1)
