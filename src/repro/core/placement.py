"""Expert placement solvers (paper §4.2.3, Table 2c, Alg 1 Phase 2 + ViBE-R).

Four policies, matching the paper's evaluation matrix plus the cluster-scale
replication extension:

* :func:`contiguous_placement` — vLLM baseline: logical experts partitioned
  contiguously, expert e → rank e // (E/G). No workload or hardware awareness.
* :func:`eplb_placement` — EPLB baseline: greedy token-count balancing.
  Identical machinery to ViBE but with the implicit assumption f_g(n) = n
  (paper: "EPLB implicitly assumes f_g(n)=n, so it cannot compensate for
  hardware throughput differences").
* :func:`vibe_placement` — the paper's contribution. Per layer:
    1. speed estimate  s_g = 1 / f_g(n_ref),  n_ref = N / E (mean per-expert
       token load),
    2. token target    τ_g = N · s_g / Σ_h s_h,
    3. experts assigned in descending load order to the rank farthest below
       its target (most remaining target capacity), subject to the uniform
       slot constraint (same #experts per rank — paper §5.1 keeps memory
       uniform; non-uniform allocation is future work there, optional here).
* :func:`vibe_r_placement` — **ViBE-R**: replication-aware co-optimization
  of workload skew and hardware variability at cluster scale (paper Fig 15
  regime; HarMoEny-style redundant sharding). Under a slot budget of
  ``slots_per_rank × G`` physical slots it (a) grants extra *copies* to the
  hottest experts (greedy largest-per-copy-load splitting), (b) spreads each
  expert's traffic over its copies speed-proportionally (fast devices absorb
  a larger share), and (c) runs the whole solve vectorized across layers —
  a 64-rank × 58-layer × 256-expert model solves in milliseconds.

Singleton placements are an integer array ``assign`` of shape (E,) with
``assign[e] = rank`` per layer ((L, E) for the model); replicated placements
are a *slot table* ``slot_expert`` of shape (L, S) (logical expert held in
each physical slot, entries repeat for replicas) plus per-copy traffic
shares. Both convert to the logical→physical permutation consumed by the
JAX MoE layer (models/moe.py ``build_slots_of``).

All solvers are pure numpy host code (control plane). The greedy fills are
vectorized across layers: a Python loop runs only over the E (or S) item
*positions*, with every layer advanced simultaneously via argmax/scatter
ops — the per-layer reference implementations are kept for the equivalence
tests.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Sequence, Union

import numpy as np

from .perf_model import PerfModel

__all__ = [
    "Placement",
    "ReplicatedPlacement",
    "copy_enumeration",
    "copy_share_cdf",
    "contiguous_placement",
    "eplb_placement",
    "vibe_placement",
    "vibe_r_placement",
    "gem_placement",
    "harmoeny_placement",
    "solve_model_placement",
    "reweight_shares_by_speed",
    "placement_to_permutation",
    "permutation_to_placement",
    "predicted_layer_latency",
    "predicted_rank_latencies",
    "layer_latency_span",
    "default_slots_per_rank",
    "normalize_slot_budget",
    "pad_phantom_column",
    "inflate_placement",
    "compact_placement",
]


@dataclasses.dataclass(frozen=True)
class Placement:
    """Expert→rank assignment for every MoE layer (one copy per expert).

    ``assign``: (L, E) int array, assign[l, e] = EP rank of logical expert e.
    ``perm``:   (L, E) int array, perm[l, p] = logical expert held in physical
                slot p (slots are rank-major: rank g owns slots
                [g*E_loc, (g+1)*E_loc)). This is what the JAX layer consumes.
    """

    assign: np.ndarray
    n_ranks: int

    def __post_init__(self):
        a = np.asarray(self.assign, dtype=np.int32)
        if a.ndim == 1:
            a = a[None, :]
        object.__setattr__(self, "assign", a)
        L, E = a.shape
        if E % self.n_ranks != 0:
            raise ValueError(f"E={E} not divisible by n_ranks={self.n_ranks}")
        counts = np.apply_along_axis(np.bincount, 1, a, minlength=self.n_ranks)
        if not np.all(counts == E // self.n_ranks):
            raise ValueError("placement violates uniform slots-per-rank")

    @property
    def n_layers(self) -> int:
        return self.assign.shape[0]

    @property
    def n_experts(self) -> int:
        return self.assign.shape[1]

    @property
    def n_slots(self) -> int:
        return self.assign.shape[1]

    @property
    def experts_per_rank(self) -> int:
        return self.n_experts // self.n_ranks

    @property
    def perm(self) -> np.ndarray:
        return placement_to_permutation(self.assign, self.n_ranks)

    def rank_loads(self, w: np.ndarray) -> np.ndarray:
        """Per-rank token loads (L, G) given per-expert loads w (L, E)."""
        w = np.atleast_2d(np.asarray(w, dtype=np.float64))
        L, E = self.assign.shape
        G = self.n_ranks
        flat = (np.arange(L)[:, None] * G + self.assign).ravel()
        return np.bincount(flat, weights=w.ravel(),
                           minlength=L * G).reshape(L, G)

    def moved_experts(self, other: "Placement") -> int:
        """Number of (layer, expert) pairs whose rank differs vs ``other``."""
        return int(np.sum(self.assign != other.assign))


@dataclasses.dataclass(frozen=True)
class ReplicatedPlacement:
    """(expert, copy)→slot placement with per-copy traffic shares (ViBE-R).

    This is the *unified* placement representation: singleton placements are
    the r_max = 1 degenerate (one copy per expert, all shares 1), so every
    consumer — controller, engine, simulator, benchmarks — handles one type
    (see :meth:`from_singleton` / :meth:`to_singleton` / :attr:`assign`).

    ``slot_expert``: (L, S) int array — logical expert whose weights occupy
        physical slot s. Slots are rank-major (rank g owns
        [g*S_loc, (g+1)*S_loc)); entries *repeat* when an expert is
        replicated. Every logical expert holds ≥ 1 slot per layer. Entries
        equal to ``n_experts`` are *phantom* slots (no resident expert,
        zero share) — how a non-uniform per-rank slot budget is expressed
        over the uniform rank-major physical layout: ranks with a smaller
        budget pad their tail slots with phantoms.
    ``share``: (L, S) float array — fraction of the expert's token traffic
        dispatched to this copy; sums to 1 over the copies of each
        (layer, expert); 0 on phantom slots. The model layer approximates
        fractional shares by hashing assignments across copies; the
        solver's shares are what the latency objective (and the simulator)
        score.
    """

    slot_expert: np.ndarray
    share: np.ndarray
    n_ranks: int
    n_experts: int

    def __post_init__(self):
        se = np.atleast_2d(np.asarray(self.slot_expert, dtype=np.int32))
        sh = np.atleast_2d(np.asarray(self.share, dtype=np.float64))
        if se.shape != sh.shape:
            raise ValueError(f"slot_expert {se.shape} != share {sh.shape}")
        L, S = se.shape
        if S % self.n_ranks != 0:
            raise ValueError(f"S={S} not divisible by n_ranks={self.n_ranks}")
        if se.min() < 0 or se.max() > self.n_experts:
            raise ValueError("slot_expert ids outside [0, n_experts] "
                             f"(= {self.n_experts} marks a phantom slot)")
        counts = _replica_counts(se, self.n_experts)
        if np.any(counts == 0):
            raise ValueError("some logical expert has no physical slot")
        if sh.min() < -1e-12:
            raise ValueError("negative copy share")
        if np.any(sh[se >= self.n_experts] > 1e-12):
            raise ValueError("phantom slots cannot carry traffic share")
        sums = np.zeros((L, self.n_experts + 1))
        np.add.at(sums, (np.arange(L)[:, None],
                         np.minimum(se, self.n_experts)), sh)
        if not np.allclose(sums[:, :self.n_experts], 1.0, atol=1e-6):
            raise ValueError("copy shares must sum to 1 per (layer, expert)")
        object.__setattr__(self, "slot_expert", se)
        object.__setattr__(self, "share", sh)

    @property
    def n_layers(self) -> int:
        return self.slot_expert.shape[0]

    @property
    def n_slots(self) -> int:
        return self.slot_expert.shape[1]

    @property
    def slots_per_rank(self) -> int:
        return self.n_slots // self.n_ranks

    @property
    def perm(self) -> np.ndarray:
        """Slot table consumed by models/moe.py (entries repeat = replicas)."""
        return self.slot_expert

    @classmethod
    def from_singleton(cls, placement: "Placement") -> "ReplicatedPlacement":
        """Lift a singleton :class:`Placement` into the unified replicated
        representation (one copy per expert, unit shares)."""
        perm = placement.perm
        return cls(perm, np.ones(perm.shape, dtype=np.float64),
                   placement.n_ranks, placement.n_experts)

    def to_singleton(self) -> "Placement":
        """The inverse of :meth:`from_singleton`; only defined for the
        degenerate r_max = 1 case with no phantom slots."""
        if self.n_slots != self.n_experts or int(self.n_copies().max()) > 1:
            raise ValueError("placement is genuinely replicated (or padded); "
                             "no singleton equivalent")
        return Placement(permutation_to_placement(self.slot_expert,
                                                  self.n_ranks), self.n_ranks)

    @property
    def assign(self) -> np.ndarray:
        """(L, E) expert→rank map of the singleton degenerate (raises for a
        genuinely replicated placement) — lets Placement consumers read the
        unified type without type-switching."""
        return self.to_singleton().assign

    def n_copies(self) -> np.ndarray:
        """(L, E) replica count per logical expert (phantoms excluded)."""
        return _replica_counts(self.slot_expert, self.n_experts)

    def rank_slot_budget(self) -> np.ndarray:
        """(L, G) count of *real* (non-phantom) slots per rank — the
        per-rank slot budget the solve actually used."""
        real = (self.slot_expert < self.n_experts)
        return real.reshape(self.n_layers, self.n_ranks,
                            self.slots_per_rank).sum(axis=2)

    def copy_shares(self, r_max: Optional[int] = None) -> np.ndarray:
        """(L, E, r_max) per-copy traffic shares, copies in slot order.

        The copy axis matches the enumeration ``build_slots_of`` uses for
        its ``slots_of`` table (ascending physical slot), so index r here
        is the share of the copy living in ``slots_of[l, e, r]``. Entries
        past an expert's replica count are zero. ``r_max`` pads the copy
        axis (must be ≥ the actual maximum replica count).
        """
        se = self.slot_expert
        L, S = se.shape
        counts = self.n_copies()
        rm = int(counts.max()) if r_max is None else int(r_max)
        if rm < int(counts.max()):
            raise ValueError(f"r_max={rm} < max replica count {counts.max()}")
        order, e_sorted, occ = copy_enumeration(se)
        sh_sorted = np.take_along_axis(self.share, order, axis=1)
        out = np.zeros((L, self.n_experts, rm))
        li, si = np.nonzero(e_sorted < self.n_experts)    # skip phantoms
        out[li, e_sorted[li, si], occ[li, si]] = sh_sorted[li, si]
        return out

    def copy_cdf(self, r_max: Optional[int] = None) -> np.ndarray:
        """(L, E, r_max) cumulative copy-share table for weighted dispatch.

        This is what the model layer consumes (via ``make_moe_tables``) for
        inverse-CDF replica selection: assignment with uniform u picks the
        first copy r with u < cdf[l, e, r]. Delegates to the canonical
        :func:`copy_share_cdf` builder — one implementation for the solver
        and the model seam.
        """
        return copy_share_cdf(self.slot_expert, self.n_experts,
                              share=self.share, r_max=r_max)

    def rank_loads(self, w: np.ndarray) -> np.ndarray:
        """Per-rank token loads (L, G): expert loads split over copies."""
        L, S = self.slot_expert.shape
        slot_load = np.take_along_axis(pad_phantom_column(w),
                                       self.slot_expert, axis=1) * self.share
        return slot_load.reshape(L, self.n_ranks, self.slots_per_rank).sum(2)

    def _window_padded(self, spr: int) -> np.ndarray:
        """slot_expert with each rank's window right-padded to ``spr``
        slots with phantoms — aligns tables of different widths."""
        L, _ = self.slot_expert.shape
        se = self.slot_expert.reshape(L, self.n_ranks, self.slots_per_rank)
        out = np.full((L, self.n_ranks, spr), self.n_experts,
                      dtype=se.dtype)
        out[:, :, :self.slots_per_rank] = se
        return out.reshape(L, -1)

    def moved_experts(self, other: "ReplicatedPlacement") -> int:
        """(layer, slot) pairs whose resident expert differs vs ``other`` —
        the weight-migration volume in expert-tensor units. Tables of
        different per-rank widths (an elastic re-solve can widen the
        survivor budget) are aligned window-by-window: a slot that only
        exists on one side counts as moved unless it is a phantom."""
        if (other.n_ranks == self.n_ranks
                and other.slots_per_rank != self.slots_per_rank):
            spr = max(self.slots_per_rank, other.slots_per_rank)
            return int(np.sum(self._window_padded(spr)
                              != other._window_padded(spr)))
        return int(np.sum(self.slot_expert != other.slot_expert))


AnyPlacement = Union[Placement, ReplicatedPlacement]


def pad_phantom_column(w: np.ndarray) -> np.ndarray:
    """(L, E) expert loads → (L, E+1) with a zero column at index E.

    THE gather guard for phantom slots: a slot table may contain the
    sentinel id ``n_experts`` (budget-padding phantom), so every
    ``take_along_axis(w, slot_expert)`` must read from a padded matrix
    where the sentinel column is 0 — one helper instead of each consumer
    re-deriving the incantation (rank_loads, incremental swap loads, the
    simulator's realized-dispatch split all go through here).
    """
    w = np.atleast_2d(np.asarray(w, dtype=np.float64))
    return np.concatenate([w, np.zeros((w.shape[0], 1))], axis=1)


def _replica_counts(slot_expert: np.ndarray, n_experts: int) -> np.ndarray:
    """(L, S) slot table → (L, E) copies per logical expert (ids ≥ E are
    phantom padding and are not counted)."""
    clipped = np.minimum(slot_expert, n_experts)
    return np.apply_along_axis(np.bincount, 1, clipped,
                               minlength=n_experts + 1)[:, :n_experts]


def copy_enumeration(slot_table: np.ndarray):
    """Canonical copy enumeration of a (L, S) slot table, vectorized.

    Groups each layer's slots by resident id — stable, so slot-ascending
    within an id — and indexes each slot's occurrence within its run:
    returns ``(order, id_sorted, occ)``, all (L, S), where ``order`` maps
    sorted position → physical slot, ``id_sorted`` is the resident id at
    that position, and ``occ`` says "this is the id's occ-th copy".

    This ordering is THE copy axis: ``build_slots_of`` (models/sharding)
    lays out ``slots_of[l, e, r]`` in it, and every share/CDF table must
    enumerate copies identically or solver-side shares and model-side
    dispatch silently disagree — which is why all of them call this one
    helper.
    """
    slot_table = np.atleast_2d(slot_table)
    L, S = slot_table.shape
    order = np.argsort(slot_table, axis=1, kind="stable")
    id_sorted = np.take_along_axis(slot_table, order, axis=1)
    pos = np.arange(S)[None, :]
    new_run = np.concatenate(
        [np.ones((L, 1), bool), id_sorted[:, 1:] != id_sorted[:, :-1]],
        axis=1)
    run_start = np.maximum.accumulate(np.where(new_run, pos, 0), axis=1)
    return order, id_sorted, pos - run_start


def copy_share_cdf(slot_table: np.ndarray, n_experts: int,
                   share: Optional[np.ndarray] = None,
                   r_max: Optional[int] = None) -> np.ndarray:
    """THE cumulative copy-share table: (L, S) slot table → (L, E, r_max).

    The single normalization behind ``ReplicatedPlacement.copy_cdf`` and
    ``models.sharding.build_copy_cdf`` — solver-side scoring and
    model-side dispatch must agree bit-for-bit on this table, so there is
    exactly one implementation. Entries ≥ ``n_experts`` are phantom
    padding and take no share; ``share=None`` means a uniform split over
    each expert's copies; trailing (padding) entries along the copy axis
    are 1.0 so inverse-CDF selection can never land outside an expert's
    real copies. Experts whose shares sum to zero (fully starved) fall
    back to a uniform split. Returns float32.
    """
    slot_table = np.atleast_2d(slot_table)
    L, S = slot_table.shape
    if share is not None:
        share = np.atleast_2d(np.asarray(share, dtype=np.float64))
        if share.shape != slot_table.shape:
            raise ValueError(
                f"share shape {share.shape} != table {slot_table.shape}")
    clipped = np.minimum(slot_table, n_experts)      # phantoms → sentinel E
    counts = np.apply_along_axis(np.bincount, 1, clipped,
                                 minlength=n_experts + 1)[:, :n_experts]
    rm = int(counts.max()) if r_max is None else int(r_max)
    if rm < int(counts.max()):
        raise ValueError(f"r_max={rm} < max replica count {counts.max()}")
    order, e_sorted, occ = copy_enumeration(clipped)
    sh_sorted = (np.ones((L, S))
                 if share is None else np.take_along_axis(share, order, 1))
    acc = np.zeros((L, n_experts, rm), dtype=np.float64)
    li, si = np.nonzero(e_sorted < n_experts)
    acc[li, e_sorted[li, si], occ[li, si]] = sh_sorted[li, si]
    totals = acc.sum(-1)
    dead = totals <= 0.0
    if dead.any():
        uniform = (np.arange(rm)[None, None, :] < counts[..., None]) * 1.0
        acc = np.where(dead[..., None], uniform, acc)
        totals = acc.sum(-1)
    cdf = np.cumsum(acc, axis=-1) / totals[..., None]
    return np.minimum(cdf, 1.0).astype(np.float32)


def placement_to_permutation(assign: np.ndarray, n_ranks: int) -> np.ndarray:
    """(L,E) assign → (L,E) perm with perm[l,p] = logical expert in slot p.

    Slots are rank-major; within a rank, logical experts are ordered by id
    (deterministic so repeated solves with equal assignment produce identical
    physical layouts — minimizes spurious weight movement). Implemented as a
    single stable argsort per layer: sorting expert ids by rank keeps the
    ascending-id order within each rank.
    """
    assign = np.atleast_2d(assign)
    return np.argsort(assign, axis=1, kind="stable").astype(np.int32)


def permutation_to_placement(perm: np.ndarray, n_ranks: int) -> np.ndarray:
    perm = np.atleast_2d(perm)
    L, E = perm.shape
    e_loc = E // n_ranks
    rank_of_slot = (np.arange(E, dtype=np.int32) // e_loc)[None, :]
    assign = np.empty((L, E), dtype=np.int32)
    np.put_along_axis(assign, perm, np.broadcast_to(rank_of_slot, (L, E)),
                      axis=1)
    return assign


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------

def contiguous_placement(n_layers: int, n_experts: int, n_ranks: int) -> Placement:
    """vLLM default: expert e on rank e // (E/G), identical at every layer."""
    e_loc = n_experts // n_ranks
    row = np.arange(n_experts, dtype=np.int32) // e_loc
    return Placement(np.tile(row, (n_layers, 1)), n_ranks)


def _greedy_target_assign(
    w_layer: np.ndarray,           # (E,) per-expert token load
    targets: np.ndarray,           # (G,) token targets τ_g
    n_ranks: int,
) -> np.ndarray:
    """Paper Alg 1 Phase 2 inner loop with the uniform-slot constraint.

    Experts in descending load order go to argmax_g (τ_g − n_g) among ranks
    with free slots. Per-layer reference implementation — production solves
    go through :func:`_greedy_target_assign_vec`; an equivalence test pins
    the two to identical output.
    """
    E = w_layer.size
    e_loc = E // n_ranks
    order = np.argsort(-w_layer, kind="stable")
    load = np.zeros(n_ranks)
    slots = np.full(n_ranks, e_loc, dtype=np.int64)
    assign = np.empty(E, dtype=np.int32)
    for e in order:
        gap = targets - load
        gap[slots == 0] = -np.inf
        g = int(np.argmax(gap))
        assign[e] = g
        load[g] += w_layer[e]
        slots[g] -= 1
    return assign


def _greedy_target_assign_vec(
    w: np.ndarray,                 # (L, E) per-expert token loads
    targets: np.ndarray,           # (L, G) token targets τ_{l,g}
) -> np.ndarray:
    """Vectorized greedy fill: all layers advance one item per iteration.

    The Python loop runs over the E item *positions* (descending-load order
    within each layer); each iteration is O(L·G) numpy work, so DeepSeek
    scale (L=58, E=256, G=64) completes in milliseconds instead of the
    seconds the per-layer double loop needs. Produces exactly the per-layer
    reference's output (same float ops in the same order, same argmax
    tie-breaking).
    """
    w = np.asarray(w, dtype=np.float64)
    L, E = w.shape
    G = targets.shape[1]
    e_loc = E // G
    order = np.argsort(-w, axis=1, kind="stable")                # (L, E)
    rows = np.arange(L)
    load = np.zeros((L, G))
    slots = np.full((L, G), e_loc, dtype=np.int64)
    assign = np.empty((L, E), dtype=np.int32)
    for i in range(E):
        item = order[:, i]                                       # (L,)
        gap = targets - load
        gap[slots == 0] = -np.inf
        g = np.argmax(gap, axis=1)                               # (L,)
        assign[rows, item] = g
        load[rows, g] += w[rows, item]
        slots[rows, g] -= 1
    return assign


def eplb_placement(
    w: np.ndarray,                 # (L, E) activation matrix
    n_ranks: int,
) -> Placement:
    """EPLB: equalize token counts. τ_g = N/G for all g (f_g(n)=n)."""
    w = np.atleast_2d(np.asarray(w, dtype=np.float64))
    L, E = w.shape
    targets = np.repeat(w.sum(axis=1, keepdims=True) / n_ranks, n_ranks,
                        axis=1)
    return Placement(_greedy_target_assign_vec(w, targets), n_ranks)


def _speed_targets(
    w: np.ndarray,                 # (L, E)
    perf_models: Sequence[PerfModel],
    n_ref_mode: str,
) -> tuple:
    """Per-layer speeds s_{l,g} and token targets τ_{l,g} → ((L,G), (L,G))."""
    L, E = w.shape
    G = len(perf_models)
    N = w.sum(axis=1)                                            # (L,)
    n_ref = np.maximum(N / (G if n_ref_mode == "rank" else E), 1.0)
    s = np.empty((L, G))
    for g, m in enumerate(perf_models):
        s[:, g] = 1.0 / np.asarray(m(n_ref), dtype=np.float64)
    targets = N[:, None] * s / s.sum(axis=1, keepdims=True)
    return s, targets


def vibe_placement(
    w: np.ndarray,                 # (L, E) activation matrix
    perf_models: Sequence[PerfModel],
    n_ref_mode: str = "rank",
) -> Placement:
    """ViBE (paper Alg 1 Phase 2): speed-proportional targets, greedy fill.

    ``n_ref_mode`` picks the operating point for the speed estimate
    s_g = 1/f_g(n_ref):

    * ``"rank"`` (default) — n_ref = N/G, the mean per-*rank* token load.
      f_g maps whole-device kernel load to latency, so this evaluates each
      device at the load it will actually run — where power-limited
      variability is expressed (paper Fig 5).
    * ``"expert"`` — n_ref = N/E, Algorithm 1's literal text. At low
      per-expert loads f_g sits in the unstressed regime where all devices
      look identical, degenerating to EPLB (see DESIGN.md §3 fidelity note).
    """
    w = np.atleast_2d(np.asarray(w, dtype=np.float64))
    _, targets = _speed_targets(w, perf_models, n_ref_mode)
    return Placement(_greedy_target_assign_vec(w, targets),
                     len(perf_models))


# ---------------------------------------------------------------------------
# ViBE-R: replication-aware placement
# ---------------------------------------------------------------------------

def default_slots_per_rank(n_experts: int, n_ranks: int) -> int:
    """Default ViBE-R slot budget: the singleton footprint rounded up, plus
    one spare slot per rank when E divides G evenly (otherwise the phantom
    padding slots already provide replication headroom)."""
    base = -(-n_experts // n_ranks)                  # ceil(E/G)
    return base + (1 if base * n_ranks == n_experts else 0)


def normalize_slot_budget(
    slot_budget,                   # None | int | (G,) array-like
    n_experts: int,
    n_ranks: int,
) -> np.ndarray:
    """Per-rank physical slot budget → validated (G,) int array.

    ``None`` → :func:`default_slots_per_rank` on every rank; a scalar is a
    uniform budget; an array gives each rank its own budget (device memory
    headroom — paper §5.1's non-uniform allocation). Every rank needs ≥ 1
    slot, the fleet must hold all E experts, and no rank may hold more
    slots than E (it would be forced to colocate sibling copies).
    """
    if slot_budget is None:
        budget = np.full(n_ranks, default_slots_per_rank(n_experts, n_ranks),
                         dtype=np.int64)
    else:
        budget = np.asarray(slot_budget, dtype=np.int64)
        if budget.ndim == 0:
            budget = np.full(n_ranks, int(budget), dtype=np.int64)
    if budget.shape != (n_ranks,):
        raise ValueError(f"slot budget shape {budget.shape} != ({n_ranks},)")
    if budget.min() < 1:
        raise ValueError("every rank needs a slot budget of at least 1")
    S = int(budget.sum())
    if S < n_experts:
        raise ValueError(
            f"slot budget {S} (over {n_ranks} ranks) cannot hold "
            f"{n_experts} experts")
    if budget.max() > n_experts:
        raise ValueError(f"per-rank slot budget {int(budget.max())} > "
                         f"E={n_experts}: that rank would hold the full "
                         "expert set and colocate sibling copies")
    return budget


def _replication_degrees(
    w: np.ndarray,                 # (L, E)
    n_extra: int,                  # copies beyond one-per-expert
    max_copies: int,
) -> np.ndarray:
    """Greedy hot-expert splitting, vectorized across layers.

    Start from one copy each; repeatedly grant a copy to the expert with the
    largest *per-copy* load w_e / c_e (the straggler bound a replica buys
    down the most). ``n_extra`` iterations of O(L·E) work.
    """
    L, E = w.shape
    rows = np.arange(L)
    copies = np.ones((L, E), dtype=np.int64)
    q = w.astype(np.float64).copy()                  # per-copy load
    for _ in range(n_extra):
        q_masked = np.where(copies >= max_copies, -np.inf, q)
        e_star = np.argmax(q_masked, axis=1)
        copies[rows, e_star] += 1
        q[rows, e_star] = w[rows, e_star] / copies[rows, e_star]
    return copies


def _replicated_solve(
    w: np.ndarray,                 # (L, E) activation matrix
    speeds: np.ndarray,            # (L, G) per-rank speed estimates s_{l,g}
    targets: np.ndarray,           # (L, G) per-rank token targets τ_{l,g}
    n_ranks: int,
    budget: np.ndarray,            # (G,) per-rank physical slot budget
    perf_models: Optional[Sequence[PerfModel]] = None,
) -> ReplicatedPlacement:
    """Shared replication machinery behind ViBE-R and HarMoEny-style solves.

    Three phases, all vectorized across layers:

    1. **Replicate** — under the slot budget S = Σ_g budget_g, grant the
       S − E spare slots to the hottest experts (largest per-copy load
       first), capped at one copy per rank.
    2. **Place** — greedy target fill over the (expert, copy) items in
       descending per-copy load order, to the rank farthest below its token
       target τ_g with free budget; a copy avoids ranks already holding a
       copy of the same expert (a colocated replica absorbs no skew).
    3. **Share** — split each expert's traffic over its copies
       proportionally to the *speed* of the rank each copy landed on, so
       the share lands where f_g is fastest (uniform speeds → uniform
       shares).

    With ``perf_models`` given, a **reweighted refill** closes the loop
    between phases 2 and 3: the greedy fill assumed uniform per-copy loads,
    but the speed-proportional shares mean copies on fast ranks carry more
    — so the fill re-runs with per-copy loads under those shares, and each
    layer keeps whichever of the two placements has the lower predicted
    straggler latency max_g f_g(n_g). Never worse than the single-pass
    solve by construction (the incremental path's
    ``reweight_shares_by_speed`` folded into the full solve). Uniform
    speeds make the reweighted loads identical to the uniform ones, so
    hardware-oblivious solves (HarMoEny) pass None and skip the refill.

    The physical layout is rank-major with ``max(budget)`` slots per rank;
    ranks below the maximum pad their tail slots with phantoms (id E,
    share 0) so non-uniform budgets ride the uniform slot table every
    consumer already understands.
    """
    L, E = w.shape
    G = n_ranks
    s_max = int(budget.max())
    S = int(budget.sum())
    rows = np.arange(L)

    # Phase 1: replication degrees (S − E spare copies, ≤ G copies each)
    copies = _replication_degrees(w, S - E, max_copies=G)

    # Expand to per-copy items: ce (L, S) expert id, cl (L, S) per-copy load
    # (uniform split at placement time; phase 3 reweights by speed).
    cum = np.cumsum(copies, axis=1)                              # (L, E)
    ce = (np.arange(S)[None, :, None] >= cum[:, None, :]).sum(2) \
        .astype(np.int32)                                        # (L, S)
    we = np.take_along_axis(w, ce, axis=1)                       # (L, S)
    cl = we / np.take_along_axis(copies, ce, axis=1)

    # Phase 2: vectorized greedy fill over copies (descending per-copy load)
    def _fill(cl: np.ndarray) -> np.ndarray:
        order = np.argsort(-cl, axis=1, kind="stable")
        load = np.zeros((L, G))
        slots_free = np.tile(budget, (L, 1))
        on_rank = np.zeros((L, G, E), dtype=bool)
        copy_rank = np.empty((L, S), dtype=np.int32)
        for i in range(S):
            item = order[:, i]                                   # (L,)
            e_item = ce[rows, item]                              # (L,)
            gap = targets - load
            invalid = (slots_free == 0) | on_rank[rows, :, e_item]
            # rows where the dedup constraint is unsatisfiable fall back to
            # the slot constraint alone (only when copies ≥ free ranks)
            stuck = invalid.all(axis=1)
            if stuck.any():
                invalid[stuck] = (slots_free[stuck] == 0)
            gap[invalid] = -np.inf
            g = np.argmax(gap, axis=1)                           # (L,)
            copy_rank[rows, item] = g
            load[rows, g] += cl[rows, item]
            slots_free[rows, g] -= 1
            on_rank[rows, g, e_item] = True
        return copy_rank

    # Phase 3: speed-proportional copy shares
    def _shares(copy_rank: np.ndarray) -> np.ndarray:
        sp = speeds[rows[:, None], copy_rank]                    # (L, S)
        denom = np.zeros((L, E))
        np.add.at(denom, (rows[:, None], ce), sp)
        return sp / np.take_along_axis(denom, ce, axis=1)

    copy_rank = _fill(cl)
    share = _shares(copy_rank)

    if perf_models is not None:
        # reweighted refill: redo the greedy under the loads the shares
        # actually send, keep per layer only when the predicted straggler
        # latency strictly improves
        def _objective(cr: np.ndarray, sh: np.ndarray) -> np.ndarray:
            rank_load = np.zeros((L, G))
            np.add.at(rank_load, (rows[:, None], cr), we * sh)
            lat = np.empty_like(rank_load)
            for g, m in enumerate(perf_models):
                lat[:, g] = m(rank_load[:, g])
            return lat.max(axis=1)
        cr2 = _fill(we * share)
        sh2 = _shares(cr2)
        better = _objective(cr2, sh2) < _objective(copy_rank, share)
        if better.any():
            copy_rank = np.where(better[:, None], cr2, copy_rank)
            share = np.where(better[:, None], sh2, share)

    # Lay out rank-major slots, copies ordered by expert id within a rank
    key = copy_rank.astype(np.int64) * (E + 1) + ce
    lay = np.argsort(key, axis=1, kind="stable")
    if s_max * G == S:             # uniform budget: no phantom padding
        return ReplicatedPlacement(
            slot_expert=np.take_along_axis(ce, lay, axis=1),
            share=np.take_along_axis(share, lay, axis=1),
            n_ranks=G, n_experts=E)
    # Non-uniform budget: each rank g filled exactly budget_g copies (the
    # greedy consumes every slot), so the rank-sorted items form contiguous
    # runs of length budget_g — scatter each run to the head of its rank's
    # s_max-slot window, phantoms (id E, share 0) fill the tail.
    ce_l = np.take_along_axis(ce, lay, axis=1)
    sh_l = np.take_along_axis(share, lay, axis=1)
    rk_l = np.take_along_axis(copy_rank, lay, axis=1)
    offsets = np.concatenate([[0], np.cumsum(budget)[:-1]])      # (G,)
    dest = rk_l * s_max + (np.arange(S)[None, :] - offsets[rk_l])
    slot_expert = np.full((L, s_max * G), E, dtype=np.int32)
    share_phys = np.zeros((L, s_max * G))
    lr = np.repeat(rows, S)
    slot_expert[lr, dest.ravel()] = ce_l.ravel()
    share_phys[lr, dest.ravel()] = sh_l.ravel()
    return ReplicatedPlacement(slot_expert=slot_expert, share=share_phys,
                               n_ranks=G, n_experts=E)


def vibe_r_placement(
    w: np.ndarray,                 # (L, E) activation matrix
    perf_models: Sequence[PerfModel],
    slots_per_rank=None,           # None | int | (G,) per-rank budgets
    n_ref_mode: str = "rank",
) -> ReplicatedPlacement:
    """ViBE-R: co-optimize replication degree with per-device speed.

    :func:`_replicated_solve` under ViBE's speed-proportional token targets
    (τ_g ∝ s_g = 1/f_g(n_ref)), including its reweighted refill (the fill
    re-run under the speed-proportional shares' realized loads, kept per
    layer only when the predicted straggler latency improves).
    ``slots_per_rank`` may be a scalar (the paper's uniform memory
    footprint) or a (G,) array of per-rank budgets driven by device memory
    headroom — ranks below the maximum pad with phantom slots.
    """
    w = np.atleast_2d(np.asarray(w, dtype=np.float64))
    L, E = w.shape
    G = len(perf_models)
    budget = normalize_slot_budget(slots_per_rank, E, G)
    speeds, targets = _speed_targets(w, perf_models, n_ref_mode)
    return _replicated_solve(w, speeds, targets, G, budget,
                             perf_models=perf_models)


def harmoeny_placement(
    w: np.ndarray,                 # (L, E) activation matrix
    n_ranks: int,
    slots_per_rank=None,           # None | int | (G,) per-rank budgets
) -> ReplicatedPlacement:
    """HarMoEny-style baseline: redundant sharding for *pure load balance*.

    The replication machinery of ViBE-R with all hardware awareness
    removed: every rank is assumed equally fast (f_g(n) = n), so token
    targets are uniform (τ_g = N/G) and each expert's traffic splits
    uniformly over its copies. Isolates what redundant hot-expert sharding
    buys *without* variability awareness — the HarMoEny baseline family the
    paper's benchmark sweep compares against.
    """
    w = np.atleast_2d(np.asarray(w, dtype=np.float64))
    L, E = w.shape
    G = n_ranks
    budget = normalize_slot_budget(slots_per_rank, E, G)
    speeds = np.ones((L, G))
    targets = np.repeat(w.sum(axis=1, keepdims=True) / G, G, axis=1)
    return _replicated_solve(w, speeds, targets, G, budget)


def gem_placement(
    w: np.ndarray,                 # (L, E) activation matrix
    perf_models: Sequence[PerfModel],
) -> Placement:
    """GEM-style variability-aware greedy mapping (no replication).

    Experts in descending load order go to the rank whose *predicted
    completion time* f_g(n_g + w_e) is lowest among ranks with free slots —
    a direct greedy on the profiled latency curves (GEM's expert-to-GPU
    mapping), in contrast to ViBE's precomputed speed-proportional token
    targets. Vectorized across layers like the other solvers.
    """
    w = np.atleast_2d(np.asarray(w, dtype=np.float64))
    L, E = w.shape
    G = len(perf_models)
    if E % G != 0:
        raise ValueError(f"E={E} not divisible by n_ranks={G}")
    e_loc = E // G
    order = np.argsort(-w, axis=1, kind="stable")                # (L, E)
    rows = np.arange(L)
    load = np.zeros((L, G))
    slots = np.full((L, G), e_loc, dtype=np.int64)
    assign = np.empty((L, E), dtype=np.int32)
    for i in range(E):
        item = order[:, i]                                       # (L,)
        wl = w[rows, item]                                       # (L,)
        t = np.stack([np.asarray(perf_models[g](load[:, g] + wl),
                                 dtype=np.float64) for g in range(G)],
                     axis=1)                                     # (L, G)
        t[slots == 0] = np.inf
        g = np.argmin(t, axis=1)                                 # (L,)
        assign[rows, item] = g
        load[rows, g] += wl
        slots[rows, g] -= 1
    return Placement(assign, G)


def reweight_shares_by_speed(
    placement: ReplicatedPlacement,
    w: np.ndarray,                 # (L, E) activation matrix
    perf_models: Sequence[PerfModel],
    n_ref_mode: str = "rank",
) -> ReplicatedPlacement:
    """Re-proportion each expert's copy shares to its ranks' current speeds.

    Solver phase 3 applied to an *existing* slot table: after slot-granular
    swaps (incremental updates) move copies between ranks, the shares riding
    with them still reflect the ranks they came from. This recomputes
    share ∝ s_g = 1/f_g(n_ref) for the rank each copy now occupies, keeping
    per-expert sums at 1 and the slot table untouched — so the weighted
    dispatch keeps steering traffic toward the fast copies.
    """
    w = np.atleast_2d(np.asarray(w, dtype=np.float64))
    se = placement.slot_expert
    L, S = se.shape
    E = placement.n_experts
    if w.shape != (L, E):
        raise ValueError(f"w shape {w.shape} != {(L, E)}")
    speeds, _ = _speed_targets(w, perf_models, n_ref_mode)
    rank_of = np.arange(S) // placement.slots_per_rank
    sp = np.where(se < E, speeds[:, rank_of], 0.0)               # (L, S)
    rows = np.arange(L)
    se_c = np.minimum(se, E)
    denom = np.zeros((L, E + 1))
    np.add.at(denom, (rows[:, None], se_c), sp)
    denom[:, E] = 1.0                                            # phantoms
    share = sp / np.take_along_axis(denom, se_c, axis=1)
    return ReplicatedPlacement(se.copy(), share, placement.n_ranks, E)


def inflate_placement(sub: ReplicatedPlacement, survivors: Sequence[int],
                      n_ranks: int) -> ReplicatedPlacement:
    """Re-inflate a placement solved over a survivor subset back to the
    full ``n_ranks`` rank space.

    ``sub`` was solved with ``sub.n_ranks == len(survivors)``;
    ``survivors[j]`` is the global rank that sub-rank j maps to. Dead
    ranks get all-phantom slot windows with zero share, so dispatch sends
    them nothing and ``rank_loads`` reads 0 there — which is how a
    topology-masked re-solve (``SolveContext.dead_ranks``) keeps the
    global slot-table geometry the engine pinned at init.
    """
    surv = np.asarray(survivors, dtype=np.int64)
    if surv.size != sub.n_ranks:
        raise ValueError(f"{surv.size} survivors but sub-placement has "
                         f"{sub.n_ranks} ranks")
    if surv.size != np.unique(surv).size:
        raise ValueError("duplicate survivor ranks")
    if surv.size and (surv.min() < 0 or surv.max() >= n_ranks):
        raise ValueError(f"survivor ranks outside [0, {n_ranks})")
    L = sub.n_layers
    spr = sub.slots_per_rank
    E = sub.n_experts
    slot_expert = np.full((L, n_ranks * spr), E, dtype=np.int32)
    share = np.zeros((L, n_ranks * spr))
    for j, g in enumerate(surv):
        slot_expert[:, g * spr:(g + 1) * spr] = \
            sub.slot_expert[:, j * spr:(j + 1) * spr]
        share[:, g * spr:(g + 1) * spr] = sub.share[:, j * spr:(j + 1) * spr]
    return ReplicatedPlacement(slot_expert, share, n_ranks, E)


def compact_placement(full: ReplicatedPlacement, survivors: Sequence[int],
                      ) -> ReplicatedPlacement:
    """Inverse of :func:`inflate_placement`: slice the survivor rank
    windows out of a full-G masked placement.

    A topology-masked solve (``SolveContext.dead_ranks``) keeps the
    original G-rank geometry with all-phantom zero-share windows on the
    dead ranks — right for a serving engine whose compiled step functions
    pinned that geometry. A *training* relaunch instead rebuilds the mesh
    over the survivors, so it wants the survivor-only geometry back:
    ``compact_placement(masked_solve, survivors)``. Refuses to drop a
    rank window still carrying share (that would silently lose experts).
    """
    surv = np.asarray(survivors, dtype=np.int64)
    if surv.size < 1:
        raise ValueError("need at least one survivor")
    if surv.size != np.unique(surv).size:
        raise ValueError("duplicate survivor ranks")
    if surv.min() < 0 or surv.max() >= full.n_ranks:
        raise ValueError(f"survivor ranks outside [0, {full.n_ranks})")
    spr = full.slots_per_rank
    dropped = np.setdiff1d(np.arange(full.n_ranks), surv)
    if dropped.size:
        cols = (dropped[:, None] * spr + np.arange(spr)).ravel()
        if np.any(full.share[:, cols] != 0.0):
            raise ValueError(
                f"ranks {dropped.tolist()} still carry dispatch share — "
                "compacting them away would lose experts")
    keep = (surv[:, None] * spr + np.arange(spr)).ravel()
    return ReplicatedPlacement(full.slot_expert[:, keep].copy(),
                               full.share[:, keep].copy(),
                               int(surv.size), full.n_experts)


def solve_model_placement(
    policy: str,
    w: np.ndarray,
    n_ranks: int,
    perf_models: Optional[Sequence[PerfModel]] = None,
    slots_per_rank=None,
    topology=None,
) -> AnyPlacement:
    """DEPRECATED string-dispatch entry point (use the policy registry).

    Thin shim over ``repro.core.policy``: resolves the name in the registry
    and solves through the :class:`~repro.core.policy.PlacementPolicy`
    protocol. Return types match the historical if/elif chain bit for bit —
    singleton policies (``contiguous``/``eplb``/``vibe``/``gem``) yield a
    :class:`Placement`, replication-capable ones (``vibe_r``/``harmoeny``)
    a :class:`ReplicatedPlacement`. ``slots_per_rank`` is forwarded only to
    policies whose capabilities accept a slot budget (the old behaviour:
    silently ignored elsewhere). ``topology`` (a
    :class:`~repro.core.topology.ClusterTopology`) is forwarded verbatim —
    ``None`` or a flat topology keeps every pre-existing policy
    bit-identical; only topology-aware policies (``vibe_h``) read it. New
    code should build a
    :class:`~repro.core.policy.SolveContext` and call
    ``get_policy(name).solve(ctx)`` directly.
    """
    warnings.warn(
        "solve_model_placement is deprecated; use "
        "repro.core.policy.get_policy(name).solve(SolveContext(...))",
        DeprecationWarning, stacklevel=2)
    from . import policy as _policy          # late: policy imports this module
    pol = _policy.get_policy(policy)
    caps = pol.capabilities
    if caps.needs_perf_models and perf_models is None:
        raise ValueError(f"{policy} placement requires perf_models")
    ctx = _policy.SolveContext(
        w=w, n_ranks=n_ranks,
        perf_models=perf_models if caps.needs_perf_models else None,
        slot_budget=slots_per_rank if caps.accepts_slot_budget else None,
        topology=topology)
    solved = pol.solve(ctx)
    return solved if caps.supports_replication else solved.to_singleton()


# ---------------------------------------------------------------------------
# Objective evaluation (paper §4.2.3 problem formulation)
# ---------------------------------------------------------------------------

def predicted_layer_latency(
    assign_layer: np.ndarray,      # (E,)
    w_layer: np.ndarray,           # (E,)
    perf_models: Sequence[PerfModel],
) -> np.ndarray:
    """Per-rank predicted latencies f_g(n_g) for one layer → (G,)."""
    G = len(perf_models)
    load = np.zeros(G)
    np.add.at(load, assign_layer, w_layer)
    return np.array([perf_models[g](load[g]) for g in range(G)])


def predicted_rank_latencies(
    placement: AnyPlacement,
    w: np.ndarray,                 # (L, E)
    perf_models: Sequence[PerfModel],
) -> np.ndarray:
    """Predicted f_g(n_{l,g}) → (L, G); replica-aware via ``rank_loads``."""
    load = placement.rank_loads(np.atleast_2d(w))
    lat = np.empty_like(load)
    for g, m in enumerate(perf_models):
        lat[:, g] = m(load[:, g])
    return lat


def layer_latency_span(
    placement: AnyPlacement,
    w: np.ndarray,
    perf_models: Sequence[PerfModel],
) -> np.ndarray:
    """Per-layer (T_max, T_mean, T_min) → (L, 3). T = max is layer latency."""
    lat = predicted_rank_latencies(placement, w, perf_models)
    return np.stack([lat.max(1), lat.mean(1), lat.min(1)], axis=1)
