"""Expert activation profiling (paper §4.2.2, Alg 1 Phase 1).

MoE routing is input-dependent and layer-specific, but activation patterns
are empirically stable for a given workload. ViBE profiles expert activation
over a representative input set, producing the activation matrix

    W ∈ R^{L×E},   w_e^{(l)} = relative token load of expert e at layer l.

The profiler consumes per-step routing tallies — available for free from the
router's top-k output (``models/moe.py`` returns them as an aux output) — and
maintains both the cumulative matrix (for initial placement) and a rolling
window (for the drift detector / recalibration statistics).
"""

from __future__ import annotations

import collections
from typing import Deque, Optional

import numpy as np

__all__ = ["ActivationProfiler", "routing_tally"]


def routing_tally(topk_idx: np.ndarray, n_experts: int,
                  weights: Optional[np.ndarray] = None) -> np.ndarray:
    """Per-expert token tally for one layer from top-k indices.

    ``topk_idx``: (T, K) int routing decisions (or any shape; flattened).
    ``weights``:  optional matching router gate weights; when given the tally
    is gate-weighted (fractional compute per token-expert pair).
    """
    idx = np.asarray(topk_idx).reshape(-1)
    if weights is None:
        return np.bincount(idx, minlength=n_experts).astype(np.float64)
    w = np.asarray(weights, dtype=np.float64).reshape(-1)
    return np.bincount(idx, weights=w, minlength=n_experts)


class ActivationProfiler:
    """Accumulates routing statistics into the activation matrix W.

    * ``update(step_counts)``     — add one forward pass's (L, E) tallies.
    * ``matrix()``                — cumulative mean W (L, E).
    * ``window_matrix()``         — rolling-window mean (drift statistics).
    * ``mean_tokens()``           — mean batch token count (stress signal).
    """

    def __init__(self, n_layers: int, n_experts: int, window: int = 100):
        self.L, self.E = int(n_layers), int(n_experts)
        self._sum = np.zeros((self.L, self.E), dtype=np.float64)
        self._count = 0
        self._win: Deque[np.ndarray] = collections.deque(maxlen=window)
        self._tok_win: Deque[float] = collections.deque(maxlen=window)

    def update(self, step_counts: np.ndarray) -> None:
        c = np.asarray(step_counts, dtype=np.float64)
        if c.shape != (self.L, self.E):
            raise ValueError(f"expected ({self.L},{self.E}), got {c.shape}")
        self._sum += c
        self._count += 1
        self._win.append(c)
        self._tok_win.append(float(c[0].sum()) if self.L else 0.0)

    @property
    def n_samples(self) -> int:
        return self._count

    def matrix(self) -> np.ndarray:
        """Cumulative mean activation matrix W (L, E)."""
        if self._count == 0:
            return np.full((self.L, self.E), 1.0 / max(self.E, 1))
        return self._sum / self._count

    def window_matrix(self) -> np.ndarray:
        if not self._win:
            return self.matrix()
        return np.mean(np.stack(self._win), axis=0)

    def mean_tokens(self) -> float:
        return float(np.mean(self._tok_win)) if self._tok_win else 0.0

    def reset(self) -> None:
        self._sum[:] = 0.0
        self._count = 0
        self._win.clear()
        self._tok_win.clear()
