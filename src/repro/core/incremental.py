"""Incremental placement update (paper Algorithm 2, §4.2.4, Appendix A.2).

Rather than re-solving placement from scratch (which reassigns >200 of 256
slots per layer and incurs large weight-transfer cost), start from the
current placement and apply the minimum number of cross-rank expert swaps:

  repeat
    g+ ← rank with highest f_g(n_g)     (slowest)
    g- ← rank with lowest  f_g(n_g)     (fastest)
    evaluate all (e_i ∈ g+, e_j ∈ g-) swaps, score by marginal reduction in
    the pair's max latency; apply the best one
  until  max_g f_g(n_g) ≤ (1+ε) · mean_g f_g(n_g)   or no beneficial swap

The paper reports convergence in 5–30 swaps/layer. We additionally support
one-sided *moves* ... no — the paper keeps uniform slots per rank, so only
swaps preserve the memory constraint; we do the same.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from .perf_model import PerfModel
from .placement import (Placement, ReplicatedPlacement, _speed_targets,
                        pad_phantom_column, reweight_shares_by_speed)

__all__ = ["Swap", "IncrementalResult", "incremental_update",
           "SlotSwap", "incremental_update_replicated"]


@dataclasses.dataclass(frozen=True)
class Swap:
    layer: int
    expert_a: int   # logical expert moving g_plus → g_minus
    expert_b: int   # logical expert moving g_minus → g_plus
    rank_a: int     # g_plus (was slowest)
    rank_b: int     # g_minus (was fastest)


@dataclasses.dataclass(frozen=True)
class SlotSwap:
    """One (expert, copy)-granular exchange between two physical slots."""
    layer: int
    slot_a: int     # slot on rank_a (was slowest)
    slot_b: int     # slot on rank_b (was fastest)
    rank_a: int
    rank_b: int


@dataclasses.dataclass(frozen=True)
class IncrementalResult:
    placement: "Placement | ReplicatedPlacement"
    swaps: List
    converged_layers: int
    per_layer_swaps: np.ndarray     # (L,)

    @property
    def total_swaps(self) -> int:
        return len(self.swaps)

    def moved_expert_count(self) -> int:
        """Experts whose rank changed = 2 per swap (both directions)."""
        return 2 * len(self.swaps)


def _rank_latencies(load: np.ndarray, perf_models: Sequence[PerfModel]) -> np.ndarray:
    return np.array([perf_models[g](load[g]) for g in range(len(perf_models))])


def incremental_update(
    placement: Placement,
    w: np.ndarray,                       # (L, E) fresh activation matrix
    perf_models: Sequence[PerfModel],
    epsilon: float = 0.03,
    max_swaps_per_layer: int = 64,
) -> IncrementalResult:
    """Paper Algorithm 2 over all layers.

    Returns a new Placement plus the swap log (the weight-migration plan:
    exactly the swapped experts' tensors move between ranks).
    """
    w = np.atleast_2d(np.asarray(w, dtype=np.float64))
    G = placement.n_ranks
    L, E = placement.assign.shape
    if w.shape != (L, E):
        raise ValueError(f"w shape {w.shape} != placement {placement.assign.shape}")

    assign = placement.assign.copy()
    swaps: List[Swap] = []
    per_layer = np.zeros(L, dtype=np.int64)
    converged = 0

    for l in range(L):
        # per-rank loads under current assignment
        load = np.zeros(G)
        np.add.at(load, assign[l], w[l])
        # expert lists per rank (mutable)
        members = [list(np.flatnonzero(assign[l] == g)) for g in range(G)]

        for _ in range(max_swaps_per_layer):
            lat = _rank_latencies(load, perf_models)
            target = (1.0 + epsilon) * lat.mean()
            if lat.max() <= target:
                break
            g_plus = int(np.argmax(lat))
            g_minus = int(np.argmin(lat))
            if g_plus == g_minus:
                break

            # evaluate all swaps between g_plus and g_minus, score by the
            # reduction in max(f_{g+}, f_{g-}) (marginal latency gain)
            cur_pair_max = max(lat[g_plus], lat[g_minus])
            best_gain, best = 0.0, None
            fp, fm = perf_models[g_plus], perf_models[g_minus]
            wl = w[l]
            lp, lm = load[g_plus], load[g_minus]
            for ei in members[g_plus]:
                for ej in members[g_minus]:
                    dn = wl[ei] - wl[ej]
                    if dn <= 0:
                        continue  # only moving load off the slow rank helps
                    new_max = max(float(fp(lp - dn)), float(fm(lm + dn)))
                    gain = cur_pair_max - new_max
                    if gain > best_gain + 1e-15:
                        best_gain, best = gain, (ei, ej, dn)
            if best is None:
                break  # no latency reduction available

            ei, ej, dn = best
            members[g_plus].remove(ei); members[g_plus].append(ej)
            members[g_minus].remove(ej); members[g_minus].append(ei)
            assign[l, ei] = g_minus
            assign[l, ej] = g_plus
            load[g_plus] -= dn
            load[g_minus] += dn
            swaps.append(Swap(l, int(ei), int(ej), g_plus, g_minus))
            per_layer[l] += 1

        lat = _rank_latencies(load, perf_models)
        if lat.max() <= (1.0 + epsilon) * lat.mean():
            converged += 1

    return IncrementalResult(
        placement=Placement(assign, G),
        swaps=swaps,
        converged_layers=converged,
        per_layer_swaps=per_layer,
    )


def _replicated_objective(placement: ReplicatedPlacement, w: np.ndarray,
                          perf_models: Sequence[PerfModel]) -> float:
    """Σ_l max_g f_g(n_{l,g}) under the placement's own traffic shares."""
    loads = placement.rank_loads(np.atleast_2d(w))               # (L, G)
    lat = np.stack([np.asarray(perf_models[g](loads[:, g]), dtype=np.float64)
                    for g in range(placement.n_ranks)], axis=1)
    return float(lat.max(axis=1).sum())


def _replicated_swap_run(
    placement: ReplicatedPlacement,
    w: np.ndarray,
    perf_models: Sequence[PerfModel],
    epsilon: float,
    max_swaps_per_layer: int,
    speeds: "np.ndarray | None" = None,
) -> IncrementalResult:
    """One slot-swap greedy pass. ``speeds=None`` scores swaps under the
    *carried* shares (legacy); ``speeds`` (L, G) scores them under the
    *post-reweight* shares each candidate map would get (folded mode): the
    two candidate experts' copy shares are re-proportioned to their
    hypothetical rank speeds before pricing the pair, and after a swap the
    affected experts' shares/loads are rebuilt so the loop's view always
    matches what :func:`reweight_shares_by_speed` will produce."""
    w = np.atleast_2d(np.asarray(w, dtype=np.float64))
    G = placement.n_ranks
    L, S = placement.slot_expert.shape
    E = placement.n_experts
    s_loc = placement.slots_per_rank

    se = placement.slot_expert.copy()
    sh = placement.share.copy()
    # frozen per-slot traffic under the fresh activation matrix (phantom
    # slots — ids == E, zero share — carry no load and never move: they
    # encode a rank's missing memory budget, not migratable capacity)
    slot_load = np.take_along_axis(pad_phantom_column(w), se, axis=1) * sh
    swaps: List[SlotSwap] = []
    per_layer = np.zeros(L, dtype=np.int64)
    converged = 0

    for l in range(L):
        load = slot_load[l].reshape(G, s_loc).sum(axis=1)
        rank_of = np.arange(S) // s_loc
        spl = None if speeds is None else speeds[l]

        def folded_pair_loads(si, sj, ei, ej, g_plus, g_minus, lp, lm):
            """(new_lp, new_lm) with ei→g-, ej→g+ and both experts' copy
            shares re-proportioned to the speeds of their new ranks."""
            new_lp, new_lm = lp, lm
            for e, src, dst in ((ei, si, g_minus), (ej, sj, g_plus)):
                cs = np.flatnonzero(se[l] == e)
                r_new = rank_of[cs].copy()
                r_new[cs == src] = dst
                sp = spl[r_new]
                sh_new = sp / sp.sum()
                we = w[l, e]
                cur = slot_load[l, cs]
                new_lp += (we * sh_new[r_new == g_plus].sum()
                           - cur[rank_of[cs] == g_plus].sum())
                new_lm += (we * sh_new[r_new == g_minus].sum()
                           - cur[rank_of[cs] == g_minus].sum())
            return new_lp, new_lm

        for _ in range(max_swaps_per_layer):
            lat = _rank_latencies(load, perf_models)
            target = (1.0 + epsilon) * lat.mean()
            if lat.max() <= target:
                break
            g_plus = int(np.argmax(lat))
            g_minus = int(np.argmin(lat))
            if g_plus == g_minus:
                break

            cur_pair_max = max(lat[g_plus], lat[g_minus])
            best_gain, best = 0.0, None
            fp, fm = perf_models[g_plus], perf_models[g_minus]
            lp, lm = load[g_plus], load[g_minus]
            slots_p = np.flatnonzero(rank_of == g_plus)
            slots_m = np.flatnonzero(rank_of == g_minus)
            experts_p = set(int(e) for e in se[l, slots_p])
            experts_m = set(int(e) for e in se[l, slots_m])
            for si in slots_p:
                ei = int(se[l, si])
                if ei >= E:
                    continue                  # phantom slot: nothing to move
                for sj in slots_m:
                    ej = int(se[l, sj])
                    if ei == ej or ej >= E:
                        continue
                    # dedup: arriving copy must not meet a sibling copy
                    if ei in experts_m or ej in experts_p:
                        continue
                    if spl is None:
                        dn = slot_load[l, si] - slot_load[l, sj]
                        if dn <= 0:
                            continue  # only off-loading the slow rank helps
                        new_lp, new_lm = lp - dn, lm + dn
                    else:
                        new_lp, new_lm = folded_pair_loads(
                            si, sj, ei, ej, g_plus, g_minus, lp, lm)
                        dn = lp - new_lp
                    new_max = max(float(fp(new_lp)), float(fm(new_lm)))
                    gain = cur_pair_max - new_max
                    if gain > best_gain + 1e-15:
                        best_gain, best = gain, (int(si), int(sj), dn)
            if best is None:
                break  # no latency reduction available

            si, sj, dn = best
            if spl is None:
                for arr in (se, sh, slot_load):
                    arr[l, si], arr[l, sj] = arr[l, sj], arr[l, si]
                load[g_plus] -= dn
                load[g_minus] += dn
            else:
                ei, ej = int(se[l, si]), int(se[l, sj])
                se[l, si], se[l, sj] = se[l, sj], se[l, si]
                # rebuild the two swapped experts' reweighted shares/loads
                for e in (ei, ej):
                    cs = np.flatnonzero(se[l] == e)
                    sp = spl[rank_of[cs]]
                    sh[l, cs] = sp / sp.sum()
                    slot_load[l, cs] = w[l, e] * sh[l, cs]
                load = slot_load[l].reshape(G, s_loc).sum(axis=1)
            swaps.append(SlotSwap(l, si, sj, g_plus, g_minus))
            per_layer[l] += 1

        lat = _rank_latencies(load, perf_models)
        if lat.max() <= (1.0 + epsilon) * lat.mean():
            converged += 1

    return IncrementalResult(
        placement=ReplicatedPlacement(se, sh, G, E),
        swaps=swaps,
        converged_layers=converged,
        per_layer_swaps=per_layer,
    )


def incremental_update_replicated(
    placement: ReplicatedPlacement,
    w: np.ndarray,                       # (L, E) fresh activation matrix
    perf_models: Sequence[PerfModel],
    epsilon: float = 0.03,
    max_swaps_per_layer: int = 64,
    reweight_shares: bool = False,
) -> IncrementalResult:
    """Algorithm 2 at (expert, copy)-slot granularity (ViBE-R placements).

    The swap unit is a physical *slot*: exchanging the residents of one slot
    on the slowest rank with one on the fastest moves exactly two expert
    copies (and their traffic shares) — the share tables are updated in
    place alongside the slot table, so per-expert share sums and replica
    counts are invariant, which keeps every logical expert resident
    somewhere. Swaps that would colocate two copies of the same expert on
    one rank are skipped (a colocated replica absorbs no skew). The swap
    log doubles as the weight-migration plan, exactly as in the singleton
    solver.

    ``reweight_shares=True`` folds the share reweighting *into* the swap
    search: the loop starts from the reweighted shares, scores every
    candidate swap under the post-reweight shares its new copy→rank map
    would get (solver phase 3 inside the objective, not applied after the
    fact), and rebuilds the swapped experts' shares after each apply. A
    carried-share pass with post-hoc :func:`reweight_shares_by_speed` is
    still run as a safety net and the better-scoring result (by
    Σ_l max_g f_g) is returned — so folding can only match or improve on
    the historical post-hoc path. Off by default: the swap loop scores
    under the carried shares and no reweighting happens at all.
    """
    w = np.atleast_2d(np.asarray(w, dtype=np.float64))
    L, S = placement.slot_expert.shape
    E = placement.n_experts
    if w.shape != (L, E):
        raise ValueError(f"w shape {w.shape} != {(L, E)}")

    if not reweight_shares:
        return _replicated_swap_run(placement, w, perf_models, epsilon,
                                    max_swaps_per_layer)

    # folded: search under post-reweight shares (same speed estimate
    # reweight_shares_by_speed uses), starting from a reweighted table
    speeds, _ = _speed_targets(w, perf_models, "rank")
    folded = _replicated_swap_run(
        reweight_shares_by_speed(placement, w, perf_models), w, perf_models,
        epsilon, max_swaps_per_layer, speeds=speeds)
    folded = dataclasses.replace(
        folded, placement=reweight_shares_by_speed(folded.placement, w,
                                                   perf_models))
    legacy = _replicated_swap_run(placement, w, perf_models, epsilon,
                                  max_swaps_per_layer)
    posthoc = dataclasses.replace(
        legacy, placement=reweight_shares_by_speed(legacy.placement, w,
                                                   perf_models))
    if (_replicated_objective(folded.placement, w, perf_models)
            <= _replicated_objective(posthoc.placement, w, perf_models)):
        return folded
    return posthoc
