"""Incremental placement update (paper Algorithm 2, §4.2.4, Appendix A.2).

Rather than re-solving placement from scratch (which reassigns >200 of 256
slots per layer and incurs large weight-transfer cost), start from the
current placement and apply the minimum number of cross-rank expert swaps:

  repeat
    g+ ← rank with highest f_g(n_g)     (slowest)
    g- ← rank with lowest  f_g(n_g)     (fastest)
    evaluate all (e_i ∈ g+, e_j ∈ g-) swaps, score by marginal reduction in
    the pair's max latency; apply the best one
  until  max_g f_g(n_g) ≤ (1+ε) · mean_g f_g(n_g)   or no beneficial swap

The paper reports convergence in 5–30 swaps/layer. We additionally support
one-sided *moves* ... no — the paper keeps uniform slots per rank, so only
swaps preserve the memory constraint; we do the same.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from .perf_model import PerfModel
from .placement import Placement

__all__ = ["Swap", "IncrementalResult", "incremental_update"]


@dataclasses.dataclass(frozen=True)
class Swap:
    layer: int
    expert_a: int   # logical expert moving g_plus → g_minus
    expert_b: int   # logical expert moving g_minus → g_plus
    rank_a: int     # g_plus (was slowest)
    rank_b: int     # g_minus (was fastest)


@dataclasses.dataclass(frozen=True)
class IncrementalResult:
    placement: Placement
    swaps: List[Swap]
    converged_layers: int
    per_layer_swaps: np.ndarray     # (L,)

    @property
    def total_swaps(self) -> int:
        return len(self.swaps)

    def moved_expert_count(self) -> int:
        """Experts whose rank changed = 2 per swap (both directions)."""
        return 2 * len(self.swaps)


def _rank_latencies(load: np.ndarray, perf_models: Sequence[PerfModel]) -> np.ndarray:
    return np.array([perf_models[g](load[g]) for g in range(len(perf_models))])


def incremental_update(
    placement: Placement,
    w: np.ndarray,                       # (L, E) fresh activation matrix
    perf_models: Sequence[PerfModel],
    epsilon: float = 0.03,
    max_swaps_per_layer: int = 64,
) -> IncrementalResult:
    """Paper Algorithm 2 over all layers.

    Returns a new Placement plus the swap log (the weight-migration plan:
    exactly the swapped experts' tensors move between ranks).
    """
    w = np.atleast_2d(np.asarray(w, dtype=np.float64))
    G = placement.n_ranks
    L, E = placement.assign.shape
    if w.shape != (L, E):
        raise ValueError(f"w shape {w.shape} != placement {placement.assign.shape}")

    assign = placement.assign.copy()
    swaps: List[Swap] = []
    per_layer = np.zeros(L, dtype=np.int64)
    converged = 0

    for l in range(L):
        # per-rank loads under current assignment
        load = np.zeros(G)
        np.add.at(load, assign[l], w[l])
        # expert lists per rank (mutable)
        members = [list(np.flatnonzero(assign[l] == g)) for g in range(G)]

        for _ in range(max_swaps_per_layer):
            lat = _rank_latencies(load, perf_models)
            target = (1.0 + epsilon) * lat.mean()
            if lat.max() <= target:
                break
            g_plus = int(np.argmax(lat))
            g_minus = int(np.argmin(lat))
            if g_plus == g_minus:
                break

            # evaluate all swaps between g_plus and g_minus, score by the
            # reduction in max(f_{g+}, f_{g-}) (marginal latency gain)
            cur_pair_max = max(lat[g_plus], lat[g_minus])
            best_gain, best = 0.0, None
            fp, fm = perf_models[g_plus], perf_models[g_minus]
            wl = w[l]
            lp, lm = load[g_plus], load[g_minus]
            for ei in members[g_plus]:
                for ej in members[g_minus]:
                    dn = wl[ei] - wl[ej]
                    if dn <= 0:
                        continue  # only moving load off the slow rank helps
                    new_max = max(float(fp(lp - dn)), float(fm(lm + dn)))
                    gain = cur_pair_max - new_max
                    if gain > best_gain + 1e-15:
                        best_gain, best = gain, (ei, ej, dn)
            if best is None:
                break  # no latency reduction available

            ei, ej, dn = best
            members[g_plus].remove(ei); members[g_plus].append(ej)
            members[g_minus].remove(ej); members[g_minus].append(ei)
            assign[l, ei] = g_minus
            assign[l, ej] = g_plus
            load[g_plus] -= dn
            load[g_minus] += dn
            swaps.append(Swap(l, int(ei), int(ej), g_plus, g_minus))
            per_layer[l] += 1

        lat = _rank_latencies(load, perf_models)
        if lat.max() <= (1.0 + epsilon) * lat.mean():
            converged += 1

    return IncrementalResult(
        placement=Placement(assign, G),
        swaps=swaps,
        converged_layers=converged,
        per_layer_swaps=per_layer,
    )
