"""Drift detection (paper §4.2.4 "Drift-triggered recalibration", Alg 1 Phase 3).

ViBE monitors two signals rather than recalibrating on a fixed cadence:

1. **Routing drift** — cosine distance between the current windowed mean
   per-layer expert-load vector w and the reference snapshot ŵ recorded at
   the last rearrangement:

       d_l = 1 − (w·ŵ)/(‖w‖‖ŵ‖)

   checked every H forward passes (default 10) over a 100-sample window;
   trigger when max_l d_l > δ_cos (default 0.05).

2. **Stress drift** — unlike EPLB, ViBE also tracks absolute token
   *magnitude*, because hardware variability is stress-dependent: the same
   routing ratios at 4× the batch tokens push devices into steeper regions
   of f_g(n). We trigger when the windowed mean batch token count deviates
   from the reference by more than ``delta_mag`` (relative).

After a rearrangement a cooldown of H forward passes suppresses spurious
re-triggers from the transient load burst caused by the rearrangement itself
(paper Appendix A.1).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Optional

import numpy as np

__all__ = ["DriftConfig", "DriftDetector", "DriftEvent"]


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    delta_cos: float = 0.05      # cosine-distance threshold (paper default)
    delta_mag: float = 0.5       # relative token-magnitude threshold
    window: int = 100            # samples in the rolling mean (paper: 100)
    interval: int = 10           # H — check every H forward passes
    cooldown: int = 10           # forward passes suppressed after a trigger


@dataclasses.dataclass(frozen=True)
class DriftEvent:
    step: int
    kind: str                    # "routing" | "stress"
    max_cos_distance: float
    layer: int                   # argmax layer for routing drift (-1 stress)
    magnitude_ratio: float


def cosine_distance(a: np.ndarray, b: np.ndarray) -> float:
    na, nb = float(np.linalg.norm(a)), float(np.linalg.norm(b))
    if na == 0.0 or nb == 0.0:
        return 0.0 if na == nb else 1.0
    return float(1.0 - np.dot(a, b) / (na * nb))


class DriftDetector:
    """Stateful monitor fed one observation per forward pass.

    ``observe(w_step, tokens)`` with w_step the (L, E) per-layer expert load
    of this step and ``tokens`` the batch token count. Returns a DriftEvent
    when recalibration should fire, else None.
    """

    def __init__(self, n_layers: int, n_experts: int,
                 config: DriftConfig = DriftConfig()):
        self.cfg = config
        self.L, self.E = n_layers, n_experts
        self._win: Deque[np.ndarray] = collections.deque(maxlen=config.window)
        self._tok_win: Deque[float] = collections.deque(maxlen=config.window)
        self._ref: Optional[np.ndarray] = None       # (L, E) snapshot ŵ
        self._ref_tokens: Optional[float] = None
        self._step = 0
        self._cooldown_until = -1
        self.events = []

    # -- reference management -------------------------------------------

    def snapshot(self) -> None:
        """Record current window mean as the reference ŵ (after rearrange)."""
        if self._win:
            self._ref = self.window_mean()
            self._ref_tokens = float(np.mean(self._tok_win))
        self._cooldown_until = self._step + self.cfg.cooldown

    def window_mean(self) -> np.ndarray:
        return np.mean(np.stack(self._win), axis=0)

    @property
    def reference(self) -> Optional[np.ndarray]:
        return self._ref

    # -- main entry point -------------------------------------------------

    def observe(self, w_step: np.ndarray, tokens: float) -> Optional[DriftEvent]:
        w_step = np.asarray(w_step, dtype=np.float64)
        if w_step.shape != (self.L, self.E):
            raise ValueError(f"expected ({self.L},{self.E}), got {w_step.shape}")
        self._win.append(w_step)
        self._tok_win.append(float(tokens))
        self._step += 1

        if self._ref is None:
            # bootstrap: snapshot once the window has filled
            if len(self._win) >= self.cfg.window:
                self.snapshot()
            return None
        if self._step <= self._cooldown_until:
            return None
        if self._step % self.cfg.interval != 0:
            return None
        if len(self._win) < self.cfg.window:
            return None

        mean = self.window_mean()
        # routing drift: max per-layer cosine distance
        dists = np.array([cosine_distance(mean[l], self._ref[l])
                          for l in range(self.L)])
        l_max = int(np.argmax(dists))
        d_max = float(dists[l_max])
        mag_ratio = (float(np.mean(self._tok_win)) /
                     max(self._ref_tokens, 1e-9))

        event = None
        if d_max > self.cfg.delta_cos:
            event = DriftEvent(self._step, "routing", d_max, l_max, mag_ratio)
        elif abs(mag_ratio - 1.0) > self.cfg.delta_mag:
            event = DriftEvent(self._step, "stress", d_max, -1, mag_ratio)
        if event is not None:
            self.events.append(event)
        return event
