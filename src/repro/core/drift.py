"""Drift detection (paper §4.2.4 "Drift-triggered recalibration", Alg 1 Phase 3).

ViBE monitors three signals rather than recalibrating on a fixed cadence:

1. **Routing drift** — cosine distance between the current windowed mean
   per-layer expert-load vector w and the reference snapshot ŵ recorded at
   the last rearrangement:

       d_l = 1 − (w·ŵ)/(‖w‖‖ŵ‖)

   checked every H forward passes (default 10) over a 100-sample window;
   trigger when max_l d_l > δ_cos (default 0.05).

2. **Stress drift** — unlike EPLB, ViBE also tracks absolute token
   *magnitude*, because hardware variability is stress-dependent: the same
   routing ratios at 4× the batch tokens push devices into steeper regions
   of f_g(n). We trigger when the windowed mean batch token count deviates
   from the reference by more than ``delta_mag`` (relative). Stress takes
   precedence over routing when both fire in the same check — a moved
   operating point mandates the full re-solve path, which the incremental
   routing path would skip.

3. **Performance drift** (:class:`PerfDriftDetector`) — the paper refreshes
   "routing *and performance* estimates": the fitted f_g models themselves
   go stale when hardware behaviour changes (thermal throttling, power-cap
   steps, device replacement). The detector watches the windowed relative
   residual |observed − f_g(n)| / f_g(n) per rank over a
   :class:`~repro.core.perf_model.TelemetryBuffer` of serving-observed
   samples and fires when any rank exceeds δ_perf; the affected ranks'
   models are then refit from the same window.

After a rearrangement a cooldown of H forward passes suppresses spurious
re-triggers from the transient load burst caused by the rearrangement itself
(paper Appendix A.1).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Optional, Sequence, Tuple

import numpy as np

from .perf_model import PerfModel, TelemetryBuffer, refit_from_samples

__all__ = ["DriftConfig", "DriftDetector", "DriftEvent",
           "PerfDriftConfig", "PerfDriftDetector", "PerfDriftEvent"]


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    delta_cos: float = 0.05      # cosine-distance threshold (paper default)
    delta_mag: float = 0.5       # relative token-magnitude threshold
    window: int = 100            # samples in the rolling mean (paper: 100)
    interval: int = 10           # H — check every H forward passes
    cooldown: int = 10           # forward passes suppressed after a trigger


@dataclasses.dataclass(frozen=True)
class DriftEvent:
    step: int
    kind: str                    # "routing" | "stress"
    max_cos_distance: float
    layer: int                   # argmax routing-drift layer; -1 when the
    #                              routing signal did not trip (pure stress)
    magnitude_ratio: float
    routing_drift: bool = False  # routing signal also above threshold (a
    #                              stress event can carry both)


def cosine_distance(a: np.ndarray, b: np.ndarray) -> float:
    na, nb = float(np.linalg.norm(a)), float(np.linalg.norm(b))
    if na == 0.0 or nb == 0.0:
        return 0.0 if na == nb else 1.0
    return float(1.0 - np.dot(a, b) / (na * nb))


class DriftDetector:
    """Stateful monitor fed one observation per forward pass.

    ``observe(w_step, tokens)`` with w_step the (L, E) per-layer expert load
    of this step and ``tokens`` the batch token count. Returns a DriftEvent
    when recalibration should fire, else None.
    """

    def __init__(self, n_layers: int, n_experts: int,
                 config: DriftConfig = DriftConfig()):
        self.cfg = config
        self.L, self.E = n_layers, n_experts
        self._win: Deque[np.ndarray] = collections.deque(maxlen=config.window)
        self._tok_win: Deque[float] = collections.deque(maxlen=config.window)
        self._ref: Optional[np.ndarray] = None       # (L, E) snapshot ŵ
        self._ref_tokens: Optional[float] = None
        self._step = 0
        self._cooldown_until = -1
        self.events = []

    # -- reference management -------------------------------------------

    def snapshot(self) -> None:
        """Record current window mean as the reference ŵ (after rearrange)."""
        if self._win:
            self._ref = self.window_mean()
            self._ref_tokens = float(np.mean(self._tok_win))
        self._cooldown_until = self._step + self.cfg.cooldown

    def window_mean(self) -> np.ndarray:
        return np.mean(np.stack(self._win), axis=0)

    @property
    def reference(self) -> Optional[np.ndarray]:
        return self._ref

    # -- main entry point -------------------------------------------------

    def observe(self, w_step: np.ndarray, tokens: float) -> Optional[DriftEvent]:
        w_step = np.asarray(w_step, dtype=np.float64)
        if w_step.shape != (self.L, self.E):
            raise ValueError(f"expected ({self.L},{self.E}), got {w_step.shape}")
        self._win.append(w_step)
        self._tok_win.append(float(tokens))
        self._step += 1

        if self._ref is None:
            # bootstrap: snapshot once the window has filled
            if len(self._win) >= self.cfg.window:
                self.snapshot()
            return None
        if self._step <= self._cooldown_until:
            return None
        if self._step % self.cfg.interval != 0:
            return None
        if len(self._win) < self.cfg.window:
            return None

        mean = self.window_mean()
        # routing drift: max per-layer cosine distance
        dists = np.array([cosine_distance(mean[l], self._ref[l])
                          for l in range(self.L)])
        l_max = int(np.argmax(dists))
        d_max = float(dists[l_max])
        mag_ratio = (float(np.mean(self._tok_win)) /
                     max(self._ref_tokens, 1e-9))

        routing = d_max > self.cfg.delta_cos
        stress = abs(mag_ratio - 1.0) > self.cfg.delta_mag
        event = None
        if stress:
            # stress takes precedence: a moved operating point mandates the
            # full re-solve path even when routing drifted simultaneously
            # (the event still carries the routing signal)
            event = DriftEvent(self._step, "stress", d_max,
                               l_max if routing else -1, mag_ratio,
                               routing_drift=routing)
        elif routing:
            event = DriftEvent(self._step, "routing", d_max, l_max, mag_ratio,
                               routing_drift=True)
        if event is not None:
            self.events.append(event)
        return event


# ---------------------------------------------------------------------------
# performance drift (the f_g refresh half of §4.2.4)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PerfDriftConfig:
    delta_perf: float = 0.15     # windowed relative-residual threshold
    # fit_perf_model's per-knot local regression removed the ~10%
    # systematic bin-mean bias at the stress knee, so thresholds below
    # 0.10 are meaningful now (they used to fire on fit error alone);
    # 0.15 remains the default as margin for serving-telemetry jitter
    window: int = 128            # telemetry samples kept per rank
    interval: int = 10           # check every H observe() calls
    cooldown: int = 20           # observations suppressed after a trigger
    min_samples: int = 8         # residual needs this many samples per rank
    n_knots: int = 8             # refit resolution (fit_perf_model)


@dataclasses.dataclass(frozen=True)
class PerfDriftEvent:
    step: int
    ranks: Tuple[int, ...]       # ranks whose residual exceeded delta_perf
    max_residual: float
    rank_residuals: np.ndarray   # (G,) windowed relative residuals (NaN→0)
    kind: str = "perf"


class PerfDriftDetector:
    """Watches observed per-rank latencies against the fitted f_g models.

    Fed one observation per engine/simulator step via
    ``observe(rank_loads, rank_latencies)`` ((G,) or (L, G) arrays — the
    per-layer rows the virtual clock computes are each a genuine (n, f_g(n))
    sample). Fires a :class:`PerfDriftEvent` when any rank's windowed mean
    relative residual |observed − f_g(n)| / f_g(n) exceeds ``delta_perf``.

    ``models`` is held by reference: :meth:`refit` replaces the stale
    entries *in place*, so a controller sharing its ``perf_models`` list
    sees the refreshed curves without any copying protocol.
    """

    def __init__(self, n_ranks: int, models: Sequence[PerfModel],
                 config: PerfDriftConfig = PerfDriftConfig()):
        if len(models) != n_ranks:
            raise ValueError("one perf model per rank required")
        self.cfg = config
        self.G = int(n_ranks)
        self.models = models if isinstance(models, list) else list(models)
        self.buffer = TelemetryBuffer(n_ranks, window=config.window)
        self._step = 0
        self._cooldown_until = -1
        self.events = []

    def snapshot(self) -> None:
        """Start the post-recalibration cooldown (mirror of
        :meth:`DriftDetector.snapshot`)."""
        self._cooldown_until = self._step + self.cfg.cooldown

    def residuals(self) -> np.ndarray:
        """(G,) current windowed relative residuals (NaN → 0 for ranks
        without enough samples)."""
        res = self.buffer.relative_residuals(self.models,
                                             self.cfg.min_samples)
        return np.nan_to_num(res, nan=0.0)

    def observe(self, rank_loads: np.ndarray,
                rank_latencies: np.ndarray) -> Optional[PerfDriftEvent]:
        self.buffer.add(rank_loads, rank_latencies)
        self._step += 1
        if self._step <= self._cooldown_until:
            return None
        if self._step % self.cfg.interval != 0:
            return None
        res = self.residuals()
        hot = np.nonzero(res > self.cfg.delta_perf)[0]
        if hot.size == 0:
            return None
        event = PerfDriftEvent(self._step, tuple(int(g) for g in hot),
                               float(res.max()), res)
        self.events.append(event)
        return event

    def refit(self, ranks: Optional[Sequence[int]] = None) -> Tuple[int, ...]:
        """Rebuild the named ranks' models from their telemetry windows.

        Replaces entries of ``self.models`` in place; returns the ranks
        actually refit (those with ≥ 2 window samples). ``None`` = every
        rank currently above threshold.
        """
        if ranks is None:
            ranks = tuple(int(g) for g in
                          np.nonzero(self.residuals()
                                     > self.cfg.delta_perf)[0])
        done = []
        for g in ranks:
            n, lat = self.buffer.samples(g)
            if n.size < 2:
                continue
            # prior= keeps the profiled curve shape (rescaled) when the
            # window lacks load diversity — a saturated server sees only
            # one operating point per step
            self.models[g] = refit_from_samples(n, lat, device_id=g,
                                                n_knots=self.cfg.n_knots,
                                                prior=self.models[g])
            done.append(int(g))
        return tuple(done)
