"""Dispatch-time token rescheduling between recalibrations (work stealing).

Placement reacts on drift-detector timescales; between recalibrations a
bursty batch or a stale profile leaves realized per-rank load diverging
from the plan — the regime HarMoEny (PAPERS.md) attacks by rebalancing at
*dispatch* time rather than placement time. This module closes that gap on
the variability-aware stack: each step the :class:`TokenRescheduler`
compares per-rank *predicted latency* — ``f_g`` on the realized loads, so
a fast rank legitimately carries more tokens — against the fleet mean, and
when the hottest rank exceeds it by a configurable headroom, shifts a
bounded fraction of traffic share away from that rank's replicated-expert
copies toward their sibling copies on faster ranks.

The mechanism is a pure reweighting of the placement's per-copy traffic
shares (``ReplicatedPlacement.share`` → ``copy_cdf``): no weights move, so
model semantics are untouched (replicas hold identical parameters), and
per-expert share sums stay exactly 1, so token conservation is structural.
The share table is a plain data input to the jitted dispatch (copy-axis
width pinned via ``r_max``), so steal updates never recompile.

Degenerate cases fall out of the math rather than special-casing:

* **r_max == 1** — a singleton expert's only copy has no sibling to
  receive share, so its removal is cancelled; nothing ever changes.
* **balanced load** — the headroom trigger never fires; shares stay at
  the solver's plan.

Everything here is deterministic host-side numpy given the tally stream —
no RNG — so steal-on runs are bit-reproducible under a fixed seed.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from .perf_model import PerfModel
from .placement import ReplicatedPlacement

__all__ = ["StealConfig", "TokenRescheduler"]


@dataclasses.dataclass(frozen=True)
class StealConfig:
    """Knobs for dispatch-time token rescheduling.

    ``headroom``  — steal only when the hottest rank's predicted latency
        exceeds the fleet mean by this relative margin. 0 chases every
        imbalance (thrash-prone); large values only fire on genuine
        stragglers.
    ``max_shift`` — fraction of a hot copy's current share moved per step;
        bounds each step's reweighting so a single noisy tally cannot
        swing the split (the next step's trigger re-evaluates from the
        shifted state, so repeated steps converge geometrically).
    ``interval``  — evaluate the trigger every this many observed steps
        (tallies are still folded into the load estimate in between).
    ``smoothing`` — EMA coefficient on realized per-expert loads: the
        weight of the newest step. 1.0 reacts to the raw last step; lower
        values trade reaction time for stability on decode-sized batches.
    """

    headroom: float = 0.1
    max_shift: float = 0.25
    interval: int = 1
    smoothing: float = 0.5

    def __post_init__(self):
        if self.headroom < 0:
            raise ValueError(f"headroom must be >= 0, got {self.headroom}")
        if not 0.0 < self.max_shift <= 1.0:
            raise ValueError("max_shift must be in (0, 1], "
                             f"got {self.max_shift}")
        if self.interval < 1:
            raise ValueError(f"interval must be >= 1, got {self.interval}")
        if not 0.0 < self.smoothing <= 1.0:
            raise ValueError("smoothing must be in (0, 1], "
                             f"got {self.smoothing}")


class TokenRescheduler:
    """Per-step bounded reweighting of a replicated placement's copy shares.

    Owns the *responsive* share table: :attr:`placement` starts as the
    solver's plan (set via :meth:`reset` at every recalibration) and drifts
    from it as :meth:`observe` reacts to realized load. Consumers price and
    dispatch against :attr:`placement`; the base plan is untouched, so a
    recalibration always restarts from the solver's intent.

    ``perf_models`` is held **by reference** (the controller's live list) —
    online perf-drift refits flow into the steal trigger without a copy
    protocol, mirroring :class:`~repro.core.drift.PerfDriftDetector`.
    """

    def __init__(self, config: StealConfig,
                 perf_models: Sequence[PerfModel]):
        self.cfg = config
        self.perf_models: List[PerfModel] = \
            perf_models if isinstance(perf_models, list) else \
            list(perf_models)
        #: monotone change counter: consumers compare against their own
        #: snapshot to learn "the responsive shares moved, refresh tables"
        #: without the rescheduler knowing who consumes them
        self.version = 0
        self.steals = 0              # steps on which any share moved
        self.share_moved = 0.0       # Σ |share delta| across all steals
        self._pl: Optional[ReplicatedPlacement] = None
        self._share: Optional[np.ndarray] = None
        self._w: Optional[np.ndarray] = None
        self._ticks = 0
        #: (G,) EMA of measured/predicted latency per rank, from
        #: observe_latency telemetry; None until the first measurement
        self._lat_bias: Optional[np.ndarray] = None

    # ------------------------------------------------------------------
    @property
    def placement(self) -> ReplicatedPlacement:
        """The responsive placement (base slot table, current shares)."""
        if self._pl is None:
            raise RuntimeError("TokenRescheduler.reset() not called")
        return self._pl

    def reset(self, placement: ReplicatedPlacement) -> None:
        """Adopt a new base placement (called at every recalibration).

        Responsive shares restart at the solver's plan and the load EMA
        restarts cold — post-recalibration tallies reflect the *new*
        layout, and the old estimate would mis-trigger against it.
        """
        if len(self.perf_models) != placement.n_ranks:
            raise ValueError(f"{len(self.perf_models)} perf models != "
                             f"{placement.n_ranks} ranks")
        self._pl = placement
        self._share = placement.share.copy()
        self._w = None
        self._ticks = 0
        # drop the measured-latency bias too: a recalibration usually means
        # the perf models were just refit from the same telemetry, and
        # keeping the bias would double-count the drift it already absorbed
        self._lat_bias = None
        self.version += 1

    # ------------------------------------------------------------------
    def observe(self, expert_loads: np.ndarray) -> bool:
        """Feed one step's realized per-expert loads; returns True when the
        responsive shares changed (consumers should refresh dispatch/CDF
        tables — :attr:`version` bumps in lockstep)."""
        if self._pl is None:
            raise RuntimeError("TokenRescheduler.reset() not called")
        w = np.atleast_2d(np.asarray(expert_loads, dtype=np.float64))
        if w.shape != (self._pl.n_layers, self._pl.n_experts):
            raise ValueError(
                f"expert_loads shape {w.shape} != "
                f"{(self._pl.n_layers, self._pl.n_experts)}")
        a = self.cfg.smoothing
        self._w = w if self._w is None else a * w + (1.0 - a) * self._w
        self._ticks += 1
        if self._ticks % self.cfg.interval:
            return False
        new_share = self._steal(self._w)
        if new_share is None:
            return False
        self._share = new_share
        self._pl = ReplicatedPlacement(self._pl.slot_expert, new_share,
                                       self._pl.n_ranks, self._pl.n_experts)
        self.version += 1
        self.steals += 1
        return True

    # ------------------------------------------------------------------
    def observe_latency(self, rank_loads: np.ndarray,
                        rank_latencies: np.ndarray) -> None:
        """Blend measured per-rank latencies into the steal trigger.

        ``rank_loads`` / ``rank_latencies`` are (G,) or (L, G) — the same
        telemetry the virtual clocks feed ``ViBEController.observe_latency``
        for perf-drift refits. The measured/predicted ratio is EMA-tracked
        per rank and multiplies :meth:`predicted_latency`, so the trigger
        (and recipient speed weights) see hardware drift *between* perf
        refits — e.g. a thermal ramp that f_g, fitted minutes ago, knows
        nothing about. :meth:`reset` clears the bias: the refit the
        recalibration just ran absorbed the same drift.
        """
        if self._pl is None:
            return
        load = np.atleast_2d(np.asarray(rank_loads, dtype=np.float64))
        lat = np.atleast_2d(np.asarray(rank_latencies, dtype=np.float64))
        if load.shape != lat.shape or load.shape[-1] != self._pl.n_ranks:
            raise ValueError(f"latency telemetry shapes {load.shape} / "
                             f"{lat.shape} do not match G={self._pl.n_ranks}")
        pred = np.empty_like(load)
        for g, m in enumerate(self.perf_models):
            pred[:, g] = m(load[:, g])
        ratio = (lat / np.maximum(pred, 1e-12)).mean(axis=0)     # (G,)
        a = self.cfg.smoothing
        self._lat_bias = ratio if self._lat_bias is None \
            else a * ratio + (1.0 - a) * self._lat_bias

    def predicted_latency(self, w: np.ndarray) -> np.ndarray:
        """(L, G) per-rank predicted latency f_g(load) under the current
        responsive shares — the steal trigger's signal. Scaled by the
        measured/predicted bias when latency telemetry has been observed."""
        load = self._pl_with(self._share).rank_loads(w)
        lat = np.empty_like(load)
        for g, m in enumerate(self.perf_models):
            lat[:, g] = m(load[:, g])
        if self._lat_bias is not None:
            lat = lat * self._lat_bias[None, :]
        return lat

    def _pl_with(self, share: np.ndarray) -> ReplicatedPlacement:
        pl = self._pl
        return ReplicatedPlacement(pl.slot_expert, share,
                                   pl.n_ranks, pl.n_experts)

    def _steal(self, w: np.ndarray) -> Optional[np.ndarray]:
        """One bounded reweighting pass; None when nothing moves.

        Vectorized across layers: per layer, the single hottest rank (by
        predicted latency) sheds ``max_shift`` of each of its resident
        copies' shares to the same experts' copies on other ranks,
        recipients weighted by the *speed* (1/latency) of the rank they
        sit on. Experts with no off-hot-rank copy keep their share — the
        removal is cancelled, never dropped.
        """
        pl = self._pl
        cfg = self.cfg
        share = self._share
        se = pl.slot_expert
        L, S = se.shape
        E, G = pl.n_experts, pl.n_ranks
        rows = np.arange(L)
        lat = self.predicted_latency(w)                          # (L, G)
        hot = np.argmax(lat, axis=1)                             # (L,)
        trigger = lat[rows, hot] > (1.0 + cfg.headroom) * lat.mean(axis=1)
        if not trigger.any():
            return None
        rank_of = np.arange(S) // pl.slots_per_rank              # (S,)
        real = se < E                                            # (L, S)
        on_hot = rank_of[None, :] == hot[:, None]                # (L, S)
        # recipients: an expert's copies off the hot rank, weighted by the
        # speed of the rank they occupy (faster rank absorbs more)
        slot_speed = 1.0 / lat[:, rank_of]                       # (L, S)
        recv_w = np.where(real & ~on_hot & trigger[:, None],
                          slot_speed, 0.0)
        se_c = np.minimum(se, E)
        denom = np.zeros((L, E + 1))
        np.add.at(denom, (rows[:, None], se_c), recv_w)
        denom[:, E] = 1.0                                        # phantoms
        has_recv = np.take_along_axis(denom, se_c, axis=1) > 0.0
        delta = np.where(trigger[:, None] & on_hot & real & has_recv,
                         share * cfg.max_shift, 0.0)
        if not delta.any():
            return None                                # e.g. r_max == 1
        removed = np.zeros((L, E + 1))
        np.add.at(removed, (rows[:, None], se_c), delta)
        gain = recv_w / np.maximum(np.take_along_axis(denom, se_c, axis=1),
                                   1e-300) \
            * np.take_along_axis(removed, se_c, axis=1)
        new_share = share - delta + gain
        self.share_moved += float(delta.sum())
        return new_share

    # ------------------------------------------------------------------
    def expected_rank_loads(self, w: np.ndarray) -> np.ndarray:
        """(L, G) fractional per-rank loads under the responsive shares —
        convenience for tests and pricing parity checks."""
        return self._pl_with(self._share).rank_loads(
            np.atleast_2d(np.asarray(w, dtype=np.float64)))

    @property
    def share_table_bytes(self) -> int:
        """Bytes of the float32 CDF/share broadcast a steal update ships to
        the fleet — what the virtual clocks charge per update."""
        if self._pl is None:
            return 0
        return int(self._pl.share.size * 4)
