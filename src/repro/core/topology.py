"""Fleet-scale cluster topology: the two-level (node / device) model the
rest of the stack prices communication against.

Every policy before this module treated the cluster as a flat set of G
ranks behind one uniform interconnect. At fleet scale that is wrong by an
order of magnitude: devices within a node talk over ICI, nodes talk over
DCN, and the two bandwidths differ ~8x — exactly the asymmetry the
Expert-Sharding and MoETuner lines of work exploit. :class:`ClusterTopology`
makes the asymmetry a first-class input:

* ``SolveContext.topology`` hands it to placement policies;
* both virtual clocks (``Engine`` and ``EPSimulator``) price a2a,
  migration, and steal-broadcast traffic through it instead of a flat
  ``bytes / ici_bw`` divide;
* :func:`vibe_h_placement` (registered as policy ``vibe_h``) is a
  two-level solver: bin experts across nodes to minimize cross-node (DCN)
  token traffic, then run the existing ``_replicated_solve`` within each
  node against that node's per-rank perf models — straggler latency and
  cross-node bytes co-optimized.

Dispatch locality model (used consistently by :meth:`node_split_loads`
and the simulator's hierarchical a2a clock): tokens originate uniformly
across devices, and a token for expert e sourced on node m goes to a
node-m copy when one exists (shares renormalized within the node);
otherwise it fans out globally in proportion to the copy shares and
crosses the DCN. Compute pricing keeps the solver's *global* shares — a
documented approximation; the communication clock is what models
locality.

All cost methods degenerate exactly to the legacy flat formulas on a
single-node topology with zero link latencies, so pre-existing goldens
stay bit-identical (pinned by ``tests/test_topology.py``).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .placement import (ReplicatedPlacement, _replicated_solve,
                        _replication_degrees, _speed_targets,
                        normalize_slot_budget, vibe_r_placement)

__all__ = [
    "ClusterTopology",
    "parse_topology",
    "vibe_h_placement",
]

#: default ICI:DCN bandwidth ratio when a 2-level topology is built
#: without an explicit DCN number (intra-node fabrics run ~an order of
#: magnitude faster than the inter-node network).
DEFAULT_DCN_RATIO = 8.0


@dataclasses.dataclass(frozen=True, eq=False)
class ClusterTopology:
    """Two-level cluster communication model.

    ``node_of``: (G,) int array — node id of each rank. Node ids must be
        contiguous ``0..K-1``; nodes may be ragged (different sizes), which
        is what lets :meth:`mask` return a survivor topology after a rank
        failure.
    ``ici_bw`` / ``dcn_bw``: per-rank link bandwidth in bytes/s for
        intra-node (ICI) and cross-node (DCN) transfers.
    ``ici_latency`` / ``dcn_latency``: per-transfer hop latency in
        seconds (0 by default, which is also what keeps the flat
        degenerate bit-identical to the legacy pricing).
    """

    node_of: np.ndarray
    ici_bw: float
    dcn_bw: float
    ici_latency: float = 0.0
    dcn_latency: float = 0.0

    def __post_init__(self):
        node_of = np.asarray(self.node_of, dtype=np.int64).ravel()
        if node_of.size < 1:
            raise ValueError("topology needs at least one rank")
        uniq = np.unique(node_of)
        if not np.array_equal(uniq, np.arange(uniq.size)):
            raise ValueError("node ids must be contiguous 0..K-1, got "
                             f"{uniq.tolist()}")
        if self.ici_bw <= 0 or self.dcn_bw <= 0:
            raise ValueError("link bandwidths must be positive")
        if self.ici_latency < 0 or self.dcn_latency < 0:
            raise ValueError("link latencies cannot be negative")
        node_of.setflags(write=False)
        object.__setattr__(self, "node_of", node_of)

    # -- construction -------------------------------------------------------

    @classmethod
    def flat(cls, n_ranks: int, ici_bw: float,
             ici_latency: float = 0.0) -> "ClusterTopology":
        """Single-node topology — the legacy flat-interconnect degenerate."""
        return cls(np.zeros(n_ranks, dtype=np.int64), ici_bw, ici_bw,
                   ici_latency, ici_latency)

    @classmethod
    def uniform(cls, n_nodes: int, devices_per_node: int, ici_bw: float,
                dcn_bw: Optional[float] = None, ici_latency: float = 0.0,
                dcn_latency: float = 0.0) -> "ClusterTopology":
        """``n_nodes`` x ``devices_per_node`` grid; DCN defaults to
        ``ici_bw / DEFAULT_DCN_RATIO``."""
        if n_nodes < 1 or devices_per_node < 1:
            raise ValueError("n_nodes and devices_per_node must be >= 1")
        node_of = np.repeat(np.arange(n_nodes, dtype=np.int64),
                            devices_per_node)
        if dcn_bw is None:
            dcn_bw = ici_bw if n_nodes == 1 else ici_bw / DEFAULT_DCN_RATIO
        return cls(node_of, ici_bw, dcn_bw, ici_latency, dcn_latency)

    # -- shape --------------------------------------------------------------

    @property
    def n_ranks(self) -> int:
        return int(self.node_of.size)

    @property
    def n_nodes(self) -> int:
        return int(self.node_of.max()) + 1

    @property
    def is_flat(self) -> bool:
        return self.n_nodes == 1

    @property
    def node_sizes(self) -> np.ndarray:
        """(K,) device count per node."""
        return np.bincount(self.node_of, minlength=self.n_nodes)

    @property
    def rank_node_sizes(self) -> np.ndarray:
        """(G,) size of the node each rank lives on."""
        return self.node_sizes[self.node_of]

    def ranks_of(self, node: int) -> np.ndarray:
        return np.flatnonzero(self.node_of == node)

    def mask(self, dead_ranks: Sequence[int]) -> "ClusterTopology":
        """Survivor topology after removing ``dead_ranks`` — nodes are
        re-labelled contiguously (a node that loses all its devices
        disappears)."""
        dead = set(int(g) for g in dead_ranks)
        keep = np.array([g for g in range(self.n_ranks) if g not in dead],
                        dtype=np.int64)
        if keep.size == 0:
            raise ValueError("cannot mask every rank")
        nodes = self.node_of[keep]
        _, relabelled = np.unique(nodes, return_inverse=True)
        return ClusterTopology(relabelled.astype(np.int64), self.ici_bw,
                               self.dcn_bw, self.ici_latency,
                               self.dcn_latency)

    # -- pricing ------------------------------------------------------------

    def xfer_cost(self, src_rank: int, dst_rank: int, nbytes: float) -> float:
        """Point-to-point transfer time between two ranks."""
        if src_rank == dst_rank or nbytes <= 0:
            return 0.0
        if self.node_of[src_rank] == self.node_of[dst_rank]:
            return nbytes / self.ici_bw + self.ici_latency
        return nbytes / self.dcn_bw + self.dcn_latency

    def a2a_cost(self, rank_bytes) -> float:
        """All-to-all time for per-rank payloads spread uniformly over all
        G destinations (the self-fraction 1/G is free). Per rank, the
        (D_g - 1)/G fraction rides ICI and the (G - D_g)/G fraction rides
        DCN; the exchange completes when the slowest rank does. Flat
        degenerate: ``rank_bytes * (G-1)/G / ici_bw``."""
        G = self.n_ranks
        rb = np.broadcast_to(np.asarray(rank_bytes, dtype=np.float64), (G,))
        D = self.rank_node_sizes.astype(np.float64)
        per_rank = (rb * (D - 1.0) / G / self.ici_bw
                    + rb * (G - D) / G / self.dcn_bw)
        t = float(per_rank.max())
        if t <= 0.0:
            return 0.0
        hop = self.dcn_latency if self.n_nodes > 1 else self.ici_latency
        return t + hop

    def cross_fraction(self) -> float:
        """Probability a uniformly random (src, dst) pair of *distinct*
        ranks crosses the DCN; 0 for flat or single-rank topologies."""
        G = float(self.n_ranks)
        if G <= 1.0:
            return 0.0
        sz = self.node_sizes.astype(np.float64)
        return 1.0 - float((sz * (sz - 1.0)).sum()) / (G * (G - 1.0))

    def migration_cost(self, nbytes: float, parallel_links: int = 1) -> float:
        """Time to move ``nbytes`` of expert weights between uniformly
        random rank pairs, striped over ``parallel_links`` concurrent
        links. The engine serializes migrations on one link
        (``parallel_links=1`` — flat degenerate ``nbytes / ici_bw``); the
        simulator models G concurrent links (flat degenerate
        ``nbytes / (G * ici_bw)``)."""
        if nbytes <= 0:
            return 0.0
        f_x = self.cross_fraction()
        per = nbytes / max(int(parallel_links), 1)
        cost = per * ((1.0 - f_x) / self.ici_bw + f_x / self.dcn_bw)
        return float(cost + (1.0 - f_x) * self.ici_latency
                     + f_x * self.dcn_latency)

    def broadcast_cost(self, nbytes: float) -> float:
        """Time to broadcast ``nbytes`` (share tables) to every rank —
        bottlenecked by the slowest link class present."""
        if nbytes <= 0:
            return 0.0
        if self.is_flat:
            return nbytes / self.ici_bw + self.ici_latency
        return (nbytes / min(self.ici_bw, self.dcn_bw)
                + max(self.ici_latency, self.dcn_latency))

    # -- locality accounting ------------------------------------------------

    def node_split_loads(self, placement,
                         loads: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Split per-rank token arrivals into intra-node and cross-node
        components under the node-preferring dispatch model.

        ``placement`` is a :class:`ReplicatedPlacement` (singleton
        placements are lifted automatically); ``loads`` is the (L, E)
        per-expert token matrix. Returns ``(local_in, cross_in)`` — two
        (L, G) arrays of tokens arriving at each rank from its own node
        vs over the DCN. ``local_in + cross_in`` sums to ``loads`` per
        layer (conservation), and on a flat topology ``cross_in`` is zero
        and ``local_in`` equals the placement's ``rank_loads``."""
        if not hasattr(placement, "slot_expert"):
            placement = ReplicatedPlacement.from_singleton(placement)
        G, K = self.n_ranks, self.n_nodes
        if placement.n_ranks != G:
            raise ValueError(f"placement has {placement.n_ranks} ranks, "
                             f"topology has {G}")
        se, sh = placement.slot_expert, placement.share
        L, S = se.shape
        E = placement.n_experts
        spr = S // G
        w = np.atleast_2d(np.asarray(loads, dtype=np.float64))
        node_of_slot = self.node_of[np.repeat(np.arange(G), spr)]    # (S,)

        # node shares sigma[l, e, m] = total copy share of e on node m
        sigma = np.zeros((L, E + 1, K))
        np.add.at(sigma,
                  (np.repeat(np.arange(L), S), se.ravel(),
                   np.tile(node_of_slot, L)), sh.ravel())
        sigma = sigma[:, :E, :]

        frac = self.node_sizes.astype(np.float64) / G                # (K,)
        covered = sigma > 1e-12
        uncov = ((~covered) * frac[None, None, :]).sum(-1)           # (L, E)

        lI = np.arange(L)[:, None]
        valid = se < E
        e_safe = np.minimum(se, E - 1)
        sig_slot = sigma[lI, e_safe, node_of_slot[None, :]]          # (L, S)
        w_slot = w[lI, e_safe]
        local = np.where(
            valid & (sig_slot > 1e-12),
            w_slot * frac[node_of_slot][None, :] * sh
            / np.maximum(sig_slot, 1e-12), 0.0)
        cross = np.where(valid, w_slot * uncov[lI, e_safe] * sh, 0.0)
        return (local.reshape(L, G, spr).sum(-1),
                cross.reshape(L, G, spr).sum(-1))


def parse_topology(spec: str, ici_bw: float,
                   dcn_bw: Optional[float] = None) -> ClusterTopology:
    """Parse a CLI topology spec: ``"2x4"`` → 2 nodes x 4 devices,
    ``"8"`` → flat 8 ranks. DCN bandwidth defaults to
    ``ici_bw / DEFAULT_DCN_RATIO`` for multi-node specs."""
    s = spec.strip().lower()
    if "x" in s:
        try:
            n_nodes, per_node = (int(p) for p in s.split("x"))
        except ValueError:
            raise ValueError(
                f"bad topology spec {spec!r} — want 'KxD'") from None
        return ClusterTopology.uniform(n_nodes, per_node, ici_bw, dcn_bw)
    try:
        n_ranks = int(s)
    except ValueError:
        raise ValueError(
            f"bad topology spec {spec!r} — want 'KxD' or 'G'") from None
    return ClusterTopology.flat(n_ranks, ici_bw)


# ---------------------------------------------------------------------------
# vibe_h: two-level node-aware hierarchical solver
# ---------------------------------------------------------------------------

def _bin_experts_to_nodes(w_l: np.ndarray, node_share: np.ndarray,
                          node_cap: np.ndarray, spare: int) -> np.ndarray:
    """Phase A of vibe_h for one layer: assign each expert to >= 1 node,
    spending the spare slots on cross-node replicas of the hottest experts
    (a replica on every sourcing node zeroes that expert's DCN traffic).

    Returns a boolean (E, K) coverage matrix. Greedy: per-copy loads in
    descending order, each copy to the node farthest below its
    speed-proportional token target, honoring per-node slot capacity and
    one-copy-per-node dedup.
    """
    E, K = w_l.size, node_cap.size
    n_extra = min(spare, E * (K - 1))
    deg = _replication_degrees(w_l[None, :], n_extra, max_copies=K)[0]
    order = np.argsort(-(w_l / deg), kind="stable")

    tau = node_share / node_share.sum() * w_l.sum()
    load = np.zeros(K)
    count = np.zeros(K, dtype=np.int64)
    cover = np.zeros((E, K), dtype=bool)
    for e in order:
        q = w_l[e] / deg[e]
        for _ in range(int(deg[e])):
            free = count < node_cap
            cand = np.flatnonzero(free & ~cover[e])
            if cand.size == 0:
                if cover[e].any():
                    break                      # trim the extra copy
                cand = np.flatnonzero(free)    # first copy must land
            m = cand[np.argmax((tau - load)[cand])]
            cover[e, m] = True
            count[m] += 1
            load[m] += q
    # a node with zero experts would break the per-node sub-solve: hand it
    # a replica of the hottest expert it doesn't already hold
    for m in np.flatnonzero(count == 0):
        e = int(np.argmax(np.where(cover[:, m], -np.inf, w_l)))
        cover[e, m] = True
        count[m] += 1
    return cover


def vibe_h_placement(
    w: np.ndarray,                 # (L, E) activation matrix
    perf_models,                   # per-rank perf models, len G
    topology: Optional[ClusterTopology] = None,
    slots_per_rank=None,           # None | int | (G,) per-rank budgets
    n_ref_mode: str = "rank",
) -> ReplicatedPlacement:
    """Two-level node-aware ViBE solve (policy ``vibe_h``).

    Per layer, phase A bins experts across nodes to minimize cross-node
    token traffic (node-copy replication of that layer's hot experts,
    speed-proportional node targets — binning is per-layer because expert
    hotness is: an aggregate-hot expert can be cold in the very layer
    where another is melting its node's DCN link); phase B runs the full
    ViBE-R ``_replicated_solve`` *within* each node against that node's
    per-rank perf models and the node's share of each resident expert's
    traffic. The per-node placements are stitched back into one global
    rank-major slot table whose copy shares are
    ``sigma(e, node) * local_share`` — they still sum to 1 per
    (layer, expert), so every downstream consumer (dispatch CDFs, clocks,
    steal) works unchanged.

    On a flat (or absent) topology this delegates to
    :func:`vibe_r_placement` exactly — there is no node structure to
    exploit, and the delegation keeps topology-free call sites
    bit-identical.
    """
    w = np.atleast_2d(np.asarray(w, dtype=np.float64))
    L, E = w.shape
    G = len(perf_models)
    if topology is None or topology.is_flat:
        return vibe_r_placement(w, perf_models, slots_per_rank=slots_per_rank,
                                n_ref_mode=n_ref_mode)
    if topology.n_ranks != G:
        raise ValueError(f"topology has {topology.n_ranks} ranks but "
                         f"{G} perf models were given")
    budget = normalize_slot_budget(slots_per_rank, E, G)
    s_max = int(budget.max())
    spare = int(budget.sum()) - E
    speeds, _ = _speed_targets(w, perf_models, n_ref_mode)       # (L, G)

    K = topology.n_nodes
    node_ranks: List[np.ndarray] = [topology.ranks_of(m) for m in range(K)]
    node_speed = np.stack([speeds[:, r].sum(1) for r in node_ranks],
                          axis=1)                                 # (L, K)
    node_cap = np.array([int(budget[r].sum()) for r in node_ranks])

    slot_expert = np.full((L, G * s_max), E, dtype=np.int32)
    share = np.zeros((L, G * s_max))
    for l in range(L):
        cover = _bin_experts_to_nodes(w[l], node_speed[l], node_cap, spare)
        # node shares: split each expert's traffic over its covering
        # nodes in proportion to aggregate node speed
        sig = cover * node_speed[l][None, :]                      # (E, K)
        sig = sig / np.maximum(sig.sum(-1, keepdims=True), 1e-12)
        for m in range(K):
            em = np.flatnonzero(cover[:, m])
            ranks = node_ranks[m]
            Em = em.size
            pm = [perf_models[g] for g in ranks]
            # a rank budget above the node's expert count is unusable
            # slots — clamp (the global table pads the tail with phantoms)
            bm = np.minimum(budget[ranks], Em)
            sig_m = sig[em, m]                                    # (Em,)
            w_m = w[l:l + 1, em] * sig_m[None, :]
            sp_m, tg_m = _speed_targets(w_m, pm, n_ref_mode)
            sub = _replicated_solve(w_m, sp_m, tg_m, ranks.size, bm,
                                    perf_models=pm)
            sm = sub.slots_per_rank
            for j, g in enumerate(ranks):
                le = sub.slot_expert[0, j * sm:(j + 1) * sm]      # (sm,)
                ls = sub.share[0, j * sm:(j + 1) * sm]
                real = le < Em
                le_safe = np.minimum(le, Em - 1)
                lo = g * s_max
                slot_expert[l, lo:lo + sm] = np.where(real, em[le_safe], E)
                share[l, lo:lo + sm] = np.where(real,
                                                sig_m[le_safe] * ls, 0.0)
    return ReplicatedPlacement(slot_expert, share, G, E)
