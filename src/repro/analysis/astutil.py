"""Small AST helpers shared by the rule modules (stdlib-only)."""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Tuple

__all__ = ["dotted_name", "FunctionIndex", "iter_functions",
           "imported_modules", "from_imports"]


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None (calls, subscripts
    and other dynamic bases yield None — we only match static spellings)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def iter_functions(tree: ast.AST) -> Iterator[Tuple[ast.AST, str]]:
    """Every (FunctionDef | AsyncFunctionDef, qualname) in the tree."""

    def visit(node: ast.AST, prefix: str) -> Iterator[Tuple[ast.AST, str]]:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                yield child, q
                yield from visit(child, f"{q}.<locals>.")
            elif isinstance(child, ast.ClassDef):
                yield from visit(child, f"{prefix}{child.name}.")
            else:
                yield from visit(child, prefix)

    yield from visit(tree, "")


class FunctionIndex:
    """Line-span index: which function encloses a given line."""

    def __init__(self, tree: ast.AST):
        #: innermost-last spans: (start, end, qualname, node)
        self.spans: List[Tuple[int, int, str, ast.AST]] = sorted(
            (f.lineno, f.end_lineno or f.lineno, q, f)
            for f, q in iter_functions(tree))

    def enclosing(self, line: int) -> Optional[str]:
        """Qualname of the innermost function containing ``line``."""
        best = None
        for start, end, q, _ in self.spans:
            if start <= line <= end:
                if best is None or start >= best[0]:
                    best = (start, q)
        return best[1] if best else None


def imported_modules(tree: ast.AST) -> Dict[str, str]:
    """Local name → module for plain ``import x [as y]`` statements."""
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = a.name
    return out


def from_imports(tree: ast.AST) -> Dict[str, Tuple[str, str, int]]:
    """Local name → (module, original name, relative level) for
    ``from m import n [as k]`` statements (``level`` counts leading dots)."""
    out: Dict[str, Tuple[str, str, int]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            for a in node.names:
                if a.name != "*":
                    out[a.asname or a.name] = (node.module or "", a.name,
                                               node.level)
    return out
