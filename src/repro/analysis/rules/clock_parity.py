"""``parity`` — the honest-pricing invariant, statically enforced.

ROADMAP: *"engine and simulator virtual clocks price the active routing
mode, moe impl, per-rank slot budgets, migration stalls, and time-varying
hardware — so every A/B knob is visible in TTFT/TPOT/goodput."* The whole
A/B methodology (and the GEM/HarMoEny baseline comparisons) rests on both
clocks pricing the same knobs: a config field the engine prices but the
simulator ignores makes every sweep that mixes the two silently
incomparable.

The rule cross-references the *shared* config surfaces — the
``ServingConfig`` base fields, ``StealConfig``, and the
``ClusterTopology`` link model — against attribute reads in each clock's
module set:

* engine clock    — ``serving/engine.py`` (+ the shared pricing helpers),
* simulator clock — ``serving/simulator.py`` (+ the same helpers).

Shared helpers (``core/steal.py``, ``core/topology.py``,
``serving/scheduler.py``, ``serving/kvcache.py``) count for *both* clocks
— a knob priced inside ``ClusterTopology.migration_cost`` is priced
wherever that method is called from. Reads of ``self.<field>`` inside the
config class's own body (``__post_init__`` validation) are excluded: a
knob is not "priced" by validating its own range.

Engine-only (``max_seq``, ``weighted_routing``, ``kv``) and simulator-only
(``ep_degree``, ``ici_bw``, ...) subclass fields are single-surface by
design and out of scope: only fields *declared on the shared classes* are
checked.

Findings anchor to the field's declaration line in the config file —
that's where the fix (price it in the other clock, or move the field down
to the single-surface subclass) starts.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Sequence, Set, Tuple

from ..findings import Finding
from ..project import ParsedFile, Project
from ..registry import register_rule

__all__ = ["ClockParityRule", "SHARED_CONFIGS", "ENGINE_FILES", "SIM_FILES",
           "SHARED_PRICING_FILES"]

#: shared-knob config classes → the file (suffix) declaring them
SHARED_CONFIGS: Tuple[Tuple[str, str], ...] = (
    ("ServingConfig", "repro/serving/config.py"),
    ("StealConfig", "repro/core/steal.py"),
    ("ClusterTopology", "repro/core/topology.py"),
)
ENGINE_FILES: Tuple[str, ...] = ("repro/serving/engine.py",)
SIM_FILES: Tuple[str, ...] = ("repro/serving/simulator.py",)
#: pricing helpers both clocks call — reads here count for both sides
SHARED_PRICING_FILES: Tuple[str, ...] = (
    "repro/core/steal.py", "repro/core/topology.py",
    "repro/serving/scheduler.py", "repro/serving/kvcache.py",
)


def _class_fields(pf: ParsedFile, cls_name: str,
                  ) -> List[Tuple[str, int, Tuple[int, int]]]:
    """(field, line, __post_init__ span) for annotated fields declared
    directly on ``cls_name`` (dataclass style); private and ClassVar fields
    skipped. Only the ``__post_init__`` span is excluded from pricing reads
    — a config class may legitimately price its own knobs in ordinary
    methods (``ClusterTopology.migration_cost`` reads ``self.dcn_bw``), but
    validating a field's range in ``__post_init__`` is not pricing."""
    for node in pf.walk():
        if isinstance(node, ast.ClassDef) and node.name == cls_name:
            span = (0, 0)            # empty span: nothing excluded
            for stmt in node.body:
                if isinstance(stmt, ast.FunctionDef) \
                        and stmt.name == "__post_init__":
                    span = (stmt.lineno, stmt.end_lineno or stmt.lineno)
            out = []
            for stmt in node.body:
                if isinstance(stmt, ast.AnnAssign) \
                        and isinstance(stmt.target, ast.Name) \
                        and not stmt.target.id.startswith("_"):
                    ann = ast.unparse(stmt.annotation)
                    if "ClassVar" in ann:
                        continue
                    out.append((stmt.target.id, stmt.lineno, span))
            return out
    return []


def _attribute_reads(pf: ParsedFile,
                     exclude_self_spans: Sequence[Tuple[int, int]],
                     ) -> Set[str]:
    """Attribute names read (Load context) in the file, minus
    ``self.<attr>`` reads inside the excluded class spans (a config's own
    ``__post_init__`` validation must not count as pricing)."""
    reads: Set[str] = set()
    for node in pf.walk():
        if not (isinstance(node, ast.Attribute)
                and isinstance(node.ctx, ast.Load)):
            continue
        if isinstance(node.value, ast.Name) and node.value.id == "self" \
                and any(a <= node.lineno <= b for a, b in exclude_self_spans):
            continue
        reads.add(node.attr)
    return reads


@register_rule
class ClockParityRule:
    family = "parity"
    scope = "project"

    def __init__(self, shared_configs=SHARED_CONFIGS,
                 engine_files=ENGINE_FILES, sim_files=SIM_FILES,
                 shared_files=SHARED_PRICING_FILES):
        self.shared_configs = tuple(shared_configs)
        self.engine_files = tuple(engine_files)
        self.sim_files = tuple(sim_files)
        self.shared_files = tuple(shared_files)

    def check(self, project: Project) -> Iterator[Finding]:
        engine_pfs = [project.file(s) for s in self.engine_files]
        sim_pfs = [project.file(s) for s in self.sim_files]
        if not any(engine_pfs) or not any(sim_pfs):
            return                   # partial scan: no clocks in view
        shared_pfs = [pf for s in self.shared_files
                      if (pf := project.file(s)) is not None]

        # class spans to exclude self-reads from, per file
        spans: Dict[str, List[Tuple[int, int]]] = {}
        fields: List[Tuple[str, str, str, int]] = []  # (cls, field, rel, ln)
        for cls_name, suffix in self.shared_configs:
            pf = project.file(suffix)
            if pf is None or pf.tree is None:
                continue
            for field, line, span in _class_fields(pf, cls_name):
                fields.append((cls_name, field, pf.rel, line))
                spans.setdefault(pf.rel, []).append(span)

        def reads(pfs: Sequence[ParsedFile]) -> Set[str]:
            out: Set[str] = set()
            for pf in pfs:
                if pf is not None and pf.tree is not None:
                    out |= _attribute_reads(pf, spans.get(pf.rel, ()))
            return out

        shared_reads = reads(shared_pfs)
        engine_reads = reads([pf for pf in engine_pfs if pf]) | shared_reads
        sim_reads = reads([pf for pf in sim_pfs if pf]) | shared_reads

        for cls_name, field, rel, line in fields:
            in_engine = field in engine_reads
            in_sim = field in sim_reads
            if in_engine == in_sim:
                continue             # priced in both — or a dead knob,
                #                      which is the unused-field lint's job
            priced, missing = (("engine", "simulator") if in_engine
                               else ("simulator", "engine"))
            yield Finding(
                rel, line, "parity.one-clock",
                f"{cls_name}.{field} is read by the {priced} clock but "
                f"never by the {missing} — every shared knob must be "
                "priced on both virtual clocks (honest-pricing "
                "invariant), or moved to a single-surface subclass")
