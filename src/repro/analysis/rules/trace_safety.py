"""``trace`` — recompile/concretization hazards in jit-traced code.

The engine's contract (PRs 4-7) is that recalibration, share-table
refreshes and chunked prefill never recompile: every step function is
``jax.jit``-compiled once per shape signature, and placement/share
changes ride through as plain array inputs. The hazards that silently
break this are all *Python-level* operations on traced values:

* ``trace.python-branch`` — ``if``/``while``/``assert`` on a traced value
  raises ``TracerBoolConversionError`` at trace time, or — worse, when the
  value happens to be weakly typed — bakes one branch into the compiled
  program. Use ``jnp.where`` / ``lax.cond``/``lax.select``.
* ``trace.concretize``    — ``int()``/``float()``/``bool()`` casts,
  ``.item()``/``.tolist()``, and ``np.*`` calls on traced values force a
  host round-trip: a trace-time error under jit, a silent device sync
  (and a recompile per value for shape-affecting uses) elsewhere.
* ``trace.shape-branch``  — branching on a traced operand's ``.shape`` /
  ``.ndim`` / ``.size`` is legal (shapes are static) but compiles one
  program per distinct shape; flagged as a *warning* so intentional
  specialization (e.g. one compile per chunk width) carries a justified
  inline suppression instead of hiding.

Reachability: a function is traced when it is (a) decorated with / passed
to ``jit``/``shard_map``/``pallas_call``/``vmap``/``grad``/``lax.*``
control-flow, (b) returned by a factory whose *result* is jitted
(``jax.jit(prefill_fn(cfg))`` — the repo's dominant pattern), or (c)
called from a traced function. Taint is interprocedural with per-call
argument masks: a helper called with only static (closure/config) args
stays untainted, so ``if cfg.is_moe:`` branching never false-positives.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterator, List, Optional, Set, Tuple

from ..astutil import dotted_name, from_imports, imported_modules
from ..findings import Finding
from ..project import ParsedFile, Project
from ..registry import register_rule

__all__ = ["TraceSafetyRule", "TRACING_ENTRYPOINTS"]

#: call/decorator names (last dotted segment) whose function-valued
#: arguments are traced by JAX
TRACING_ENTRYPOINTS = {
    "jit", "pjit", "shard_map", "pallas_call", "vmap", "pmap", "grad",
    "value_and_grad", "remat", "checkpoint", "scan", "cond", "while_loop",
    "fori_loop", "switch", "associated_scan", "custom_vjp", "custom_jvp",
}

#: attribute reads that stay static under tracing (abstract-value metadata)
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding", "aval",
                 "weak_type"}
_SHAPE_ATTRS = {"shape", "ndim", "size"}

#: calls whose result is always a static Python value
_STATIC_FUNCS = {"len", "isinstance", "issubclass", "hasattr", "callable",
                 "type", "id", "repr", "str", "format"}

_CAST_FUNCS = {"int", "float", "bool", "complex"}

_CONCRETIZING_METHODS = {"item", "tolist", "__array__"}


def _module_of(rel: str) -> Optional[str]:
    """Dotted module for a repo-relative path (anchored at ``repro``)."""
    parts = rel.split("/")
    if "repro" not in parts or not rel.endswith(".py"):
        return None
    parts = parts[parts.index("repro"):]
    parts[-1] = parts[-1][:-3]
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


@dataclasses.dataclass
class _Func:
    """One project function with everything taint analysis needs."""

    node: ast.AST                    # FunctionDef / AsyncFunctionDef
    qualname: str
    module: str
    pf: ParsedFile
    #: param names currently known tainted (grows monotonically)
    tainted: Set[str] = dataclasses.field(default_factory=set)
    traced: bool = False

    @property
    def params(self) -> List[str]:
        a = self.node.args
        names = [p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)]
        if a.vararg:
            names.append(a.vararg.arg)
        if a.kwarg:
            names.append(a.kwarg.arg)
        return [n for n in names if n not in ("self", "cls")]


class _ModuleView:
    """Name-resolution view of one module: defs, imports, nested map."""

    def __init__(self, pf: ParsedFile, module: str):
        self.pf = pf
        self.module = module
        self.defs: Dict[str, ast.AST] = {}
        if pf.tree is not None:
            for node in pf.tree.body:
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    self.defs[node.name] = node
            self.imports = imported_modules(pf.tree)
            self.froms = from_imports(pf.tree)
        else:
            self.imports, self.froms = {}, {}

    def resolve_from(self, name: str) -> Optional[Tuple[str, str]]:
        """(module, original name) a from-imported local name refers to."""
        if name not in self.froms:
            return None
        mod, orig, level = self.froms[name]
        if level == 0:
            return mod, orig
        base = self.module.split(".")
        # `from . import x` in a module drops the leaf; in a package
        # (__init__) the module dotted name *is* the package already —
        # both arrive here as the module name of the importing file
        if not self.pf.rel.endswith("__init__.py"):
            base = base[:-1]
        base = base[:len(base) - (level - 1)] if level > 1 else base
        return ".".join(base + (mod.split(".") if mod else [])).strip("."), \
            orig


class TraceSafetyRule:
    family = "trace"
    scope = "project"

    # -- project model ------------------------------------------------------

    def _build(self, project: Project):
        views: Dict[str, _ModuleView] = {}
        funcs: Dict[Tuple[str, str], _Func] = {}
        parents: Dict[Tuple[str, str], Optional[str]] = {}
        for pf in project.files:
            mod = _module_of(pf.rel)
            if mod is None or pf.tree is None:
                continue
            views[mod] = _ModuleView(pf, mod)

            def visit(node: ast.AST, prefix: str, parent: Optional[str]):
                for child in ast.iter_child_nodes(node):
                    if isinstance(child, (ast.FunctionDef,
                                          ast.AsyncFunctionDef)):
                        q = f"{prefix}{child.name}"
                        funcs[(mod, q)] = _Func(child, q, mod, pf)
                        parents[(mod, q)] = parent
                        visit(child, f"{q}.<locals>.", q)
                    elif isinstance(child, ast.ClassDef):
                        visit(child, f"{prefix}{child.name}.", parent)
                    else:
                        visit(child, prefix, parent)

            visit(pf.tree, "", None)
        return views, funcs, parents

    def _lookup(self, views, funcs, module: str, name: str, depth: int = 0,
                ) -> Optional[Tuple[str, str]]:
        """Resolve a bare name in ``module`` to a project function key,
        chasing package-__init__ re-exports."""
        if depth > 6 or module not in views:
            return None
        view = views[module]
        if (module, name) in funcs:
            return (module, name)
        target = view.resolve_from(name)
        if target is not None:
            tmod, tname = target
            if (tmod, tname) in funcs:
                return (tmod, tname)
            return self._lookup(views, funcs, tmod, tname, depth + 1)
        return None

    def _resolve_callee(self, views, funcs, module: str, call: ast.Call,
                        ) -> Optional[Tuple[str, str]]:
        """Project-function key a call's callee statically refers to."""
        name = dotted_name(call.func)
        if name is None:
            return None
        parts = name.split(".")
        if len(parts) == 1:
            return self._lookup(views, funcs, module, parts[0])
        if len(parts) == 2 and module in views:
            imod = views[module].imports.get(parts[0])
            if imod is not None:
                return self._lookup(views, funcs, imod, parts[1])
        return None

    def _callable_arg_targets(self, views, funcs, module: str,
                              arg: ast.AST) -> List[Tuple[str, str]]:
        """Functions an argument expression makes traceable: a direct
        reference, a partial(...) wrapper, or a factory call whose
        returned inner functions become the traced callable."""
        out: List[Tuple[str, str]] = []
        if isinstance(arg, ast.Name):
            key = self._lookup(views, funcs, module, arg.id)
            if key is not None:
                out.append(key)
        elif isinstance(arg, ast.Call):
            cal = dotted_name(arg.func) or ""
            if cal.split(".")[-1] == "partial":
                if arg.args:
                    out.extend(self._callable_arg_targets(
                        views, funcs, module, arg.args[0]))
            else:
                key = self._resolve_callee(views, funcs, module, arg)
                if key is not None:
                    out.extend(self._returned_inners(funcs, key))
        return out

    def _returned_inners(self, funcs, key) -> List[Tuple[str, str]]:
        """Nested functions a factory returns (``jax.jit(make_fn(cfg))``)."""
        fn = funcs[key]
        mod, q = key
        inners = []
        for node in ast.walk(fn.node):
            if isinstance(node, ast.Return) \
                    and isinstance(node.value, ast.Name):
                ik = (mod, f"{q}.<locals>.{node.value.id}")
                if ik in funcs:
                    inners.append(ik)
        return inners

    # -- root discovery -----------------------------------------------------

    def _static_params(self, fn: _Func, call: Optional[ast.Call],
                       ) -> Set[str]:
        """Param names jit treats as static at this entry point
        (``static_argnames``/``static_argnums`` keywords): static params
        arrive as concrete Python values, so branching on them is fine."""
        out: Set[str] = set()
        if call is None:
            return out
        a = fn.node.args
        positional = [p.arg for p in (a.posonlyargs + a.args)]
        for kw in call.keywords:
            v = kw.value
            if kw.arg == "static_argnames":
                consts = [v] if isinstance(v, ast.Constant) else \
                    list(getattr(v, "elts", ()))
                out |= {c.value for c in consts
                        if isinstance(c, ast.Constant)
                        and isinstance(c.value, str)}
            elif kw.arg == "static_argnums":
                consts = [v] if isinstance(v, ast.Constant) else \
                    list(getattr(v, "elts", ()))
                for c in consts:
                    if isinstance(c, ast.Constant) \
                            and isinstance(c.value, int) \
                            and 0 <= c.value < len(positional):
                        out.add(positional[c.value])
        return out

    def _roots(self, views, funcs,
               ) -> List[Tuple[Tuple[str, str], Set[str]]]:
        roots: List[Tuple[Tuple[str, str], Set[str]]] = []
        for (mod, q), fn in funcs.items():
            for dec in getattr(fn.node, "decorator_list", ()):
                target = dec.func if isinstance(dec, ast.Call) else dec
                name = (dotted_name(target) or "").split(".")[-1]
                if name in TRACING_ENTRYPOINTS:
                    call = dec if isinstance(dec, ast.Call) else None
                    roots.append(((mod, q), self._static_params(fn, call)))
                elif name == "partial" and isinstance(dec, ast.Call):
                    inner = (dotted_name(dec.args[0]) if dec.args else
                             None) or ""
                    if inner.split(".")[-1] in TRACING_ENTRYPOINTS:
                        roots.append(((mod, q),
                                      self._static_params(fn, dec)))
        for mod, view in views.items():
            if view.pf.tree is None:
                continue
            for node in ast.walk(view.pf.tree):
                if not isinstance(node, ast.Call):
                    continue
                name = (dotted_name(node.func) or "").split(".")[-1]
                if name not in TRACING_ENTRYPOINTS:
                    continue
                for arg in list(node.args) + [kw.value
                                              for kw in node.keywords]:
                    for key in self._callable_arg_targets(
                            views, funcs, mod, arg):
                        roots.append(
                            (key, self._static_params(funcs[key], node)))
        return roots

    # -- taint engine -------------------------------------------------------

    def check(self, project: Project) -> Iterator[Finding]:
        views, funcs, parents = self._build(project)
        if not funcs:
            return
        worklist = []
        for key, static in self._roots(views, funcs):
            fn = funcs[key]
            new = set(fn.params) - static     # static_argnames stay Python
            if not fn.traced or new - fn.tainted:
                fn.traced = True
                fn.tainted |= new
                worklist.append(key)
        seen_edges: Set[Tuple[Tuple[str, str], Tuple[str, str]]] = set()
        steps = 0
        while worklist and steps < 10_000:
            steps += 1
            key = worklist.pop()
            fn = funcs[key]
            local_taint = self._local_taint(fn)
            for call in ast.walk(fn.node):
                if not isinstance(call, ast.Call):
                    continue
                callee = self._resolve_callee(views, funcs, fn.module, call)
                if callee is None:
                    # function-valued args to lax control flow etc.
                    name = (dotted_name(call.func) or "").split(".")[-1]
                    if name in TRACING_ENTRYPOINTS:
                        for arg in call.args:
                            for t in self._callable_arg_targets(
                                    views, funcs, fn.module, arg):
                                tfn = funcs[t]
                                if not tfn.traced or \
                                        set(tfn.params) - tfn.tainted:
                                    tfn.traced = True
                                    tfn.tainted |= set(tfn.params)
                                    worklist.append(t)
                    continue
                cfn = funcs[callee]
                new = self._tainted_call_params(cfn, call, local_taint)
                edge = (key, callee)
                if not cfn.traced or (new - cfn.tainted) \
                        or edge not in seen_edges:
                    seen_edges.add(edge)
                    grew = (new - cfn.tainted) or not cfn.traced
                    cfn.traced = True
                    cfn.tainted |= new
                    if grew:
                        worklist.append(callee)
        for key, fn in funcs.items():
            if fn.traced:
                yield from self._check_function(fn)

    def _tainted_call_params(self, cfn: _Func, call: ast.Call,
                             local_taint: Set[str]) -> Set[str]:
        """Callee param names receiving a tainted argument at this site."""
        params = cfn.params
        out: Set[str] = set()
        for i, arg in enumerate(call.args):
            if isinstance(arg, ast.Starred):
                if self._tainted(arg.value, local_taint):
                    out |= set(params)      # can't match positions — widen
                continue
            if i < len(params) and self._tainted(arg, local_taint):
                out.add(params[i])
        for kw in call.keywords:
            if self._tainted(kw.value, local_taint):
                out.add(kw.arg) if kw.arg is not None \
                    else out.update(params)
        return out & set(params)

    def _local_taint(self, fn: _Func) -> Set[str]:
        """Names tainted inside ``fn``: tainted params + derived locals
        (two passes over the body cover loop-carried flows)."""
        tainted = set(fn.tainted)
        body = list(getattr(fn.node, "body", []))
        for _ in range(2):
            before = len(tainted)
            for stmt in body:
                for node in ast.walk(stmt):
                    if isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)) \
                            and node is not fn.node:
                        continue     # nested defs analyzed separately
                    if isinstance(node, ast.Assign):
                        if self._tainted(node.value, tainted):
                            for t in node.targets:
                                tainted |= self._target_names(t)
                    elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                        if node.value is not None \
                                and self._tainted(node.value, tainted):
                            tainted |= self._target_names(node.target)
                    elif isinstance(node, ast.NamedExpr):
                        if self._tainted(node.value, tainted):
                            tainted |= self._target_names(node.target)
                    elif isinstance(node, ast.For):
                        if self._tainted(node.iter, tainted):
                            tainted |= self._target_names(node.target)
            if len(tainted) == before:
                break
        return tainted

    def _target_names(self, target: ast.AST) -> Set[str]:
        out: Set[str] = set()
        for node in ast.walk(target):
            if isinstance(node, ast.Name):
                out.add(node.id)
        return out

    def _tainted(self, node: ast.AST, tainted: Set[str]) -> bool:
        if isinstance(node, ast.Name):
            return node.id in tainted
        if isinstance(node, (ast.Constant, ast.Lambda)):
            return False
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return False
            return self._tainted(node.value, tainted)
        if isinstance(node, ast.Compare):
            # `x is None` / `x is not y`: Python identity on a tracer is a
            # static answer, not a concretization
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False
            # `"key" in batch`: dict/pytree membership of a static string
            # key is a host-side container lookup, not a traced comparison
            if all(isinstance(op, (ast.In, ast.NotIn)) for op in node.ops) \
                    and isinstance(node.left, ast.Constant) \
                    and isinstance(node.left.value, str):
                return False
            return any(self._tainted(c, tainted)
                       for c in [node.left] + node.comparators)
        if isinstance(node, ast.Call):
            name = (dotted_name(node.func) or "").split(".")[-1]
            if name in _STATIC_FUNCS:
                return False
            children: List[ast.AST] = list(node.args) + \
                [kw.value for kw in node.keywords]
            if not isinstance(node.func, ast.Name):
                children.append(node.func)
            return any(self._tainted(c, tainted) for c in children)
        return any(self._tainted(c, tainted)
                   for c in ast.iter_child_nodes(node)
                   if isinstance(c, ast.expr))

    # -- hazard checks ------------------------------------------------------

    def _check_function(self, fn: _Func) -> Iterator[Finding]:
        tainted = self._local_taint(fn)
        rel = fn.pf.rel
        where = f"{fn.qualname} (traced: reachable from a jit/shard_map/" \
                "pallas entry point)"
        for node in ast.walk(fn.node):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not fn.node:
                continue             # nested defs get their own pass
            if isinstance(node, (ast.If, ast.While)):
                kind = "if" if isinstance(node, ast.If) else "while"
                yield from self._branch_findings(rel, node.test,
                                                 node.lineno, kind,
                                                 tainted, where)
            elif isinstance(node, ast.Assert):
                yield from self._branch_findings(rel, node.test,
                                                 node.lineno, "assert",
                                                 tainted, where)
            elif isinstance(node, ast.Call):
                yield from self._call_findings(rel, node, tainted, where, fn)

    def _branch_findings(self, rel, test, lineno, kind, tainted, where,
                         ) -> Iterator[Finding]:
        if self._tainted(test, tainted):
            yield Finding(
                rel, lineno, "trace.python-branch",
                f"Python `{kind}` on a traced value in {where} — "
                "concretizes at trace time; use jnp.where / lax.cond")
            return
        for node in ast.walk(test):
            if isinstance(node, ast.Attribute) \
                    and node.attr in _SHAPE_ATTRS \
                    and self._tainted_base(node.value, tainted):
                yield Finding(
                    rel, lineno, "trace.shape-branch",
                    f"`{kind}` on a traced operand's .{node.attr} in "
                    f"{where} — legal but compiles one program per "
                    "distinct shape; suppress with a justification if "
                    "the specialization is intentional",
                    severity="warning")
                return

    def _tainted_base(self, node: ast.AST, tainted: Set[str]) -> bool:
        """Tainted ignoring the static-attr exemption (x.shape has an
        untainted *value* but a tainted *base operand*)."""
        if isinstance(node, ast.Name):
            return node.id in tainted
        if isinstance(node, ast.Attribute):
            return self._tainted_base(node.value, tainted)
        if isinstance(node, ast.Subscript):
            return self._tainted_base(node.value, tainted)
        return self._tainted(node, tainted)

    def _call_findings(self, rel, node: ast.Call, tainted, where, fn,
                       ) -> Iterator[Finding]:
        name = dotted_name(node.func)
        last = (name or "").split(".")[-1]
        args_tainted = any(self._tainted(a, tainted) for a in node.args)
        if last in _CAST_FUNCS and name == last and args_tainted:
            yield Finding(
                rel, node.lineno, "trace.concretize",
                f"{last}() cast of a traced value in {where} — forces "
                "host concretization (trace-time error under jit)")
        elif name is not None and "." in name and args_tainted:
            base = name.split(".")[0]
            if fn.pf.tree is not None \
                    and imported_modules(fn.pf.tree).get(base) == "numpy":
                yield Finding(
                    rel, node.lineno, "trace.concretize",
                    f"{name}() on a traced value in {where} — numpy "
                    "pulls the array to host; use jnp/lax equivalents")
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in _CONCRETIZING_METHODS \
                and self._tainted(node.func.value, tainted):
            yield Finding(
                rel, node.lineno, "trace.concretize",
                f".{node.func.attr}() on a traced value in {where} — "
                "forces host concretization")


register_rule(TraceSafetyRule)
