"""``imports`` — unused-import detection (the in-repo F401).

CI's ruff lane catches these too, but ruff is not installed in every dev
container this repo runs in; this rule keeps the check available wherever
``python -m repro.analysis`` runs, with the same suppression/baseline
machinery as the repo-invariant rules.

``__init__.py`` files are exempt (their imports *are* the re-export
surface), as are names listed in ``__all__``, ``from __future__``
imports, and explicit re-export aliases (``import x as x``).
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Set, Tuple

from ..findings import Finding
from ..project import ParsedFile
from ..registry import register_rule

__all__ = ["UnusedImportRule"]


def _exported_names(tree: ast.AST) -> Set[str]:
    """Names in ``__all__`` (string-literal lists/tuples only)."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "__all__"
                for t in node.targets):
            if isinstance(node.value, (ast.List, ast.Tuple)):
                for elt in node.value.elts:
                    if isinstance(elt, ast.Constant) \
                            and isinstance(elt.value, str):
                        out.add(elt.value)
    return out


@register_rule
class UnusedImportRule:
    family = "imports"
    scope = "file"

    def check(self, pf: ParsedFile) -> Iterator[Finding]:
        if pf.tree is None or pf.rel.endswith("__init__.py"):
            return
        imported: List[Tuple[str, int, str]] = []   # (name, line, spelled)
        for node in ast.walk(pf.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    local = a.asname or a.name.split(".")[0]
                    if a.asname == a.name:
                        continue                    # explicit re-export
                    imported.append((local, node.lineno, a.name))
            elif isinstance(node, ast.ImportFrom):
                if node.module == "__future__":
                    continue
                for a in node.names:
                    if a.name == "*":
                        continue
                    if a.asname == a.name:
                        continue                    # explicit re-export
                    local = a.asname or a.name
                    imported.append((local, node.lineno,
                                     f"{node.module or '.'}.{a.name}"))
        if not imported:
            return
        used: Set[str] = set()
        for node in ast.walk(pf.tree):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                used.add(node.id)
            elif isinstance(node, ast.Attribute):
                # the base Name is already collected; nothing extra needed
                pass
        # names referenced in string annotations ("ClusterTopology") count
        for node in ast.walk(pf.tree):
            if isinstance(node, ast.Constant) and isinstance(node.value, str):
                txt = node.value
                # plausible annotation strings only — a docstring that
                # *mentions* a class must not mark its import as used
                if txt.isidentifier() or (
                        " " not in txt and ("[" in txt or "." in txt)):
                    for name, _, _ in imported:
                        if name in txt:
                            used.add(name)
        used |= _exported_names(pf.tree)
        for name, line, spelled in imported:
            if name not in used:
                yield Finding(pf.rel, line, "imports.unused",
                              f"{spelled!r} imported but unused")
