"""``frozen`` — frozen-config and registry-singleton hygiene.

Every config in this repo is a frozen dataclass, and both registries
(placement policies, schedulers, analysis rules) hand out long-lived
singletons. Two mutation patterns defeat those guarantees while running
fine on the happy path:

* ``frozen.setattr-outside-post-init`` — ``object.__setattr__`` is the
  sanctioned escape hatch *only* inside ``__post_init__`` (normalizing a
  field during construction). Anywhere else it mutates an object every
  holder believes is immutable — configs are shared across engine,
  simulator, controller and benchmark sweeps, so a mutation in one
  consumer corrupts the others' view.
* ``frozen.registry-mutation`` — assigning attributes on an object
  returned by ``get_policy`` / ``get_scheduler`` / ``get_rule`` mutates
  the registry's shared singleton: every later lookup (other tests, other
  engines in the same process) sees the edit.
"""

from __future__ import annotations

import ast
from typing import Iterator, Set

from ..astutil import FunctionIndex, dotted_name
from ..findings import Finding
from ..project import ParsedFile
from ..registry import register_rule

__all__ = ["FrozenConfigRule", "REGISTRY_GETTERS"]

REGISTRY_GETTERS = ("get_policy", "get_scheduler", "get_rule")


def _is_registry_call(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and (dotted_name(node.func) or "").split(".")[-1]
            in REGISTRY_GETTERS)


@register_rule
class FrozenConfigRule:
    family = "frozen"
    scope = "file"

    def check(self, pf: ParsedFile) -> Iterator[Finding]:
        if pf.tree is None:
            return
        index = FunctionIndex(pf.tree)
        singleton_names = self._singleton_bindings(pf)
        for node in pf.walk():
            if isinstance(node, ast.Call):
                name = dotted_name(node.func)
                if name is not None and name.endswith("__setattr__"):
                    enclosing = index.enclosing(node.lineno) or "<module>"
                    if enclosing.split(".")[-1] != "__post_init__":
                        yield Finding(
                            pf.rel, node.lineno,
                            "frozen.setattr-outside-post-init",
                            f"object.__setattr__ in {enclosing}() mutates "
                            "a frozen object after construction — the "
                            "escape hatch is for __post_init__ "
                            "normalization only")
                # setattr(get_policy(...), ...) — same mutation, spelled
                # through the builtin
                elif name == "setattr" and node.args \
                        and self._is_singleton(node.args[0],
                                               singleton_names):
                    yield Finding(
                        pf.rel, node.lineno, "frozen.registry-mutation",
                        "setattr on a registry-returned singleton — every "
                        "later lookup shares this object")
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if isinstance(t, ast.Attribute) and self._is_singleton(
                            t.value, singleton_names):
                        yield Finding(
                            pf.rel, node.lineno, "frozen.registry-mutation",
                            "attribute assignment on a registry-returned "
                            f"singleton (.{t.attr} = ...) — every later "
                            "lookup shares this object")

    def _is_singleton(self, node: ast.AST, names: Set[str]) -> bool:
        if _is_registry_call(node):
            return True
        return isinstance(node, ast.Name) and node.id in names

    def _singleton_bindings(self, pf: ParsedFile) -> Set[str]:
        """Names ever assigned from a registry getter (flow-insensitive)."""
        out: Set[str] = set()
        for node in pf.walk():
            if isinstance(node, ast.Assign) and _is_registry_call(node.value):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        out.add(t.id)
        return out
