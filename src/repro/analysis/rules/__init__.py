"""Built-in rule families. Importing this package registers them all.

Third-party rules register the same way: import
:func:`repro.analysis.register_rule`, decorate a class with
``family``/``scope``/``check``, and the CLI/driver pick it up.
"""

from . import (clock_parity, config_hygiene, determinism, imports,
               trace_safety)

__all__ = ["clock_parity", "config_hygiene", "determinism", "imports",
           "trace_safety"]
