"""``det`` — seed-determinism hygiene for ``repro.core`` / ``repro.serving``.

Both virtual clocks, the golden-placement suite and the seed-determinism
battery assume bit-identical replays: same seed, same trace, same
placement, same virtual timeline. Three things silently break that
contract and are invisible at review time:

* ``det.unseeded-rng``   — module-level ``np.random.*`` / stdlib
  ``random.*`` sampling draws from hidden global state;
  ``np.random.default_rng()`` with no seed is entropy-seeded. Every draw
  must come from an explicitly seeded ``Generator`` (or a threaded-through
  ``rng`` argument).
* ``det.wall-clock``     — ``time.time()`` & friends leak host wall-clock
  into code whose only clock is supposed to be virtual.
* ``det.set-iteration``  — iterating a ``set``/``frozenset`` yields
  hash-order, which varies across processes (PYTHONHASHSEED) for str
  keys; wrap in ``sorted(...)`` before iterating. Membership tests are
  fine — only iteration order is nondeterministic.

The rule only fires inside ``repro/core/`` and ``repro/serving/`` — the
deterministic replay core. Benchmarks and launch scripts may time and
sample freely.
"""

from __future__ import annotations

import ast
from typing import Iterator, Set

from ..astutil import FunctionIndex, dotted_name, imported_modules
from ..findings import Finding
from ..project import ParsedFile
from ..registry import register_rule

__all__ = ["DeterminismRule", "SCOPED_DIRS"]

SCOPED_DIRS = ("repro/core/", "repro/serving/")

#: np.random attributes that are fine: explicit-seed constructors and
#: non-sampling plumbing (Generator is a type annotation / isinstance
#: target; default_rng is checked separately for a missing seed argument)
_NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "BitGenerator",
                 "PCG64", "Philox"}

_WALL_CLOCK = {"time.time", "time.time_ns", "time.perf_counter",
               "time.perf_counter_ns", "time.monotonic",
               "time.monotonic_ns", "time.process_time",
               "datetime.datetime.now", "datetime.datetime.utcnow",
               "datetime.date.today"}

#: stdlib random's module-level samplers (all draw from the hidden global
#: Mersenne Twister); random.Random(seed)/SystemRandom instances are fine
_STDLIB_SAMPLERS = {"random", "randint", "randrange", "uniform", "choice",
                    "choices", "shuffle", "sample", "gauss", "normalvariate",
                    "betavariate", "expovariate", "seed", "getrandbits"}


def _numpy_aliases(pf: ParsedFile) -> Set[str]:
    return {local for local, mod in imported_modules(pf.tree).items()
            if mod == "numpy"}


@register_rule
class DeterminismRule:
    family = "det"
    scope = "file"

    def check(self, pf: ParsedFile) -> Iterator[Finding]:
        if pf.tree is None or not any(d in pf.rel for d in SCOPED_DIRS):
            return
        np_names = _numpy_aliases(pf)
        mods = imported_modules(pf.tree)
        has_random = any(m == "random" for m in mods.values())
        has_time = any(m in ("time", "datetime") for m in mods.values())
        index = FunctionIndex(pf.tree)
        bindings = self._set_bindings(pf, index)
        for node in pf.walk():
            if isinstance(node, ast.Call):
                yield from self._check_call(pf, node, np_names,
                                            has_random, has_time)
            elif isinstance(node, (ast.For, ast.comprehension)):
                it = node.iter
                line = getattr(node, "lineno", it.lineno)
                scope = index.enclosing(line)
                local = bindings.get(scope, set()) | bindings.get(None, set())
                if self._is_set_expr(it, local):
                    yield Finding(
                        pf.rel, line, "det.set-iteration",
                        "iteration over an unordered set — hash order "
                        "varies across processes; iterate sorted(...) "
                        "instead")

    def _check_call(self, pf: ParsedFile, node: ast.Call,
                    np_names: Set[str], has_random: bool,
                    has_time: bool) -> Iterator[Finding]:
        name = dotted_name(node.func)
        if name is None:
            return
        parts = name.split(".")
        # numpy global-state samplers: np.random.<fn>(...)
        if len(parts) >= 3 and parts[0] in np_names \
                and parts[1] == "random" and parts[2] not in _NP_RANDOM_OK:
            yield Finding(pf.rel, node.lineno, "det.unseeded-rng",
                          f"{name}() draws from numpy's hidden global RNG "
                          "state — use a seeded np.random.default_rng")
        # entropy-seeded generator: np.random.default_rng() with no args
        elif len(parts) >= 3 and parts[0] in np_names \
                and parts[1] == "random" and parts[2] == "default_rng" \
                and not node.args and not node.keywords:
            yield Finding(pf.rel, node.lineno, "det.unseeded-rng",
                          "np.random.default_rng() without a seed is "
                          "entropy-seeded — pass an explicit seed")
        # stdlib random module samplers
        elif has_random and len(parts) == 2 and parts[0] == "random" \
                and parts[1] in _STDLIB_SAMPLERS:
            yield Finding(pf.rel, node.lineno, "det.unseeded-rng",
                          f"{name}() uses the stdlib global RNG — use a "
                          "seeded np.random.default_rng (or "
                          "random.Random(seed))")
        elif has_time and name in _WALL_CLOCK:
            yield Finding(pf.rel, node.lineno, "det.wall-clock",
                          f"{name}() reads host wall-clock inside the "
                          "deterministic core — thread virtual time "
                          "through instead")

    def _is_set_expr(self, node: ast.AST, bound: Set[str]) -> bool:
        """Is ``node`` (a loop's iterable) statically a set?"""
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Call):
            fname = dotted_name(node.func)
            if fname in ("set", "frozenset"):
                return True
            # set-returning set methods: a.union(b), a.difference(b), ...
            if isinstance(node.func, ast.Attribute) and node.func.attr in (
                    "union", "intersection", "difference",
                    "symmetric_difference") \
                    and self._is_set_expr(node.func.value, bound):
                return True
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
            return self._is_set_expr(node.left, bound) \
                or self._is_set_expr(node.right, bound)
        if isinstance(node, ast.Name):
            return node.id in bound
        return False

    def _set_bindings(self, pf: ParsedFile, index: FunctionIndex):
        """Names assigned from a literal/constructor set, keyed by the
        enclosing function's qualname (None = module level). Scoping per
        function keeps a set-typed local in one method from tainting a
        same-named parameter of another."""
        out: dict = {}
        for node in pf.walk():
            if isinstance(node, ast.Assign):
                v = node.value
                if isinstance(v, (ast.Set, ast.SetComp)) or (
                        isinstance(v, ast.Call)
                        and dotted_name(v.func) in ("set", "frozenset")):
                    scope = index.enclosing(node.lineno)
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            out.setdefault(scope, set()).add(t.id)
        return out
