"""Finding: one static-analysis diagnostic, pinned to file:line.

Mirrors the shape every consumer needs — the CLI renders them as
``path:line: severity rule message``, the GitHub formatter as workflow
commands, and the baseline matcher compares the ``(path, rule, message)``
identity (line numbers churn under unrelated edits, so they are display
metadata, not identity).
"""

from __future__ import annotations

import dataclasses

__all__ = ["Finding", "SEVERITIES"]

#: ordered weakest → strongest; the CLI exits non-zero on ANY unsuppressed
#: finding regardless of severity (a warning you disagree with gets an
#: inline justified suppression, not a free pass)
SEVERITIES = ("warning", "error")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One diagnostic: where, which rule, what went wrong.

    ``rule`` is a dotted id ``family.check`` (e.g. ``trace.concretize``);
    ``--select``/``--ignore`` and inline suppressions match by exact id or
    by family prefix.
    """

    path: str                    # repo-relative, forward slashes
    line: int
    rule: str
    message: str
    severity: str = "error"

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity must be one of {SEVERITIES}, "
                             f"got {self.severity!r}")
        if "." not in self.rule:
            raise ValueError("rule id must be 'family.check', "
                             f"got {self.rule!r}")

    @property
    def family(self) -> str:
        return self.rule.split(".", 1)[0]

    def key(self) -> tuple:
        """Baseline identity: stable across pure line-number churn."""
        return (self.path, self.rule, self.message)

    def render(self) -> str:
        return (f"{self.path}:{self.line}: {self.severity} "
                f"[{self.rule}] {self.message}")

    def render_github(self) -> str:
        kind = "error" if self.severity == "error" else "warning"
        return (f"::{kind} file={self.path},line={self.line},"
                f"title={self.rule}::{self.message}")
