"""Parsed-source model + analysis driver: files, suppressions, baseline.

``# viblint: ignore[rule-id, ...] -- justification`` on a line suppresses
matching findings *on that line only*; the justification after ``--`` is
mandatory (an unexplained suppression is itself a finding —
``suppress.unjustified`` — so exceptions stay auditable). Rule ids match
exactly or by family prefix (``ignore[trace]`` covers every trace check).

The baseline file grandfathers known findings so a new rule can land
before every historical violation is fixed: a JSON object with a
``findings`` list (matched by ``(path, rule, message)`` — line numbers are
display metadata, not identity) and a ``suppression_budget`` int. The
``benchmarks/run.py --check`` lint gate fails when either the active
finding count or the number of inline suppressions grows past what the
committed baseline admits, so neither can creep in silently.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import json
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from .findings import Finding
from .registry import get_rule, registered_rules

__all__ = ["ParsedFile", "Project", "Baseline", "AnalysisReport", "analyze",
           "load_project"]

#: marker grammar: ``viblint: ignore[trace.concretize, det] -- reason``
_SUPPRESS_RE = re.compile(
    r"#\s*viblint:\s*ignore\[([^\]]*)\]\s*(?:--\s*(.*\S))?\s*$")
#: anything that *looks* like a marker attempt — used to flag typos
#: (``viblint ignore[...]``, ``viblint: ignore x``) as suppress.malformed
#: without tripping on prose comments that merely mention the tool
_MARKER_ATTEMPT_RE = re.compile(r"#\s*viblint\b")


@dataclasses.dataclass
class ParsedFile:
    """One source file: text, AST, and per-line suppressions."""

    path: Path                       # absolute
    rel: str                         # project-relative, forward slashes
    source: str
    tree: Optional[ast.AST]          # None when the file failed to parse
    #: line → rule ids / family prefixes suppressed on that line
    suppressions: Dict[int, Set[str]] = dataclasses.field(
        default_factory=dict)
    #: lines carrying an ignore[...] with no `-- justification`
    unjustified: List[int] = dataclasses.field(default_factory=list)

    def walk(self) -> Iterator[ast.AST]:
        return iter(()) if self.tree is None else ast.walk(self.tree)

    def suppressed(self, finding: Finding) -> bool:
        ids = self.suppressions.get(finding.line)
        return bool(ids) and (finding.rule in ids or finding.family in ids)


def _comments(source: str) -> Iterator[Tuple[int, str]]:
    """(line, text) of every comment token — suppression markers live in
    real comments only, so docstrings *describing* the syntax are inert."""
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                yield tok.start[0], tok.string
    except tokenize.TokenError:      # unterminated string etc. — the AST
        return                       # parse already reported it


def _parse_file(path: Path, rel: str) -> Tuple[ParsedFile, List[Finding]]:
    source = path.read_text(encoding="utf-8")
    findings: List[Finding] = []
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as e:
        tree = None
        findings.append(Finding(rel, e.lineno or 1, "parse.syntax-error",
                                f"file does not parse: {e.msg}"))
    pf = ParsedFile(path, rel, source, tree)
    for lineno, text in _comments(source):
        m = _SUPPRESS_RE.search(text)
        if m is None:
            if _MARKER_ATTEMPT_RE.search(text):
                # a malformed marker would otherwise silently suppress
                # nothing while the author believes it does
                findings.append(Finding(
                    rel, lineno, "suppress.malformed",
                    "unparseable viblint marker — expected "
                    "`# viblint: ignore[rule-id] -- justification`"))
            continue
        ids = {s.strip() for s in m.group(1).split(",") if s.strip()}
        if not ids:
            findings.append(Finding(rel, lineno, "suppress.malformed",
                                    "viblint ignore[] lists no rule ids"))
            continue
        if not m.group(2):
            pf.unjustified.append(lineno)
            findings.append(Finding(
                rel, lineno, "suppress.unjustified",
                "suppression without a justification — append "
                "`-- <why this exception is sound>`"))
            continue                 # unjustified markers suppress nothing
        pf.suppressions[lineno] = ids
    return pf, findings


@dataclasses.dataclass
class Project:
    """Every parsed file under the analyzed paths, root-relative."""

    root: Path
    files: List[ParsedFile]

    def file(self, suffix: str) -> Optional[ParsedFile]:
        """Look a file up by relative-path suffix (e.g.
        ``repro/serving/engine.py``); None when absent from the scan."""
        for pf in self.files:
            if pf.rel.endswith(suffix):
                return pf
        return None

    @property
    def suppression_count(self) -> int:
        return sum(len(pf.suppressions) for pf in self.files)


def load_project(paths: Sequence[Path], root: Optional[Path] = None,
                 ) -> Tuple[Project, List[Finding]]:
    """Collect and parse ``*.py`` under ``paths`` (files or directories).

    ``root`` anchors the relative paths findings report; defaults to the
    common parent so ``repro.analysis src/`` and ``repro.analysis
    src/repro/core`` emit comparable paths.
    """
    seen: Set[Path] = set()
    py_files: List[Path] = []
    for p in paths:
        p = Path(p).resolve()
        candidates = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for c in candidates:
            if c not in seen and c.suffix == ".py":
                seen.add(c)
                py_files.append(c)
    if root is None:
        root = Path(".").resolve()
    root = Path(root).resolve()
    files, findings = [], []
    for p in py_files:
        try:
            rel = p.relative_to(root).as_posix()
        except ValueError:
            rel = p.as_posix()
        pf, f = _parse_file(p, rel)
        files.append(pf)
        findings.extend(f)
    return Project(root, files), findings


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Baseline:
    """Grandfathered findings + the inline-suppression budget."""

    findings: List[Tuple[str, str, str]] = dataclasses.field(
        default_factory=list)            # (path, rule, message) keys
    suppression_budget: int = 0

    @classmethod
    def load(cls, path: Path) -> "Baseline":
        raw = json.loads(Path(path).read_text(encoding="utf-8"))
        return cls(
            findings=[(f["path"], f["rule"], f["message"])
                      for f in raw.get("findings", [])],
            suppression_budget=int(raw.get("suppression_budget", 0)))

    def dump(self, path: Path, findings: Sequence[Finding] = ()) -> None:
        payload = {
            "findings": [{"path": f.path, "rule": f.rule,
                          "message": f.message}
                         for f in sorted(findings)],
            "suppression_budget": self.suppression_budget,
        }
        Path(path).write_text(json.dumps(payload, indent=2) + "\n",
                              encoding="utf-8")


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AnalysisReport:
    """Everything one analysis run produced, pre-partitioned."""

    active: List[Finding]            # unsuppressed, unbaselined — failures
    suppressed: List[Finding]        # silenced by a justified inline marker
    baselined: List[Finding]         # grandfathered by the baseline file
    suppression_count: int           # justified inline markers in the scan
    stale_baseline: List[Tuple[str, str, str]]  # baseline entries nothing
    #                                  matched — fixed findings to prune

    @property
    def ok(self) -> bool:
        return not self.active


def analyze(paths: Sequence[Path], *, root: Optional[Path] = None,
            select: Sequence[str] = (), ignore: Sequence[str] = (),
            baseline: Optional[Baseline] = None) -> AnalysisReport:
    """Run every registered rule over ``paths`` and partition the findings.

    ``select``/``ignore`` filter by exact rule id or family prefix
    (select wins first, then ignore removes). The driver-level findings
    (parse errors, malformed/unjustified suppressions) are always active —
    they are defects of the suppression machinery itself.
    """
    project, findings = load_project(paths, root=root)
    for family in registered_rules():
        rule = get_rule(family)
        if rule.scope == "project":
            findings.extend(rule.check(project))
        else:
            for pf in project.files:
                findings.extend(rule.check(pf))

    def matches(f: Finding, pats: Sequence[str]) -> bool:
        return any(f.rule == p or f.family == p for p in pats)

    if select:
        findings = [f for f in findings
                    if matches(f, select) or f.family in ("parse", "suppress")]
    if ignore:
        findings = [f for f in findings if not matches(f, ignore)]

    by_rel = {pf.rel: pf for pf in project.files}
    active, suppressed, baselined = [], [], []
    remaining = list(baseline.findings) if baseline is not None else []
    for f in sorted(set(findings)):
        pf = by_rel.get(f.path)
        if pf is not None and pf.suppressed(f):
            suppressed.append(f)
        elif f.key() in remaining:
            remaining.remove(f.key())
            baselined.append(f)
        else:
            active.append(f)
    return AnalysisReport(active=active, suppressed=suppressed,
                          baselined=baselined,
                          suppression_count=project.suppression_count,
                          stale_baseline=remaining)
