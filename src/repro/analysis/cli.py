"""``python -m repro.analysis`` — the static-analysis CLI.

Exit status 0 means zero unsuppressed, unbaselined findings; anything
else is 1. ``--format github`` emits workflow commands so CI annotates
the offending lines directly.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .project import Baseline, analyze
from .registry import registered_rules

__all__ = ["main"]

DEFAULT_BASELINE = ".viblint-baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-invariant static analysis "
                    f"(rule families: {', '.join(registered_rules())})")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to analyze (default: src)")
    ap.add_argument("--select", default="",
                    help="comma-separated rule ids/families to run "
                         "(default: all)")
    ap.add_argument("--ignore", default="",
                    help="comma-separated rule ids/families to skip")
    ap.add_argument("--format", choices=("text", "github"), default="text")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON grandfathering known findings "
                         f"(default: {DEFAULT_BASELINE} when present)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write the active findings to the baseline file "
                         "and exit 0 (deliberate grandfathering only)")
    ap.add_argument("--root", default=None,
                    help="path findings are reported relative to "
                         "(default: cwd)")
    args = ap.parse_args(argv)

    baseline_path = Path(args.baseline) if args.baseline else \
        Path(DEFAULT_BASELINE)
    baseline = None
    if baseline_path.exists():
        baseline = Baseline.load(baseline_path)

    report = analyze(
        [Path(p) for p in args.paths],
        root=Path(args.root) if args.root else None,
        select=[s for s in args.select.split(",") if s],
        ignore=[s for s in args.ignore.split(",") if s],
        baseline=baseline)

    if args.write_baseline:
        bl = baseline or Baseline()
        bl.suppression_budget = max(bl.suppression_budget,
                                    report.suppression_count)
        bl.dump(baseline_path, report.active)
        print(f"wrote {len(report.active)} finding(s) + suppression budget "
              f"{bl.suppression_budget} to {baseline_path}")
        return 0

    for f in report.active:
        print(f.render_github() if args.format == "github" else f.render())
    summary = (f"{len(report.active)} finding(s), "
               f"{len(report.suppressed)} suppressed, "
               f"{len(report.baselined)} baselined, "
               f"{report.suppression_count} inline suppression(s)")
    if report.stale_baseline:
        summary += (f"; {len(report.stale_baseline)} stale baseline "
                    "entr(ies) — fixed findings, prune them")
    print(("# " if args.format == "text" else "") + summary,
          file=sys.stderr)
    return 1 if report.active else 0
