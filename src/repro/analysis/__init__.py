"""repro.analysis — repo-invariant static analysis (stdlib-only).

The conventions that make this repo's A/B claims trustworthy are not
enforceable by generic linters: honest pricing on *both* virtual clocks,
no recompiles across recalibrations/share refreshes/chunked prefill, and
bit-identical seeded replays. This package encodes them as AST rules over
a pluggable registry (the placement-policy registry pattern):

* ``trace``   — recompile/concretization hazards in jit-reachable code
* ``det``     — seed-determinism hygiene in ``repro.core``/``repro.serving``
* ``parity``  — clock-pricing parity across engine and simulator
* ``frozen``  — frozen-config + registry-singleton mutation hygiene
* ``imports`` — unused imports (in-repo F401 for ruff-less containers)

CLI::

    python -m repro.analysis src/ [--select trace,parity] [--ignore det]
        [--format github] [--baseline .viblint-baseline.json]

Suppress one finding with a justified inline marker (the justification is
mandatory)::

    x = int(n_valid)   # viblint: ignore[trace.concretize] -- host-side
                       #   scalar: this branch runs outside the jit

Deliberately stdlib-only: the CI lint lane runs it without installing
jax/numpy.
"""

from .findings import Finding
from .project import (AnalysisReport, Baseline, ParsedFile, Project, analyze,
                      load_project)
from .registry import (AnalysisRule, UnknownRuleError, get_rule,
                       register_rule, registered_rules)
from . import rules as _rules        # registers the built-in families

__all__ = [
    "Finding",
    "AnalysisReport",
    "Baseline",
    "ParsedFile",
    "Project",
    "analyze",
    "load_project",
    "AnalysisRule",
    "UnknownRuleError",
    "get_rule",
    "register_rule",
    "registered_rules",
]

del _rules
