"""Pluggable analysis-rule registry (the placement-policy registry pattern).

A rule is any object satisfying :class:`AnalysisRule`:

* ``family``  — the registry key and finding-id prefix (``trace``, ``det``,
  ``parity``, ``frozen``, ``imports``). Every finding a rule emits must
  carry a ``family.<check>`` rule id under its own family.
* ``scope``   — ``"file"`` (checked one :class:`~repro.analysis.project.
  ParsedFile` at a time) or ``"project"`` (sees the whole
  :class:`~repro.analysis.project.Project` at once — cross-file rules like
  clock-pricing parity need both virtual clocks in view).
* ``check(target)`` — yields :class:`~repro.analysis.findings.Finding`s.

Registering a rule (one module, no driver edits) exposes it to the CLI's
``--select``/``--ignore``, inline ``# viblint: ignore[...]`` suppressions,
and the baseline machinery at once::

    from repro.analysis import Finding, register_rule

    @register_rule
    class NoPrintRule:
        family = "style"
        scope = "file"
        def check(self, pf):
            for node in pf.walk():
                ...
                yield Finding(pf.rel, node.lineno, "style.print", "...")
"""

from __future__ import annotations

from typing import Dict, Iterable, Protocol, Tuple, runtime_checkable

from .findings import Finding

__all__ = ["AnalysisRule", "UnknownRuleError", "register_rule", "get_rule",
           "registered_rules"]

SCOPES = ("file", "project")


@runtime_checkable
class AnalysisRule(Protocol):
    """Protocol every registered analysis rule satisfies."""

    family: str
    scope: str

    def check(self, target) -> Iterable[Finding]:
        """Yield findings for one file (scope="file") or the whole
        project (scope="project")."""
        ...


class UnknownRuleError(ValueError):
    """Raised for a rule family absent from the registry."""


_REGISTRY: Dict[str, AnalysisRule] = {}


def register_rule(rule, *, replace: bool = False):
    """Add a rule to the registry; usable as a class decorator.

    Accepts an :class:`AnalysisRule` instance or a zero-arg class (which is
    instantiated). Duplicate families raise unless ``replace=True``.
    Returns the argument unchanged so decorated classes stay usable.
    """
    inst = rule() if isinstance(rule, type) else rule
    family = getattr(inst, "family", "")
    if not family or not isinstance(family, str):
        raise ValueError("analysis rule needs a non-empty string .family")
    if not isinstance(inst, AnalysisRule):
        raise TypeError(f"{family!r} does not satisfy the AnalysisRule "
                        "protocol (family/scope/check)")
    if inst.scope not in SCOPES:
        raise ValueError(f"rule {family!r} scope must be one of {SCOPES}, "
                         f"got {inst.scope!r}")
    if family in _REGISTRY and not replace:
        raise ValueError(f"analysis rule family {family!r} already "
                         "registered (pass replace=True to override)")
    _REGISTRY[family] = inst
    return rule


def get_rule(family: str) -> AnalysisRule:
    """Registry lookup; unknown families list what *is* registered."""
    try:
        return _REGISTRY[family]
    except KeyError:
        raise UnknownRuleError(
            f"unknown analysis rule family {family!r}; registered: "
            f"{', '.join(registered_rules())}") from None


def registered_rules() -> Tuple[str, ...]:
    """Sorted families of all registered rules (drives the CLI listing)."""
    return tuple(sorted(_REGISTRY))
