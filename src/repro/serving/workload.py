"""Synthetic serving workloads (paper §5.1 Table 2b).

Two benchmark families, statistically matched to the paper's datasets:

* ``sharegpt`` — variable lengths: input ~ lognormal(mean 219.2), output ~
  lognormal(mean 200.8). Variable-length inputs create routing variance
  (paper §5.2: "hot experts can exhibit sudden load spikes").
* ``sonnet``   — fixed 1024-token input / 128-token output: stable routing
  that closely matches time-averaged placement statistics.

Requests arrive via a Poisson process at a target QPS (the vLLM client
replay the paper uses). Each workload also carries a *routing profile* — a
per-layer expert-popularity matrix sampled from a Dirichlet whose
concentration controls skew, calibrated to the paper's Fig 4 observation
(busiest EP rank >24% of tokens, lightest <10%, under 8-way contiguous
placement of 256 experts). Step-level expert loads are multinomial draws
from that profile, so "activation patterns are relatively stable for a
given benchmark" (§4.2.2) holds by construction while per-step noise
remains.

Beyond the flat Poisson client, *traces* model millions-of-users-shaped
traffic: an :class:`ArrivalSpec` picks the arrival process (poisson /
bursty MMPP / diurnal thinning) and a :class:`TraceSpec` mixes
multi-tenant request populations (chat vs long-context, each with its own
length distribution and TTFT SLO) over it — see :data:`TRACES` and
:func:`sample_trace`.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["Request", "WorkloadSpec", "WORKLOADS", "sample_requests",
           "routing_profile", "step_loads", "topic_loadings",
           "ArrivalSpec", "TenantSpec", "TraceSpec", "TRACES",
           "sample_arrivals", "sample_trace"]


@dataclasses.dataclass(frozen=True)
class Request:
    req_id: int
    arrival: float                 # seconds
    prompt_len: int
    output_len: int
    tenant: str = ""               # trace tenant (multi-tenant mixes)
    ttft_slo: Optional[float] = None   # per-request deadline override


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    mean_in: float
    mean_out: float
    fixed: bool                    # fixed lengths (sonnet) vs lognormal
    cv_in: float = 1.2             # coefficient of variation (variable only)
    cv_out: float = 1.1
    routing_alpha: float = 0.25    # Dirichlet concentration (lower = skewed)
    routing_seed: int = 17         # identity of the workload's hot experts
    burst_sigma: float = 0.3       # per-step i.i.d. lognormal spikes
    n_topics: int = 8              # correlated routing factors per step
    topic_sigma: float = 0.5       # topic-factor strength
    # Per-step routing deviation has two parts. ``burst_sigma`` is i.i.d.
    # per-expert noise; ``topic_sigma`` drives a low-rank *correlated*
    # component: a batch of similar prompts routes similarly, so groups of
    # experts spike together across layers. Correlated spikes are what make
    # a token-balanced static placement fragile — the paper's §5.2
    # mechanism: "hot experts can exhibit sudden load spikes that deviate
    # from the profiled average … EPLB may assign these spike-prone experts
    # to slow GPUs."


WORKLOADS: Dict[str, WorkloadSpec] = {
    # variable lengths → more routing variance (paper §5.2)
    "sharegpt": WorkloadSpec("sharegpt", mean_in=219.2, mean_out=200.8,
                             fixed=False, routing_alpha=0.2, routing_seed=17,
                             burst_sigma=0.4, topic_sigma=0.8),
    # fixed lengths → stable routing matching time-averaged statistics
    "sonnet": WorkloadSpec("sonnet", mean_in=1024, mean_out=128,
                           fixed=True, routing_alpha=0.3, routing_seed=91,
                           burst_sigma=0.1, topic_sigma=0.15),
    # long-context family: document-scale prompts, short answers — the
    # head-of-line-blocking stressor for chunked prefill (one of these
    # behind a chat burst is exactly where P90 TTFT separates schedulers)
    "longcontext": WorkloadSpec("longcontext", mean_in=4096, mean_out=96,
                                fixed=False, cv_in=0.6, cv_out=0.8,
                                routing_alpha=0.22, routing_seed=53,
                                burst_sigma=0.3, topic_sigma=0.5),
}


# ---------------------------------------------------------------------------
# arrival processes + multi-tenant traces
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    """Arrival-process family at a target *mean* rate (set per sample).

    * ``poisson`` — exponential gaps (the flat baseline).
    * ``bursty``  — 2-state MMPP: a burst state at ``burst_factor ×`` the
      mean rate occupying ``burst_fraction`` of the time, a quiet state
      sized so the long-run rate stays at the target. Burst/quiet sojourns
      are exponential with mean ``sojourn`` seconds.
    * ``diurnal`` — inhomogeneous Poisson via thinning: rate(t) =
      qps · (1 + amplitude · sin(2πt / period)).
    """

    process: str = "poisson"         # poisson | bursty | diurnal
    burst_factor: float = 4.0        # burst-state rate multiplier
    burst_fraction: float = 0.2      # long-run fraction of time in burst
    sojourn: float = 2.0             # mean burst/quiet dwell (seconds)
    amplitude: float = 0.8           # diurnal swing (< 1)
    period: float = 60.0             # diurnal cycle (seconds)

    def __post_init__(self):
        if self.process not in ("poisson", "bursty", "diurnal"):
            raise ValueError(f"unknown arrival process {self.process!r}")
        if not 0.0 < self.burst_fraction < 1.0:
            raise ValueError("burst_fraction must be in (0, 1)")
        if self.burst_factor * self.burst_fraction >= 1.0 \
                and self.process == "bursty":
            raise ValueError("burst_factor × burst_fraction must be < 1 "
                             "(quiet-state rate would go negative)")
        if not 0.0 <= self.amplitude < 1.0:
            raise ValueError("amplitude must be in [0, 1)")


def sample_arrivals(spec: ArrivalSpec, n: int, qps: float,
                    rng: np.random.Generator) -> np.ndarray:
    """(n,) sorted arrival times with long-run mean rate ``qps``."""
    qps = max(qps, 1e-9)
    if spec.process == "poisson":
        return np.cumsum(rng.exponential(1.0 / qps, size=n))
    if spec.process == "diurnal":
        # thinning against the peak rate
        peak = qps * (1.0 + spec.amplitude)
        out, t = [], 0.0
        while len(out) < n:
            t += rng.exponential(1.0 / peak)
            rate = qps * (1.0 + spec.amplitude
                          * math.sin(2.0 * math.pi * t / spec.period))
            if rng.uniform() * peak <= rate:
                out.append(t)
        return np.asarray(out)
    # bursty MMPP: quiet-state rate balances the long-run mean
    hi = qps * spec.burst_factor
    lo = qps * (1.0 - spec.burst_factor * spec.burst_fraction) \
        / (1.0 - spec.burst_fraction)
    # dwell times hit the target duty cycle
    dwell = {True: spec.sojourn, False: spec.sojourn
             * (1.0 - spec.burst_fraction) / spec.burst_fraction}
    out, t = [], 0.0
    burst = rng.uniform() < spec.burst_fraction
    next_switch = t + rng.exponential(dwell[burst])
    while len(out) < n:
        rate = hi if burst else lo
        gap = rng.exponential(1.0 / max(rate, 1e-9))
        if t + gap >= next_switch:
            t = next_switch
            burst = not burst
            next_switch = t + rng.exponential(dwell[burst])
            continue
        t += gap
        out.append(t)
    return np.asarray(out)


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One request population inside a trace."""

    name: str
    workload: str                    # WORKLOADS key (length distribution)
    weight: float                    # mixing probability
    ttft_slo: Optional[float] = None # tenant deadline (None = serving default)


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Arrival process × multi-tenant mix = one serving trace."""

    name: str
    arrival: ArrivalSpec
    tenants: Tuple[TenantSpec, ...]

    def __post_init__(self):
        if not self.tenants:
            raise ValueError("trace needs at least one tenant")
        for t in self.tenants:
            if t.workload not in WORKLOADS:
                raise ValueError(f"tenant {t.name!r}: unknown workload "
                                 f"{t.workload!r}")

    @property
    def primary(self) -> WorkloadSpec:
        """Highest-weight tenant's workload (drives the routing profile)."""
        return WORKLOADS[max(self.tenants, key=lambda t: t.weight).workload]


TRACES: Dict[str, TraceSpec] = {
    # the legacy flat client as a trace (sanity baseline)
    "flat": TraceSpec("flat", ArrivalSpec("poisson"),
                      (TenantSpec("chat", "sharegpt", 1.0),)),
    # chat bursts with long-context stragglers mixed in: the paper's P90
    # TTFT stressor — a 4096-token prefill head-of-line-blocks a burst of
    # chats unless prefill is chunked and deadline-scheduled
    "bursty": TraceSpec(
        "bursty", ArrivalSpec("bursty", burst_factor=4.0,
                              burst_fraction=0.2, sojourn=2.0),
        (TenantSpec("chat", "sharegpt", 0.85, ttft_slo=0.25),
         TenantSpec("longctx", "longcontext", 0.15, ttft_slo=0.60))),
    # slow sinusoidal load swing, three tenants (batch jobs have no TTFT
    # urgency; interactive chat does)
    "diurnal": TraceSpec(
        "diurnal", ArrivalSpec("diurnal", amplitude=0.8, period=60.0),
        (TenantSpec("chat", "sharegpt", 0.6, ttft_slo=0.25),
         TenantSpec("batch", "sonnet", 0.25, ttft_slo=2.0),
         TenantSpec("longctx", "longcontext", 0.15, ttft_slo=0.60))),
}


def sample_trace(trace: TraceSpec, n: int, qps: float,
                 seed: int = 0) -> List[Request]:
    """Sample ``n`` requests from a trace at long-run rate ``qps``."""
    rng = np.random.default_rng(seed)
    arrivals = sample_arrivals(trace.arrival, n, qps, rng)
    weights = np.array([t.weight for t in trace.tenants], dtype=np.float64)
    weights = weights / weights.sum()
    choice = rng.choice(len(trace.tenants), size=n, p=weights)
    reqs: List[Request] = []
    for i in range(n):
        ten = trace.tenants[int(choice[i])]
        spec = WORKLOADS[ten.workload]
        if spec.fixed:
            p_in, p_out = int(spec.mean_in), int(spec.mean_out)
        else:
            p_in = max(1, int(_lognormal(rng, spec.mean_in, spec.cv_in, 1)[0]))
            p_out = max(1, int(_lognormal(rng, spec.mean_out, spec.cv_out,
                                          1)[0]))
        reqs.append(Request(i, float(arrivals[i]), p_in, p_out,
                            tenant=ten.name, ttft_slo=ten.ttft_slo))
    return reqs


def topic_loadings(spec: WorkloadSpec, n_layers: int,
                   n_experts: int) -> np.ndarray:
    """(L, E, n_topics) expert↔topic affinity, fixed per workload."""
    rng = np.random.default_rng(spec.routing_seed + 1)
    a = rng.normal(0.0, 1.0, size=(n_layers, n_experts, spec.n_topics))
    return a / np.sqrt(spec.n_topics)


def _lognormal(rng, mean: float, cv: float, size: int) -> np.ndarray:
    sigma2 = math.log(1.0 + cv * cv)
    mu = math.log(mean) - 0.5 * sigma2
    return rng.lognormal(mu, math.sqrt(sigma2), size=size)


def sample_requests(spec: WorkloadSpec, n: int, qps: float,
                    seed: int = 0) -> List[Request]:
    """Poisson arrivals at ``qps``; lengths per the workload family."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(qps, 1e-9), size=n)
    arrivals = np.cumsum(gaps)
    if spec.fixed:
        p_in = np.full(n, int(spec.mean_in))
        p_out = np.full(n, int(spec.mean_out))
    else:
        p_in = np.maximum(1, _lognormal(rng, spec.mean_in, spec.cv_in,
                                        n)).astype(int)
        p_out = np.maximum(1, _lognormal(rng, spec.mean_out, spec.cv_out,
                                         n)).astype(int)
    return [Request(i, float(arrivals[i]), int(p_in[i]), int(p_out[i]))
            for i in range(n)]


def routing_profile(spec: WorkloadSpec, n_layers: int,
                    n_experts: int) -> np.ndarray:
    """(L, E) expert-popularity matrix (rows sum to 1), workload-stable."""
    rng = np.random.default_rng(spec.routing_seed)
    return rng.dirichlet(np.full(n_experts, spec.routing_alpha),
                         size=n_layers)


def step_loads(profile: np.ndarray, tokens: int, top_k: int,
               rng: np.random.Generator,
               phase_scale: Optional[np.ndarray] = None) -> np.ndarray:
    """Multinomial per-layer expert token loads for one forward pass.

    Each of ``tokens`` tokens selects ``top_k`` experts per layer; the
    returned (L, E) counts therefore sum to tokens·top_k per row.
    ``phase_scale`` optionally perturbs popularity (drift experiments).
    """
    L, E = profile.shape
    prof = profile if phase_scale is None else profile * phase_scale
    prof = prof / prof.sum(axis=1, keepdims=True)
    out = np.empty((L, E), dtype=np.float64)
    n = tokens * top_k
    for l in range(L):
        out[l] = rng.multinomial(n, prof[l])
    return out
