"""Synthetic serving workloads (paper §5.1 Table 2b).

Two benchmark families, statistically matched to the paper's datasets:

* ``sharegpt`` — variable lengths: input ~ lognormal(mean 219.2), output ~
  lognormal(mean 200.8). Variable-length inputs create routing variance
  (paper §5.2: "hot experts can exhibit sudden load spikes").
* ``sonnet``   — fixed 1024-token input / 128-token output: stable routing
  that closely matches time-averaged placement statistics.

Requests arrive via a Poisson process at a target QPS (the vLLM client
replay the paper uses). Each workload also carries a *routing profile* — a
per-layer expert-popularity matrix sampled from a Dirichlet whose
concentration controls skew, calibrated to the paper's Fig 4 observation
(busiest EP rank >24% of tokens, lightest <10%, under 8-way contiguous
placement of 256 experts). Step-level expert loads are multinomial draws
from that profile, so "activation patterns are relatively stable for a
given benchmark" (§4.2.2) holds by construction while per-step noise
remains.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

__all__ = ["Request", "WorkloadSpec", "WORKLOADS", "sample_requests",
           "routing_profile", "step_loads", "topic_loadings"]


@dataclasses.dataclass(frozen=True)
class Request:
    req_id: int
    arrival: float                 # seconds
    prompt_len: int
    output_len: int


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    mean_in: float
    mean_out: float
    fixed: bool                    # fixed lengths (sonnet) vs lognormal
    cv_in: float = 1.2             # coefficient of variation (variable only)
    cv_out: float = 1.1
    routing_alpha: float = 0.25    # Dirichlet concentration (lower = skewed)
    routing_seed: int = 17         # identity of the workload's hot experts
    burst_sigma: float = 0.3       # per-step i.i.d. lognormal spikes
    n_topics: int = 8              # correlated routing factors per step
    topic_sigma: float = 0.5       # topic-factor strength
    # Per-step routing deviation has two parts. ``burst_sigma`` is i.i.d.
    # per-expert noise; ``topic_sigma`` drives a low-rank *correlated*
    # component: a batch of similar prompts routes similarly, so groups of
    # experts spike together across layers. Correlated spikes are what make
    # a token-balanced static placement fragile — the paper's §5.2
    # mechanism: "hot experts can exhibit sudden load spikes that deviate
    # from the profiled average … EPLB may assign these spike-prone experts
    # to slow GPUs."


WORKLOADS: Dict[str, WorkloadSpec] = {
    # variable lengths → more routing variance (paper §5.2)
    "sharegpt": WorkloadSpec("sharegpt", mean_in=219.2, mean_out=200.8,
                             fixed=False, routing_alpha=0.2, routing_seed=17,
                             burst_sigma=0.4, topic_sigma=0.8),
    # fixed lengths → stable routing matching time-averaged statistics
    "sonnet": WorkloadSpec("sonnet", mean_in=1024, mean_out=128,
                           fixed=True, routing_alpha=0.3, routing_seed=91,
                           burst_sigma=0.1, topic_sigma=0.15),
}


def topic_loadings(spec: WorkloadSpec, n_layers: int,
                   n_experts: int) -> np.ndarray:
    """(L, E, n_topics) expert↔topic affinity, fixed per workload."""
    rng = np.random.default_rng(spec.routing_seed + 1)
    a = rng.normal(0.0, 1.0, size=(n_layers, n_experts, spec.n_topics))
    return a / np.sqrt(spec.n_topics)


def _lognormal(rng, mean: float, cv: float, size: int) -> np.ndarray:
    sigma2 = math.log(1.0 + cv * cv)
    mu = math.log(mean) - 0.5 * sigma2
    return rng.lognormal(mu, math.sqrt(sigma2), size=size)


def sample_requests(spec: WorkloadSpec, n: int, qps: float,
                    seed: int = 0) -> List[Request]:
    """Poisson arrivals at ``qps``; lengths per the workload family."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(qps, 1e-9), size=n)
    arrivals = np.cumsum(gaps)
    if spec.fixed:
        p_in = np.full(n, int(spec.mean_in))
        p_out = np.full(n, int(spec.mean_out))
    else:
        p_in = np.maximum(1, _lognormal(rng, spec.mean_in, spec.cv_in,
                                        n)).astype(int)
        p_out = np.maximum(1, _lognormal(rng, spec.mean_out, spec.cv_out,
                                         n)).astype(int)
    return [Request(i, float(arrivals[i]), int(p_in[i]), int(p_out[i]))
            for i in range(n)]


def routing_profile(spec: WorkloadSpec, n_layers: int,
                    n_experts: int) -> np.ndarray:
    """(L, E) expert-popularity matrix (rows sum to 1), workload-stable."""
    rng = np.random.default_rng(spec.routing_seed)
    return rng.dirichlet(np.full(n_experts, spec.routing_alpha),
                         size=n_layers)


def step_loads(profile: np.ndarray, tokens: int, top_k: int,
               rng: np.random.Generator,
               phase_scale: Optional[np.ndarray] = None) -> np.ndarray:
    """Multinomial per-layer expert token loads for one forward pass.

    Each of ``tokens`` tokens selects ``top_k`` experts per layer; the
    returned (L, E) counts therefore sum to tokens·top_k per row.
    ``phase_scale`` optionally perturbs popularity (drift experiments).
    """
    L, E = profile.shape
    prof = profile if phase_scale is None else profile * phase_scale
    prof = prof / prof.sum(axis=1, keepdims=True)
    out = np.empty((L, E), dtype=np.float64)
    n = tokens * top_k
    for l in range(L):
        out[l] = rng.multinomial(n, prof[l])
    return out
