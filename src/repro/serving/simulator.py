"""Discrete-event multi-device EP serving simulator (DESIGN.md §4).

This CPU host has one device, so multi-GPU variability cannot be *measured*
here; it is *modeled*: per-device ground-truth latency functions come from
:mod:`repro.core.variability` (calibrated to the paper's measured regimes),
per-layer expert loads come from the workload routing profiles (or from real
JAX router tallies via the engine), and placement comes from the real
solvers. The simulator then plays the paper's synchronized-EP execution
model:

    step = Σ_layers [ t_attn + t_a2a + max_g f_g(n_g) ]  (+ dense-TP layers)

with continuous batching, prefill/decode separation (the paper emulates
disaggregation, §5.1), drift-aware recalibration events and their migration
stalls (Fig 12). Every paper figure regenerates through this path — and a
real deployment would use the same class for what-if placement scoring, so
it is a first-class library feature, not scaffolding.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.core import (ClusterVariability, Placement,
                        VariabilityEvent, ViBEController)
from repro.core.placement import copy_enumeration, pad_phantom_column
from .config import SimConfig
from .kvcache import PagedKVCache
from .metrics import RequestRecord
from .scheduler import Action, RequestView, SchedulerContext, get_scheduler
from .workload import (Request, WorkloadSpec, routing_profile, step_loads,
                       topic_loadings)

__all__ = ["SimConfig", "EPSimulator", "rank_latency_matrix", "LayerStats",
           "realized_rank_loads", "capacity_bucket_rows"]


def capacity_bucket_rows(tokens: float, top_k: int, n_slots: int,
                         capacity_factor: float) -> int:
    """Token rows the fixed-bucket (capacity) kernel allocates per slot.

    Single source for every capacity *pricing* consumer (simulator, engine
    virtual clock, benches) so they cannot drift apart. The model layer's
    per-device capacity additionally rounds up to a multiple of 4 from its
    *local* token count (MXU alignment, ``moe_layer``); pricing stays at
    this abstract global level on purpose.
    """
    return max(int(np.ceil(tokens * top_k / n_slots * capacity_factor)), 1)


# ---------------------------------------------------------------------------
# vectorized ground-truth timing
# ---------------------------------------------------------------------------

def rank_latency_matrix(cluster: ClusterVariability, n_lg: np.ndarray,
                        rng: Optional[np.random.Generator] = None,
                        t: float = 0.0) -> np.ndarray:
    """(L, G) per-rank token loads → (L, G) ground-truth MoE kernel seconds.

    Vectorized version of ``ClusterVariability.latency`` (same formula),
    evaluated at virtual-clock time ``t`` so scheduled drift events
    (thermal ramps, power caps, replacements) show up in simulated and
    engine-clocked latencies alike. The per-rank loads already reflect
    replica-aware splitting when they come from
    ``ReplicatedPlacement.rank_loads`` (each expert's tokens are divided
    over its copies by the solver's traffic shares), so latency projection
    is placement-representation-agnostic.
    """
    n = np.maximum(np.asarray(n_lg, dtype=np.float64), 0.0)
    stress = np.clip(n / cluster.n_tdp, 0.0, 1.0) ** cluster.stress_gamma
    base = cluster.base_speeds_at(t) if cluster.events else cluster.speeds
    speed = 1.0 - (cluster.throttle + (1.0 - base[None, :])) * stress
    if cluster.events:
        speed = speed * cluster.multipliers_at(t)[None, :]
    speed = np.maximum(speed, 0.1)
    flops = 2.0 * n * cluster.d_model * cluster.d_ff * 3.0
    t_mem = cluster.weight_bytes / cluster.hbm_bw
    lat = cluster.t_base + np.maximum(t_mem,
                                      flops / cluster.peak_flops) / speed
    if rng is not None and cluster.jitter_sigma > 0:
        lat = lat * (1.0 + rng.normal(0.0, cluster.jitter_sigma,
                                      size=lat.shape))
    return np.maximum(lat, 1e-9)


def realized_rank_loads(placement, loads: np.ndarray) -> np.ndarray:
    """(L, E) expert loads → (L, G) per-rank loads as *dispatch* realizes them.

    ``Placement.rank_loads`` scores the solver's intended split — for a
    ``ReplicatedPlacement`` that means fractional tokens per copy. The real
    model layer sends whole tokens: each assignment picks one copy by
    inverse-CDF over the share table (models/moe.py ``_select_slots``).
    This scores that token-granular dispatch: each expert's integer load is
    apportioned over its copies by largest-remainder rounding of the shares
    — the allocation the hash-based selection converges to, exact to ±1
    token per copy. Singleton placements pass through unchanged (one copy
    holds all of an expert's tokens either way), so the function is
    placement-representation-agnostic like ``rank_latency_matrix``.

    Fully vectorized (this runs per simulated step, and the engine's
    virtual clock calls it per engine step): copies are grouped with the
    canonical ``copy_enumeration``, and the largest-remainder top-up is a
    second in-run ranking by descending fractional part.
    """
    loads = np.atleast_2d(np.asarray(loads, dtype=np.float64))
    share = getattr(placement, "share", None)
    if share is None:
        return placement.rank_loads(loads)
    se = placement.slot_expert
    L, S = se.shape
    E = placement.n_experts
    rows = np.arange(L)[:, None]
    # phantom slots (ids == E, budget padding) get a sentinel column with
    # zero load, zero share, and a unit denominator so they contribute
    # nothing without tripping 0/0
    loads_pad = pad_phantom_column(loads)
    order, e_sorted, _ = copy_enumeration(se)
    sh = np.take_along_axis(share, order, axis=1)
    denom = np.zeros((L, E + 1))
    np.add.at(denom, (rows, e_sorted), sh)
    denom[:, E] = 1.0
    exact = sh / denom[rows, e_sorted] * loads_pad[rows, e_sorted]
    base = np.floor(exact)
    base_sum = np.zeros((L, E + 1))
    np.add.at(base_sum, (rows, e_sorted), base)
    rem = np.maximum(np.round(loads_pad - base_sum), 0.0)  # leftovers (L, E+1)
    rem[:, E] = 0.0
    # rank copies within each expert's run by descending fractional part
    # (stable → slot order breaks ties, matching the copy axis); the first
    # rem[l, e] of them absorb one leftover token each
    frac = exact - base
    key = e_sorted.astype(np.float64) * 2.0 + (1.0 - frac)
    ford = np.argsort(key, axis=1, kind="stable")
    e_f = np.take_along_axis(e_sorted, ford, axis=1)
    pos = np.arange(S)[None, :]
    new_run = np.concatenate(
        [np.ones((L, 1), bool), e_f[:, 1:] != e_f[:, :-1]], axis=1)
    run_start = np.maximum.accumulate(np.where(new_run, pos, 0), axis=1)
    bump = ((pos - run_start) < rem[rows, e_f]).astype(np.float64)
    slot_tok = np.zeros((L, S))
    slot_tok[rows, np.take_along_axis(order, ford, axis=1)] = \
        np.take_along_axis(base, ford, axis=1) + bump
    return slot_tok.reshape(L, placement.n_ranks,
                            placement.slots_per_rank).sum(axis=2)


@dataclasses.dataclass
class LayerStats:
    """Per-step MoE layer accounting (feeds Figs 1, 6, 10)."""
    rank_time: np.ndarray            # (L, G)
    rank_load: np.ndarray            # (L, G)

    @property
    def layer_time(self) -> np.ndarray:
        return self.rank_time.max(axis=1)

    @property
    def latency_gap(self) -> np.ndarray:
        return self.rank_time.max(axis=1) - self.rank_time.min(axis=1)

    @property
    def barrier_idle(self) -> float:
        return float((self.rank_time.max(axis=1, keepdims=True)
                      - self.rank_time).sum())


# ---------------------------------------------------------------------------
# simulator
# ---------------------------------------------------------------------------

# SimConfig moved to serving/config.py (frozen, part of the unified
# ServingConfig hierarchy); re-exported here for back-compat. The
# ``moe_impl`` semantics are unchanged: "ragged" (default, matches the
# model layer's dropless default and the historical simulator behaviour)
# prices the realized routed tokens; "capacity" prices the fixed-bucket
# kernel honestly — every rank runs slots_per_rank × capacity rows
# (zero-padding included) regardless of skew, and per-slot overflow is
# tallied into ``dropped_assignments`` instead of adding compute.


class EPSimulator:
    """Serving simulator for one model on one variability cluster."""

    def __init__(self, model: ArchConfig, cluster: ClusterVariability,
                 workload: WorkloadSpec, sim: SimConfig = SimConfig(),
                 controller: Optional[ViBEController] = None,
                 placement: Optional[Placement] = None,
                 profile: Optional[np.ndarray] = None):
        if not model.is_moe:
            raise ValueError("EPSimulator requires an MoE model config")
        if sim.moe_impl not in ("ragged", "capacity"):
            raise ValueError("moe_impl must be 'ragged' or 'capacity', "
                             f"got {sim.moe_impl!r}")
        self.model = model
        self.cluster = cluster
        self.workload = workload
        self.cfg = sim
        self.L = model._n_moe_layers()
        self.E = model.n_experts
        self.G = sim.ep_degree
        self.controller = controller
        self._static_placement = placement
        self.profile = (profile if profile is not None
                        else routing_profile(workload, self.L, self.E))
        self._topics = (topic_loadings(workload, self.L, self.E)
                        if workload.topic_sigma > 0 else None)
        self.rng = np.random.default_rng(sim.seed)
        # virtual-clock time of the step being simulated: run() keeps it
        # current; drift events (ClusterVariability.events) key off it
        self.now = 0.0
        # accounting
        self.layer_stats: List[LayerStats] = []
        self.rank_busy = np.zeros(self.G)
        self.total_layer_time = 0.0
        self.total_barrier_idle = 0.0
        self.dropped_assignments = 0.0   # capacity-bucket overflow (moe_impl)
        self.steps = 0
        self.migration_stalls: List[Tuple[float, float, int]] = []
        # hierarchical a2a accounting (cfg.topology set + multi-node):
        # cumulative dispatch+combine bytes by link class
        self.ici_bytes = 0.0
        self.dcn_bytes = 0.0
        if sim.topology is not None and sim.topology.n_ranks != self.G:
            raise ValueError(f"topology has {sim.topology.n_ranks} ranks "
                             f"but ep_degree is {self.G}")
        self.expert_bytes = (3 * model.d_model * model.moe_d_ff * 2
                             if model.moe_d_ff else 0)
        # dispatch-time work stealing (controller.rescheduler): track the
        # responsive-share version so each share-only update is charged
        # its table broadcast exactly once
        self.steal_updates = 0
        rs = getattr(controller, "rescheduler", None)
        self._steal_version = rs.version if rs is not None else 0
        # fault injection (inject_faults): pending specs by at_step, the
        # applied/skipped audit log, and any open dcn_degrade window
        # (virtual-time expiry, healthy cfg to restore)
        self._fault_queue: List = []
        self.fault_log: List[Tuple] = []
        self._dcn_restore: Optional[Tuple[float, SimConfig]] = None

    # -- placement ---------------------------------------------------------

    @property
    def placement(self) -> Placement:
        """What this step's dispatch routes (and is priced) against: the
        controller's responsive placement when work stealing is on (same
        slot table, steal-adjusted shares), its plan otherwise."""
        if self.controller is not None:
            return getattr(self.controller, "dispatch_placement",
                           self.controller.placement)
        if self._static_placement is None:
            raise ValueError("need controller or static placement")
        return self._static_placement

    # -- per-step timing ---------------------------------------------------

    def _draw_loads(self, tokens: int,
                    phase_scale: Optional[np.ndarray] = None) -> np.ndarray:
        prof = self.profile if phase_scale is None else \
            self.profile * phase_scale
        log_spike = 0.0
        if self._topics is not None:
            # correlated spikes: topic factors shared by the whole batch
            z = self.rng.normal(0.0, self.workload.topic_sigma,
                                size=self.workload.n_topics)
            log_spike = self._topics @ z                       # (L, E)
        if self.workload.burst_sigma > 0:
            log_spike = log_spike + self.rng.normal(
                0.0, self.workload.burst_sigma, size=prof.shape)
        if np.ndim(log_spike):
            prof = prof * np.exp(log_spike)
        prof = prof / prof.sum(axis=1, keepdims=True)
        n = tokens * self.model.top_k
        if self.cfg.poisson_loads:
            return self.rng.poisson(prof * n).astype(np.float64)
        return step_loads(prof, tokens, self.model.top_k, self.rng)

    def _attn_time(self, tokens: int, ctx: float) -> float:
        """Per-layer attention + dense-projection time (TP over G ranks)."""
        m = self.model
        proj = 4 * m.d_model * m.n_heads * m.hd        # qkvo, weighted 2x MACs
        score = 4 * ctx * m.n_heads * m.hd
        flops = self.cfg.attn_flops_scale * 2.0 * tokens * (proj + score)
        return flops / (self.G * self.cluster.peak_flops) + self.cluster.t_base

    def _a2a_time(self, tokens: int) -> float:
        """Dispatch + combine all-to-all per MoE layer (aggregate links)."""
        bw = self.cfg.ici_bw or self.cluster.ici_bw
        bytes_per_rank = (tokens * self.model.top_k * self.model.d_model
                          * self.cfg.act_bytes
                          * (self.G - 1) / (self.G * self.G))
        return 2.0 * bytes_per_rank / bw + self.cluster.t_base

    def _hier_a2a(self, pl, loads: np.ndarray) -> float:
        """Topology-priced all-to-all across all L MoE layers.

        Splits each rank's incoming tokens into intra-node (ICI) and
        cross-node (DCN) components via
        :meth:`ClusterTopology.node_split_loads` — the node-preferring
        dispatch model — and prices each class at its own link bandwidth.
        Per layer the exchange completes when the slowest rank does;
        dispatch + combine doubles the traffic. Also accumulates the
        cumulative byte split (``ici_bytes`` / ``dcn_bytes``) — the
        fig15_hier gate's metric.
        """
        topo = self.cfg.topology
        bpt = self.model.d_model * self.cfg.act_bytes   # bytes/routed token
        local_in, cross_in = topo.node_split_loads(
            pl, np.atleast_2d(np.asarray(loads, dtype=np.float64)))
        D = topo.rank_node_sizes.astype(np.float64)[None, :]     # (1, G)
        # incoming local tokens: (D-1)/D of them crossed an ICI link (the
        # rest originated on the receiving rank itself); cross-node tokens
        # all rode the DCN
        ici_b = local_in * (D - 1.0) / D * bpt                   # (L, G)
        dcn_b = cross_in * bpt
        per_rank = ici_b / topo.ici_bw + dcn_b / topo.dcn_bw
        self.ici_bytes += 2.0 * float(ici_b.sum())
        self.dcn_bytes += 2.0 * float(dcn_b.sum())
        hop = self.cluster.t_base + topo.dcn_latency
        return float((2.0 * per_rank.max(axis=1) + hop).sum())

    def _capacity_rank_loads(self, pl, loads: np.ndarray,
                             tokens: int) -> np.ndarray:
        """Fixed-bucket (moe_impl="capacity") compute pricing.

        The capacity kernel runs ``slots_per_rank × capacity`` rows on every
        rank — zero padding included — so per-rank compute is flat in the
        realized skew; what skew *does* change is the overflow, tallied into
        ``dropped_assignments`` (the artifact the ragged path removes)."""
        loads = np.atleast_2d(loads)
        n_slots = int(getattr(pl, "n_slots", self.E))
        cap = capacity_bucket_rows(tokens, self.model.top_k, n_slots,
                                   self.cfg.capacity_factor)
        share = getattr(pl, "share", None)
        if share is None:
            slot_load = loads                  # singleton: slot == expert
        else:
            slot_load = np.take_along_axis(
                pad_phantom_column(loads), pl.slot_expert, axis=1) * share
        self.dropped_assignments += float(
            np.maximum(slot_load - cap, 0.0).sum())
        if hasattr(pl, "rank_slot_budget"):
            # non-uniform budgets: each rank runs its own bucket count
            # (phantom slots allocate nothing)
            return pl.rank_slot_budget().astype(np.float64) * cap
        s_loc = max(n_slots // self.G, 1)
        return np.full((loads.shape[0], self.G), float(s_loc * cap))

    def step_time(self, tokens: int, ctx: float,
                  loads: Optional[np.ndarray] = None) -> float:
        """One synchronized forward pass over all layers."""
        if loads is None:
            loads = self._draw_loads(tokens)
        pl = self.placement
        # replica-aware dispatch: ReplicatedPlacement splits each expert's
        # tokens over its copies (speed-proportional shares); singleton
        # placements map expert→rank one-to-one. Same call either way.
        # ``realized_loads`` swaps the fractional split for the
        # token-granular one the model-layer dispatch actually produces.
        # ``moe_impl="capacity"`` instead prices the fixed-bucket kernel's
        # padded compute (+ overflow drop accounting).
        if self.cfg.moe_impl == "capacity":
            rank_load = self._capacity_rank_loads(pl, loads, tokens)
        else:
            rank_load = (realized_rank_loads(pl, loads)
                         if self.cfg.realized_loads
                         else pl.rank_loads(loads))              # (L, G)
        rank_time = rank_latency_matrix(self.cluster, rank_load, self.rng,
                                        t=self.now)
        layer_t = rank_time.max(axis=1)
        moe_t = float(layer_t.sum())
        self.rank_busy += rank_time.sum(axis=0)
        self.total_layer_time += moe_t
        self.total_barrier_idle += float(
            (layer_t[:, None] - rank_time).sum())
        if self.cfg.record_layer_stats:
            self.layer_stats.append(LayerStats(rank_time, rank_load))
        self.steps += 1

        topo = self.cfg.topology
        if topo is not None and not topo.is_flat:
            t = moe_t + self._hier_a2a(pl, loads)
        else:
            t = moe_t + self.L * self._a2a_time(tokens)
        t += self.model.n_layers * self._attn_time(tokens, ctx)
        t += self.cfg.step_overhead

        t += self.observe_step(loads, tokens, latencies=(rank_load, rank_time))
        return t

    def observe_step(self, tallies, tokens: float, latencies=None) -> float:
        """Feed one step's telemetry; returns migration-stall seconds.

        The unified observation surface (same shape as
        ``Engine.observe_step``). Performance-drift feed first (§4.2.4
        f_g refresh): the jittered per-rank ``latencies`` —
        ``(rank_load, rank_time)`` — ARE the serving telemetry a real
        deployment would measure. Then the routing feed (``tallies``,
        per-expert loads). Each can fire its own recalibration; both
        charge a migration stall (returned, so external callers can add
        it to their clock the way ``step_time`` does internally).
        """
        if self.controller is None:
            return 0.0
        stall = 0.0
        recalibrated = False
        if latencies is not None:
            rank_load, rank_time = latencies
            upd = self.controller.observe_latency(rank_load, rank_time)
            recalibrated |= upd is not None
            stall += self._account_update(upd, tokens)
        upd = self.controller.observe(tallies, tokens=float(tokens))
        recalibrated |= upd is not None
        stall += self._account_update(upd, tokens)
        rs = getattr(self.controller, "rescheduler", None)
        if rs is not None and rs.version != self._steal_version:
            if not recalibrated:
                # share-only steal update: the fleet syncs just the new
                # CDF table — no weights move (a recalibration's migration
                # stall already covers its own table rebuild)
                topo = self.cfg.topology
                if topo is not None:
                    stall += topo.broadcast_cost(rs.share_table_bytes)
                else:
                    bw = self.cfg.ici_bw or self.cluster.ici_bw
                    stall += rs.share_table_bytes / bw
                self.steal_updates += 1
            self._steal_version = rs.version
        return stall

    def _account_update(self, upd, tokens: int) -> float:
        """Migration stall (coordination + weight transfer) for one
        recalibration, or 0.0 when none fired."""
        if upd is None:
            return 0.0
        moved_bytes = upd.moved_experts * self.expert_bytes
        topo = self.cfg.topology
        if topo is not None:
            # G concurrent links; flat degenerate = bytes / (G * ici_bw),
            # exactly the legacy divide below
            xfer = topo.migration_cost(moved_bytes, parallel_links=self.G)
        else:
            bw = self.cfg.ici_bw or self.cluster.ici_bw
            xfer = moved_bytes / (self.G * bw)
        stall = self.cfg.migration_overhead + xfer
        self.migration_stalls.append((stall, float(tokens),
                                      upd.moved_experts))
        return stall

    # -- fault injection ----------------------------------------------------

    def inject_faults(self, schedule) -> None:
        """Arm a :class:`~repro.serving.faults.FaultSchedule`: the next
        ``run`` applies each spec once ``self.steps`` reaches its
        ``at_step``. Rank faults route through the controller's
        mask/unmask re-solve (migration stall charged like a
        recalibration); ``transient_stall`` composes with the live
        variability scenario; ``dcn_degrade`` shrinks ``cfg.topology``'s
        cross-node bandwidth for its duration. Infeasible specs are
        logged in ``fault_log``, never raised."""
        self._fault_queue = list(schedule.faults)
        self.fault_log = []
        self._dcn_restore = None

    def _poll_faults(self, t: float) -> float:
        """Apply due faults at step granularity; returns stall seconds."""
        if self._dcn_restore is not None and t >= self._dcn_restore[0]:
            self.cfg = self._dcn_restore[1]
            self._dcn_restore = None
        stall = 0.0
        while self._fault_queue and self._fault_queue[0].at_step <= self.steps:
            stall += self._apply_fault(self._fault_queue.pop(0), t)
        return stall

    def _flush_faults(self, t: float) -> None:
        """Drain the fault queue when traffic ends before the schedule
        does (same contract as the engine drill's flush): every fault is
        still exercised — a late ``rank_recover`` must restore the fleet
        even if the last request finished first — and any open DCN
        window is closed."""
        while self._fault_queue:
            self._apply_fault(self._fault_queue.pop(0), t)
        if self._dcn_restore is not None:
            self.cfg = self._dcn_restore[1]
            self._dcn_restore = None

    def _apply_fault(self, spec, t: float) -> float:
        ctl = self.controller
        if spec.kind in ("rank_fail", "rank_recover"):
            if ctl is None:
                self.fault_log.append((spec, "skipped: no controller"))
                return 0.0
            try:
                if spec.kind == "rank_fail":
                    if spec.rank in ctl.dead_ranks:
                        self.fault_log.append(
                            (spec, f"skipped: rank {spec.rank} already dead"))
                        return 0.0
                    if len(ctl.dead_ranks) + 1 >= ctl.G:
                        self.fault_log.append(
                            (spec, "skipped: would kill the last survivor"))
                        return 0.0
                    upd = ctl.mask_ranks(
                        tuple(set(ctl.dead_ranks) | {spec.rank}))
                else:
                    if spec.rank not in ctl.dead_ranks:
                        self.fault_log.append(
                            (spec, f"skipped: rank {spec.rank} is not dead"))
                        return 0.0
                    upd = ctl.unmask_ranks((spec.rank,))
            except ValueError as e:
                # e.g. a singleton policy that cannot tile the survivors
                self.fault_log.append((spec, f"skipped: {e}"))
                return 0.0
            self.fault_log.append((spec, "applied"))
            return self._account_update(upd, 0)
        if spec.kind == "transient_stall":
            self.cluster.events.append(VariabilityEvent(
                "transient", t_start=t, magnitude=spec.magnitude,
                device=spec.rank if spec.rank >= 0 else None,
                duration=spec.duration))
            self.fault_log.append((spec, "applied"))
            return 0.0
        # dcn_degrade
        topo = self.cfg.topology
        if topo is None:
            self.fault_log.append(
                (spec, "skipped: no fleet topology (flat pricing)"))
            return 0.0
        healthy = self.cfg if self._dcn_restore is None \
            else self._dcn_restore[1]
        self.cfg = dataclasses.replace(self.cfg, topology=dataclasses.replace(
            topo, dcn_bw=topo.dcn_bw * (1.0 - spec.magnitude)))
        self._dcn_restore = (t + spec.duration, healthy)
        self.fault_log.append((spec, "applied"))
        return 0.0

    # -- event loop (continuous batching, prefill-priority) ----------------

    def run(self, requests: Sequence[Request], phase: str = "mixed",
            drift_profile: Optional[np.ndarray] = None,
            drift_at: Optional[float] = None) -> List[RequestRecord]:
        """Serve a request trace. ``phase``: "mixed" | "prefill" | "decode".

        * prefill: paper's prefill isolation (long input, 1 output token).
        * decode:  warm prefix cache — prompt cost skipped (paper §5.1).
        * drift_profile/drift_at: swap the routing profile at a given time
          (the SG→SN / SN→SG transitions of §5.4).

        With ``cfg.scheduler`` set the loop is scheduler-driven
        (:meth:`_run_scheduled`): chunked prefill, SLO-aware ordering and
        optional paged-KV admission. ``cfg.scheduler=None`` keeps this
        legacy prefill-priority whole-prompt loop byte-for-byte.
        """
        if self.cfg.scheduler is not None:
            return self._run_scheduled(requests, phase, drift_profile,
                                       drift_at)
        recs = {r.req_id: RequestRecord(r.req_id, r.arrival, r.prompt_len,
                                        r.output_len, tenant=r.tenant)
                for r in requests}
        arrivals = collections.deque(sorted(requests, key=lambda r: r.arrival))
        waiting: collections.deque = collections.deque()
        running: List[List] = []      # [req, tokens_left, ctx]
        t = 0.0
        switched = False

        while arrivals or waiting or running:
            self.now = t                      # drift events key off this
            t += self._poll_faults(t)         # injected faults (chaos)
            self.now = t
            if drift_at is not None and not switched and t >= drift_at:
                self.profile = drift_profile
                switched = True
            # admit arrivals
            while arrivals and arrivals[0].arrival <= t:
                waiting.append(arrivals.popleft())
            if not waiting and not running:
                if arrivals:
                    t = arrivals[0].arrival
                    continue
                break

            if waiting:
                # prefill step: chunk of whole prompts under the token budget
                batch, toks = [], 0
                while waiting and (not batch or
                                   toks + waiting[0].prompt_len
                                   <= self.cfg.max_prefill_tokens):
                    r = waiting.popleft()
                    batch.append(r)
                    toks += r.prompt_len
                ctx = np.mean([r.prompt_len for r in batch]) / 2
                dt = (self.step_time(toks, ctx) if phase != "decode"
                      else self.cluster.t_base)
                t += dt
                for r in batch:
                    recs[r.req_id].first_token_at = t
                    if r.output_len <= 1 or phase == "prefill":
                        recs[r.req_id].finished_at = t
                    else:
                        running.append([r, r.output_len - 1, r.prompt_len])
                continue

            # decode step: one token for up to max_batch running seqs
            batch = running[:self.cfg.max_batch]
            toks = len(batch)
            ctx = float(np.mean([b[2] for b in batch]))
            dt = self.step_time(toks, ctx)
            t += dt
            done = []
            for b in batch:
                b[1] -= 1
                b[2] += 1
                if b[1] <= 0:
                    recs[b[0].req_id].finished_at = t
                    done.append(b)
            for b in done:
                running.remove(b)
        self._flush_faults(t)
        return list(recs.values())

    # -- event loop (scheduler-driven: chunked prefill, SLO ordering) -------

    def _run_scheduled(self, requests: Sequence[Request], phase: str,
                       drift_profile: Optional[np.ndarray],
                       drift_at: Optional[float]) -> List[RequestRecord]:
        """Scheduler-driven serving loop (``cfg.scheduler`` set).

        Per step a registered scheduler picks a prefill batch (all its
        chunks run in one synchronized step under the
        ``max_prefill_tokens`` budget, each priced at its own context
        depth) or a decode step. ``cfg.kv`` adds paged-KV admission:
        requests wait until the block pool can commit their full
        reservation. ``cfg.kv=None`` keeps admission unbounded (legacy).
        """
        sched_cfg = self.cfg.scheduler
        scheduler = get_scheduler(sched_cfg.name)
        kv = PagedKVCache(self.cfg.kv) if self.cfg.kv is not None else None
        recs = {r.req_id: RequestRecord(r.req_id, r.arrival, r.prompt_len,
                                        r.output_len, tenant=r.tenant)
                for r in requests}
        by_id = {r.req_id: r for r in requests}
        arrivals = collections.deque(sorted(requests,
                                            key=lambda r: r.arrival))
        waiting: collections.deque = collections.deque()
        prefilling: Dict[int, int] = {}   # req_id -> prompt tokens done
        running: List[List] = []          # [req, tokens_left, ctx]
        t = 0.0
        streak = 0
        switched = False

        while arrivals or waiting or prefilling or running:
            self.now = t
            t += self._poll_faults(t)         # injected faults (chaos)
            self.now = t
            if drift_at is not None and not switched and t >= drift_at:
                self.profile = drift_profile
                switched = True
            while arrivals and arrivals[0].arrival <= t:
                waiting.append(arrivals.popleft())
            if not waiting and not prefilling and not running:
                t = arrivals[0].arrival
                continue

            wviews = []
            for r in waiting:
                if kv is not None and not kv.can_admit(r.prompt_len
                                                       + r.output_len):
                    continue
                wviews.append(RequestView(r.req_id, r.arrival, r.prompt_len,
                                          r.output_len, 0, r.ttft_slo))
            pviews = [RequestView(by_id[i].req_id, by_id[i].arrival,
                                  by_id[i].prompt_len, by_id[i].output_len,
                                  done, by_id[i].ttft_slo)
                      for i, done in prefilling.items()]
            action = scheduler.schedule(SchedulerContext(
                now=t, config=sched_cfg, waiting=wviews, prefilling=pviews,
                n_running=len(running), prefill_streak=streak,
                can_start=len(wviews),
                chunk_budget=self.cfg.max_prefill_tokens))

            if action.kind == "prefill":
                # admission pass: earlier admissions in the same batch
                # shrink the pool, so re-check each new request against
                # the live allocator state (the view was a snapshot)
                chunks = []
                for c in action.chunks:
                    r = by_id[c.req_id]
                    if c.req_id not in prefilling:
                        if kv is not None and not kv.can_admit(
                                r.prompt_len + r.output_len):
                            continue
                        waiting.remove(r)
                        if kv is not None:
                            kv.allocate(r.req_id,
                                        r.prompt_len + r.output_len)
                        prefilling[c.req_id] = 0
                    chunks.append(c)
                if chunks:
                    # one synchronized step runs the whole chunk batch;
                    # each chunk priced at its own attention depth
                    toks = sum(c.n_tokens for c in chunks)
                    depths = [prefilling[c.req_id] + c.n_tokens / 2
                              for c in chunks]
                    ctx = float(np.mean(depths))
                    dt = (self.step_time(toks, ctx) if phase != "decode"
                          else self.cluster.t_base)
                    t += dt
                    for c in chunks:
                        r = by_id[c.req_id]
                        if kv is not None:
                            kv.advance(r.req_id, c.n_tokens)
                        prefilling[c.req_id] += c.n_tokens
                        if prefilling[c.req_id] >= r.prompt_len:
                            del prefilling[c.req_id]
                            recs[r.req_id].first_token_at = t
                            if r.output_len <= 1 or phase == "prefill":
                                recs[r.req_id].finished_at = t
                                if kv is not None:
                                    kv.free_seq(r.req_id)
                            else:
                                running.append([r, r.output_len - 1,
                                                r.prompt_len])
                    streak += 1
                    continue
                # every candidate lost admission since the snapshot —
                # behave as if the scheduler had answered decode/idle
                action = Action("decode") if running else Action("idle")

            if action.kind == "decode":
                batch = running[:self.cfg.max_batch]
                toks = len(batch)
                ctx = float(np.mean([b[2] for b in batch]))
                dt = self.step_time(toks, ctx)
                t += dt
                done = []
                for b in batch:
                    b[1] -= 1
                    b[2] += 1
                    if kv is not None:
                        kv.extend(b[0].req_id)
                    if b[1] <= 0:
                        recs[b[0].req_id].finished_at = t
                        done.append(b)
                        if kv is not None:
                            kv.free_seq(b[0].req_id)
                for b in done:
                    running.remove(b)
                streak = 0
                continue

            # idle: nothing runnable now — jump to the next arrival, or
            # give up if KV admission can never be satisfied (requests
            # too large for the pool with nothing in flight to free)
            if arrivals:
                t = arrivals[0].arrival
                continue
            break
        self._flush_faults(t)
        return list(recs.values())

    # -- summary helpers ----------------------------------------------------

    def utilization_spread(self) -> np.ndarray:
        """Per-rank busy-time share (Fig 10b frequency-uniformity proxy)."""
        total = self.rank_busy.sum()
        return self.rank_busy / total if total else self.rank_busy
