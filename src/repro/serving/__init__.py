# Serving substrate: workload generation, SLO metrics, the discrete-event
# multi-device EP simulator, and the JAX continuous-batching engine —
# configured through the unified ServingConfig hierarchy (config.py),
# scheduled by the pluggable scheduler registry (scheduler.py), admitted
# by the paged KV cache (kvcache.py), hardened by the fault-injection /
# chaos-drill harness (faults.py) + the elastic shrink/grow path
# (elastic.py).
from .config import (EngineConfig, KVCacheConfig, SchedulerConfig,
                     ServingConfig, SimConfig)
from .elastic import (FailureReport, RecoveryReport, fail_rank,
                      recover_rank, run_with_failure)
from .engine import Engine, EngineStats
from .faults import (FAULT_KINDS, ChaosReport, FaultInjector, FaultSchedule,
                     FaultSpec, chaos_invariants, run_chaos)
from .kvcache import BlockAllocator, PagedKVCache
from .metrics import PAPER_SLOS, SLO, RejectReason, RequestRecord, goodput, \
    per_tenant_ttft, slo_frontier, summarize
from .scheduler import (Action, Chunk, RequestView, Scheduler,
                        SchedulerContext, UnknownSchedulerError,
                        get_scheduler, register_scheduler,
                        registered_schedulers, shed_victims)
from .simulator import (EPSimulator, LayerStats, rank_latency_matrix,
                        realized_rank_loads)
from .workload import (TRACES, WORKLOADS, ArrivalSpec, Request, TenantSpec,
                       TraceSpec, WorkloadSpec, routing_profile,
                       sample_arrivals, sample_requests, sample_trace,
                       step_loads)

__all__ = [
    "EngineConfig", "KVCacheConfig", "SchedulerConfig", "ServingConfig",
    "SimConfig",
    "Engine", "EngineStats",
    "FailureReport", "RecoveryReport", "fail_rank", "recover_rank",
    "run_with_failure",
    "FAULT_KINDS", "ChaosReport", "FaultInjector", "FaultSchedule",
    "FaultSpec", "chaos_invariants", "run_chaos",
    "BlockAllocator", "PagedKVCache",
    "PAPER_SLOS", "SLO", "RejectReason", "RequestRecord", "goodput",
    "per_tenant_ttft", "slo_frontier", "summarize",
    "Action", "Chunk", "RequestView", "Scheduler", "SchedulerContext",
    "UnknownSchedulerError", "get_scheduler", "register_scheduler",
    "registered_schedulers", "shed_victims",
    "EPSimulator", "LayerStats", "rank_latency_matrix",
    "realized_rank_loads",
    "TRACES", "WORKLOADS", "ArrivalSpec", "Request", "TenantSpec",
    "TraceSpec", "WorkloadSpec", "routing_profile", "sample_arrivals",
    "sample_requests", "sample_trace", "step_loads",
]
