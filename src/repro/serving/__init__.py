# Serving substrate: workload generation, SLO metrics, the discrete-event
# multi-device EP simulator, and the JAX continuous-batching engine.
from .engine import Engine, EngineStats
from .metrics import PAPER_SLOS, SLO, RequestRecord, goodput, slo_frontier, \
    summarize
from .simulator import (EPSimulator, LayerStats, SimConfig,
                        rank_latency_matrix, realized_rank_loads)
from .workload import WORKLOADS, Request, WorkloadSpec, routing_profile, \
    sample_requests, step_loads

__all__ = [
    "Engine", "EngineStats",
    "PAPER_SLOS", "SLO", "RequestRecord", "goodput", "slo_frontier",
    "summarize",
    "EPSimulator", "LayerStats", "SimConfig", "rank_latency_matrix",
    "realized_rank_loads",
    "WORKLOADS", "Request", "WorkloadSpec", "routing_profile",
    "sample_requests", "step_loads",
]
