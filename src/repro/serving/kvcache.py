"""Paged/block KV cache: free-list allocator + watermark admission.

Replaces the implicit "dense ``max_batch × max_seq`` cache, capacity =
lane count" model: cache memory is a pool of fixed-size blocks, each
sequence owns a block table, and admission is gated by the pool's free
headroom — batch capacity is bounded by memory, not a hardcoded constant.

Admission is *committing*: a request reserves its full worst-case block
count up front (prompt + output, clamped to the engine's ``max_seq``), so
``extend`` during decode can never fail mid-request and no preemption
machinery is needed. The ``watermark`` fraction of the pool is held back
from admission as headroom.

On this single-device smoke host the physical JAX cache stays a dense
lane-indexed tensor (a real paged-attention kernel needs a device gather
per block); this module is the *memory accounting* layer that decides
what may run, and its invariants — a block is never double-assigned,
never leaked across request lifecycles — are pinned by property tests.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from .config import KVCacheConfig

__all__ = ["BlockAllocator", "PagedKVCache", "KVCacheConfig"]


class BlockAllocator:
    """LIFO free-list over ``n_blocks`` fixed-size blocks.

    LIFO keeps recently-freed (cache-warm) blocks hot. Double-frees and
    foreign blocks raise — silent corruption here would surface as
    cross-request KV reuse.
    """

    def __init__(self, n_blocks: int):
        if n_blocks < 1:
            raise ValueError(f"n_blocks must be >= 1, got {n_blocks}")
        self.n_blocks = n_blocks
        self._free: List[int] = list(range(n_blocks - 1, -1, -1))
        self._allocated: set = set()

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> List[int]:
        """Pop ``n`` blocks; raises if the pool cannot satisfy it."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} blocks")
        if n > len(self._free):
            raise MemoryError(f"KV pool exhausted: want {n} blocks, "
                              f"{len(self._free)} free of {self.n_blocks}")
        out = [self._free.pop() for _ in range(n)]
        self._allocated.update(out)
        return out

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            if b not in self._allocated:
                raise ValueError(f"double free / foreign block {b}")
            self._allocated.remove(b)
            self._free.append(b)


@dataclasses.dataclass
class _SeqAlloc:
    blocks: List[int]                # committed block table
    n_tokens: int = 0                # cache rows currently in use


class PagedKVCache:
    """Per-sequence block tables over one :class:`BlockAllocator`.

    Lifecycle: ``can_admit`` → ``allocate(seq_id, total_tokens)`` (commits
    the full reservation) → ``extend(seq_id)`` per decoded token (always
    succeeds inside the reservation) → ``free_seq(seq_id)``.
    """

    def __init__(self, config: KVCacheConfig):
        self.config = config
        self.allocator = BlockAllocator(config.n_blocks)
        self._seqs: Dict[int, _SeqAlloc] = {}
        self.peak_blocks = 0         # high-water mark (utilization stat)

    # -- admission ---------------------------------------------------------

    def _reserve_floor(self) -> int:
        """Blocks the watermark keeps out of admission's reach."""
        return int(self.config.n_blocks * self.config.watermark)

    def can_admit(self, total_tokens: int) -> bool:
        need = self.config.blocks_for(total_tokens)
        return self.allocator.n_free - self._reserve_floor() >= need

    def allocate(self, seq_id: int, total_tokens: int) -> List[int]:
        """Commit the full reservation for a sequence up front."""
        if seq_id in self._seqs:
            raise ValueError(f"seq {seq_id} already allocated")
        blocks = self.allocator.alloc(self.config.blocks_for(total_tokens))
        self._seqs[seq_id] = _SeqAlloc(blocks)
        self.peak_blocks = max(self.peak_blocks, self.used_blocks)
        return blocks

    # -- lifecycle ---------------------------------------------------------

    def advance(self, seq_id: int, n_tokens: int) -> None:
        """Mark ``n_tokens`` more cache rows in use (prefill chunk)."""
        s = self._seqs[seq_id]
        s.n_tokens += int(n_tokens)
        cap = len(s.blocks) * self.config.block_size
        if s.n_tokens > cap:
            raise ValueError(f"seq {seq_id} overran its reservation "
                             f"({s.n_tokens} > {cap} rows)")

    def extend(self, seq_id: int) -> None:
        """One decoded token; always inside the committed reservation."""
        self.advance(seq_id, 1)

    def free_seq(self, seq_id: int) -> None:
        s = self._seqs.pop(seq_id)
        self.allocator.free(s.blocks)

    # -- stats -------------------------------------------------------------

    @property
    def used_blocks(self) -> int:
        return self.config.n_blocks - self.allocator.n_free

    @property
    def n_seqs(self) -> int:
        return len(self._seqs)

    def utilization(self) -> float:
        return self.used_blocks / self.config.n_blocks
