"""Pluggable continuous-batching schedulers (SLO-aware serving loop).

Mirrors the placement-policy registry (``repro.core.policy``): a scheduler
is any object with a ``name`` and a ``schedule(ctx) -> Action`` method;
registering it exposes it to the engine, the simulator's scheduled loop,
``launch/serve.py --scheduler`` and every benchmark at once.

Per step the serving loop builds a :class:`SchedulerContext` — who is
waiting (arrived, not admitted), who is mid-prefill, how many sequences
are decoding, how many new admissions the KV pool + lane budget allow —
and the scheduler answers with an :class:`Action`: a list of prefill
:class:`Chunk` s to run (respecting ``ctx.chunk_budget``), a decode step,
or idle. Built-ins:

* ``fcfs``            — prefill-priority in arrival order; with
  ``prefill_chunk = 0`` this replicates the legacy engine loop exactly.
* ``slo_edf``         — earliest-deadline-first over TTFT deadlines
  (``arrival + ttft_slo``), with a decode-starvation bound: after
  ``decode_starvation_bound`` consecutive prefill steps a decode step is
  forced whenever sequences are running (property-tested).
* ``decode_priority`` — decode whenever anything runs; prefill only on an
  empty decode batch (the TPOT-protective extreme).

Registering a custom scheduler::

    from repro.serving.scheduler import Action, register_scheduler

    @register_scheduler
    class MyScheduler:
        name = "mine"
        def schedule(self, ctx):
            ...
            return Action("decode")
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Protocol, Tuple, runtime_checkable

from .config import SchedulerConfig

__all__ = [
    "RequestView", "Chunk", "Action", "SchedulerContext", "Scheduler",
    "UnknownSchedulerError", "register_scheduler", "get_scheduler",
    "registered_schedulers", "shed_victims",
]


@dataclasses.dataclass(frozen=True)
class RequestView:
    """What a scheduler may know about one request."""

    req_id: int
    arrival: float
    prompt_len: int
    output_len: int
    prefilled: int = 0               # prompt tokens already in the cache
    ttft_slo: Optional[float] = None # per-request override (multi-tenant)

    @property
    def remaining(self) -> int:
        return self.prompt_len - self.prefilled

    def deadline(self, default_slo: float) -> float:
        return self.arrival + (self.ttft_slo if self.ttft_slo is not None
                               else default_slo)

    def headroom(self, now: float, default_slo: float) -> float:
        """Seconds until (negative: since) this request's TTFT deadline —
        the load-shedding priority key (lowest headroom sheds first)."""
        return self.deadline(default_slo) - now


@dataclasses.dataclass(frozen=True)
class Chunk:
    """One prefill slice: ``n_tokens`` of request ``req_id``'s prompt."""

    req_id: int
    n_tokens: int


@dataclasses.dataclass(frozen=True)
class Action:
    kind: str                        # "prefill" | "decode" | "idle"
    chunks: Tuple[Chunk, ...] = ()

    def __post_init__(self):
        if self.kind not in ("prefill", "decode", "idle"):
            raise ValueError(f"unknown action kind {self.kind!r}")
        if self.kind == "prefill" and not self.chunks:
            raise ValueError("prefill action needs at least one chunk")


@dataclasses.dataclass
class SchedulerContext:
    """One step's scheduling state, as the serving loop sees it."""

    now: float
    config: SchedulerConfig
    waiting: List[RequestView]       # arrived, unadmitted (arrival order)
    prefilling: List[RequestView]    # admitted, prompt partially in cache
    n_running: int                   # sequences in the decode batch
    prefill_streak: int              # consecutive prefill steps so far
    can_start: int                   # new admissions allowed (lanes + KV)
    chunk_budget: int                # prefill tokens allowed this step
    blocked: List[RequestView] = dataclasses.field(default_factory=list)
    #                                  waiting but NOT KV-admissible right
    #                                  now (the shed/preempt candidates)
    kv_utilization: float = 0.0      # used / total KV blocks this step

    def build_chunks(self, ordered: List[RequestView]) -> Tuple[Chunk, ...]:
        """Greedy chunk packing over ``ordered`` candidates.

        Each candidate contributes one chunk of ``config.prefill_chunk``
        tokens (0 = its whole remaining prompt), until ``chunk_budget`` is
        spent. New (unprefilled) requests count against ``can_start``.
        """
        chunks: List[Chunk] = []
        budget = self.chunk_budget
        starts = self.can_start
        for v in ordered:
            if v.remaining <= 0:
                continue
            if v.prefilled == 0:
                if starts <= 0:
                    continue
            size = v.remaining if self.config.prefill_chunk <= 0 \
                else min(self.config.prefill_chunk, v.remaining)
            if chunks and size > budget:
                break
            if v.prefilled == 0:
                starts -= 1
            chunks.append(Chunk(v.req_id, size))
            budget -= size
            if budget <= 0:
                break
        return tuple(chunks)


@runtime_checkable
class Scheduler(Protocol):
    """Protocol every registered scheduler satisfies."""

    name: str

    def schedule(self, ctx: SchedulerContext) -> Action:
        ...


class UnknownSchedulerError(ValueError):
    """Raised for a scheduler name absent from the registry."""


_REGISTRY: Dict[str, Scheduler] = {}


def register_scheduler(sched, *, replace: bool = False):
    """Add a scheduler to the registry; usable as a class decorator."""
    inst = sched() if isinstance(sched, type) else sched
    name = getattr(inst, "name", "")
    if not name or not isinstance(name, str):
        raise ValueError("scheduler needs a non-empty string .name")
    if not isinstance(inst, Scheduler):
        raise TypeError(f"{name!r} does not satisfy the Scheduler protocol "
                        "(name/schedule)")
    if name in _REGISTRY and not replace:
        raise ValueError(f"scheduler {name!r} already registered "
                         "(pass replace=True to override)")
    _REGISTRY[name] = inst
    return sched


def get_scheduler(name: str) -> Scheduler:
    """Registry lookup; unknown names list what *is* registered."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownSchedulerError(
            f"unknown scheduler {name!r}; registered schedulers: "
            f"{', '.join(registered_schedulers())}") from None


def registered_schedulers() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# built-in schedulers
# ---------------------------------------------------------------------------

@register_scheduler
class FcfsScheduler:
    """Prefill-priority, arrival order — the legacy engine loop as a
    policy. Mid-prefill requests finish before new admissions."""

    name = "fcfs"

    def schedule(self, ctx: SchedulerContext) -> Action:
        chunks = ctx.build_chunks(list(ctx.prefilling) + list(ctx.waiting))
        if chunks:
            return Action("prefill", chunks)
        if ctx.n_running > 0:
            return Action("decode")
        return Action("idle")


@register_scheduler
class SloEdfScheduler:
    """Earliest-TTFT-deadline-first prefill with a decode-starvation bound.

    Prefill candidates (mid-prefill and admissible waiting alike) are
    ordered by ``arrival + ttft_slo``; after ``decode_starvation_bound``
    consecutive prefill steps, a decode step is forced whenever sequences
    are running, so TPOT can never be starved indefinitely by a deep
    prefill backlog.
    """

    name = "slo_edf"

    def schedule(self, ctx: SchedulerContext) -> Action:
        cfg = ctx.config
        if ctx.n_running > 0 \
                and ctx.prefill_streak >= cfg.decode_starvation_bound:
            return Action("decode")
        cand = sorted(list(ctx.prefilling) + list(ctx.waiting),
                      key=lambda v: (v.deadline(cfg.ttft_slo), v.arrival,
                                     v.req_id))
        chunks = ctx.build_chunks(cand)
        if chunks:
            return Action("prefill", chunks)
        if ctx.n_running > 0:
            return Action("decode")
        return Action("idle")


# ---------------------------------------------------------------------------
# overload protection: watermark load shedding
# ---------------------------------------------------------------------------

def shed_victims(ctx: SchedulerContext) -> Tuple[int, ...]:
    """Watermark-based load-shedding policy: req_ids to reject this step.

    Fires only when ``config.shed_watermark > 0`` and KV-pool utilization
    has reached it. Victims are the not-yet-admitted requests (admissible
    and KV-blocked alike) whose TTFT deadline has already lapsed — they
    cannot meet their SLO even if admitted immediately, so under memory
    pressure completing them only delays requests that still can. Ordered
    lowest-SLO-headroom first, so the engine rejects the most hopeless
    work first when it caps how much to shed. Mid-prefill requests are
    never shed here (their KV investment is the engine's preemption
    problem, not admission's).
    """
    wm = ctx.config.shed_watermark
    if wm <= 0.0 or ctx.kv_utilization < wm:
        return ()
    cand = [(v.headroom(ctx.now, ctx.config.ttft_slo), v.req_id)
            for v in list(ctx.waiting) + list(ctx.blocked)]
    return tuple(req_id for h, req_id in sorted(cand) if h <= 0.0)


@register_scheduler
class DecodePriorityScheduler:
    """Decode whenever anything runs; prefill only on an empty decode
    batch. Protects TPOT at the cost of TTFT under sustained load."""

    name = "decode_priority"

    def schedule(self, ctx: SchedulerContext) -> Action:
        if ctx.n_running > 0:
            return Action("decode")
        chunks = ctx.build_chunks(list(ctx.prefilling) + list(ctx.waiting))
        if chunks:
            return Action("prefill", chunks)
        return Action("idle")
