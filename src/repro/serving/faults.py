"""Declarative fault injection + the chaos drill harness.

A production fleet does not fail on cue: devices die, recover, stall,
and the cross-node fabric degrades — often while the workload itself is
bursting. This module turns those hazards into *declarative, seed-
deterministic schedules* so robustness is a regression-testable property
instead of an incident report:

* :class:`FaultSpec` — one fault: ``kind`` ∈ {``rank_fail``,
  ``rank_recover``, ``transient_stall``, ``dcn_degrade``}, fired when the
  serving loop reaches ``at_step`` engine steps.
* :class:`FaultSchedule` — an ordered bundle of specs.
  :meth:`FaultSchedule.default` draws a randomized-but-reproducible
  drill (fail → stall → DCN brownout → recover) from a seed;
  :meth:`FaultSchedule.parse` reads the compact CLI DSL used by
  ``serve --chaos`` (``fail@4:1,stall@6:2x0.4+0.5,recover@9:1``).
* :class:`FaultInjector` — applies due faults to a live
  :class:`~repro.serving.engine.Engine` between steps. ``rank_fail`` /
  ``rank_recover`` route through the elastic shrink/grow path
  (:func:`~repro.serving.elastic.fail_rank` /
  :func:`~repro.serving.elastic.recover_rank`); ``transient_stall``
  appends a ``transient`` :class:`~repro.core.variability.VariabilityEvent`
  to the live :class:`~repro.core.variability.ClusterVariability` — it
  *composes* with any pre-scheduled variability scenario, both virtual
  clocks price it; ``dcn_degrade`` temporarily shrinks the topology's
  cross-node bandwidth (restored on the virtual clock after
  ``duration``). Infeasible faults (failing the last survivor,
  recovering a live rank) are skipped and logged, never raised — a chaos
  schedule must not crash the drill it is stressing.
* :func:`run_chaos` — the drill: serve a trace under a schedule, then
  check the **chaos invariants** on the quiesced engine:

  1. zero leaked KV blocks (``used_blocks == 0 and n_seqs == 0``),
  2. every submitted request finished *or* carries a typed
     :class:`~repro.serving.metrics.RejectReason`,
  3. token conservation — ``prefill_tokens + decode_tokens ==
     useful_tokens + lost_tokens`` on the engine ledger,
  4. metric sanity — every finished request has a finite, non-negative
     TTFT.

``launch/serve.py --chaos`` and the CI smoke lane run this end to end;
``benchmarks/bench_fig_chaos.py`` gates the degraded-goodput floor.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.variability import VariabilityEvent

from .engine import Engine
from .metrics import RequestRecord
from .workload import Request

__all__ = ["FAULT_KINDS", "FaultSpec", "FaultSchedule", "FaultInjector",
           "ChaosReport", "chaos_invariants", "run_chaos"]

#: the fault vocabulary, with the CLI DSL aliases in parse().
FAULT_KINDS = ("rank_fail", "rank_recover", "transient_stall", "dcn_degrade")

_KIND_ALIASES = {"fail": "rank_fail", "recover": "rank_recover",
                 "stall": "transient_stall", "dcn": "dcn_degrade"}

#: DSL grammar: kind@step[:rank][xMAG][+DUR]  e.g. stall@6:2x0.4+0.5
_SPEC_RE = re.compile(
    r"^(?P<kind>[a-z_]+)@(?P<step>\d+)"
    r"(?::(?P<rank>\d+))?"
    r"(?:x(?P<mag>[0-9.]+))?"
    r"(?:\+(?P<dur>[0-9.]+))?$")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One declarative fault, fired at ``at_step`` serving-loop steps.

    ``rank`` targets one EP rank (required for ``rank_fail`` /
    ``rank_recover``; optional for ``transient_stall``, where ``-1``
    means fleet-wide; ignored by ``dcn_degrade``). ``magnitude`` is the
    fractional slowdown (stall) or fractional DCN-bandwidth loss
    (degrade); ``duration`` is the hazard window in virtual seconds for
    the two transient kinds.
    """

    kind: str
    at_step: int
    rank: int = -1
    magnitude: float = 0.5
    duration: float = 0.5

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.at_step < 0:
            raise ValueError(f"at_step must be >= 0, got {self.at_step}")
        if self.kind in ("rank_fail", "rank_recover") and self.rank < 0:
            raise ValueError(f"{self.kind} needs a target rank")
        if self.kind in ("transient_stall", "dcn_degrade"):
            if not 0.0 < self.magnitude < 1.0:
                raise ValueError(f"{self.kind} magnitude must be in (0, 1), "
                                 f"got {self.magnitude}")
            if self.duration <= 0.0:
                raise ValueError(f"{self.kind} duration must be > 0, "
                                 f"got {self.duration}")


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """An ordered (by ``at_step``) bundle of :class:`FaultSpec` s."""

    faults: Tuple[FaultSpec, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(
            sorted(self.faults, key=lambda f: f.at_step)))

    def __len__(self) -> int:
        return len(self.faults)

    @classmethod
    def default(cls, n_ranks: int, seed: int = 0) -> "FaultSchedule":
        """Seed-deterministic randomized drill: one rank fails early, a
        *different* rank stalls, the DCN browns out, and the failed rank
        recovers — never killing the whole fleet. Same ``(n_ranks,
        seed)`` → same schedule, so CI chaos runs are reproducible."""
        if n_ranks < 2:
            raise ValueError("default chaos schedule needs >= 2 ranks "
                             "(it fails one and keeps serving)")
        rng = np.random.default_rng(seed)
        victim = int(rng.integers(0, n_ranks))
        fail_at = int(rng.integers(3, 7))
        stall_rank = (victim + 1 + int(rng.integers(0, n_ranks - 1))) \
            % n_ranks
        return cls((
            FaultSpec("rank_fail", fail_at, rank=victim),
            FaultSpec("transient_stall", fail_at + 1 + int(rng.integers(0, 3)),
                      rank=stall_rank,
                      magnitude=0.3 + 0.2 * float(rng.random()),
                      duration=0.3 + 0.5 * float(rng.random())),
            FaultSpec("dcn_degrade", fail_at + 2 + int(rng.integers(0, 3)),
                      magnitude=0.5,
                      duration=0.5 + 0.5 * float(rng.random())),
            FaultSpec("rank_recover", fail_at + 6 + int(rng.integers(0, 4)),
                      rank=victim),
        ))

    @classmethod
    def parse(cls, spec: str, n_ranks: int) -> "FaultSchedule":
        """Parse the ``--chaos`` CLI value.

        ``"default"`` / ``"default:SEED"`` draw :meth:`default`;
        otherwise a comma-separated DSL, one fault per item::

            fail@4:1               kill rank 1 at step 4
            recover@9:1            bring rank 1 back at step 9
            stall@6:2x0.4+0.5      rank 2 runs 40% slow for 0.5 s
            dcn@7x0.5+0.8          DCN bandwidth halves for 0.8 s
        """
        spec = spec.strip()
        if spec == "default":
            return cls.default(n_ranks)
        m = re.fullmatch(r"default:(\d+)", spec)
        if m:
            return cls.default(n_ranks, seed=int(m.group(1)))
        faults: List[FaultSpec] = []
        for item in spec.split(","):
            item = item.strip()
            if not item:
                continue
            m = _SPEC_RE.fullmatch(item)
            if m is None:
                raise ValueError(
                    f"bad fault spec {item!r}; expected "
                    "kind@step[:rank][xMAG][+DUR], e.g. fail@4:1 or "
                    "stall@6:2x0.4+0.5")
            kind = _KIND_ALIASES.get(m.group("kind"), m.group("kind"))
            kw: dict = {}
            if m.group("rank") is not None:
                kw["rank"] = int(m.group("rank"))
            if m.group("mag") is not None:
                kw["magnitude"] = float(m.group("mag"))
            if m.group("dur") is not None:
                kw["duration"] = float(m.group("dur"))
            faults.append(FaultSpec(kind, int(m.group("step")), **kw))
        if not faults:
            raise ValueError("empty chaos schedule")
        return cls(tuple(faults))


class FaultInjector:
    """Applies a :class:`FaultSchedule` to a live engine between steps.

    ``poll`` fires every spec whose ``at_step`` the engine has reached;
    ``flush`` fires everything still pending (the drill uses it when the
    queue drains before the schedule does, so every fault is exercised);
    ``finish`` restores any still-open DCN degradation window. Each
    applied fault lands in ``applied`` (spec, result) and each infeasible
    one in ``skipped`` (spec, reason) — chaos must not crash the system
    it is stressing.
    """

    def __init__(self, schedule: FaultSchedule):
        self._pending: List[FaultSpec] = list(schedule.faults)
        self.applied: List[Tuple[FaultSpec, Any]] = []
        self.skipped: List[Tuple[FaultSpec, str]] = []
        # open dcn_degrade window: (virtual-time expiry, healthy config)
        self._dcn_restore: Optional[Tuple[float, Any]] = None

    def pending(self) -> bool:
        return bool(self._pending)

    def poll(self, engine: Engine) -> None:
        """Apply every fault due at the engine's current step count."""
        self._expire_dcn(engine)
        while self._pending \
                and self._pending[0].at_step <= engine.stats.steps:
            self._apply(engine, self._pending.pop(0))

    def flush(self, engine: Engine) -> None:
        """Apply every remaining fault regardless of step count."""
        while self._pending:
            self._apply(engine, self._pending.pop(0))
        self._expire_dcn(engine)

    def finish(self, engine: Engine) -> None:
        """Close any open DCN window (drill teardown)."""
        if self._dcn_restore is not None:
            engine.config = self._dcn_restore[1]
            self._dcn_restore = None

    # -- application --------------------------------------------------------

    def _expire_dcn(self, engine: Engine) -> None:
        if self._dcn_restore is not None \
                and engine.stats.virtual_time >= self._dcn_restore[0]:
            engine.config = self._dcn_restore[1]
            self._dcn_restore = None

    def _apply(self, engine: Engine, spec: FaultSpec) -> None:
        try:
            if spec.kind == "rank_fail":
                self._apply_fail(engine, spec)
            elif spec.kind == "rank_recover":
                self._apply_recover(engine, spec)
            elif spec.kind == "transient_stall":
                self._apply_stall(engine, spec)
            else:
                self._apply_dcn(engine, spec)
        except ValueError as e:
            # infeasible under the current fleet state — log, don't crash
            self.skipped.append((spec, str(e)))

    def _apply_fail(self, engine: Engine, spec: FaultSpec) -> None:
        from .elastic import fail_rank
        ctl = engine.controller
        if ctl is None:
            self.skipped.append((spec, "no controller"))
            return
        if spec.rank in ctl.dead_ranks:
            self.skipped.append((spec, f"rank {spec.rank} already dead"))
            return
        if len(ctl.dead_ranks) + 1 >= ctl.G:
            self.skipped.append((spec, "would kill the last survivor"))
            return
        self.applied.append((spec, fail_rank(engine, spec.rank)))

    def _apply_recover(self, engine: Engine, spec: FaultSpec) -> None:
        from .elastic import recover_rank
        ctl = engine.controller
        if ctl is None:
            self.skipped.append((spec, "no controller"))
            return
        if spec.rank not in ctl.dead_ranks:
            self.skipped.append((spec, f"rank {spec.rank} is not dead"))
            return
        self.applied.append((spec, recover_rank(engine, spec.rank)))

    def _apply_stall(self, engine: Engine, spec: FaultSpec) -> None:
        if engine.cluster is None:
            self.skipped.append((spec, "no cluster variability model"))
            return
        ev = VariabilityEvent(
            "transient", t_start=engine.stats.virtual_time,
            magnitude=spec.magnitude,
            device=spec.rank if spec.rank >= 0 else None,
            duration=spec.duration)
        # events is the live schedule both virtual clocks consult — the
        # injected stall composes with any pre-scheduled scenario
        engine.cluster.events.append(ev)
        self.applied.append((spec, ev))

    def _apply_dcn(self, engine: Engine, spec: FaultSpec) -> None:
        topo = engine.config.topology
        if topo is None:
            self.skipped.append((spec, "no fleet topology (flat pricing)"))
            return
        if self._dcn_restore is None:
            healthy = engine.config
        else:
            # stacked windows: keep the original healthy config, extend
            healthy = self._dcn_restore[1]
        degraded = dataclasses.replace(
            topo, dcn_bw=topo.dcn_bw * (1.0 - spec.magnitude))
        engine.config = dataclasses.replace(engine.config, topology=degraded)
        self._dcn_restore = (
            engine.stats.virtual_time + spec.duration, healthy)
        self.applied.append((spec, degraded))


# ---------------------------------------------------------------------------
# the drill
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ChaosReport:
    """What one chaos drill did and whether the invariants held."""

    applied: List[Tuple[FaultSpec, Any]]
    skipped: List[Tuple[FaultSpec, str]]
    records: List[RequestRecord]
    violations: List[str]
    steps: int = 0

    @property
    def ok(self) -> bool:
        return not self.violations

    def summary(self) -> str:
        kinds = ",".join(s.kind for s, _ in self.applied) or "none"
        return (f"chaos: {len(self.applied)} faults applied [{kinds}], "
                f"{len(self.skipped)} skipped, "
                f"{len(self.violations)} violations")


def chaos_invariants(engine: Engine) -> List[str]:
    """Check the post-drill invariants on a quiesced engine; returns the
    violations (empty = healthy). See the module docstring for the list."""
    violations: List[str] = []
    kv = engine.kv
    if kv.used_blocks != 0 or kv.n_seqs != 0:
        violations.append(
            f"leaked KV: {kv.used_blocks} blocks / {kv.n_seqs} seqs still "
            "held after quiesce")
    st = engine.stats
    processed = st.prefill_tokens + st.decode_tokens
    accounted = st.useful_tokens + st.lost_tokens
    if processed != accounted:
        violations.append(
            f"token ledger broken: prefill+decode={processed} != "
            f"useful+lost={accounted} "
            f"(prefill={st.prefill_tokens} decode={st.decode_tokens} "
            f"useful={st.useful_tokens} lost={st.lost_tokens})")
    for rec in engine.records.values():
        finished = np.isfinite(rec.finished_at)
        if not finished and not rec.rejected:
            violations.append(
                f"request {rec.req_id} neither finished nor carries a "
                "typed rejection")
        if finished and not (np.isfinite(rec.ttft) and rec.ttft >= 0):
            violations.append(
                f"request {rec.req_id} finished with insane TTFT "
                f"{rec.ttft!r}")
    return violations


def run_chaos(engine: Engine, requests: Sequence[Request],
              schedule: FaultSchedule, max_steps: int = 20_000,
              ) -> ChaosReport:
    """Serve ``requests`` under ``schedule``, then audit the invariants.

    The drill never raises on a fault the fleet state makes infeasible —
    those are logged in ``ChaosReport.skipped``. If the queue drains
    before the schedule does, the remaining faults are flushed and the
    engine gets another chance to run (a flushed ``rank_fail`` requeues
    drained work).
    """
    injector = FaultInjector(schedule)
    engine.submit(list(requests))
    steps = 0
    while steps < max_steps:
        injector.poll(engine)
        if not engine.step():
            if injector.pending():
                injector.flush(engine)
                if engine.step():
                    steps += 1
                    continue
            break
        steps += 1
    injector.finish(engine)
    return ChaosReport(applied=injector.applied, skipped=injector.skipped,
                       records=list(engine.records.values()),
                       violations=chaos_invariants(engine), steps=steps)
