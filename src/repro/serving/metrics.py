"""SLO metrics: TTFT/TPOT percentiles, goodput, sustainable QPS (paper §5.1).

Goodput = rate of SLO-compliant requests (both TTFT and TPOT within their
thresholds) — the paper's primary quality-of-service metric, with the 90%
compliance target defining the sustainable-QPS frontier.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Sequence

import numpy as np

__all__ = ["SLO", "RejectReason", "RequestRecord", "summarize", "goodput",
           "slo_frontier", "per_tenant_ttft", "PAPER_SLOS"]


class RejectReason(enum.Enum):
    """Typed admission/overload rejection causes (engine ``submit`` + the
    shedding path). A rejected request is *not* an engine bug: it carries
    its reason on the :class:`RequestRecord` so the chaos-drill invariant
    "every submitted request completes **or** is rejected with a typed
    reason" is checkable, and ``EngineStats.rejected`` tallies by reason
    for the ``serve`` summary line."""

    TOO_LONG = "too_long"        # prompt_len exceeds the engine's max_seq
    NEVER_FITS = "never_fits"    # worst-case KV reservation exceeds the
    #                              admissible pool (would wait forever)
    SHED = "shed"                # overload: load-shedding dropped it under
    #                              KV-pool pressure (watermark breach)


@dataclasses.dataclass(frozen=True)
class SLO:
    ttft: float                    # seconds
    tpot: float                    # seconds/token


#: Paper Table 2b thresholds.
PAPER_SLOS: Dict[tuple, SLO] = {
    ("sharegpt", "deepseek-v3-671b"): SLO(0.250, 0.125),
    ("sharegpt", "qwen3-moe-235b-a22b"): SLO(0.250, 0.100),
    ("sonnet", "deepseek-v3-671b"): SLO(0.350, 0.125),
    ("sonnet", "qwen3-moe-235b-a22b"): SLO(0.350, 0.100),
}


@dataclasses.dataclass
class RequestRecord:
    req_id: int
    arrival: float
    prompt_len: int
    output_len: int
    first_token_at: float = float("nan")
    finished_at: float = float("nan")
    tenant: str = ""               # workload tenant tag ("" = untagged)
    reject_reason: Optional[RejectReason] = None   # None = never rejected
    preemptions: int = 0           # decode evictions under KV pressure
    requeues: int = 0              # total trips back to the waiting queue
    #                                (rank-failure drains + preemptions) —
    #                                the bounded-retry/backoff ledger

    @property
    def rejected(self) -> bool:
        return self.reject_reason is not None

    @property
    def ttft(self) -> float:
        return self.first_token_at - self.arrival

    @property
    def tpot(self) -> float:
        # output_len == 1 means the prefill's argmax IS the full response:
        # zero decode steps, so the per-output-token latency is 0 by
        # definition (a division by output_len - 1 would be 0/0 here)
        if self.output_len <= 1:
            return 0.0
        return (self.finished_at - self.first_token_at) / (self.output_len - 1)

    def meets(self, slo: SLO) -> bool:
        return (np.isfinite(self.ttft) and self.ttft <= slo.ttft
                and self.tpot <= slo.tpot)


def _pct(xs: np.ndarray, p: float) -> float:
    return float(np.percentile(xs, p)) if xs.size else float("nan")


def summarize(records: Sequence[RequestRecord]) -> Dict[str, float]:
    ttft = np.array([r.ttft for r in records if np.isfinite(r.ttft)])
    tpot = np.array([r.tpot for r in records if np.isfinite(r.tpot)])
    return {
        "n": len(records),
        "n_rejected": sum(1 for r in records if r.rejected),
        "ttft_p50": _pct(ttft, 50), "ttft_p90": _pct(ttft, 90),
        "ttft_p99": _pct(ttft, 99),
        "tpot_p50": _pct(tpot, 50), "tpot_p90": _pct(tpot, 90),
        "tpot_p99": _pct(tpot, 99),
    }


def per_tenant_ttft(records: Sequence[RequestRecord],
                    percentile: float = 90.0) -> Dict[str, float]:
    """Per-tenant TTFT percentile — the multi-tenant fairness view.

    Groups records by their ``tenant`` tag and reports the requested TTFT
    percentile per group (unfinished requests, NaN TTFT, are excluded the
    same way :func:`summarize` excludes them). The aggregation is a pure
    function of each tenant's TTFT *multiset*, so it is invariant to
    record order — pinned by a property test."""
    by_tenant: Dict[str, List[float]] = {}
    for r in records:
        if np.isfinite(r.ttft):
            by_tenant.setdefault(r.tenant, []).append(r.ttft)
    return {t: _pct(np.array(xs), percentile)
            for t, xs in by_tenant.items()}


def goodput(records: Sequence[RequestRecord], slo: SLO) -> float:
    """Fraction of requests meeting both SLO thresholds."""
    if not records:
        return 0.0
    return float(np.mean([r.meets(slo) for r in records]))


def slo_frontier(qps_to_goodput: Dict[float, float],
                 target: float = 0.90) -> float:
    """Max sustainable QPS holding ≥ target goodput (linear interpolation).

    "Sustainable" means the piecewise-linear goodput curve stays ≥ target
    at every rate up to the frontier, so the frontier is the *first*
    downward crossing: if goodput dips below target anywhere in the sweep,
    higher sampled rates do not extend the frontier even when a later
    (non-monotone / noisy) sample pops back above target — previously such
    a dip between non-adjacent above-target samples was sailed past and
    the recovery point reported instead. Curves that never drop below the
    target yield the largest sampled QPS; curves already below it at the
    lowest sampled QPS yield 0.
    """
    pts = sorted(qps_to_goodput.items())
    if not pts or pts[0][1] < target:
        return 0.0
    for (q0, g0), (q, g) in zip(pts, pts[1:]):
        if g < target:
            # first downward crossing: g0 ≥ target > g (g0 > g follows)
            return q0 + (q - q0) * (g0 - target) / (g0 - g)
    return pts[-1][0]
