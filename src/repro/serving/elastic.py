"""Live serving elasticity: survive losing an EP rank mid-traffic.

A fleet-scale deployment loses devices — hardware faults, preemptions,
scheduled node drains. The elastic path keeps the engine serving through
the loss instead of crashing or leaking state:

1. **Drain** — every in-flight lane the dead rank owned (lane ``b`` is
   owned by rank ``b % G``: its KV shard lives there) is torn down: the
   KV blocks go back to the pool, the request goes back to the head of
   the waiting queue (its :class:`~repro.serving.metrics.RequestRecord`
   persists, so TTFT keeps measuring from the *original* first token).
2. **Re-solve** — :meth:`ViBEController.mask_ranks` marks the rank dead
   and runs a topology-masked full solve over the survivors: the dead
   rank's window becomes all-phantom zero-share slots, so dispatch stops
   sending it tokens while the slot-table geometry (and the compiled step
   functions) stay put.
3. **Remap** — the engine applies the survivor placement through the
   normal migration path (``_apply_perm``), so the weight-shuffle stall
   is priced on the virtual clock exactly like a recalibration
   (topology-aware when ``EngineConfig.topology`` is set).
4. **Re-admit** — the drained requests flow back through the paged-KV
   admission gate and re-prefill on the survivor fleet.

The result is a bounded goodput dip rather than an outage: every admitted
request still completes (pinned by ``tests/test_serving_elastic.py``
together with the no-leaked-KV-blocks invariant), at the price of the
redone prefill/decode tokens tallied in :class:`FailureReport`.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple


from .engine import Engine
from .metrics import RequestRecord
from .workload import Request

__all__ = ["FailureReport", "RecoveryReport", "fail_rank", "recover_rank",
           "run_with_failure"]


@dataclasses.dataclass
class FailureReport:
    """What one injected rank failure cost the serving fleet."""

    rank: int                        # the rank that died
    at_time: float                   # virtual-clock time of the failure
    drained_prefills: int            # in-flight prefills torn down
    drained_decodes: int             # decode lanes torn down
    redone_tokens: int               # prefill+decode tokens to be replayed
    moved_experts: int               # slots migrated by the survivor solve
    migration_bytes: int             # weight bytes the remap shipped


def fail_rank(engine: Engine, rank: int) -> FailureReport:
    """Inject the loss of ``rank`` into a running engine.

    Drains the dead rank's in-flight lanes, masks the rank out of the
    controller's solve, and remaps the engine onto the survivor placement
    (migration stall charged to the virtual clock). Idempotent per rank:
    failing an already-dead rank raises.
    """
    ctl = engine.controller
    if ctl is None:
        raise ValueError("fail_rank needs a controller-driven engine")
    G = ctl.G
    if not 0 <= rank < G:
        raise ValueError(f"rank {rank} outside [0, {G})")
    if rank in ctl.dead_ranks:
        raise ValueError(f"rank {rank} is already dead")

    drained_p = drained_d = redone = 0
    # drain in-flight prefills whose lane (KV shard) lived on the dead rank
    for req_id, st in list(engine._prefilling.items()):
        if st.lane % G != rank:
            continue
        del engine._prefilling[req_id]
        engine.kv.free_seq(req_id)
        redone += st.prefilled
        engine.records[req_id].requeues += 1
        engine.waiting.appendleft(st.req)
        drained_p += 1
    # drain decode lanes: the produced-so-far tokens are lost with the KV
    # shard, so the request replays prompt + generation from scratch
    for b in range(engine.max_batch):
        r = engine.slot_req[b]
        if r is None or b % G != rank:
            continue
        decoded = int(r.output_len - 1 - engine.slot_left[b])
        redone += r.prompt_len + max(decoded, 0)
        engine.slot_req[b] = None
        engine.slot_left[b] = 0
        engine.pos[b] = 0
        engine.kv.free_seq(r.req_id)
        # re-queue the original Request, bypassing submit(): the record
        # already exists and must persist (TTFT measures the first byte
        # the client saw, not the recovery replay)
        engine.records[r.req_id].requeues += 1
        engine.waiting.appendleft(r)
        drained_d += 1
    # drained work feeds the token-conservation ledger: those processed
    # tokens are no longer attributable to any finished request
    engine.stats.lost_tokens += redone

    upd = ctl.mask_ranks(tuple(set(ctl.dead_ranks) | {rank}))
    # the masked solve keeps the original G-rank geometry whenever the
    # default budget allows; an explicit budget can still widen the table
    want = ctl.placement.perm.shape[1]
    if want > engine.n_slots:
        engine._expand_slots(want)
        engine._r_max = min(ctl.G, engine.n_slots - ctl.E + 1)
    engine._apply_perm(engine._controller_perm())
    return FailureReport(rank=rank, at_time=engine.stats.virtual_time,
                         drained_prefills=drained_p,
                         drained_decodes=drained_d, redone_tokens=redone,
                         moved_experts=upd.moved_experts,
                         migration_bytes=upd.migration_bytes)


@dataclasses.dataclass
class RecoveryReport:
    """What re-adding a recovered rank cost (and restored)."""

    rank: int                        # the rank that came back
    at_time: float                   # virtual-clock time of the recovery
    moved_experts: int               # slots migrated by the grow re-solve
    migration_bytes: int             # weight bytes rehydrated onto the fleet
    dead_after: Tuple[int, ...]      # remaining dead set ((), when healthy)


def recover_rank(engine: Engine, rank: int) -> RecoveryReport:
    """Elastic *grow*: bring a previously failed ``rank`` back into the
    serving fleet — the inverse of :func:`fail_rank`.

    :meth:`ViBEController.unmask_ranks` re-solves over the enlarged
    survivor set, so traffic shares flow back onto the recovered rank; the
    engine re-expands slot geometry if the solve asks for it and applies
    the placement through the normal migration path, so the weight
    *rehydration* (shipping the recovered rank its expert shards) is
    priced on the virtual clock exactly like any recalibration. No lanes
    are drained — recovery only adds capacity. A fail→recover round trip
    with no interleaved traffic restores the healthy placement
    bit-identically (property-tested at the controller level).
    """
    ctl = engine.controller
    if ctl is None:
        raise ValueError("recover_rank needs a controller-driven engine")
    if not 0 <= rank < ctl.G:
        raise ValueError(f"rank {rank} outside [0, {ctl.G})")
    if rank not in ctl.dead_ranks:
        raise ValueError(f"rank {rank} is not dead — nothing to recover")
    upd = ctl.unmask_ranks((rank,))
    want = ctl.placement.perm.shape[1]
    if want > engine.n_slots:
        engine._expand_slots(want)
        engine._r_max = min(ctl.G, engine.n_slots - ctl.E + 1)
    engine._apply_perm(engine._controller_perm())
    return RecoveryReport(rank=rank, at_time=engine.stats.virtual_time,
                          moved_experts=upd.moved_experts,
                          migration_bytes=upd.migration_bytes,
                          dead_after=ctl.dead_ranks)


def run_with_failure(engine: Engine, requests: Sequence[Request], rank: int,
                     at_step: int = 5, max_steps: int = 10_000,
                     ) -> Tuple[List[RequestRecord], Optional[FailureReport]]:
    """Serve ``requests`` end to end, killing ``rank`` after ``at_step``
    engine steps — the elasticity drill.

    Returns the request records plus the :class:`FailureReport` (None only
    if the engine never ran a step). The drill asserts nothing itself;
    tests and the CI lane check completion + KV-leak + goodput-dip bounds
    on the returned records.
    """
    engine.submit(list(requests))
    report: Optional[FailureReport] = None
    for _ in range(max_steps):
        if report is None and engine.stats.steps >= at_step:
            report = fail_rank(engine, rank)
        if not engine.step():
            if report is None:
                # traffic drained before the failure point — inject now so
                # the drill still exercises the mask/remap path, then give
                # the (empty) queue one more chance to run
                report = fail_rank(engine, rank)
                if engine.step():
                    continue
            break
    return list(engine.records.values()), report
