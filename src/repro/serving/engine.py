"""JAX serving engine: continuous batching + KV cache + ViBE integration.

This is the *real-system* integration layer: the actual JAX model runs
(prefill + batched decode with per-slot positions), the router's tallies
feed the ViBE controller, and a placement update migrates the stacked
expert weights via :func:`repro.models.moe.apply_placement` and swaps the
slot-lookup tables **without recompiling** the step functions.

Configuration is one frozen :class:`EngineConfig` (serving/config.py):

* **Paged KV cache** — admission is gated by a block pool
  (:class:`~repro.serving.kvcache.PagedKVCache`), not a hardcoded batch
  cap; the default pool exactly covers the lanes, so legacy behavior is
  unchanged until a pool is configured.
* **Scheduler-driven steps** — each :meth:`step` asks a registered
  scheduler (serving/scheduler.py) what to run: a prefill chunk, a decode
  step, or idle. The default (``fcfs``, ``prefill_chunk=0``) replicates
  the legacy prefill-priority whole-prompt loop bit-for-bit.
* **Chunked prefill** — with ``prefill_chunk > 0`` long prompts run as
  fixed-width chunks (:func:`repro.models.model.prefill_chunk_fn`)
  interleaved with decode steps, and each chunk is priced on the virtual
  clock individually, so long-context requests stop head-of-line-blocking
  TTFT.

Because this host has one CPU device, wall-clock here is meaningless for
multi-rank behaviour; the engine keeps a *virtual clock* driven by the same
ground-truth cluster model the simulator uses (DESIGN.md §4), applied to
the *real* per-step routing tallies the model just produced. On a real
multi-chip deployment the virtual clock is replaced by measured step times
(pass them to :meth:`observe_step`); nothing else changes.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import (ClusterVariability, ReplicatedPlacement,
                        ViBEController)
from repro.models import (ShardingRules, decode_fn, init_cache, init_params,
                          make_moe_tables, moe_perm_shape, prefill_chunk_fn,
                          prefill_fn, refresh_moe_share_tables)
from repro.models.model import block_layout
from repro.models.moe import apply_placement
from .config import EngineConfig
from .kvcache import PagedKVCache
from .metrics import RejectReason, RequestRecord
from .scheduler import (RequestView, SchedulerContext, get_scheduler,
                        shed_victims)
from .simulator import (capacity_bucket_rows, rank_latency_matrix,
                        realized_rank_loads)
from .workload import Request

__all__ = ["Engine", "EngineStats", "EngineConfig"]


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    prefill_steps: int = 0           # requests whose prefill completed
    chunk_steps: int = 0             # individual prefill-chunk model calls
    decode_steps: int = 0
    migrations: int = 0
    migrated_slots: int = 0
    migration_bytes: int = 0
    steal_updates: int = 0           # share-only table refreshes (stealing)
    dropped_assignments: float = 0.0  # capacity-overflow drops (all layers)
    virtual_time: float = 0.0
    # token-conservation ledger (chaos-drill invariant): every token the
    # model processed is either useful (belongs to a finished request's
    # prompt + decode stream) or lost (thrown away by a rank-failure drain
    # or a preemption and replayed later) — when the engine is idle,
    # prefill_tokens + decode_tokens == useful_tokens + lost_tokens.
    prefill_tokens: int = 0          # prompt tokens run through prefill
    decode_tokens: int = 0           # decode-lane participations run
    useful_tokens: int = 0           # processed tokens of finished requests
    lost_tokens: int = 0             # processed tokens discarded by
    #                                  drains/preemptions (replayed later)
    preemptions: int = 0             # decode lanes evicted under KV pressure
    rejected: Dict[str, int] = dataclasses.field(default_factory=dict)
    #                                  RejectReason.value → count


@dataclasses.dataclass
class _Prefilling:
    """An admitted request whose prompt is (partially) in the cache."""

    req: Request
    lane: int
    prompt: np.ndarray               # (1, prompt_len) generated tokens
    prefilled: int = 0


class Engine:
    """Continuous-batching engine for one (smoke-scale) model.

    ``Engine(cfg, EngineConfig(...), controller=..., cluster=...)`` is the
    configured surface; the legacy keyword form
    ``Engine(cfg, max_batch=..., max_seq=..., ...)`` still works through
    :meth:`EngineConfig.from_kwargs` (bit-identical, ``DeprecationWarning``).
    """

    # class-level fallback: skeleton engines built without __init__
    # (pricing-path tests use Engine.__new__) read default knobs here
    config = EngineConfig()

    def __init__(self, cfg: ArchConfig,
                 config: Optional[EngineConfig] = None, *,
                 rules: Optional[ShardingRules] = None,
                 controller: Optional[ViBEController] = None,
                 cluster: Optional[ClusterVariability] = None,
                 **legacy):
        if legacy:
            if config is not None:
                raise TypeError("pass either an EngineConfig or legacy "
                                "keyword arguments, not both")
            config = EngineConfig.from_kwargs(**legacy)
        elif config is None:
            config = EngineConfig()
        if not isinstance(config, EngineConfig):
            raise TypeError("config must be an EngineConfig, "
                            f"got {type(config).__name__}")
        self.config = config = config.resolve()
        self.cfg = cfg
        self.rules = rules
        self.controller = controller
        self.cluster = cluster
        self.max_batch = config.max_batch
        self.max_seq = config.max_seq
        # which grouped-FFN implementation the virtual clock prices:
        # "ragged" (dropless — cost is the realized dispatched load, the
        # model layer's default) or "capacity" (fixed buckets — every rank
        # pays slots_per_rank × capacity rows regardless of skew). Defaults
        # to the sharding rules' resolved impl so clock and dispatch agree.
        moe_impl = config.moe_impl
        if moe_impl is None:
            moe_impl = (rules.moe_impl_resolved if rules is not None
                        else "ragged")
        self.moe_impl = moe_impl
        # share-weighted replica routing: fold the controller placement's
        # per-copy traffic shares into the dispatch tables so the model
        # steers tokens the way the solver's latency objective assumes.
        # False = share-oblivious uniform split over copies (same selector,
        # flat CDF) — the A/B + regression knob.
        self.weighted_routing = config.weighted_routing
        self.stats = EngineStats()
        key = jax.random.PRNGKey(config.seed)
        self.params = init_params(cfg, key, rules)
        self.n_moe, self.n_slots = (moe_perm_shape(cfg, rules, "train")
                                    if cfg.is_moe else (0, 0))
        self._perm = (np.tile(np.arange(self.n_slots, dtype=np.int32),
                              (self.n_moe, 1)) if cfg.is_moe else None)
        self._share: Optional[np.ndarray] = None
        self._r_max: Optional[int] = None
        if cfg.is_moe and controller is not None:
            # Replication-capable policies: when the controller's placement
            # uses a slot budget beyond one-per-expert (replicated copies),
            # grow the stacked expert tensors to match. The budget is read
            # off the placement itself, so engine and controller cannot
            # disagree. Placements are always the unified
            # ReplicatedPlacement (singleton = r_max 1 degenerate), so no
            # type-switching here.
            want = controller.placement.perm.shape[1]
            if want > self.n_slots:
                self._expand_slots(want)
            # pin the copy-axis width to its reachable maximum (≤ one
            # copy per rank, ≤ spare slots + 1; exactly 1 for singleton
            # policies) so recalibrations that change replication degrees
            # keep table shapes — and the compiled step functions — stable.
            self._r_max = min(controller.G,
                              self.n_slots - controller.E + 1)
        if controller is not None \
                and getattr(controller, "rescheduler", None) is not None \
                and not self.weighted_routing:
            # stolen shares can only steer dispatch through the weighted
            # CDF tables; with a uniform split they'd be silently inert
            raise ValueError("controller has work stealing enabled "
                             "(ViBEConfig.steal) but weighted_routing is "
                             "False — stolen shares would never reach "
                             "dispatch")
        if config.topology is not None and controller is not None \
                and config.topology.n_ranks != controller.G:
            raise ValueError(f"topology has {config.topology.n_ranks} ranks "
                             f"but the controller has {controller.G}")
        self._steal_version = 0
        if controller is not None:
            self._apply_perm(self._controller_perm(), charge=False)
        else:
            self.moe_tables = make_moe_tables(
                cfg, rules, perm=self._perm,
                n_slots=self.n_slots) if cfg.is_moe else None
        self._prefill = jax.jit(prefill_fn(cfg, rules))
        self._decode = jax.jit(decode_fn(cfg, rules))
        # scheduling + memory: registered scheduler, paged KV admission
        self.scheduler = get_scheduler(config.scheduler.name)
        self._sched_cfg = config.scheduler
        self._chunk = config.scheduler.prefill_chunk
        self._prefill_chunk = (jax.jit(prefill_chunk_fn(cfg, rules))
                               if self._chunk > 0 else None)
        self.kv = PagedKVCache(config.kv)
        self._prefill_streak = 0
        # slot state
        self.cache = init_cache(cfg, self.max_batch, self.max_seq, rules)
        self.tokens = jnp.zeros((self.max_batch, 1), jnp.int32)
        self.pos = np.zeros(self.max_batch, np.int64)
        self.slot_req: List[Optional[Request]] = [None] * self.max_batch
        self.slot_left = np.zeros(self.max_batch, np.int64)
        self.records: Dict[int, RequestRecord] = {}
        self.waiting: collections.deque = collections.deque()
        self._prefilling: Dict[int, _Prefilling] = {}

    # -- placement plumbing -------------------------------------------------

    def _expand_slots(self, n_slots: int) -> None:
        """Grow stacked expert tensors to ``n_slots`` physical slots.

        New slot p starts holding logical expert p % E (round-robin replica),
        gathered from the identity layout — the slot-table application path
        (``apply_placement`` + ``make_moe_tables``) then works unchanged for
        replicated placements.
        """
        if n_slots < self.n_slots:
            raise ValueError(f"cannot shrink slots {self.n_slots}→{n_slots}")
        if n_slots == self.n_slots:
            return
        E = self.cfg.n_experts
        src = np.concatenate([np.arange(self.n_slots, dtype=np.int32),
                              np.arange(self.n_slots, n_slots,
                                        dtype=np.int32) % E])
        gi = jnp.asarray(src)
        _, specs = block_layout(self.cfg)
        for i, spec in enumerate(specs):
            if spec.ffn != "moe":
                continue
            leaf = self.params["blocks"][i]["ffn"]
            grown = {k: jnp.take(leaf[k], gi, axis=1)
                     for k in ("w1", "w2", "w3") if k in leaf}
            self.params["blocks"][i]["ffn"] = {**leaf, **grown}
        self._perm = np.tile(src, (self.n_moe, 1))
        self.n_slots = n_slots

    def _controller_perm(self) -> np.ndarray:
        pl = self.controller.placement
        perm = pl.perm                                  # (n_moe, n_slots)
        if perm.shape != (self.n_moe, self.n_slots):
            raise ValueError(f"controller placement {perm.shape} != "
                             f"{(self.n_moe, self.n_slots)}")
        return perm

    def _controller_share(self) -> Optional[np.ndarray]:
        """Per-slot traffic shares of the controller's placement, or None.

        None (singleton placements, or ``weighted_routing=False``) keeps the
        uniform split over copies in the dispatch tables.
        """
        if self.controller is None or not self.weighted_routing:
            return None
        # dispatch_placement = responsive (steal-adjusted) shares when the
        # controller runs a TokenRescheduler, the plan's shares otherwise
        pl = getattr(self.controller, "dispatch_placement",
                     self.controller.placement)
        return getattr(pl, "share", None)

    _AUTO_SHARE = object()      # sentinel: derive from the controller

    def _apply_perm(self, new_perm: np.ndarray, share=_AUTO_SHARE,
                    charge: bool = True) -> int:
        """Migrate expert weights + slot/share tables to a new placement.

        ``share`` defaults to the controller placement's traffic shares
        (respecting ``weighted_routing``) so dispatch tables and the
        virtual clock can never desync; pass an explicit array (or None
        for a uniform split) only to override. The share table rides along
        exactly like the slot table: both are plain array inputs to the
        jitted step functions (copy-axis width pinned via ``_r_max``), so
        recalibration — including share-only changes — never recompiles.
        """
        if share is Engine._AUTO_SHARE:
            share = self._controller_share()
        nb, specs = block_layout(self.cfg)
        m = self.n_moe // nb
        moved_total = 0
        moe_positions = [i for i, s in enumerate(specs) if s.ffn == "moe"]
        for jj, i in enumerate(moe_positions):
            old_j = self._perm[jj::m] if m else self._perm
            new_j = new_perm[jj::m]
            leaf = self.params["blocks"][i]["ffn"]
            migrated, moved = apply_placement(leaf, old_j, new_j)
            self.params["blocks"][i]["ffn"] = {**leaf, **migrated}
            moved_total += moved
        self._perm = new_perm.copy()
        self._share = None if share is None else np.array(share)
        self.moe_tables = make_moe_tables(self.cfg, self.rules,
                                          perm=self._perm,
                                          n_slots=self.n_slots,
                                          share=self._share,
                                          r_max=self._r_max)
        self._sync_steal_version()
        if charge:
            per_slot = 3 * self.cfg.d_model * self.cfg.moe_d_ff * 2
            moved_bytes = moved_total * per_slot
            self.stats.migrations += 1
            self.stats.migrated_slots += moved_total
            self.stats.migration_bytes += moved_bytes
            if self.cluster is not None:
                # the weight transfer stalls serving: charge it to the
                # virtual clock so engine-measured TTFT/TPOT see the same
                # migration stalls the simulator models (sim.migration_stalls).
                # A configured topology prices the cross-node fraction at
                # DCN bandwidth (flat topology degenerates to the same
                # divide); the engine serializes migrations on one link.
                topo = self.config.topology
                if topo is not None:
                    self.stats.virtual_time += topo.migration_cost(moved_bytes)
                else:
                    self.stats.virtual_time += \
                        moved_bytes / self.cluster.ici_bw
        return moved_total

    def _observe(self, tallies: np.ndarray, tokens: float) -> None:
        if self.controller is None:
            return
        t = self._controller_tallies(tallies)
        upd = self.controller.observe(t, tokens=tokens)
        if upd is not None:
            self._apply_perm(self._controller_perm())
        elif self._steal_dirty():
            self._apply_share()

    def _steal_dirty(self) -> bool:
        rs = getattr(self.controller, "rescheduler", None)
        return rs is not None and rs.version != self._steal_version

    def _sync_steal_version(self) -> None:
        rs = getattr(self.controller, "rescheduler", None)
        self._steal_version = rs.version if rs is not None else 0

    def _apply_share(self) -> None:
        """Share-only dispatch-table refresh after a steal update.

        The slot table (and thus the weights) is untouched — only the
        cumulative-share CDF the inverse-CDF replica selector reads is
        rebuilt (:func:`refresh_moe_share_tables` reuses the existing
        ``slots_of``/``n_copies``). Shapes are pinned, so no recompile;
        the clock charges only the small share-table broadcast.
        """
        rs = self.controller.rescheduler
        self._share = np.array(rs.placement.share)
        self.moe_tables = refresh_moe_share_tables(
            self.cfg, self.moe_tables, self._perm, self._share)
        self._sync_steal_version()
        self.stats.steal_updates += 1
        if self.cluster is not None:
            topo = self.config.topology
            if topo is not None:
                self.stats.virtual_time += \
                    topo.broadcast_cost(rs.share_table_bytes)
            else:
                self.stats.virtual_time += \
                    rs.share_table_bytes / self.cluster.ici_bw

    def _controller_tallies(self, tallies: np.ndarray) -> np.ndarray:
        """Pad router tallies (logical experts) to the controller's width.

        The model returns (n_moe, E+1) tallies — logical-expert counts plus
        a capacity-dropped column (accounted in ``stats``, not load); strip
        the drop column first. Singleton controllers treat every physical
        slot as an expert (phantoms see zero load); a ViBE-R controller
        works on logical experts directly, so its width can be below the
        slot count."""
        t = np.asarray(tallies, dtype=np.float64)[:, :self.cfg.n_experts]
        if t.shape[1] < self.controller.E:
            t = np.pad(t, ((0, 0), (0, self.controller.E - t.shape[1])))
        return t

    # -- virtual clock -------------------------------------------------------

    def _clock_placement(self):
        """The placement whose traffic split the virtual clock prices.

        With weighted routing the dispatch follows the solver's shares, so
        the clock prices the controller placement directly. With
        ``weighted_routing=False`` the dispatch splits uniformly over
        copies — pricing the solver's shares then would hide exactly the
        gap the A/B knob exists to measure, so the clock uses a uniform-
        share view of the same slot table (cached per placement object).

        With stealing on, ``dispatch_placement`` is the responsive
        (steal-adjusted) placement — the clock prices what the dispatch
        tables actually did this step, since tables refresh *after* each
        step's observation.
        """
        pl = getattr(self.controller, "dispatch_placement",
                     self.controller.placement)
        if self.weighted_routing:
            return pl
        if getattr(self, "_uniform_clock_src", None) is not pl:
            se = pl.slot_expert
            nc_pad = np.concatenate(          # phantom col: avoid 0-division
                [pl.n_copies(), np.ones((pl.n_layers, 1))], axis=1)
            share = np.where(se < pl.n_experts,
                             1.0 / np.take_along_axis(nc_pad, se, axis=1),
                             0.0)
            self._uniform_clock_pl = ReplicatedPlacement(
                se, share, pl.n_ranks, pl.n_experts)
            self._uniform_clock_src = pl
        return self._uniform_clock_pl

    def _charge(self, tallies: np.ndarray, tokens: int) -> float:
        """Advance virtual time using ground-truth cluster latencies.

        With ``moe_impl="ragged"`` (default) loads are the *realized*
        token-granular split of the routing-mode placement
        (``realized_rank_loads``) — the dropless kernel's cost tracks
        exactly what the dispatch tables did this step, so weighted vs
        uniform replica routing shows up in TTFT/TPOT, not just in the
        tables. With ``moe_impl="capacity"`` every rank is charged its full
        bucket allocation (its real-slot count × capacity rows, zero
        padding included — non-uniform slot budgets charge each rank its
        actual bucket count) — the fixed-bucket kernel's honest,
        skew-oblivious cost.

        The per-rank (load, latency) rows also feed the controller's
        performance-drift telemetry (``observe_latency``): the virtual
        clock stands in for the kernel timers a real deployment would
        read, so a drifting ``ClusterVariability`` (events schedule) is
        observed — and recalibrated against — through exactly the samples
        serving produced.
        """
        if self.cluster is None or self.controller is None \
                or not self.cfg.is_moe:
            dt = 1e-3 * max(tokens, 1)                  # trivial fallback
            self.stats.virtual_time += dt
            return dt
        if self.moe_impl == "capacity":
            cf = self.config.capacity_factor if self.rules is None \
                else self.rules.capacity_factor
            cap = capacity_bucket_rows(tokens, self.cfg.top_k,
                                       self.n_slots, cf)
            # per-rank *real* slot counts from the placement itself:
            # non-uniform budgets mean ranks run different bucket counts
            # (phantom slots allocate nothing)
            budget = self.controller.placement.rank_slot_budget()
            rank_load = budget.astype(np.float64) * cap
        else:
            rank_load = realized_rank_loads(
                self._clock_placement(), self._controller_tallies(tallies))
        rank_time = rank_latency_matrix(self.cluster, rank_load,
                                        t=self.stats.virtual_time)
        dt = float(rank_time.max(1).sum())
        self.stats.virtual_time += dt
        upd = self.controller.observe_latency(rank_load, rank_time)
        if upd is not None:
            self._apply_perm(self._controller_perm())
        return dt

    def observe_step(self, tallies, tokens: float, latencies=None) -> float:
        """Feed one step's telemetry; returns the step's virtual duration.

        The unified observation surface (same shape as
        ``EPSimulator.observe_step``): price the step, feed the per-rank
        latency telemetry to the controller's drift detector, then feed
        the routing tallies to the skew detector — either may trigger a
        placement update, which is applied (and its migration stall
        charged) before returning.

        ``latencies`` — optional measured ``(rank_load, rank_time)`` pair
        from a real deployment's kernel timers; None (the smoke-host
        default) prices the step on the virtual clock instead.
        """
        tall = np.asarray(tallies)
        if latencies is None:
            dt = self._charge(tall, tokens)
        else:
            rank_load, rank_time = latencies
            rank_time = np.asarray(rank_time, dtype=np.float64)
            dt = float(rank_time.max(1).sum())
            self.stats.virtual_time += dt
            if self.controller is not None:
                upd = self.controller.observe_latency(rank_load, rank_time)
                if upd is not None:
                    self._apply_perm(self._controller_perm())
        self._observe(tall, float(tokens))
        return dt

    # -- request lifecycle ----------------------------------------------------

    def submit(self, reqs: List[Request]) -> List[RequestRecord]:
        """Submit requests; returns the records of the ones REJECTED.

        Rejection is typed, not an exception: an infeasible request (prompt
        beyond ``max_seq``, or a worst-case KV reservation the pool can
        never satisfy) gets a :class:`RequestRecord` carrying its
        :class:`RejectReason` — it never enters the waiting queue, and
        ``stats.rejected`` tallies the reason for the serve summary line.
        Feasible requests queue as before.
        """
        out = []
        for r in reqs:
            rec = RequestRecord(r.req_id, r.arrival, r.prompt_len,
                                r.output_len, tenant=r.tenant)
            self.records[r.req_id] = rec
            total = min(r.prompt_len + r.output_len, self.max_seq)
            floor = int(self.kv.config.n_blocks * self.kv.config.watermark)
            if r.prompt_len > self.max_seq:
                self._reject(rec, RejectReason.TOO_LONG)
            elif self.kv.config.blocks_for(total) > \
                    self.kv.config.n_blocks - floor:
                # needs more KV blocks than admission can ever hand out:
                # queueing it would wait forever behind the watermark
                self._reject(rec, RejectReason.NEVER_FITS)
            else:
                self.waiting.append(r)
                continue
            out.append(rec)
        return out

    def _reject(self, rec: RequestRecord, reason: RejectReason) -> None:
        rec.reject_reason = reason
        self.stats.rejected[reason.value] = \
            self.stats.rejected.get(reason.value, 0) + 1

    def _lane_free(self, b: int) -> bool:
        if self.slot_req[b] is not None:
            return False
        return all(p.lane != b for p in self._prefilling.values())

    def _free_slot(self) -> Optional[int]:
        for b in range(self.max_batch):
            if self._lane_free(b):
                return b
        return None

    def _insert_cache(self, slot: int, pre_cache) -> None:
        """Insert a prefilled (batch-1) cache pytree into engine slot."""
        def ins(ec, pc):
            if pc.ndim >= 3 and ec.shape[2] != pc.shape[2]:
                pad = [(0, 0)] * pc.ndim
                pad[2] = (0, ec.shape[2] - pc.shape[2])
                pc = jnp.pad(pc, pad)
            return ec.at[:, slot].set(pc[:, 0].astype(ec.dtype))
        self.cache = jax.tree.map(ins, self.cache, pre_cache)

    def _release(self, lane: int) -> None:
        r = self.slot_req[lane]
        self.slot_req[lane] = None
        self.kv.free_seq(r.req_id)

    # -- scheduling ----------------------------------------------------------

    def _build_context(self) -> SchedulerContext:
        prefilling = [RequestView(p.req.req_id, p.req.arrival,
                                  p.req.prompt_len, p.req.output_len,
                                  p.prefilled, p.req.ttft_slo)
                      for p in self._prefilling.values()]
        waiting, blocked = [], []
        for r in self.waiting:
            total = min(r.prompt_len + r.output_len, self.max_seq)
            view = RequestView(r.req_id, r.arrival, r.prompt_len,
                               r.output_len, 0, r.ttft_slo)
            (waiting if self.kv.can_admit(total) else blocked).append(view)
        n_free = sum(1 for b in range(self.max_batch) if self._lane_free(b))
        n_running = sum(1 for s in self.slot_req if s is not None)
        return SchedulerContext(
            now=self.stats.virtual_time, config=self._sched_cfg,
            waiting=waiting, prefilling=prefilling, n_running=n_running,
            prefill_streak=self._prefill_streak, can_start=n_free,
            chunk_budget=self._chunk if self._chunk > 0 else self.max_seq,
            blocked=blocked, kv_utilization=self.kv.utilization())

    # -- overload protection -------------------------------------------------

    def _shed_overload(self) -> None:
        """Watermark load shedding (``SchedulerConfig.shed_watermark``).

        The policy lives in the scheduler module (:func:`shed_victims` —
        under KV pressure, reject waiting requests whose TTFT deadline has
        lapsed, lowest headroom first); the engine applies it: victims
        leave the queue and their records carry ``RejectReason.SHED``.
        """
        if self._sched_cfg.shed_watermark <= 0.0 or not self.waiting:
            return
        victims = set(shed_victims(self._build_context()))
        if not victims:
            return
        keep: collections.deque = collections.deque()
        for r in self.waiting:
            if r.req_id in victims:
                self._reject(self.records[r.req_id], RejectReason.SHED)
            else:
                keep.append(r)
        self.waiting = keep

    def _maybe_preempt(self) -> None:
        """Preempt one decode lane when KV pressure starves admission.

        Fires only when ``SchedulerConfig.preempt_decodes`` is set, some
        request is waiting, and *none* of the waiting requests fits the
        free KV pool — the committing-admission deadlock a shrunken pool
        (or a rank-failure re-admission wave) can produce. The victim is
        the decode lane with the fewest produced tokens (least work lost);
        its KV is freed and the request requeued at the *tail* (backoff —
        drains use the head). A request preempted ``max_preemptions``
        times becomes immune, which bounds per-request retries and rules
        out preemption livelock.
        """
        cfgp = self._sched_cfg
        if not cfgp.preempt_decodes or not self.waiting:
            return
        if any(self.kv.can_admit(min(r.prompt_len + r.output_len,
                                     self.max_seq))
               for r in self.waiting):
            return
        victims = []
        for b in range(self.max_batch):
            r = self.slot_req[b]
            if r is None:
                continue
            if self.records[r.req_id].preemptions >= cfgp.max_preemptions:
                continue
            decoded = int(r.output_len - 1 - self.slot_left[b])
            victims.append((max(decoded, 0), b))
        if not victims:
            return
        decoded, b = min(victims)
        r = self.slot_req[b]
        self.slot_req[b] = None
        self.slot_left[b] = 0
        self.pos[b] = 0
        self.kv.free_seq(r.req_id)
        rec = self.records[r.req_id]
        rec.preemptions += 1
        rec.requeues += 1
        self.stats.preemptions += 1
        # the prompt and the produced-so-far tokens die with the KV shard
        self.stats.lost_tokens += r.prompt_len + decoded
        self.waiting.append(r)

    def step(self) -> bool:
        """One engine step, as directed by the configured scheduler:
        one prefill chunk (or whole prompt), or one batched decode.

        Overload protection runs first (both off by default): watermark
        load shedding rejects hopeless waiting requests under KV-pool
        pressure, and decode preemption evicts a running lane when KV
        starvation blocks every waiting request.

        Returns False when idle (no waiting or running requests).
        """
        self._shed_overload()
        self._maybe_preempt()
        action = self.scheduler.schedule(self._build_context())
        if action.kind == "prefill":
            # the engine runs one chunk per step so the virtual clock
            # prices every chunk individually (the simulator's scheduled
            # loop batches a whole token budget instead)
            self._exec_prefill(action.chunks[0].req_id)
            self._prefill_streak += 1
            self.stats.steps += 1
            return True
        if action.kind == "decode":
            self._exec_decode()
            self._prefill_streak = 0
            self.stats.steps += 1
            return True
        return False

    def _exec_prefill(self, req_id: int) -> None:
        st = self._prefilling.get(req_id)
        if st is None:
            # admission: reserve a lane + the full worst-case KV block
            # count (so decode extension can never fail mid-request)
            r = next(x for x in self.waiting if x.req_id == req_id)
            self.waiting = collections.deque(
                x for x in self.waiting if x.req_id != req_id)
            lane = self._free_slot()
            self.kv.allocate(r.req_id,
                             min(r.prompt_len + r.output_len, self.max_seq))
            # the engine can't start before the request arrives
            self.stats.virtual_time = max(self.stats.virtual_time, r.arrival)
            prompt = np.random.default_rng(r.req_id).integers(
                0, self.cfg.vocab, size=(1, r.prompt_len))
            st = _Prefilling(r, lane, prompt)
            self._prefilling[req_id] = st
        if self._chunk > 0:
            self._prefill_one_chunk(st)
        else:
            self._prefill_whole(st)

    def _prefill_whole(self, st: _Prefilling) -> None:
        """Legacy whole-prompt prefill (``prefill_chunk = 0``)."""
        r = st.req
        batch = {"tokens": jnp.asarray(st.prompt, jnp.int32)}
        logits, pre_cache, tallies = self._prefill(
            self.params, batch, self.moe_tables)
        self._insert_cache(st.lane, pre_cache)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        self.tokens = self.tokens.at[st.lane, 0].set(nxt[0])
        st.prefilled = r.prompt_len
        self.kv.advance(r.req_id, min(r.prompt_len, self.max_seq))
        self.stats.prefill_tokens += r.prompt_len
        tall = np.asarray(tallies)
        if self.cfg.is_moe and tall.size:
            self.stats.dropped_assignments += float(tall[:, -1].sum())
        self.observe_step(tall, float(r.prompt_len))
        self._finish_prefill(st)
        self.stats.prefill_steps += 1

    def _prefill_one_chunk(self, st: _Prefilling) -> None:
        """One fixed-width chunk of ``st``'s prompt into its lane."""
        r = st.req
        C = self._chunk
        off = st.prefilled
        n_valid = min(C, r.prompt_len - off)
        buf = np.zeros((1, C), np.int64)
        buf[0, :n_valid] = st.prompt[0, off:off + n_valid]
        logits, self.cache, tallies = self._prefill_chunk(
            self.params, jnp.asarray(buf, jnp.int32), self.cache,
            st.lane, off, n_valid, self.moe_tables)
        st.prefilled += n_valid
        self.kv.advance(r.req_id, n_valid)
        self.stats.prefill_tokens += n_valid
        # interleaved decode steps write a garbage row at pos[lane] for
        # reserved lanes; parking pos at the next chunk offset makes the
        # next chunk's first (always-valid) row overwrite it
        self.pos[st.lane] = st.prefilled
        tall = np.asarray(tallies)
        if self.cfg.is_moe and tall.size:
            self.stats.dropped_assignments += float(tall[:, -1].sum())
        self.observe_step(tall, float(n_valid))
        self.stats.chunk_steps += 1
        if st.prefilled >= r.prompt_len:
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            self.tokens = self.tokens.at[st.lane, 0].set(nxt[0])
            self._finish_prefill(st)
            self.stats.prefill_steps += 1

    def _finish_prefill(self, st: _Prefilling) -> None:
        r = st.req
        del self._prefilling[r.req_id]
        self.pos[st.lane] = r.prompt_len
        self.slot_req[st.lane] = r
        self.slot_left[st.lane] = r.output_len - 1
        rec = self.records[r.req_id]
        if not np.isfinite(rec.first_token_at):
            # a re-admitted request (rank failure re-prefilled it) keeps
            # its original first-token time — TTFT measures the first
            # byte the client saw, not the recovery replay
            rec.first_token_at = self.stats.virtual_time
        if r.output_len <= 1:
            rec.finished_at = self.stats.virtual_time
            self.stats.useful_tokens += r.prompt_len
            self._release(st.lane)

    def _exec_decode(self) -> None:
        active = [b for b in range(self.max_batch)
                  if self.slot_req[b] is not None]
        pos = jnp.asarray(np.minimum(self.pos, self.max_seq - 1), jnp.int32)
        logits, self.cache, tallies = self._decode(
            self.params, self.tokens, self.cache, pos, self.moe_tables)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        self.tokens = nxt[:, None]
        tall = np.asarray(tallies)
        if self.cfg.is_moe and tall.size:
            self.stats.dropped_assignments += float(tall[:, -1].sum())
        self.observe_step(tall, float(len(active)))
        self.stats.decode_tokens += len(active)
        for b in active:
            if self.pos[b] < self.max_seq:
                # the new token occupied a fresh cache row (beyond
                # max_seq the write is clamped onto the last row)
                self.kv.extend(self.slot_req[b].req_id)
            self.pos[b] += 1
            self.slot_left[b] -= 1
            if self.slot_left[b] <= 0 or self.pos[b] >= self.max_seq - 1:
                r = self.slot_req[b]
                rec = self.records[r.req_id]
                rec.finished_at = self.stats.virtual_time
                # decode participations so far = (output_len-1) - slot_left
                # (exact even for the early max_seq-clamp finish)
                self.stats.useful_tokens += r.prompt_len + max(
                    int(r.output_len - 1 - self.slot_left[b]), 0)
                self._release(b)
        self.stats.decode_steps += 1

    def run(self, max_steps: int = 10_000) -> List[RequestRecord]:
        for _ in range(max_steps):
            if not self.step():
                break
        return list(self.records.values())
