"""Unified serving configuration (the redesigned API surface).

One frozen hierarchy configures every serving consumer:

* :class:`ServingConfig` — knobs shared by the JAX engine and the
  discrete-event simulator (batching, MoE pricing impl, scheduler).
* :class:`EngineConfig`  — the engine's surface (KV cache, sequence
  budget, routing knobs). ``Engine(cfg, EngineConfig(...))`` replaces the
  accreted keyword sprawl; the legacy kwargs still work through
  :meth:`EngineConfig.from_kwargs` (bit-identical, ``DeprecationWarning``).
* :class:`SimConfig`     — the simulator's surface (previously a mutable
  dataclass in ``serving/simulator.py``; now frozen and part of the same
  hierarchy, re-exported there for back-compat).

Sub-configs:

* :class:`KVCacheConfig`   — paged/block KV cache geometry + watermark
  admission (``serving/kvcache.py``).
* :class:`SchedulerConfig` — which registered scheduler runs the
  continuous-batching loop, chunked-prefill sizing, SLO deadlines
  (``serving/scheduler.py``).
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:
    from repro.core.topology import ClusterTopology

__all__ = ["KVCacheConfig", "SchedulerConfig", "ServingConfig",
           "EngineConfig", "SimConfig"]


@dataclasses.dataclass(frozen=True)
class KVCacheConfig:
    """Paged KV-cache geometry: fixed-size blocks + free-list allocator.

    ``watermark`` holds back a fraction of the block pool from admission
    (headroom for in-flight growth); admission reserves a request's full
    worst-case block count up front (``min(prompt+output, max_seq)``
    rounded up to blocks), so allocation after admission can never fail.
    """

    block_size: int = 16             # tokens per KV block
    n_blocks: int = 64               # total block pool (memory budget)
    watermark: float = 0.0           # fraction of blocks kept free

    def __post_init__(self):
        if self.block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {self.block_size}")
        if self.n_blocks < 1:
            raise ValueError(f"n_blocks must be >= 1, got {self.n_blocks}")
        if not 0.0 <= self.watermark < 1.0:
            raise ValueError("watermark must be in [0, 1), "
                             f"got {self.watermark}")

    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to hold ``n_tokens`` cache rows."""
        return max(-(-int(n_tokens) // self.block_size), 1)


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Continuous-batching scheduler selection + chunked-prefill sizing.

    ``name`` is a key in the ``serving/scheduler.py`` registry (``fcfs``,
    ``slo_edf``, ``decode_priority``, or anything third parties register).
    ``prefill_chunk = 0`` keeps the legacy whole-prompt prefill; > 0 splits
    prompts into fixed-token chunks interleaved with decode steps.
    ``decode_starvation_bound`` caps consecutive prefill-only steps while
    decodes are pending (enforced by the SLO-aware policies; pinned by a
    property test). ``ttft_slo``/``tpot_slo`` are the default per-request
    deadlines (a request's own ``ttft_slo`` field overrides).

    Overload protection (off by default — legacy behaviour unchanged):
    ``shed_watermark > 0`` enables watermark load shedding — when KV-pool
    utilization reaches the watermark, waiting requests whose TTFT
    deadline has already lapsed (lowest SLO headroom first) are rejected
    with ``RejectReason.SHED`` instead of queuing forever.
    ``preempt_decodes`` lets the engine evict a running decode lane (free
    its KV, requeue the request) when waiting work is starved by KV
    pressure; each request is preempted at most ``max_preemptions`` times
    (the bounded-retry guard — beyond that it is immune, which also rules
    out preemption livelock).
    """

    name: str = "fcfs"
    prefill_chunk: int = 0           # tokens per prefill chunk; 0 = whole
    max_prefill_tokens: int = 8192   # per-step prefill token budget
    decode_starvation_bound: int = 4
    ttft_slo: float = 0.35
    tpot_slo: float = 0.125
    shed_watermark: float = 0.0      # KV utilization triggering shedding;
    #                                  0 disables (legacy)
    preempt_decodes: bool = False    # evict decodes under KV pressure
    max_preemptions: int = 2         # per-request preemption cap (backoff)

    def __post_init__(self):
        if self.prefill_chunk < 0:
            raise ValueError("prefill_chunk must be >= 0, "
                             f"got {self.prefill_chunk}")
        if self.max_prefill_tokens < 1:
            raise ValueError("max_prefill_tokens must be >= 1, "
                             f"got {self.max_prefill_tokens}")
        if self.decode_starvation_bound < 1:
            raise ValueError("decode_starvation_bound must be >= 1, "
                             f"got {self.decode_starvation_bound}")
        if not 0.0 <= self.shed_watermark <= 1.0:
            raise ValueError("shed_watermark must be in [0, 1], "
                             f"got {self.shed_watermark}")
        if self.max_preemptions < 0:
            raise ValueError("max_preemptions must be >= 0, "
                             f"got {self.max_preemptions}")


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Knobs shared by :class:`EngineConfig` and :class:`SimConfig`."""

    max_batch: int = 4               # concurrent decode lanes / batch cap
    moe_impl: Optional[str] = None   # "ragged" | "capacity" | None=derive
    capacity_factor: float = 1.25    # bucket sizing for moe_impl="capacity"
    seed: int = 0
    scheduler: Optional[SchedulerConfig] = None   # None = legacy loop/fcfs
    topology: Optional["ClusterTopology"] = None
    # fleet topology (repro.core.topology): when set, both virtual clocks
    # price a2a / migration / steal-broadcast traffic through the two-level
    # ICI/DCN model instead of the flat ici_bw divide. None keeps the
    # legacy flat pricing bit-identical.

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.moe_impl not in (None, "ragged", "capacity"):
            raise ValueError("moe_impl must be 'ragged' or 'capacity', "
                             f"got {self.moe_impl!r}")


#: legacy Engine(**kwargs) names from_kwargs accepts, with their defaults
_ENGINE_LEGACY_DEFAULTS = dict(max_batch=4, max_seq=64,
                               weighted_routing=True, moe_impl=None, seed=0)


@dataclasses.dataclass(frozen=True)
class EngineConfig(ServingConfig):
    """The JAX continuous-batching engine's configuration surface."""

    max_seq: int = 64
    weighted_routing: bool = True
    kv: Optional[KVCacheConfig] = None   # None = pool sized to the lanes

    def __post_init__(self):
        super().__post_init__()
        if self.max_seq < 2:
            raise ValueError(f"max_seq must be >= 2, got {self.max_seq}")
        sched = self.scheduler
        if sched is not None and sched.prefill_chunk > 0 \
                and self.max_seq % sched.prefill_chunk != 0:
            # chunk offsets must tile the cache exactly (the chunked
            # attention writes [offset, offset+chunk) windows)
            raise ValueError(
                f"prefill_chunk ({sched.prefill_chunk}) must divide "
                f"max_seq ({self.max_seq})")

    @classmethod
    def from_kwargs(cls, **kwargs) -> "EngineConfig":
        """Deprecated shim for the legacy ``Engine(**kwargs)`` surface.

        Produces a config whose behavior is bit-identical to the legacy
        engine: whole-prompt FCFS prefill, KV block pool sized to exactly
        cover the lanes (admission never binds before a free lane does),
        zero watermark.
        """
        unknown = set(kwargs) - set(_ENGINE_LEGACY_DEFAULTS)
        if unknown:
            raise TypeError(f"unknown Engine kwargs: {sorted(unknown)}")
        warnings.warn(
            "Engine(max_batch=..., max_seq=..., ...) keyword configuration "
            "is deprecated; pass an EngineConfig instead: "
            "Engine(cfg, EngineConfig(...), controller=..., cluster=...)",
            DeprecationWarning, stacklevel=3)
        kw = {**_ENGINE_LEGACY_DEFAULTS, **kwargs}
        return cls(max_batch=kw["max_batch"], max_seq=kw["max_seq"],
                   weighted_routing=kw["weighted_routing"],
                   moe_impl=kw["moe_impl"], seed=kw["seed"])

    def resolve(self) -> "EngineConfig":
        """Fill the ``None`` sub-configs with their legacy-equivalent
        defaults (KV pool covering every lane, FCFS whole-prompt
        scheduler) so the engine runs off one fully-specified object."""
        kv = self.kv
        if kv is None:
            bs = KVCacheConfig.block_size
            kv = KVCacheConfig(
                block_size=bs,
                n_blocks=self.max_batch * math.ceil(self.max_seq / bs),
                watermark=0.0)
        sched = self.scheduler if self.scheduler is not None \
            else SchedulerConfig()
        return dataclasses.replace(self, kv=kv, scheduler=sched)


@dataclasses.dataclass(frozen=True)
class SimConfig(ServingConfig):
    """Discrete-event EP simulator configuration (see simulator.py)."""

    max_batch: int = 64              # decode batch cap
    moe_impl: str = "ragged"         # what the MoE kernel computes per rank
    ep_degree: int = 8
    max_prefill_tokens: int = 8192   # prefill chunk budget per step
    ici_bw: Optional[float] = None   # aggregate bytes/s; None = cluster preset
    act_bytes: float = 1.0           # a2a payload bytes/elem (FP8, Table 2a)
    attn_flops_scale: float = 0.35   # MLA-compression adjustment (DESIGN §4)
    poisson_loads: bool = True       # Poisson approx to multinomial (fast)
    realized_loads: bool = False     # score token-granular dispatched loads
    record_layer_stats: bool = False
    migration_overhead: float = 2e-3 # fixed coordination cost per rearrange
    step_overhead: float = 8e-3      # engine scheduling/launch cost per step
    kv: Optional[KVCacheConfig] = None   # block-pool admission (scheduled
    # loop only); None = unbounded admission, the legacy behavior

    def __post_init__(self):
        super().__post_init__()
        if self.moe_impl not in ("ragged", "capacity"):
            raise ValueError("moe_impl must be 'ragged' or 'capacity', "
                             f"got {self.moe_impl!r}")
