"""qwen3-moe-235b-a22b — 128-expert top-8 MoE (paper's second eval model).

[moe] 94L d_model=4096 64H (GQA kv=4) d_ff=1536 vocab=151936, MoE 128e top-8
[hf:Qwen/Qwen3-30B-A3B; hf]
"""

import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=0,                  # every layer MoE
    vocab=151936,
    head_dim=128,            # qwen3 uses head_dim 128 (64H × 128 = 8192 > d_model)
    n_experts=128,
    top_k=8,
    moe_d_ff=1536,
    moe_every=1,
    mlp_gated=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="qwen3-moe-235b-smoke",
    n_layers=3,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    n_experts=8,
    top_k=2,
    moe_d_ff=96,
    vocab=512,
)
