"""codeqwen1.5-7b — qwen1.5-arch dense code LM (MHA: kv == heads).

[dense] 32L d_model=4096 32H (GQA kv=32) d_ff=13440 vocab=92416
[hf:Qwen/CodeQwen1.5-7B; hf]
"""

import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    head_dim=128,
    mlp_gated=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/CodeQwen1.5-7B; hf",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="codeqwen1.5-7b-smoke",
    n_layers=3,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=448,
    vocab=512,
)
