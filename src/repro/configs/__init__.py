"""Assigned architecture configs (+ the paper's own DeepSeek-V3).

``get(name)`` returns the full published config; ``get_smoke(name)`` a
reduced same-family config for CPU tests. ``ALL_ARCHS`` lists the ten
assigned ids (dry-run set); ``deepseek-v3-671b`` is additionally available
as the paper's own model.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from .base import ArchConfig, ShapeSpec, SHAPES, shape_applicable

ALL_ARCHS: List[str] = [
    "smollm-360m",
    "gemma3-4b",
    "starcoder2-7b",
    "codeqwen1.5-7b",
    "jamba-1.5-large-398b",
    "xlstm-350m",
    "hubert-xlarge",
    "granite-moe-3b-a800m",
    "qwen3-moe-235b-a22b",
    "pixtral-12b",
]

EXTRA_ARCHS: List[str] = ["deepseek-v3-671b"]

_MODULES: Dict[str, str] = {
    "smollm-360m": "smollm_360m",
    "gemma3-4b": "gemma3_4b",
    "starcoder2-7b": "starcoder2_7b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "xlstm-350m": "xlstm_350m",
    "hubert-xlarge": "hubert_xlarge",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "pixtral-12b": "pixtral_12b",
    "deepseek-v3-671b": "deepseek_v3",
}


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ArchConfig:
    return _module(name).SMOKE


__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "shape_applicable",
           "ALL_ARCHS", "EXTRA_ARCHS", "get", "get_smoke"]
