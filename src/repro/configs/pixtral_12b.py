"""pixtral-12b — VLM: pixtral-ViT frontend (stubbed) + mistral-nemo decoder.

[vlm] 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072
[hf:mistralai/Pixtral-12B-2409; unverified]

The ViT frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (frontend_dim = pixtral vision hidden 1024),
projected into the first ``n_patches`` sequence positions; the remaining
positions are text tokens.
"""

import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    head_dim=128,
    frontend="vision",
    frontend_dim=1024,       # pixtral ViT hidden size (stubbed)
    n_patches=256,           # patches prepended to the sequence
    mlp_gated=True,
    rope_theta=1_000_000.0,
    source="hf:mistralai/Pixtral-12B-2409; unverified",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="pixtral-12b-smoke",
    n_layers=3,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=384,
    vocab=512,
    frontend_dim=64,
    n_patches=8,
)
