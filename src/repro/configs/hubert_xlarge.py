"""hubert-xlarge — encoder-only audio transformer (w2v2 architecture).

[audio] 48L d_model=1280 16H (GQA kv=16) d_ff=5120 vocab=504
[arXiv:2106.07447; unverified]

The modality frontend (conv feature extractor) is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings of dim
``frontend_dim``; the model projects them to d_model. Encoder-only: no
causal mask, no KV cache, no decode shapes.
"""

import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,               # k-means target codebook (CTC-style head)
    head_dim=80,
    causal=False,
    frontend="audio",
    frontend_dim=512,        # conv feature extractor output dim (stubbed)
    mlp_gated=False,         # w2v2-family: GELU MLP
    source="arXiv:2106.07447; unverified",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="hubert-xlarge-smoke",
    n_layers=3,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=384,
    vocab=64,
    frontend_dim=48,
)
