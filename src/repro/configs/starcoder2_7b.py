"""starcoder2-7b — dense code LM, GQA + RoPE.

[dense] 32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152
[arXiv:2402.19173; hf]
"""

import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab=49152,
    head_dim=128,
    mlp_gated=False,         # starcoder2: standard 2-matrix GELU MLP
    rope_theta=1_000_000.0,
    source="arXiv:2402.19173; hf",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="starcoder2-7b-smoke",
    n_layers=3,
    d_model=144,
    n_heads=6,
    n_kv_heads=2,
    head_dim=24,
    d_ff=576,
    vocab=512,
)
