"""deepseek-v3-671b — the paper's own primary evaluation model.

256 routed experts (top-8) + 1 shared expert, 61 layers, d_model 7168,
expert d_ff 2048 [arXiv:2412.19437]. This is the model behind the paper's
Figs 1, 3–6, 8–14 (8×EP on MI325X/MI300X).

Fidelity note (DESIGN.md §3): DeepSeek-V3 uses MLA attention; ViBE is an
*expert-placement* technique and never touches attention, so we model
attention as GQA (kv=16, head_dim 128) — the MoE side (256 experts, top-8,
shared expert, sigmoid-free softmax gating) is exact, which is what the
placement experiments exercise.
"""

import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=16,
    d_ff=18432,              # first dense layers' FFN (moe_offset below)
    vocab=129280,
    head_dim=128,
    n_experts=256,
    top_k=8,
    moe_d_ff=2048,
    n_shared_experts=1,
    moe_every=1,
    mlp_gated=True,
    source="arXiv:2412.19437 (paper's own model)",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="deepseek-v3-smoke",
    n_layers=3,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    n_experts=16,
    top_k=4,
    moe_d_ff=64,
    n_shared_experts=1,
    vocab=512,
)
