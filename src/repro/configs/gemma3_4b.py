"""gemma3-4b — dense LM with 5:1 local:global attention, 128k context.

[dense] 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144
[hf:google/gemma-3-1b-pt; unverified]
"""

import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_ff=10240,
    vocab=262144,
    head_dim=256,            # gemma3 uses wide heads
    window=1024,             # sliding-window for local layers
    global_every=6,          # 5 local : 1 global
    mlp_gated=True,          # GeGLU-family gated MLP
    rope_theta=1_000_000.0,  # long-context rope base (global layers)
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt; unverified",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="gemma3-4b-smoke",
    n_layers=6,              # one full 5:1 local:global super-block
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=384,
    vocab=512,
    window=16,
)
