"""granite-moe-3b-a800m — fine-grained MoE, 40 experts top-8.

[moe] 32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40e top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

Note (DESIGN.md §5): E=40 does not divide the 16-way production model axis —
at that mesh the experts use expert-TP (d_ff sharded); at EP-divisible
meshes (EP ∈ {8, 10, 20, 40}) the full ViBE placement path applies.
"""

import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=0,                  # every layer is MoE
    vocab=49155,
    head_dim=64,
    n_experts=40,
    top_k=8,
    moe_d_ff=512,
    moe_every=1,
    mlp_gated=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="granite-moe-3b-smoke",
    n_layers=3,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    n_experts=8,
    top_k=2,
    moe_d_ff=64,
    vocab=512,
)
