"""Architecture config schema + shape suite (assigned pool).

Every assigned architecture gets one module in this package defining a
``CONFIG`` (exact published numbers) and a ``SMOKE`` (reduced same-family
config for CPU tests). ``repro.configs.get(name)`` resolves either.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

__all__ = ["ArchConfig", "ShapeSpec", "SHAPES", "shape_applicable"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                        # dense-FFN inner dim (0 = no dense FFN)
    vocab: int
    head_dim: int = 0                # 0 → d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert FFN inner dim
    n_shared_experts: int = 0        # DeepSeek-style always-on experts
    moe_every: int = 1               # a layer is MoE if (l % moe_every == moe_offset)
    moe_offset: int = 0
    # --- attention pattern ---
    causal: bool = True              # False → encoder-only (no decode)
    window: int = 0                  # sliding-window size (0 = full attention)
    global_every: int = 0            # gemma3: 1 global layer per N (rest windowed)
    # --- hybrid (jamba) ---
    attn_every: int = 0              # 1 attention layer per N (rest Mamba); 0 = all attn
    # --- ssm ---
    ssm_d_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    slstm_every: int = 0             # xlstm: 1 sLSTM per N blocks (rest mLSTM)
    # --- frontend stubs ---
    frontend: str = "none"           # none | audio | vision
    frontend_dim: int = 0            # precomputed feature dim fed by input_specs
    n_patches: int = 0               # vlm: image patches prepended to the sequence
    # --- misc ---
    mlp_gated: bool = True           # SwiGLU (True) vs GELU 2-matrix MLP (False)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    source: str = ""                 # provenance tag from the assignment table

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def has_attention(self) -> bool:
        return True  # every assigned arch has some attention (xlstm: none — see is_recurrent)

    @property
    def is_recurrent(self) -> bool:
        return self.family == "ssm"

    @property
    def is_decoder(self) -> bool:
        return self.causal

    def n_params(self) -> int:
        """Total parameter count (embeddings included once unless tied)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        L = self.n_layers
        n_attn = self._n_attn_layers()
        # attention
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        per = attn * n_attn
        # mamba layers
        n_mamba = L - n_attn if self.attn_every else 0
        if n_mamba:
            di = self.ssm_expand * d
            mamba = d * 2 * di + di * self.ssm_conv + di * (self.ssm_d_state * 2 + 2) \
                + di * self.ssm_d_state + di * d
            per += mamba * n_mamba
        if self.family == "ssm":
            di = self.ssm_expand * d
            per += L * (d * 2 * di + di * d + di * (3 * hd // max(hd, 1)))  # approx proj
        # FFN / MoE
        n_moe = self._n_moe_layers()
        n_dense_ffn = (L - n_moe) if self.d_ff else 0
        mats = 3 if self.mlp_gated else 2
        per += n_dense_ffn * mats * d * self.d_ff
        per += n_moe * (self.n_experts + self.n_shared_experts) * mats * d * self.moe_d_ff
        per += n_moe * d * self.n_experts   # router
        # norms (negligible) + frontend proj
        per += 2 * L * d
        if self.frontend_dim:
            per += self.frontend_dim * d
        return emb + per

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if not self.is_moe:
            return self.n_params()
        full = self.n_params()
        n_moe = self._n_moe_layers()
        mats = 3 if self.mlp_gated else 2
        all_experts = n_moe * self.n_experts * mats * self.d_model * self.moe_d_ff
        active = n_moe * self.top_k * mats * self.d_model * self.moe_d_ff
        return full - all_experts + active

    def _n_moe_layers(self) -> int:
        if not self.is_moe:
            return 0
        return sum(1 for l in range(self.n_layers)
                   if l % self.moe_every == self.moe_offset)

    def _n_attn_layers(self) -> int:
        if self.family == "ssm":
            return 0
        if self.attn_every:
            return sum(1 for l in range(self.n_layers) if l % self.attn_every == 0)
        return self.n_layers


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


#: The assigned LM shape suite (applies to every arch, modulo skips).
SHAPES: Dict[str, ShapeSpec] = {
    "train_4k":    ShapeSpec("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeSpec("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeSpec("long_500k",   524_288, 1,   "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Skip matrix (DESIGN.md §5). Returns (runnable, reason_if_not)."""
    if shape.kind == "decode" and not cfg.is_decoder:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k":
        sub_quadratic = (cfg.family in ("ssm", "hybrid")
                         or (cfg.window > 0 and cfg.global_every > 0)
                         or (cfg.window > 0))
        if not sub_quadratic:
            return False, "pure full-attention arch — 500k context skipped"
    return True, ""
