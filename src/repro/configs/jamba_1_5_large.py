"""jamba-1.5-large-398b — hybrid Mamba+attention (1:7 interleave) with MoE.

[hybrid] 72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536,
MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE [arXiv:2403.19887; hf]

Layer pattern (per arXiv:2403.19887): blocks of 8 layers, 1 attention + 7
Mamba; MoE replaces the MLP on every other layer (moe_every=2).
"""

import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,              # dense-MLP layers inner dim
    vocab=65536,
    head_dim=128,
    n_experts=16,
    top_k=2,
    moe_d_ff=24576,          # expert inner dim
    moe_every=2,
    moe_offset=1,
    attn_every=8,            # 1 attention layer per 8 (rest Mamba)
    ssm_d_state=16,
    ssm_conv=4,
    ssm_expand=2,
    mlp_gated=True,
    source="arXiv:2403.19887; hf",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="jamba-1.5-large-smoke",
    n_layers=8,              # one full super-block: 1 attn + 7 mamba
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    moe_d_ff=256,
    n_experts=4,
    top_k=2,
    vocab=512,
    ssm_d_state=8,
)
