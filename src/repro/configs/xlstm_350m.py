"""xlstm-350m — sLSTM + mLSTM recurrent LM.

[ssm] 24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304
[arXiv:2405.04517; unverified]

Block pattern per the xLSTM paper's 7:1 ratio: 1 sLSTM block per 8, rest
mLSTM (matrix-memory). d_ff=0: blocks carry their own up/down projections
(expand factor 2) instead of a separate FFN.
"""

import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    head_dim=256,
    slstm_every=8,           # 1 sLSTM per 8 blocks, rest mLSTM
    ssm_expand=2,
    mlp_gated=True,
    source="arXiv:2405.04517; unverified",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="xlstm-350m-smoke",
    n_layers=2,
    d_model=64,
    n_heads=2,
    n_kv_heads=2,
    head_dim=32,
    vocab=512,
    slstm_every=2,
)
