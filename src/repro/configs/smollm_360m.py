"""smollm-360m — llama-arch small dense LM.

[dense] 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152
[hf:HuggingFaceTB/SmolLM-135M; hf]
"""

import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab=49152,
    head_dim=64,
    mlp_gated=True,          # llama family: SwiGLU
    rope_theta=10000.0,
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M; hf",
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="smollm-360m-smoke",
    n_layers=3,
    d_model=96,
    n_heads=3,
    n_kv_heads=1,
    head_dim=32,
    d_ff=256,
    vocab=512,
)
