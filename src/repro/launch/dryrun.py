import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the *real* step function (train_step /
prefill_step / serve_step), lowers it against ShapeDtypeStruct stand-ins
with the production shardings (no allocation), compiles it, and records:

* ``memory_analysis()``   — per-device buffer sizes (proves it fits),
* ``cost_analysis()``     — XLA's module-level FLOPs (body-once),
* trip-count-corrected FLOPs / bytes / collective bytes from the compiled
  HLO text (launch/hlo_analysis.py) — the §Roofline inputs.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out results/dryrun

A failure here (sharding mismatch, OOM at compile, unsupported collective)
is a bug in the system, not in the run.
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs import ALL_ARCHS, EXTRA_ARCHS, SHAPES, get, shape_applicable
from repro.models import (decode_fn, init_params, loss_fn,
                          make_moe_tables, prefill_fn)
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update, \
    cosine_lr
from .hlo_analysis import parse_hlo
from .mesh import make_production_mesh
from .sharding import batch_specs, cache_specs, make_rules, param_specs, \
    tree_shardings

__all__ = ["run_cell", "input_specs", "main"]


def _struct_tree(shapes, specs, mesh):
    return jax.tree.map(
        lambda st, sp: jax.ShapeDtypeStruct(st.shape, st.dtype,
                                            sharding=NamedSharding(mesh, sp)),
        shapes, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def input_specs(arch: str, shape_name: str, mesh) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins (+ shardings) for one cell."""
    cfg = get(arch)
    shape = SHAPES[shape_name]
    phase = {"train": "train", "prefill": "prefill",
             "decode": "decode"}[shape.kind]
    rules = make_rules(cfg, mesh, phase)
    out: Dict[str, Any] = {"cfg": cfg, "rules": rules, "shape": shape,
                           "phase": phase}

    pshapes = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), rules, phase))
    pspecs = param_specs(cfg, rules, phase)
    out["params"] = _struct_tree(pshapes, pspecs, mesh)
    out["param_specs"] = pspecs

    if cfg.is_moe:
        st, nc, cdf = make_moe_tables(cfg, rules, phase=phase)
        out["moe_tables"] = (jax.device_put(st), jax.device_put(nc),
                             jax.device_put(cdf))
    else:
        out["moe_tables"] = None

    if shape.kind in ("train", "prefill"):
        bshapes, bspecs = batch_specs(cfg, rules, shape)
        out["batch"] = _struct_tree(bshapes, bspecs, mesh)
    if shape.kind == "train":
        oshapes = jax.eval_shape(adamw_init, pshapes)
        # moments/master mirror the param specs leaf-wise (ZeRO-style)
        ospecs = type(oshapes)(P(), pspecs, pspecs, pspecs)
        out["opt"] = _struct_tree(oshapes, ospecs, mesh)
        out["opt_specs"] = ospecs
    if shape.kind == "decode":
        B, S = shape.global_batch, shape.seq_len
        cshapes, cspecs = cache_specs(cfg, rules, B, S)
        out["cache"] = _struct_tree(cshapes, cspecs, mesh)
        out["token"] = jax.ShapeDtypeStruct(
            (B, 1), jnp.int32, sharding=NamedSharding(mesh, rules.spec(
                rules.dp if B % max(rules.axis_size(rules.dp), 1) == 0
                else None, None)))
        out["pos"] = jax.ShapeDtypeStruct(
            (B,), jnp.int32, sharding=NamedSharding(mesh, P()))
    return out


def _build_lowered(spec: Dict[str, Any], mesh):
    cfg, rules, shape = spec["cfg"], spec["rules"], spec["shape"]
    if shape.kind == "train":
        lossf = loss_fn(cfg, rules)
        ocfg = AdamWConfig()

        def step(params, opt, batch, mt):
            (loss, (tallies, aux)), grads = jax.value_and_grad(
                lossf, has_aux=True)(params, batch, mt)
            lr = cosine_lr(ocfg, opt.step)
            params, opt = adamw_update(grads, opt, params, ocfg, lr)
            return params, opt, loss, tallies

        pshard = tree_shardings(mesh, spec["param_specs"])
        oshard = tree_shardings(mesh, spec["opt_specs"])
        fn = jax.jit(step, donate_argnums=(0, 1),
                     out_shardings=(pshard, oshard,
                                    NamedSharding(mesh, P()),
                                    NamedSharding(mesh, P())))
        return fn.lower(spec["params"], spec["opt"], spec["batch"],
                        spec["moe_tables"])
    if shape.kind == "prefill":
        pf = prefill_fn(cfg, rules)
        fn = jax.jit(pf)
        return fn.lower(spec["params"], spec["batch"], spec["moe_tables"])
    df = decode_fn(cfg, rules)
    fn = jax.jit(df, donate_argnums=(2,))
    return fn.lower(spec["params"], spec["token"], spec["cache"],
                    spec["pos"], spec["moe_tables"])


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             analyze: bool = True) -> Dict[str, Any]:
    """Lower+compile one cell; returns the record for EXPERIMENTS.md."""
    cfg = get(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_params": cfg.n_params(), "n_active_params": cfg.n_active_params(),
    }
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        spec = input_specs(arch, shape_name, mesh)
        with compat.use_mesh(mesh):
            lowered = _build_lowered(spec, mesh)
            t1 = time.time()
            compiled = lowered.compile()
        t2 = time.time()
        rec.update(status="ok", lower_s=round(t1 - t0, 1),
                   compile_s=round(t2 - t1, 1))
        try:
            ma = compiled.memory_analysis()
            rec["memory"] = {
                k: int(getattr(ma, k)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes")
                if hasattr(ma, k)}
            arg = rec["memory"].get("argument_size_in_bytes", 0)
            tmp = rec["memory"].get("temp_size_in_bytes", 0)
            alias = rec["memory"].get("alias_size_in_bytes", 0)
            outb = rec["memory"].get("output_size_in_bytes", 0)
            rec["memory"]["per_device_total_bytes"] = arg + tmp + max(
                outb - alias, 0)
        except Exception as e:                      # pragma: no cover
            rec["memory_error"] = str(e)
        try:
            ca = compat.cost_analysis_dict(compiled)
            rec["xla_cost"] = {k: float(ca[k]) for k in
                               ("flops", "bytes accessed") if k in ca}
        except Exception as e:                      # pragma: no cover
            rec["xla_cost_error"] = str(e)
        if analyze:
            costs = parse_hlo(compiled.as_text())
            rec["hlo"] = {
                "flops_per_device": costs.flops,
                "bytes_per_device": costs.bytes_accessed,
                "collective_bytes_per_device": costs.collective_bytes,
                "collective_by_kind": costs.collective_by_kind,
                "while_trip_counts": costs.while_trip_counts,
            }
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:],
                   elapsed_s=round(time.time() - t0, 1))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--include-extra", action="store_true",
                    help="also run the paper's own deepseek-v3 config")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-analyze", action="store_true")
    args = ap.parse_args()

    archs = ([args.arch] if args.arch else
             ALL_ARCHS + (EXTRA_ARCHS if args.include_extra else []))
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_fail = 0
    for multi in meshes:
        for arch in archs:
            for shape in shapes:
                tag = f"{'multi' if multi else 'single'}__{arch}__{shape}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[cached] {tag}: {prev['status']}")
                        continue
                rec = run_cell(arch, shape, multi,
                               analyze=not args.no_analyze)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                msg = rec["status"]
                if rec["status"] == "ok":
                    mem = rec.get("memory", {}).get("per_device_total_bytes", 0)
                    msg += (f" compile={rec['compile_s']}s "
                            f"mem/dev={mem/2**30:.2f}GiB "
                            f"flops/dev={rec.get('hlo', {}).get('flops_per_device', 0):.3g}")
                elif rec["status"] == "error":
                    n_fail += 1
                    msg += " " + rec["error"][:160]
                print(f"[{tag}] {msg}", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
