"""Serving driver: the JAX engine with ViBE end-to-end on real routing.

Brings up a smoke-scale model in the continuous-batching engine, profiles
the cluster (Alg 1 Phase 1), computes the initial placement (Phase 2),
serves with drift-aware recalibration (Phase 3) and reports SLO metrics
against the virtual clock (DESIGN.md §4).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-235b-a22b \
        --requests 12 --policy vibe
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_smoke
from repro.core import (DriftConfig, PerfDriftConfig, SCENARIOS, ViBEConfig,
                        ViBEController, make_cluster, make_scenario,
                        registered_policies)
from repro.models import moe_perm_shape
from repro.serving import Engine, WORKLOADS, sample_requests, summarize

__all__ = ["serve", "main"]


def serve(arch: str, *, policy: str = "vibe", n_requests: int = 12,
          qps: float = 50.0, workload: str = "sharegpt",
          regime: str = "mi325x", max_batch: int = 4, max_seq: int = 96,
          adaptive: bool = True, weighted_routing: bool = True,
          moe_impl: str = "ragged", variability_scenario: str = "none",
          scenario_start: float = 0.0, scenario_duration: float = 2.0,
          perf_drift_delta: float = 0.0, seed: int = 0):
    cfg = get_smoke(arch)
    if not cfg.is_moe:
        raise SystemExit(f"{arch} has no MoE layers — ViBE serving n/a")
    n_moe, n_slots = moe_perm_shape(cfg, None, "train")
    ranks = min(8, n_slots)
    # hardware-drift schedule: the ground-truth cluster changes over the
    # virtual clock (thermal ramp, power cap, interference, replacement)
    events = ([] if variability_scenario in ("none", "")
              else make_scenario(variability_scenario, ranks,
                                 t0=scenario_start,
                                 duration=scenario_duration))
    cluster = make_cluster(ranks, regime, d_model=cfg.d_model,
                           d_ff=cfg.moe_d_ff,
                           experts_per_rank=max(n_slots // ranks, 1),
                           seed=seed, events=events)
    perf = cluster.fit_models()                    # Phase 1: profiling (t=0)
    # ``policy`` may be any name in the repro.core.policy registry;
    # replication-capable policies use their default slot budget (singleton
    # footprint plus one spare replica slot per rank) and the engine reads
    # the resulting budget off the controller's placement.
    controller = ViBEController(
        n_moe, n_slots, ranks, perf,
        ViBEConfig(policy=policy, adaptive=adaptive,
                   drift=DriftConfig(window=20, interval=5, cooldown=5),
                   perf_drift=(PerfDriftConfig(delta_perf=perf_drift_delta,
                                               window=64, interval=5,
                                               cooldown=10, min_samples=8)
                               if perf_drift_delta > 0 else None),
                   expert_bytes=3 * cfg.d_model * cfg.moe_d_ff * 2))
    # weighted_routing threads the vibe_r solver's per-copy traffic shares
    # into the dispatch tables (share-weighted replica routing); disabling
    # it keeps the legacy uniform split for A/B comparison.
    engine = Engine(cfg, controller=controller, cluster=cluster,
                    max_batch=max_batch, max_seq=max_seq,
                    weighted_routing=weighted_routing, moe_impl=moe_impl,
                    seed=seed)
    wl = WORKLOADS[workload]
    reqs = sample_requests(wl, n_requests, qps=qps, seed=seed)
    reqs = [type(r)(r.req_id, r.arrival, min(r.prompt_len, max_seq // 2),
                    min(r.output_len, max_seq // 2 - 1)) for r in reqs]
    engine.submit(reqs)
    records = engine.run()
    return engine, records


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-235b-a22b")
    ap.add_argument("--policy", default="vibe",
                    choices=list(registered_policies()))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--workload", default="sharegpt")
    ap.add_argument("--regime", default="mi325x")
    ap.add_argument("--static", dest="adaptive", action="store_false")
    ap.add_argument("--uniform-replica-routing", dest="weighted_routing",
                    action="store_false",
                    help="ignore the solver's per-copy traffic shares and "
                         "split assignments uniformly across replicas "
                         "(share-oblivious A/B baseline; vibe_r only)")
    ap.add_argument("--moe-impl", choices=("ragged", "capacity"),
                    default="ragged",
                    help="grouped-FFN implementation the virtual clock "
                         "prices: 'ragged' (default) = sort-based dropless "
                         "dispatch, MoE cost tracks realized routed tokens; "
                         "'capacity' = fixed per-slot buckets, every rank "
                         "pays slots×capacity rows and overflow drops "
                         "(legacy baseline)")
    ap.add_argument("--variability-scenario", default="none",
                    choices=("none",) + tuple(sorted(SCENARIOS)),
                    help="hardware-drift schedule applied to the ground-"
                         "truth cluster over the virtual clock (thermal "
                         "ramp on one device, fleet power cap, transient "
                         "interference, device replacement)")
    ap.add_argument("--scenario-start", type=float, default=0.0,
                    help="virtual-clock time (s) the drift scenario begins")
    ap.add_argument("--scenario-duration", type=float, default=2.0,
                    help="ramp/transient length (s) for scenarios that "
                         "have one")
    ap.add_argument("--perf-drift-delta", type=float, default=0.0,
                    help="enable online performance-drift recalibration: "
                         "refit f_g and re-solve when any rank's windowed "
                         "relative latency residual exceeds this threshold "
                         "(0 = routing-only recalibration, the default)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    engine, records = serve(args.arch, policy=args.policy,
                            n_requests=args.requests,
                            workload=args.workload, regime=args.regime,
                            adaptive=args.adaptive,
                            weighted_routing=args.weighted_routing,
                            moe_impl=args.moe_impl,
                            variability_scenario=args.variability_scenario,
                            scenario_start=args.scenario_start,
                            scenario_duration=args.scenario_duration,
                            perf_drift_delta=args.perf_drift_delta,
                            seed=args.seed)
    s = summarize(records)
    st = engine.stats
    routing = ("share-weighted" if args.weighted_routing
               else "uniform") + f" replica routing, {args.moe_impl} FFN"
    print(f"[serve] {args.policy} on {args.arch} ({routing}): "
          f"{st.steps} steps "
          f"({st.prefill_steps} prefill / {st.decode_steps} decode), "
          f"virtual time {st.virtual_time:.3f}s")
    print(f"[serve] TTFT p50/p90 = {s['ttft_p50']:.4f}/{s['ttft_p90']:.4f}s "
          f"TPOT p50 = {s['tpot_p50']:.5f}s")
    kinds = {}
    for u in engine.controller.updates:
        kinds[u.kind] = kinds.get(u.kind, 0) + 1
    by_kind = (" (" + ", ".join(f"{k}: {v}" for k, v in sorted(kinds.items()))
               + ")") if kinds else ""
    print(f"[serve] recalibrations: {st.migrations}{by_kind}, migrated slots "
          f"{st.migrated_slots}, bytes {st.migration_bytes}, dropped "
          f"assignments {st.dropped_assignments:.0f}")
    if args.variability_scenario != "none":
        print(f"[serve] hardware drift: scenario {args.variability_scenario} "
              f"from t={args.scenario_start:.2f}s, perf-drift delta "
              f"{args.perf_drift_delta:g}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
