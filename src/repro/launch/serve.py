"""Serving driver: the JAX engine with ViBE end-to-end on real routing.

Brings up a smoke-scale model in the continuous-batching engine, profiles
the cluster (Alg 1 Phase 1), computes the initial placement (Phase 2),
serves with drift-aware recalibration (Phase 3) and reports SLO metrics
against the virtual clock (DESIGN.md §4).

The engine side is configured through :class:`EngineConfig`: pick a
scheduler from the registry (``--scheduler slo_edf``), enable chunked
prefill (``--prefill-chunk 12``), size the paged KV block pool
(``--kv-blocks/--block-size``), and feed either a single workload family
or a multi-tenant arrival trace (``--workload bursty``).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-235b-a22b \
        --requests 12 --policy vibe --scheduler slo_edf --workload bursty
"""

from __future__ import annotations

import argparse
import dataclasses
from typing import Optional, Union

import numpy as np

from repro.configs import get_smoke
from repro.core import (DriftConfig, PerfDriftConfig, SCENARIOS, StealConfig,
                        ViBEConfig, ViBEController, default_slots_per_rank,
                        get_policy, make_cluster, make_scenario, parse_topology,
                        registered_policies)
from repro.models import moe_perm_shape
from repro.serving import (ChaosReport, Engine, EngineConfig, FaultSchedule,
                           KVCacheConfig, SchedulerConfig, TRACES, WORKLOADS,
                           registered_schedulers, run_chaos,
                           run_with_failure, sample_requests, sample_trace,
                           summarize)

__all__ = ["serve", "derive_slot_budget", "main"]


def derive_slot_budget(n_ranks: int, n_experts: int, expert_bytes: int,
                       spec: Union[str, int, None] = "auto"):
    """Per-rank physical slot budget from device memory telemetry.

    ``spec``:

    * ``"auto"``  — query the local accelerator's allocator
      (``jax.Device.memory_stats``) for free HBM, emulate ``n_ranks``
      devices sharing it, and size each rank's replica budget by how many
      expert tensors fit in its share after a safety margin. Hosts
      without memory telemetry (the CPU CI runner) fall back
      deterministically to the policy-default budget, so smoke runs are
      identical across hosts.
    * ``"default"`` / ``None`` — policy-default budget (returns None).
    * an integer — uniform per-rank budget, passed through.

    Returns a ``(n_ranks,)`` int array or None (= let the policy choose).
    """
    if spec in (None, "default", ""):
        return None
    if not isinstance(spec, str) or spec.lstrip("-").isdigit():
        return np.full(n_ranks, int(spec), dtype=np.int64)
    if spec != "auto":
        raise ValueError("slots_per_rank must be 'auto', 'default' or an "
                         f"integer, got {spec!r}")
    base = default_slots_per_rank(n_experts, n_ranks)
    stats = None
    try:
        import jax
        stats = jax.local_devices()[0].memory_stats()
    except Exception:
        stats = None
    if not stats:
        # deterministic CPU fallback: exactly the policy-default budget
        return np.full(n_ranks, base, dtype=np.int64)
    free = int(stats.get("bytes_limit", 0)) - int(stats.get("bytes_in_use", 0))
    if free <= 0:
        return np.full(n_ranks, base, dtype=np.int64)
    # 80% of this emulated rank's share of free memory holds its experts;
    # clamp to [policy default, E) so the budget always solves
    fit = int(0.8 * free / n_ranks / max(expert_bytes, 1))
    per_rank = int(np.clip(fit, base, max(n_experts - 1, base)))
    return np.full(n_ranks, per_rank, dtype=np.int64)


def serve(arch: str, *, policy: str = "vibe", n_requests: int = 12,
          qps: float = 50.0, workload: str = "sharegpt",
          regime: str = "mi325x", max_batch: int = 4, max_seq: int = 96,
          adaptive: bool = True, weighted_routing: bool = True,
          moe_impl: str = "ragged", scheduler: str = "fcfs",
          prefill_chunk: int = 0, kv_blocks: Optional[int] = None,
          block_size: int = 16, slots_per_rank: Union[str, int, None] = "auto",
          variability_scenario: str = "none",
          scenario_start: float = 0.0, scenario_duration: float = 2.0,
          perf_drift_delta: float = 0.0, steal: bool = False,
          steal_headroom: float = 0.1, topology: Optional[str] = None,
          fail_rank: int = -1, fail_at_step: int = 5,
          chaos: Optional[str] = None, shed_watermark: float = 0.0,
          preempt: bool = False, seed: int = 0):
    """Returns ``(engine, records, report)``; ``report`` is None unless
    ``fail_rank >= 0`` ran the elasticity drill (:class:`FailureReport`)
    or ``chaos`` ran the chaos drill (:class:`ChaosReport`)."""
    if chaos and fail_rank >= 0:
        raise SystemExit("--chaos and --fail-rank are mutually exclusive "
                         "(a chaos schedule already includes rank faults)")
    cfg = get_smoke(arch)
    if not cfg.is_moe:
        raise SystemExit(f"{arch} has no MoE layers — ViBE serving n/a")
    n_moe, n_slots = moe_perm_shape(cfg, None, "train")
    ranks = min(8, n_slots)
    # hardware-drift schedule: the ground-truth cluster changes over the
    # virtual clock (thermal ramp, power cap, interference, replacement)
    events = ([] if variability_scenario in ("none", "")
              else make_scenario(variability_scenario, ranks,
                                 t0=scenario_start,
                                 duration=scenario_duration))
    cluster = make_cluster(ranks, regime, d_model=cfg.d_model,
                           d_ff=cfg.moe_d_ff,
                           experts_per_rank=max(n_slots // ranks, 1),
                           seed=seed, events=events)
    perf = cluster.fit_models()                    # Phase 1: profiling (t=0)
    topo = None
    if topology:
        # fleet topology spec ("2x4" = 2 nodes x 4 devices, "8" = flat):
        # threads into the solver (vibe_h node binning) and both pricing
        # paths (migration / broadcast costs see the ICI/DCN asymmetry)
        topo = parse_topology(topology, ici_bw=cluster.ici_bw)
        if topo.n_ranks != ranks:
            raise SystemExit(f"topology {topology!r} has {topo.n_ranks} "
                             f"ranks but the engine runs {ranks}")
    expert_bytes = 3 * cfg.d_model * cfg.moe_d_ff * 2
    # replication-capable policies honour a per-rank physical slot budget
    # derived from device memory telemetry (paper §5.1's non-uniform
    # allocation); other policies keep their fixed footprint.
    budget = None
    if get_policy(policy).capabilities.accepts_slot_budget:
        budget = derive_slot_budget(ranks, cfg.n_experts, expert_bytes,
                                    slots_per_rank)
    controller = ViBEController(
        n_moe, n_slots, ranks, perf,
        ViBEConfig(policy=policy, adaptive=adaptive,
                   drift=DriftConfig(window=20, interval=5, cooldown=5),
                   perf_drift=(PerfDriftConfig(delta_perf=perf_drift_delta,
                                               window=64, interval=5,
                                               cooldown=10, min_samples=8)
                               if perf_drift_delta > 0 else None),
                   expert_bytes=expert_bytes,
                   slot_budget=budget,
                   steal=(StealConfig(headroom=steal_headroom)
                          if steal else None),
                   topology=topo))
    # weighted_routing threads the vibe_r solver's per-copy traffic shares
    # into the dispatch tables (share-weighted replica routing); disabling
    # it keeps the legacy uniform split for A/B comparison.
    econfig = EngineConfig(
        max_batch=max_batch, max_seq=max_seq, moe_impl=moe_impl, seed=seed,
        weighted_routing=weighted_routing,
        scheduler=SchedulerConfig(name=scheduler,
                                  prefill_chunk=prefill_chunk,
                                  shed_watermark=shed_watermark,
                                  preempt_decodes=preempt),
        kv=(KVCacheConfig(block_size=block_size, n_blocks=kv_blocks)
            if kv_blocks else None),
        topology=topo)
    engine = Engine(cfg, econfig, controller=controller, cluster=cluster)
    if workload in TRACES:
        reqs = sample_trace(TRACES[workload], n_requests, qps=qps, seed=seed)
    else:
        reqs = sample_requests(WORKLOADS[workload], n_requests, qps=qps,
                               seed=seed)
    reqs = [dataclasses.replace(r, prompt_len=min(r.prompt_len, max_seq // 2),
                                output_len=min(r.output_len,
                                               max_seq // 2 - 1))
            for r in reqs]
    if chaos:
        # chaos drill: serve under a declarative fault schedule, then
        # audit the invariants (leaks, completion-or-reject, token ledger)
        schedule = FaultSchedule.parse(chaos, ranks)
        report = run_chaos(engine, reqs, schedule)
        return engine, report.records, report
    if fail_rank >= 0:
        # elasticity drill: kill a rank mid-traffic, serve through it
        records, report = run_with_failure(engine, reqs, fail_rank,
                                           at_step=fail_at_step)
        return engine, records, report
    engine.submit(reqs)
    records = engine.run()
    return engine, records, None


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-235b-a22b")
    ap.add_argument("--policy", default="vibe",
                    choices=list(registered_policies()))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--workload", default="sharegpt",
                    choices=sorted(WORKLOADS) + sorted(TRACES),
                    help="a workload family (Poisson arrivals) or a "
                         "multi-tenant arrival trace (bursty/diurnal/flat)")
    ap.add_argument("--qps", type=float, default=50.0)
    ap.add_argument("--regime", default="mi325x")
    ap.add_argument("--scheduler", default="fcfs",
                    choices=list(registered_schedulers()),
                    help="continuous-batching scheduler (serving/"
                         "scheduler.py registry)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="split prompts into fixed chunks of this many "
                         "tokens, interleaved with decode steps "
                         "(0 = whole-prompt prefill)")
    ap.add_argument("--kv-blocks", type=int, default=0,
                    help="paged KV cache pool size in blocks (0 = pool "
                         "sized to exactly cover the decode lanes)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV block")
    ap.add_argument("--slots-per-rank", default="auto",
                    help="replica slot budget per rank for replication-"
                         "capable policies: 'auto' (device memory "
                         "telemetry, deterministic CPU fallback), "
                         "'default', or an integer")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=96)
    ap.add_argument("--static", dest="adaptive", action="store_false")
    ap.add_argument("--uniform-replica-routing", dest="weighted_routing",
                    action="store_false",
                    help="ignore the solver's per-copy traffic shares and "
                         "split assignments uniformly across replicas "
                         "(share-oblivious A/B baseline; vibe_r only)")
    ap.add_argument("--moe-impl", choices=("ragged", "capacity"),
                    default="ragged",
                    help="grouped-FFN implementation the virtual clock "
                         "prices: 'ragged' (default) = sort-based dropless "
                         "dispatch, MoE cost tracks realized routed tokens; "
                         "'capacity' = fixed per-slot buckets, every rank "
                         "pays slots×capacity rows and overflow drops "
                         "(legacy baseline)")
    ap.add_argument("--variability-scenario", default="none",
                    choices=("none",) + tuple(sorted(SCENARIOS)),
                    help="hardware-drift schedule applied to the ground-"
                         "truth cluster over the virtual clock (thermal "
                         "ramp on one device, fleet power cap, transient "
                         "interference, device replacement)")
    ap.add_argument("--scenario-start", type=float, default=0.0,
                    help="virtual-clock time (s) the drift scenario begins")
    ap.add_argument("--scenario-duration", type=float, default=2.0,
                    help="ramp/transient length (s) for scenarios that "
                         "have one")
    ap.add_argument("--steal", action="store_true",
                    help="dispatch-time token rescheduling (work stealing): "
                         "between recalibrations, shift bounded traffic "
                         "shares off the predicted-slowest rank's replica "
                         "copies toward copies on faster ranks (replication-"
                         "capable policies only, e.g. --policy vibe_r)")
    ap.add_argument("--steal-headroom", type=float, default=0.1,
                    help="steal only when the hottest rank's predicted "
                         "latency exceeds the fleet mean by this relative "
                         "margin (default 0.1)")
    ap.add_argument("--topology", default=None,
                    help="fleet topology spec: 'KxD' (K nodes x D devices, "
                         "ICI within a node, ~8x-slower DCN between nodes) "
                         "or 'G' (flat). Threads into the solver (vibe_h "
                         "bins experts by node) and the virtual clock's "
                         "migration/broadcast pricing")
    ap.add_argument("--fail-rank", type=int, default=-1,
                    help="elasticity drill: kill this EP rank after a few "
                         "engine steps — drain its lanes, mask it out of "
                         "the solve, remap onto the survivors, re-admit "
                         "(-1 = no failure)")
    ap.add_argument("--chaos", default=None,
                    help="chaos drill: serve under a declarative fault "
                         "schedule and audit the invariants (no leaked KV, "
                         "complete-or-typed-reject, token conservation). "
                         "'default' / 'default:SEED' draws a randomized "
                         "fail+stall+dcn+recover drill; or a comma list "
                         "like 'fail@4:1,stall@6:2x0.4+0.5,recover@9:1'")
    ap.add_argument("--shed-watermark", type=float, default=0.0,
                    help="overload protection: once KV-pool utilization "
                         "reaches this fraction, shed waiting requests "
                         "whose TTFT deadline already lapsed (typed "
                         "rejection; 0 = never shed)")
    ap.add_argument("--preempt", action="store_true",
                    help="overload protection: under KV starvation, evict "
                         "the youngest decode lane (free its KV, requeue "
                         "the request, bounded retries) so waiting work "
                         "can admit")
    ap.add_argument("--perf-drift-delta", type=float, default=0.0,
                    help="enable online performance-drift recalibration: "
                         "refit f_g and re-solve when any rank's windowed "
                         "relative latency residual exceeds this threshold "
                         "(0 = routing-only recalibration, the default)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    engine, records, report = serve(args.arch, policy=args.policy,
                            n_requests=args.requests, qps=args.qps,
                            workload=args.workload, regime=args.regime,
                            max_batch=args.max_batch, max_seq=args.max_seq,
                            adaptive=args.adaptive,
                            weighted_routing=args.weighted_routing,
                            moe_impl=args.moe_impl,
                            scheduler=args.scheduler,
                            prefill_chunk=args.prefill_chunk,
                            kv_blocks=args.kv_blocks or None,
                            block_size=args.block_size,
                            slots_per_rank=args.slots_per_rank,
                            variability_scenario=args.variability_scenario,
                            scenario_start=args.scenario_start,
                            scenario_duration=args.scenario_duration,
                            perf_drift_delta=args.perf_drift_delta,
                            steal=args.steal,
                            steal_headroom=args.steal_headroom,
                            topology=args.topology,
                            fail_rank=args.fail_rank,
                            chaos=args.chaos,
                            shed_watermark=args.shed_watermark,
                            preempt=args.preempt,
                            seed=args.seed)
    s = summarize(records)
    st = engine.stats
    routing = ("share-weighted" if args.weighted_routing
               else "uniform") + f" replica routing, {args.moe_impl} FFN"
    sched = (f"{args.scheduler}"
             + (f", chunk={args.prefill_chunk}" if args.prefill_chunk
                else ", whole-prompt"))
    print(f"[serve] {args.policy} on {args.arch} ({routing}; {sched}): "
          f"{st.steps} steps "
          f"({st.prefill_steps} prefill / {st.chunk_steps} chunks / "
          f"{st.decode_steps} decode), "
          f"virtual time {st.virtual_time:.3f}s")
    print(f"[serve] TTFT p50/p90 = {s['ttft_p50']:.4f}/{s['ttft_p90']:.4f}s "
          f"TPOT p50 = {s['tpot_p50']:.5f}s")
    print(f"[serve] KV pool: {engine.kv.config.n_blocks} blocks x "
          f"{engine.kv.config.block_size} tokens, peak used "
          f"{engine.kv.peak_blocks}")
    kinds = {}
    for u in engine.controller.updates:
        kinds[u.kind] = kinds.get(u.kind, 0) + 1
    by_kind = (" (" + ", ".join(f"{k}: {v}" for k, v in sorted(kinds.items()))
               + ")") if kinds else ""
    print(f"[serve] recalibrations: {st.migrations}{by_kind}, migrated slots "
          f"{st.migrated_slots}, bytes {st.migration_bytes}, dropped "
          f"assignments {st.dropped_assignments:.0f}")
    if st.rejected or st.preemptions:
        by_r = ", ".join(f"{k}: {v}" for k, v in sorted(st.rejected.items()))
        print(f"[serve] overload: rejected {sum(st.rejected.values())}"
              + (f" ({by_r})" if by_r else "")
              + f", preemptions {st.preemptions}")
    if isinstance(report, ChaosReport):
        print(f"[serve] {report.summary()}")
        for spec, why in report.skipped:
            print(f"[serve]   skipped {spec.kind}@{spec.at_step}: {why}")
        finished = sum(1 for r in records if np.isfinite(r.finished_at))
        print(f"[serve] chaos drill: {finished}/{len(records)} finished, "
              "token ledger prefill+decode="
              f"{st.prefill_tokens + st.decode_tokens} vs useful+lost="
              f"{st.useful_tokens + st.lost_tokens}")
        if not report.ok:
            for v in report.violations:
                print(f"[serve] CHAOS VIOLATION: {v}")
            return 1
    elif report is not None:
        finished = sum(1 for r in records if np.isfinite(r.finished_at))
        print(f"[serve] failure drill: rank {report.rank} died at "
              f"t={report.at_time:.3f}s — drained "
              f"{report.drained_prefills} prefills / "
              f"{report.drained_decodes} decodes, "
              f"{report.redone_tokens} tokens redone, "
              f"{report.moved_experts} expert slots remapped; "
              f"{finished}/{len(records)} requests completed, "
              f"KV blocks in use after drain: {engine.kv.used_blocks}")
        if finished < len(records) or engine.kv.used_blocks != 0:
            print("[serve] FAILURE DRILL FAILED: incomplete requests or "
                  "leaked KV blocks")
            return 1
    if args.steal:
        rs = engine.controller.rescheduler
        print(f"[serve] stealing: {st.steal_updates} share updates "
              f"({rs.steals} steal steps, {rs.share_moved:.3f} total share "
              f"moved, headroom {args.steal_headroom:g})")
    if args.variability_scenario != "none":
        print(f"[serve] hardware drift: scenario {args.variability_scenario} "
              f"from t={args.scenario_start:.2f}s, perf-drift delta "
              f"{args.perf_drift_delta:g}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
