"""Training driver: data → model → optimizer → checkpoint → fault tolerance.

Runs real steps on whatever devices exist (smoke configs on this CPU host;
the same code path lowers on the production mesh — the dry-run proves it).
Integrates the production features end-to-end:

* async sharded checkpointing with atomic commit + restart,
* per-step routing-tally collection feeding a ViBE placement for the
  *serving* fleet (training is where activation profiling happens),
* straggler EWMA tracking (per-step wall time here; per-rank on real HW).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-moe-235b-a22b \
        --smoke --steps 20 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get, get_smoke
from repro.models import init_params, loss_fn, make_moe_tables
from repro.training import (AdamWConfig, Checkpointer, DataConfig,
                            adamw_init, adamw_update, cosine_lr,
                            synthetic_batch)

__all__ = ["train", "main"]


def train(arch: str, *, smoke: bool = True, steps: int = 20,
          seq_len: int = 64, batch: int = 4, ckpt_dir: str = "",
          ckpt_every: int = 10, seed: int = 0, log_every: int = 5,
          resume: bool = True):
    cfg = get_smoke(arch) if smoke else get(arch)
    data = DataConfig(seq_len=seq_len, global_batch=batch, seed=seed)
    lossf = loss_fn(cfg)
    ocfg = AdamWConfig()
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw_init(params, ocfg)
    mt = make_moe_tables(cfg, None)
    start = 0
    ck = Checkpointer(ckpt_dir) if ckpt_dir else None
    if ck is not None and resume:
        step0, tree, extras = ck.restore_latest({"params": params, "opt": opt})
        if step0 is not None:
            params, opt = tree["params"], tree["opt"]
            start = step0
            print(f"[train] resumed from step {start}")

    @jax.jit
    def step_fn(params, opt, batch, mt):
        (loss, (tallies, aux)), grads = jax.value_and_grad(
            lossf, has_aux=True)(params, batch, mt)
        lr = cosine_lr(ocfg, opt.step, total=max(steps, 1))
        params, opt = adamw_update(grads, opt, params, ocfg, lr)
        return params, opt, loss, tallies

    tallies_acc = None
    losses = []
    for s in range(start, steps):
        b = {k: jnp.asarray(v)
             for k, v in synthetic_batch(cfg, data, s).items()}
        t0 = time.time()
        params, opt, loss, tallies = step_fn(params, opt, b, mt)
        loss = float(loss)
        losses.append(loss)
        if cfg.is_moe:
            # keep the logical-expert columns; the last column is the
            # capacity-dropped-assignment count (see models.moe_layer)
            t = np.asarray(tallies)[:, :cfg.n_experts]
            tallies_acc = t if tallies_acc is None else tallies_acc + t
        if s % log_every == 0 or s == steps - 1:
            print(f"[train] step {s} loss {loss:.4f} "
                  f"({time.time() - t0:.2f}s)")
        if ck is not None and (s + 1) % ckpt_every == 0:
            ck.save(s + 1, {"params": params, "opt": opt},
                    extras={"loss": loss})
    if ck is not None:
        ck.save(steps, {"params": params, "opt": opt},
                extras={"loss": losses[-1] if losses else None},
                blocking=True)
    return params, opt, losses, tallies_acc


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-235b-a22b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    _, _, losses, tallies = train(
        args.arch, smoke=args.smoke, steps=args.steps, seq_len=args.seq_len,
        batch=args.batch, ckpt_dir=args.ckpt_dir, seed=args.seed)
    print(f"[train] done: loss {losses[0]:.4f} → {losses[-1]:.4f}")
    if tallies is not None:
        spread = tallies.sum(0)
        print("[train] expert tally spread: max/min = "
              f"{spread.max() / max(spread.min(), 1):.2f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
