# Launch layer: production mesh builders, per-arch sharding rules, the
# multi-pod dry-run, roofline analysis, and runnable train/serve drivers.
# NOTE: dryrun.py sets XLA_FLAGS at import — import it only in dry-run
# processes, never from tests or benches.
from .mesh import make_mesh, make_production_mesh

__all__ = ["make_mesh", "make_production_mesh"]
