"""Roofline analysis from dry-run records (EXPERIMENTS.md §Roofline).

Hardware constants (TPU v5e target): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI. The dry-run stores *per-device* quantities (the compiled
module is SPMD-partitioned), so

    compute    = flops_per_device    / peak
    memory     = bytes_per_device    / hbm_bw
    collective = coll_bytes_per_device / ici_bw

equal the spec's global-quantity-over-(chips × rate) formulas exactly.

MODEL_FLOPS uses 6·N·T (train) / 2·N·T (prefill) / 2·N_active·B (decode),
N = active params; the ratio MODEL_FLOPS/HLO_FLOPS exposes remat recompute,
the causal-flash masked half, dense-dispatch overcompute, etc.

Usage::

    PYTHONPATH=src python -m repro.launch.roofline --dir results/dryrun
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import SHAPES, get

__all__ = ["PEAK_FLOPS", "HBM_BW", "ICI_BW", "roofline_terms", "load_records",
           "format_table"]

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link (1 effective link, conservative)


def model_flops_per_device(rec: Dict) -> float:
    cfg = get(rec["arch"])
    shape = SHAPES[rec["shape"]]
    n_act = cfg.n_active_params()
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    if shape.kind == "train":
        total = 6.0 * n_act * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        total = 2.0 * n_act * shape.global_batch * shape.seq_len
    else:                                      # decode: one token per seq
        total = 2.0 * n_act * shape.global_batch
    return total / chips


def roofline_terms(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok" or "hlo" not in rec:
        return None
    h = rec["hlo"]
    compute = h["flops_per_device"] / PEAK_FLOPS
    memory = h["bytes_per_device"] / HBM_BW
    coll = h["collective_bytes_per_device"] / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": coll}
    dom = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    bound = max(terms.values())
    return {
        **terms,
        "dominant": dom.replace("_s", ""),
        "model_flops_per_device": mf,
        "useful_ratio": mf / max(h["flops_per_device"], 1.0),
        # fraction of the bound the *useful* compute represents: how close
        # the useful work runs to the roofline given all three ceilings
        "roofline_fraction": (mf / PEAK_FLOPS) / max(bound, 1e-12),
        "mem_gib": rec.get("memory", {}).get("per_device_total_bytes", 0)
        / 2**30,
    }


def load_records(directory: str) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def format_table(recs: List[Dict], mesh: str = "16x16") -> str:
    hdr = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | useful | roofline frac | mem GiB |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for rec in recs:
        if rec.get("mesh") != mesh:
            continue
        if rec.get("status") == "skipped":
            lines.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                         f"skipped: {rec['reason']} | — | — | — |")
            continue
        t = roofline_terms(rec)
        if t is None:
            lines.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                         "ERROR | — | — | — |")
            continue
        lines.append(
            f"| {rec['arch']} | {rec['shape']} "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | {t['dominant']} "
            f"| {t['useful_ratio']:.2f} | {t['roofline_fraction']:.3f} "
            f"| {t['mem_gib']:.2f} |")
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    recs = load_records(args.dir)
    print(format_table(recs, args.mesh))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
