"""Production mesh builders (MULTI-POD DRY-RUN step 1).

Functions, not module-level constants — importing this module never touches
jax device state (smoke tests must keep seeing 1 device).

Mesh construction goes through :mod:`repro.compat` so the same call works
on JAX versions with and without ``jax.sharding.AxisType`` (0.4.x meshes
are implicitly Auto-typed).
"""

from __future__ import annotations

import jax

from repro import compat

__all__ = ["make_production_mesh", "make_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 two-pod (512 chips).

    The dry-run host exposes 512 placeholder devices; the single-pod mesh
    takes the first 256 so both meshes build in one process.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax (launch/dryrun.py does this)")
    return compat.make_mesh(shape, axes, devices=devices)


def make_mesh(shape, axes):
    """Arbitrary test mesh with Auto axis types (shard_map-compatible)."""
    return compat.make_mesh(tuple(shape), tuple(axes))
