"""Per-arch sharding rules: param specs, input specs, cache specs.

The mesh is fixed — (16,16) = ("data","model") or (2,16,16) with "pod" —
and each arch maps its logical parallel axes onto it (DESIGN.md §5):

* attention / dense FFN — TP over "model" ("heads" mode when head counts
  divide, else "context": sequence-sharded activations, replicated heads);
* MoE experts — EP over "model" for train/prefill (a2a dispatch), EP over
  *all* axes for decode (replicated dispatch, expert duplication);
* weights — FSDP over ("pod","data") for archs too big to replicate
  (gathered per scanned layer inside the block body);
* batch — DP over ("pod","data").

Param specs are assigned by tree-path pattern over the init_params
structure, so a new arch needs no new sharding code unless it adds a new
leaf kind.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import ShardingRules, init_cache, init_params

__all__ = ["make_rules", "param_specs", "batch_specs", "cache_specs",
           "tree_shardings", "FSDP_THRESHOLD"]

#: params above this (count) get FSDP weight sharding over ("pod","data").
#: Below it, weights+optimizer replicate across "data" (pure DP) — cheaper
#: in collectives, and small enough to fit (≤1B ⇒ ≤7 GB fp32 opt state).
FSDP_THRESHOLD = 1e9


def make_rules(cfg: ArchConfig, mesh: Optional[Mesh],
               phase: str = "train",
               moe_impl: str = "auto") -> ShardingRules:
    if mesh is None:
        return ShardingRules(mesh=None, moe_dispatch="dense",
                             moe_impl=moe_impl)
    tp_size = mesh.shape.get("model", 1)
    heads_ok = (cfg.n_heads % tp_size == 0 and cfg.n_kv_heads % tp_size == 0
                and tp_size <= cfg.n_kv_heads * (cfg.n_heads // cfg.n_kv_heads))
    fsdp = (("pod", "data") if cfg.n_params() > FSDP_THRESHOLD else None)
    # big experts (≥256 MB per matrix): decode slots over the model axis
    # with per-expert F sliced over the dp axes (expert-TP decode)
    expert_tp = (cfg.is_moe
                 and cfg.d_model * cfg.moe_d_ff * 2 > 256 * 1024 * 1024)
    return ShardingRules(
        mesh=mesh,
        dp=("pod", "data"),
        tp="model",
        ep=("model",),
        ep_all=("pod", "data", "model"),
        fsdp=fsdp,
        attn_mode="heads" if heads_ok else "context",
        moe_dispatch="auto",
        moe_impl=moe_impl,
        capacity_factor=1.25 if phase == "train" else 1.5,
        remat=(phase == "train"),
        decode_expert_tp=expert_tp,
    )


# ---------------------------------------------------------------------------
# param specs by tree path
# ---------------------------------------------------------------------------

def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(f"[{k.idx}]")
    return tuple(names)


def param_specs(cfg: ArchConfig, rules: ShardingRules,
                phase: str = "train") -> Any:
    """Pytree of PartitionSpec matching init_params(cfg, …, phase)."""
    shapes = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), rules, phase))
    tp = rules.tp
    f = rules.fsdp if rules.fsdp else None
    ep = rules.ep[0] if len(rules.ep) == 1 else rules.ep
    ep_dec = rules.ep_all
    heads = rules.attn_mode == "heads"
    tp_size = rules.axis_size(tp)

    vocab_ok = cfg.vocab % max(tp_size, 1) == 0

    def spec(path, leaf):
        names = _path_names(path)
        name = names[-1]
        in_moe = "ffn" in names and cfg.is_moe and leaf.ndim == 4
        sp = rules.spec  # filters axes absent from the mesh
        if name == "embed":
            return sp(tp if vocab_ok else None, f)
        if name == "head":
            return sp(f, tp if vocab_ok else None)
        if name in ("final_norm", "ln1", "ln2", "ln_scale", "dt_bias",
                    "D_skip"):
            return sp(*([None] * leaf.ndim))
        if in_moe and name in ("w1", "w3", "w2"):
            if phase == "decode":
                if rules.decode_expert_tp:
                    ftp = tuple(a for a in rules.ep_all
                                if a not in rules.ep)
                    if name == "w2":
                        return sp(None, ep, ftp, None)
                    return sp(None, ep, None, ftp)
                return sp(None, ep_dec, None, None)
            return sp(None, ep, f, None)
        if name == "router":
            return sp(None, None, None)
        if name == "wq":
            return sp(None, f, tp if heads else None)
        if name in ("wk", "wv"):
            return sp(None, f, tp if heads else None)
        if name == "wo":
            return sp(None, tp if heads else None, f)
        if name in ("w1", "w3"):                     # dense MLP (3-D: nb,D,F)
            return sp(None, f, tp)
        if name == "w2":
            return sp(None, tp, f)
        if name == "in_proj":                        # mamba (nb, D, 2di)
            return sp(None, f, tp)
        if name == "conv_w":
            return sp(None, None, tp)
        if name == "x_proj":
            return sp(None, tp, None)
        if name == "dt_proj":
            return sp(None, None, tp)
        if name == "A_log":
            return sp(None, tp, None)
        if name == "out_proj":
            return sp(None, tp, f)
        if name == "up":                             # xlstm (nb, D, k·di)
            return sp(None, f, tp)
        if name in ("wq", "wk", "wv"):
            return sp(None, None, tp)
        if name in ("w_if", "w_gates"):
            return sp(None, tp, None)
        if name == "r_gates":                        # (nb, H, hd, 4hd) small
            return sp(None, tp if cfg.n_heads % max(tp_size, 1) == 0 else None,
                      None, None)
        if name == "down":
            return sp(None, tp, f)
        if name == "frontend":
            return sp(None, tp)
        return sp(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec, shapes)


# ---------------------------------------------------------------------------
# inputs / cache
# ---------------------------------------------------------------------------

def batch_specs(cfg: ArchConfig, rules: ShardingRules,
                shape: ShapeSpec) -> Tuple[Any, Any]:
    """(ShapeDtypeStructs, PartitionSpecs) for a train/prefill batch."""
    B, S = shape.global_batch, shape.seq_len
    dp = rules.dp
    dp_ok = B % max(rules.axis_size(dp), 1) == 0
    bspec = rules.spec(dp if dp_ok else None, None)
    if cfg.frontend == "audio":
        structs = {"feats": jax.ShapeDtypeStruct((B, S, cfg.frontend_dim),
                                                 jnp.bfloat16),
                   "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        specs = {"feats": rules.spec(dp if dp_ok else None, None, None),
                 "labels": bspec}
    elif cfg.frontend == "vision":
        st = S - cfg.n_patches
        structs = {"tokens": jax.ShapeDtypeStruct((B, st), jnp.int32),
                   "labels": jax.ShapeDtypeStruct((B, st), jnp.int32),
                   "patches": jax.ShapeDtypeStruct(
                       (B, cfg.n_patches, cfg.frontend_dim), jnp.bfloat16)}
        specs = {"tokens": bspec, "labels": bspec,
                 "patches": rules.spec(dp if dp_ok else None, None, None)}
    else:
        structs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                   "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        specs = {"tokens": bspec, "labels": bspec}
    if shape.kind == "prefill":
        structs.pop("labels", None)
        specs.pop("labels", None)
    return structs, specs


def cache_specs(cfg: ArchConfig, rules: ShardingRules, batch: int,
                max_seq: int) -> Tuple[Any, Any]:
    """(cache ShapeDtypeStructs, cache PartitionSpecs) for decode."""
    shapes = jax.eval_shape(lambda: init_cache(cfg, batch, max_seq, rules))
    dp = rules.dp
    tp = rules.tp
    dp_ok = batch % max(rules.axis_size(dp), 1) == 0
    b_ax = dp if dp_ok else None
    heads = rules.attn_mode == "heads"
    tp_size = rules.axis_size(tp)

    def spec(leaf):
        if leaf.ndim == 5 and leaf.shape[2] == max_seq:
            # attention KV cache (nb, B, S, KV, hd)
            if heads and cfg.n_kv_heads % max(tp_size, 1) == 0:
                return rules.spec(None, b_ax, None if dp_ok else tp,
                                  tp if dp_ok else None, None)
            # context mode: shard the sequence (flash-decode psums)
            seq_ax = tp if dp_ok else (dp + (tp,) if isinstance(dp, tuple)
                                       else (dp, tp))
            return rules.spec(None, b_ax, seq_ax, None, None)
        if leaf.ndim == 5:
            # mlstm C (nb, B, H, hd, hd)
            h_ok = leaf.shape[2] % max(tp_size, 1) == 0
            return rules.spec(None, b_ax, tp if h_ok else None, None, None)
        if leaf.ndim == 4:
            # mamba h (nb, B, di, ds) or conv (nb, B, k-1, di)
            if leaf.shape[-1] > 8 and leaf.shape[2] % max(tp_size, 1) != 0:
                return rules.spec(None, b_ax, None, tp)   # conv: di last
            if leaf.shape[2] % max(tp_size, 1) == 0:
                return rules.spec(None, b_ax, tp, None)
            return rules.spec(None, b_ax, None, None)
        if leaf.ndim == 3:
            # mlstm n / slstm states (nb, B, H, hd) is 4-D; (nb,B,H) 3-D
            return rules.spec(None, b_ax, None)
        return rules.spec(*([None] * leaf.ndim))

    return shapes, jax.tree.map(spec, shapes)


def tree_shardings(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
