"""Trip-count-aware cost analysis of compiled HLO (roofline provenance).

``compiled.cost_analysis()`` counts every ``while`` body **once** (verified
experimentally on this backend — see EXPERIMENTS.md §Roofline provenance),
which under-counts every ``lax.scan``: the layer stack, flash-attention
chunk loops, chunked-loss loops. This module re-derives the three roofline
inputs from ``compiled.as_text()`` with loop multipliers:

1. split the HLO module into named computations,
2. build the call graph (fusion `calls=`, while `body=`/`condition=`,
   conditional branches),
3. extract each while's trip count from the largest integer constant in its
   condition computation (XLA canonicalizes scan conditions to
   ``lt(counter, constant(N))``),
4. propagate multipliers from ENTRY and accumulate per-computation:
   * FLOPs   — ``dot``/``convolution`` ops (2 · result elems · contracted
     elems); elementwise flops are ignored (⪅1% for these models),
   * bytes   — operand + result bytes of HBM-touching ops (fusion, dot,
     copy, gather/scatter, dynamic slices, custom-call, reduce, sort),
   * collective bytes — operand bytes of all-gather / all-reduce /
     reduce-scatter / all-to-all / collective-permute (+ ragged variants).

All sizes are *per-device* (SPMD-partitioned module). The parser is
intentionally conservative: unknown shapes contribute zero rather than
raising mid-sweep; ``parse_hlo(..., strict=True)`` raises instead (tests).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HloCosts", "parse_hlo", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")
#: ops whose operands+results approximate HBM traffic post-fusion.
#: View-like / usually-fused ops (broadcast, reshape, transpose, slice,
#: pad, iota, concatenate) are excluded — when XLA leaves them top-level
#: they are layout no-ops or tiny; counting them inflated the memory term
#: ~5× on the flash-attention inner loops.
_HBM_OPS = {"fusion", "dot", "convolution", "copy", "gather", "scatter",
            "dynamic-slice", "dynamic-update-slice", "custom-call",
            "sort"} | set(_COLLECTIVES)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of a (possibly tuple) shape string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_elems(shape_str: str) -> int:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class _Op:
    name: str
    shape: str
    opcode: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    ops: List[_Op]
    defs: Dict[str, str]              # op name → result shape string


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    while_trip_counts: Dict[str, int] = dataclasses.field(
        default_factory=dict)

    def add(self, other: "HloCosts", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes_accessed += other.bytes_accessed * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.collective_by_kind.items():
            self.collective_by_kind[k] = (
                self.collective_by_kind.get(k, 0.0) + v * mult)


def _split_computations(text: str) -> Tuple[Dict[str, _Computation], str]:
    comps: Dict[str, _Computation] = {}
    entry_name: Optional[str] = None
    cur: Optional[_Computation] = None
    for line in text.splitlines():
        if cur is None:
            # computation headers start at column 0 (optionally "ENTRY"),
            # contain "->" and open a brace; param lists can nest parens.
            s = line.rstrip()
            if (s.endswith("{") and "->" in s and line[:1] not in " \t"
                    and (s.startswith("%") or s.startswith("ENTRY"))):
                is_entry = s.startswith("ENTRY")
                name = s.split()[1] if is_entry else s.split()[0]
                name = name.lstrip("%").split("(")[0].rstrip()
                cur = _Computation(name, [], {})
                if is_entry:
                    entry_name = name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        dm = _DEF_RE.match(line)
        if dm:
            name, shape, opcode = dm.group(1), dm.group(2), dm.group(3)
            # operands: names inside the first (...) after the opcode
            after = line.split(opcode + "(", 1)
            operands = []
            if len(after) == 2:
                depth, buf = 1, []
                for ch in after[1]:
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            break
                    buf.append(ch)
                operands = _OPERAND_RE.findall("".join(buf))
            op = _Op(name, shape, opcode, operands, line)
            cur.ops.append(op)
            cur.defs[name] = shape
    return comps, entry_name or ""


def _local_costs(comp: _Computation, comps: Dict[str, _Computation],
                 strict: bool) -> Tuple[HloCosts, List[Tuple[str, str]]]:
    """(costs of this computation alone, [(callee, kind), ...])."""
    c = HloCosts()
    calls: List[Tuple[str, str]] = []
    for op in comp.ops:
        code = op.opcode
        if code in ("parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast", "after-all", "partition-id", "replica-id"):
            continue
        if code == "while":
            b = _BODY_RE.search(op.line)
            cn = _COND_RE.search(op.line)
            if b:
                calls.append((b.group(1), "while"))
            if cn:
                calls.append((cn.group(1), "while-cond:" + (b.group(1) if b else "")))
            continue
        if code == "conditional":
            m = _BRANCH_RE.search(op.line)
            if m:
                for name in m.group(1).split(","):
                    calls.append((name.strip().lstrip("%"), "call"))
            continue
        if code in ("fusion", "call", "map", "reduce", "reduce-window",
                    "scatter", "sort", "select-and-scatter", "custom-call",
                    "all-reduce", "reduce-scatter"):
            for m in (_CALLS_RE.search(op.line), _TO_APPLY_RE.search(op.line)):
                if m:
                    calls.append((m.group(1), "call"))
        # flops
        if code in ("dot", "convolution"):
            out_elems = _shape_elems(op.shape)
            contract = 1
            cm = _CONTRACT_RE.search(op.line)
            if cm and op.operands:
                lhs_shape = comp.defs.get(op.operands[0], "")
                sm = _SHAPE_RE.search(lhs_shape)
                if sm:
                    dims = [int(d) for d in sm.group(2).split(",") if d]
                    for ci in cm.group(1).split(","):
                        if ci and int(ci) < len(dims):
                            contract *= dims[int(ci)]
                elif strict:
                    raise ValueError(f"unknown lhs shape for {op.line}")
            c.flops += 2.0 * out_elems * contract
        # bytes
        if code in _HBM_OPS:
            if code == "dynamic-slice":
                # reads only the slice (plus writes it) — billing the whole
                # operand would charge every scan step the full stacked array
                b = 2 * _shape_bytes(op.shape)
            elif code == "dynamic-update-slice":
                # in-place when aliased: reads+writes the update region only
                upd = (comp.defs.get(op.operands[1], "")
                       if len(op.operands) > 1 else op.shape)
                b = 2 * _shape_bytes(upd)
            elif code == "scatter":
                # touches the scattered rows, not the whole buffer
                upd = (comp.defs.get(op.operands[-1], "")
                       if len(op.operands) >= 3 else op.shape)
                b = 2 * _shape_bytes(upd)
            elif code == "fusion":
                cm = _CALLS_RE.search(op.line)
                callee = comps.get(cm.group(1)) if cm else None
                b = _fusion_result_bytes(callee, _shape_bytes(op.shape))
                for i, o in enumerate(op.operands):
                    full = _shape_bytes(comp.defs.get(o, ""))
                    b += min(full, _fusion_param_read(callee, i, full))
            else:
                b = _shape_bytes(op.shape)
                for o in op.operands:
                    b += _shape_bytes(comp.defs.get(o, ""))
            c.bytes_accessed += b
        # collectives
        for kind in _COLLECTIVES:
            if code == kind or code == kind + "-start":
                cb = sum(_shape_bytes(comp.defs.get(o, ""))
                         for o in op.operands)
                if cb == 0:                      # e.g. operands are params
                    cb = _shape_bytes(op.shape)
                c.collective_bytes += cb
                c.collective_by_kind[kind] = (
                    c.collective_by_kind.get(kind, 0.0) + cb)
                break
    return c, calls


def _fusion_param_read(callee: Optional[_Computation], idx: int,
                       full: float) -> float:
    """Bytes a fusion actually reads of parameter ``idx``.

    When every consumer of the parameter inside the fusion body is a
    dynamic-slice (the lax.scan xs access pattern), only the slices are
    read — billing the whole stacked operand would charge each scan step
    the full (n_blocks, …) array.
    """
    if callee is None:
        return full
    pname = None
    for op in callee.ops:
        if op.opcode == "parameter" and f"parameter({idx})" in op.line:
            pname = op.name
            break
    if pname is None:
        return full
    sliced = 0.0
    for op in callee.ops:
        if pname in op.operands:
            if op.opcode == "dynamic-slice" and op.operands[0] == pname:
                sliced += _shape_bytes(op.shape)
            elif (op.opcode == "dynamic-update-slice"
                  and op.operands[0] == pname):
                upd = (callee.defs.get(op.operands[1], "")
                       if len(op.operands) > 1 else "")
                sliced += _shape_bytes(upd)
            else:
                return full                    # consumed elsewhere: full read
    return sliced if sliced > 0 else full


def _fusion_result_bytes(callee: Optional[_Computation],
                         default: float) -> float:
    """Bytes a fusion actually writes.

    A fusion whose ROOT is a dynamic-update-slice reports the full updated
    buffer as its result shape, but (with aliasing) writes only the update
    region — e.g. the scan ys write-back of a KV cache stack.
    """
    if callee is None:
        return default
    root = next((op for op in callee.ops if "ROOT" in op.line), None)
    if root is None:
        return default
    # follow a trailing bitcast to the real producer
    if root.opcode in ("bitcast", "copy") and root.operands:
        prod = next((op for op in callee.ops
                     if op.name == root.operands[0]), None)
        root = prod or root
    if root.opcode == "dynamic-update-slice" and len(root.operands) > 1:
        upd = callee.defs.get(root.operands[1], "")
        if upd:
            return _shape_bytes(upd)
    return default


def _trip_count(cond: _Computation) -> int:
    consts = [int(m) for op in cond.ops
              for m in _CONST_RE.findall(op.line)]
    return max(consts) if consts else 1


def parse_hlo(text: str, strict: bool = False) -> HloCosts:
    comps, entry = _split_computations(text)
    if entry not in comps:
        if strict:
            raise ValueError("no ENTRY computation found")
        return HloCosts()
    local: Dict[str, Tuple[HloCosts, List[Tuple[str, str]]]] = {}
    for name, comp in comps.items():
        local[name] = _local_costs(comp, comps, strict)

    total = HloCosts()
    seen_guard: Dict[str, float] = {}

    def visit(name: str, mult: float, depth: int = 0) -> None:
        if name not in local or depth > 64:
            return
        costs, calls = local[name]
        total.add(costs, mult)
        for callee, kind in calls:
            if kind == "while":
                # the matching condition computation rode along in `calls`
                cond_name = next((c for c, k in calls
                                  if k == "while-cond:" + callee), None)
                trips = _trip_count(comps[cond_name]) \
                    if cond_name in comps else 1
                total.while_trip_counts[callee] = trips
                visit(callee, mult * trips, depth + 1)
            elif kind.startswith("while-cond:"):
                visit(callee, mult, depth + 1)   # condition cost ~negligible
            else:
                visit(callee, mult, depth + 1)

    visit(entry, 1.0)
    return total
