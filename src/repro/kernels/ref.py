"""Pure-jnp oracles for the Pallas kernels (tests assert_allclose vs these)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["moe_ffn_ref", "ragged_moe_ffn_ref", "router_topk_ref"]


def moe_ffn_ref(w1: jnp.ndarray, w3: jnp.ndarray, w2: jnp.ndarray,
                toks: jnp.ndarray) -> jnp.ndarray:
    """Grouped SwiGLU expert FFN. toks (E, C, D) → (E, C, D).

    Matches models.moe.expert_ffn_ref exactly (the EP dispatch oracle).
    """
    h = jnp.einsum("ecd,edf->ecf", toks, w1)
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", toks, w3)
    return jnp.einsum("ecf,efd->ecd", h, w2)


def ragged_moe_ffn_ref(w1: jnp.ndarray, w3: jnp.ndarray, w2: jnp.ndarray,
                       toks: jnp.ndarray,
                       tile_group: jnp.ndarray) -> jnp.ndarray:
    """Ragged grouped SwiGLU FFN oracle. toks (T, D) → (T, D).

    ``toks`` is the group-sorted flat buffer (each expert's segment padded
    to a multiple of the row tile ``bm = T // len(tile_group)``);
    ``tile_group`` holds the owning expert per (bm, D) tile, sentinel ``E``
    for unoccupied tiles. Pure jnp: per-tile weight gather + batched GEMMs,
    so jitted XLA cost scales with the buffer's tile count — the shape the
    Pallas kernel (and the dispatch paths) must reproduce exactly.
    """
    T, D = toks.shape
    n_tiles = tile_group.shape[0]
    E = w1.shape[0]
    g = jnp.minimum(tile_group, E - 1)
    x = toks.reshape(n_tiles, T // n_tiles, D)
    h = jnp.einsum("nbd,ndf->nbf", x, w1[g])
    h = jax.nn.silu(h) * jnp.einsum("nbd,ndf->nbf", x, w3[g])
    y = jnp.einsum("nbf,nfd->nbd", h, w2[g])
    y = y * (tile_group < E).astype(y.dtype)[:, None, None]
    return y.reshape(T, D).astype(toks.dtype)


def router_topk_ref(logits: jnp.ndarray, top_k: int):
    """Softmax → top-k → renormalize. logits (T, E) → ((T,K) f32, (T,K) i32)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, idx = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights, idx.astype(jnp.int32)
