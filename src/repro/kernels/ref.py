"""Pure-jnp oracles for the Pallas kernels (tests assert_allclose vs these)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["moe_ffn_ref", "router_topk_ref"]


def moe_ffn_ref(w1: jnp.ndarray, w3: jnp.ndarray, w2: jnp.ndarray,
                toks: jnp.ndarray) -> jnp.ndarray:
    """Grouped SwiGLU expert FFN. toks (E, C, D) → (E, C, D).

    Matches models.moe.expert_ffn_ref exactly (the EP dispatch oracle).
    """
    h = jnp.einsum("ecd,edf->ecf", toks, w1)
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", toks, w3)
    return jnp.einsum("ecf,efd->ecd", h, w2)


def router_topk_ref(logits: jnp.ndarray, top_k: int):
    """Softmax → top-k → renormalize. logits (T, E) → ((T,K) f32, (T,K) i32)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, idx = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights, idx.astype(jnp.int32)
