"""Ragged (dropless) grouped MoE expert FFN — MegaBlocks-style on TPU.

The capacity-bucket kernel (:mod:`.moe_ffn`) pads every expert to a fixed
``capacity``: hot experts overflow (dropped assignments), cold experts burn
MXU cycles on all-zero rows, and the grouped-FFN cost is ``E_loc × capacity``
no matter how skewed the realized routing is. This kernel consumes the
*ragged* layout instead:

* tokens arrive as one flat buffer ``(T, D)``, sorted by expert, each
  expert's segment zero-padded up to the next multiple of the row-tile
  ``bm`` (so every (bm, D) tile belongs to exactly one expert);
* a per-tile expert id array ``tile_group`` (``n_tiles = T // bm``) is
  passed as a **scalar-prefetch** operand (`pltpu.PrefetchScalarGridSpec`):
  the block index maps read it to DMA the right expert's weight blocks, the
  MegaBlocks grouped-GEMM trick;
* tiles past the occupied prefix carry the sentinel id ``E`` — the kernel
  skips their GEMMs entirely (``pl.when``) and writes zeros, and an expert
  with zero routed tokens owns zero tiles, so compute scales with the
  *realized* token count, not with ``E_loc × max_e load_e``.

``ragged_tile_metadata`` builds the layout from per-expert segment sizes
with pure ``jnp`` ops (cumsum + searchsorted), so the whole plan is
O(E log E) array work and jit-compatible: sizes are data-dependent *values*
inside static shapes (``n_tiles`` is a static worst-case bound).

Validated on CPU with ``interpret=True`` against ``ref.ragged_moe_ffn_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ragged_tile_metadata", "ragged_n_tiles", "ragged_moe_ffn_pallas"]


def ragged_n_tiles(n_assign: int, n_groups: int, bm: int) -> int:
    """Static worst-case (bm, D)-tile count for ``n_assign`` rows split over
    ``n_groups`` segments, each padded to a multiple of ``bm``:
    sum_g ceil(s_g / bm) <= floor(A / bm) + G."""
    return n_assign // bm + n_groups


def ragged_tile_metadata(sizes: jnp.ndarray, bm: int, n_tiles: int):
    """Group-aligned ragged layout from per-group segment sizes.

    ``sizes``: (G,) int32 routed-token count per group (data-dependent
    values, static shape). Each group's segment is padded to a multiple of
    ``bm`` so tiles never straddle groups. Returns

    * ``row_offsets`` (G + 1,) int32 — row where each group's segment starts
      in the flat buffer (``row_offsets[-1]`` = total occupied rows);
    * ``tile_group`` (n_tiles,) int32 — owning group per (bm, D) tile, with
      the sentinel ``G`` for tiles past the occupied prefix (callers skip
      them). A group with ``sizes[g] == 0`` owns no tiles at all.
    """
    sizes = sizes.astype(jnp.int32)
    padded = ((sizes + bm - 1) // bm) * bm
    row_offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(padded, dtype=jnp.int32)])
    tile_cum = row_offsets[1:] // bm                     # (G,) cumulative tiles
    tile_group = jnp.searchsorted(
        tile_cum, jnp.arange(n_tiles, dtype=jnp.int32), side="right")
    return row_offsets, tile_group.astype(jnp.int32)


def _kernel(g_ref, x_ref, w1_ref, w3_ref, w2_ref, o_ref, acc_ref, *,
            n_groups: int):
    """Grid (n_tiles, F/bf); F innermost → acc stays in VMEM across F."""
    i, f = pl.program_id(0), pl.program_id(1)

    @pl.when(g_ref[i] < n_groups)
    def _compute():
        x = x_ref[...]                                 # (bm, D)
        h = jnp.dot(x, w1_ref[0], preferred_element_type=jnp.float32)
        g = jnp.dot(x, w3_ref[0], preferred_element_type=jnp.float32)
        h = (jax.nn.silu(h) * g).astype(x.dtype)       # (bm, bf)
        y = jnp.dot(h, w2_ref[0], preferred_element_type=jnp.float32)

        @pl.when(f == 0)
        def _init():
            acc_ref[...] = y

        @pl.when(f > 0)
        def _accum():
            acc_ref[...] += y

    @pl.when((g_ref[i] >= n_groups) & (f == 0))
    def _empty():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(f == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bf", "interpret"))
def ragged_moe_ffn_pallas(w1, w3, w2, toks, tile_group, *, bf: int = 256,
                          interpret: bool = False):
    """toks (T, D) group-sorted flat buffer, tile_group (T // bm,) int32,
    w1/w3 (E, D, F), w2 (E, F, D) → (T, D).

    The row tile ``bm`` is implied by ``T // len(tile_group)``; F is padded
    to a multiple of ``bf`` (zero padding is exact for SwiGLU). Tiles whose
    ``tile_group`` is the sentinel ``E`` are skipped (zeros out); occupied
    tiles fetch their expert's weight blocks through the scalar-prefetch
    index maps.
    """
    T, D = toks.shape
    n_tiles = tile_group.shape[0]
    bm = T // n_tiles
    E, _, F = w1.shape
    bf = min(bf, F) if F >= 128 else F
    pf = (-F) % bf
    if pf:
        w1 = jnp.pad(w1, ((0, 0), (0, 0), (0, pf)))
        w3 = jnp.pad(w3, ((0, 0), (0, 0), (0, pf)))
        w2 = jnp.pad(w2, ((0, 0), (0, pf), (0, 0)))
    Fp = F + pf

    wid = lambda i, f, g: (jnp.minimum(g[i], E - 1), 0, f)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles, Fp // bf),
        in_specs=[
            pl.BlockSpec((bm, D), lambda i, f, g: (i, 0)),
            pl.BlockSpec((1, D, bf), wid),
            pl.BlockSpec((1, D, bf), wid),
            pl.BlockSpec((1, bf, D),
                         lambda i, f, g: (jnp.minimum(g[i], E - 1), f, 0)),
        ],
        out_specs=pl.BlockSpec((bm, D), lambda i, f, g: (i, 0)),
        scratch_shapes=[pltpu.VMEM((bm, D), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, n_groups=E),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, D), toks.dtype),
        interpret=interpret,
    )(tile_group, toks, w1, w3, w2)
