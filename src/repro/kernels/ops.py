"""Jitted public wrappers for the Pallas kernels.

On a TPU backend the kernels compile natively; elsewhere (this CPU host)
they run in ``interpret=True`` mode, which executes the kernel body exactly
— so the same call sites work in smoke tests and in production.

``pick_blocks`` chooses MXU-aligned block shapes under the v5e VMEM budget
(~16 MiB usable): resident set = x(bm,D) + acc(bm,D,f32) + 3 weight blocks
(D·bf or bf·D) + h(bm,bf).
"""

from __future__ import annotations

from typing import Tuple

import jax

from .moe_ffn import fused_moe_ffn_pallas
from .ragged_moe_ffn import ragged_moe_ffn_pallas
from .router import router_topk_pallas

__all__ = ["fused_moe_ffn", "ragged_moe_ffn", "router_topk", "pick_blocks"]

_VMEM_BUDGET = 14 * 1024 * 1024     # leave headroom under 16 MiB


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pick_blocks(D: int, F: int, dtype_bytes: int = 2) -> Tuple[int, int]:
    """(bm, bf) fitting the VMEM budget, preferring large MXU-aligned tiles."""
    for bm in (512, 256, 128):
        for bf in (1024, 512, 256, 128):
            resident = (bm * D * dtype_bytes          # x block
                        + bm * D * 4                  # fp32 accumulator
                        + 3 * D * bf * dtype_bytes    # w1/w3/w2 blocks
                        + bm * bf * 4)                # h in fp32
            if resident <= _VMEM_BUDGET:
                return bm, min(bf, F)
    return 128, 128


def fused_moe_ffn(w1, w3, w2, toks):
    """Drop-in replacement for models.moe.expert_ffn_ref (same signature)."""
    E, C, D = toks.shape
    F = w1.shape[-1]
    bm, bf = pick_blocks(D, F)
    return fused_moe_ffn_pallas(w1, w3, w2, toks, bm=bm, bf=bf,
                                interpret=not _on_tpu())


def ragged_moe_ffn(w1, w3, w2, toks, tile_group):
    """Ragged grouped FFN: flat group-sorted (T, D) buffer + per-tile expert
    ids (see kernels.ragged_moe_ffn). Drop-in for the dispatch's ragged
    ffn slot; the row tile bm is implied by T // len(tile_group)."""
    D = toks.shape[-1]
    F = w1.shape[-1]
    _, bf = pick_blocks(D, F)
    return ragged_moe_ffn_pallas(w1, w3, w2, toks, tile_group, bf=bf,
                                 interpret=not _on_tpu())


def router_topk(logits, top_k: int):
    return router_topk_pallas(logits, top_k, interpret=not _on_tpu())
