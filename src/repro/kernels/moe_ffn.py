"""Fused grouped MoE expert FFN — the paper's kernel-level hot spot.

The paper's measurements are dominated by the *fused MoE kernel* (AITER on
ROCm): per MoE layer, 49% of prefill time (Fig 3), and it is precisely this
kernel whose per-device latency f_g(n) ViBE profiles and balances. This is
the TPU-native adaptation (DESIGN.md §3):

* GPU version: per-expert grouped GEMM tiles scheduled across CUs, fusing
  gate/up/down projections with the silu epilogue.
* TPU version (here): one ``pl.pallas_call`` over grid (E, C/bm, F/bf) with
  the **output block resident in VMEM across the F sweep** — the F axis is
  innermost, so the (bm, D) fp32 accumulator never round-trips to HBM, and
  the three GEMMs + silu fuse into a single kernel. MXU alignment comes
  from 128-multiple block shapes; VMEM budget drives the block choice
  (see ``ops.pick_blocks``).

Capacity-bucket semantics: unused capacity rows are zero (the EP dispatch
scatters into a zero buffer), and SwiGLU(0) = 0, so no masking is needed.

Validated on CPU with ``interpret=True`` against ``ref.moe_ffn_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["fused_moe_ffn_pallas"]


def _kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref, acc_ref):
    """Grid (E, C/bm, F/bf); F innermost → acc stays in VMEM across F."""
    f = pl.program_id(2)
    x = x_ref[0]                                   # (bm, D)
    h = jnp.dot(x, w1_ref[0], preferred_element_type=jnp.float32)
    g = jnp.dot(x, w3_ref[0], preferred_element_type=jnp.float32)
    h = (jax.nn.silu(h) * g).astype(x.dtype)       # (bm, bf)
    y = jnp.dot(h, w2_ref[0], preferred_element_type=jnp.float32)

    @pl.when(f == 0)
    def _init():
        acc_ref[...] = y

    @pl.when(f > 0)
    def _accum():
        acc_ref[...] += y

    @pl.when(f == pl.num_programs(2) - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bf", "interpret"))
def fused_moe_ffn_pallas(w1, w3, w2, toks, *, bm: int = 128, bf: int = 256,
                         interpret: bool = False):
    """toks (E, C, D), w1/w3 (E, D, F), w2 (E, F, D) → (E, C, D).

    C is padded to a multiple of ``bm`` and F to a multiple of ``bf``
    (zero padding is exact for SwiGLU — see module docstring).
    """
    E, C, D = toks.shape
    F = w1.shape[-1]
    bm = min(bm, C) if C >= 8 else C
    bf = min(bf, F) if F >= 128 else F
    pc = (-C) % bm
    pf = (-F) % bf
    if pc:
        toks = jnp.pad(toks, ((0, 0), (0, pc), (0, 0)))
    if pf:
        w1 = jnp.pad(w1, ((0, 0), (0, 0), (0, pf)))
        w3 = jnp.pad(w3, ((0, 0), (0, 0), (0, pf)))
        w2 = jnp.pad(w2, ((0, 0), (0, pf), (0, 0)))
    Cp, Fp = C + pc, F + pf

    grid = (E, Cp // bm, Fp // bf)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, D), lambda e, i, f: (e, i, 0)),
            pl.BlockSpec((1, D, bf), lambda e, i, f: (e, 0, f)),
            pl.BlockSpec((1, D, bf), lambda e, i, f: (e, 0, f)),
            pl.BlockSpec((1, bf, D), lambda e, i, f: (e, f, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, D), lambda e, i, f: (e, i, 0)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, D), toks.dtype),
        scratch_shapes=[pltpu.VMEM((bm, D), jnp.float32)],
        interpret=interpret,
    )(toks, w1, w3, w2)
    return out[:, :C] if pc else out
