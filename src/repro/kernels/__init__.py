# Pallas TPU kernels for the paper's compute hot-spot: the fused grouped
# MoE expert FFN (the kernel whose per-device latency ViBE balances) and the
# router gating that feeds it. ops.py = jit'd wrappers; ref.py = oracles.
from . import ops, ref

__all__ = ["ops", "ref"]
