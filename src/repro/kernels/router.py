"""Fused softmax + top-k router gating kernel.

The router itself is small, but on the serving path it sits between the
attention output and the MoE dispatch on every layer; fusing softmax,
iterative top-k selection and renormalization avoids three HBM round-trips
of the (T, E) probability tensor. Top-k is realized as K unrolled
max/argmax/mask sweeps — K ≤ 8 for every assigned arch, and each sweep is a
row reduction the VPU handles natively.

Validated on CPU with ``interpret=True`` against ``ref.router_topk_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["router_topk_pallas"]


def _kernel(logits_ref, w_ref, idx_ref, *, top_k):
    x = logits_ref[...].astype(jnp.float32)              # (bt, E)
    bt, E = x.shape
    m = jnp.max(x, axis=-1, keepdims=True)
    p = jnp.exp(x - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)           # softmax
    cols = jax.lax.broadcasted_iota(jnp.int32, (bt, E), 1)
    total = jnp.zeros((bt, 1), jnp.float32)
    sel_w = []
    sel_i = []
    for k in range(top_k):                               # unrolled: K ≤ 8
        w = jnp.max(p, axis=-1, keepdims=True)           # (bt, 1)
        i = jnp.argmax(p, axis=-1).astype(jnp.int32)     # (bt,)
        sel_w.append(w)
        sel_i.append(i[:, None])
        total = total + w
        p = jnp.where(cols == i[:, None], -1.0, p)       # mask the winner
    w_all = jnp.concatenate(sel_w, axis=-1)              # (bt, K)
    w_ref[...] = w_all / jnp.maximum(total, 1e-9)
    idx_ref[...] = jnp.concatenate(sel_i, axis=-1)


@functools.partial(jax.jit, static_argnames=("top_k", "bt", "interpret"))
def router_topk_pallas(logits, top_k: int, *, bt: int = 256,
                       interpret: bool = False):
    """logits (T, E) → (weights (T, K) f32, idx (T, K) i32)."""
    T, E = logits.shape
    bt = min(bt, T)
    pt = (-T) % bt
    if pt:
        logits = jnp.pad(logits, ((0, pt), (0, 0)))
    Tp = T + pt
    w, idx = pl.pallas_call(
        functools.partial(_kernel, top_k=top_k),
        grid=(Tp // bt,),
        in_specs=[pl.BlockSpec((bt, E), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bt, top_k), lambda i: (i, 0)),
                   pl.BlockSpec((bt, top_k), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((Tp, top_k), jnp.float32),
                   jax.ShapeDtypeStruct((Tp, top_k), jnp.int32)],
        interpret=interpret,
    )(logits)
    return (w[:T], idx[:T]) if pt else (w, idx)
