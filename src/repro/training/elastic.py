"""Fault tolerance & elasticity: straggler detection and EP re-planning.

ViBE composes directly with elasticity (DESIGN.md §8): the placement
solvers are parametric in the rank set, so losing (or regaining) a device
is "re-solve placement over the survivors and migrate the minimal expert
set". Three pieces:

* :class:`StragglerDetector` — per-rank EWMA of step latencies; flags ranks
  persistently slower than the fleet median by a threshold. A flagged rank
  is first *absorbed* (ViBE shifts load off it — the paper's mechanism used
  as a mitigation), and only *excluded* if it degrades past a hard limit.
* :func:`replan_after_loss` — rebuild the EP placement on the surviving
  ranks (slot-count padding keeps E divisible), returning the migration
  plan (which surviving slots must fetch which experts).
* :func:`elastic_targets` — speed-weighted *data* split for non-MoE work
  (Fig 6's variability-informed token assignment applied to DP batches).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core import (PerfModel, ReplicatedPlacement, SolveContext,
                        compact_placement, get_policy)

__all__ = ["StragglerDetector", "replan_after_loss", "elastic_targets"]


@dataclasses.dataclass
class StragglerDetector:
    n_ranks: int
    alpha: float = 0.1              # EWMA factor
    soft_ratio: float = 1.10        # flag: 10% above median
    hard_ratio: float = 1.50        # exclude: 50% above median
    min_steps: int = 20

    def __post_init__(self):
        self.ewma = np.zeros(self.n_ranks)
        self.steps = 0

    def observe(self, rank_times: np.ndarray) -> Dict[str, List[int]]:
        """Feed per-rank step times; returns {'soft': [...], 'hard': [...]}."""
        rank_times = np.asarray(rank_times, dtype=np.float64)
        if self.steps == 0:
            self.ewma[:] = rank_times
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * rank_times
        self.steps += 1
        if self.steps < self.min_steps:
            return {"soft": [], "hard": []}
        med = float(np.median(self.ewma))
        soft = [g for g in range(self.n_ranks)
                if self.ewma[g] > self.soft_ratio * med]
        hard = [g for g in range(self.n_ranks)
                if self.ewma[g] > self.hard_ratio * med]
        return {"soft": soft, "hard": hard}


def replan_after_loss(
    w: np.ndarray,                      # (L, E) activation matrix
    perf_models: Sequence[PerfModel],   # original G models
    lost_ranks: Sequence[int],
    policy: str = "vibe",
) -> Tuple[ReplicatedPlacement, np.ndarray]:
    """Re-solve placement over surviving ranks with any registered policy.

    Routes through the registry's *masked-solve* path
    (``SolveContext.dead_ranks``) — the same code the serving
    controller's ``mask_ranks`` / ``unmask_ranks`` elastic shrink/grow
    uses — so training relaunch and live serving cannot disagree about
    what a survivor solve means (survivor budgets, masked topology,
    replication-capability guards). The full-G masked result (dead ranks
    hold all-phantom zero-share windows) is then compacted to the
    survivor-only geometry with
    :func:`~repro.core.placement.compact_placement`, because a training
    relaunch rebuilds the mesh over the survivors rather than pinning the
    old geometry. Returns (unified placement over G' survivors —
    singleton policies give the r_max = 1 degenerate — and rank_map (G',)
    giving each new rank index its original physical rank id; the
    launcher uses it to rebuild the mesh and the migration plan).
    """
    G = len(perf_models)
    dead = tuple(sorted(set(int(g) for g in lost_ranks)))
    survivors = [g for g in range(G) if g not in set(dead)]
    if not survivors:
        raise ValueError("no surviving ranks")
    pol = get_policy(policy)
    ctx = SolveContext(
        w=w, n_ranks=G,
        perf_models=(tuple(perf_models)
                     if pol.capabilities.needs_perf_models else None),
        dead_ranks=dead)
    full = pol.solve(ctx)
    return (compact_placement(full, survivors),
            np.asarray(survivors, dtype=np.int32))


def elastic_targets(perf_models: Sequence[PerfModel],
                    total_items: int, n_ref: float) -> np.ndarray:
    """Speed-proportional work split across ranks (Fig 6 for DP batches)."""
    s = np.array([m.speed(n_ref) for m in perf_models])
    raw = total_items * s / s.sum()
    out = np.floor(raw).astype(np.int64)
    # distribute the remainder to the fastest ranks
    rem = total_items - int(out.sum())
    order = np.argsort(-(raw - out))
    out[order[:rem]] += 1
    return out
