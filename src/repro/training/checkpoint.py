"""Sharded checkpointing: per-host leaf files, async save, atomic commit,
restore-with-re-mesh.

Layout::

    <dir>/ckpt_<step>.tmp/      # written first
        manifest.json           # treedef, shapes/dtypes, step, extras
        <leaf_id>.s<k>.npy      # leaf k-th host shard (split on axis 0)
    <dir>/ckpt_<step>/          # atomic rename when every file is fsynced
        COMMIT                  # marker: readers only trust committed dirs

* **Async**: the device→host snapshot is taken synchronously (cheap, and
  consistent), the file writes happen on a background thread so training
  continues; ``wait()`` joins before the next save or at shutdown.
* **Re-mesh restore**: leaves are stored as plain full-logical arrays split
  into ``n_shards`` axis-0 files; restore concatenates and the caller
  ``device_put``s with whatever NamedSharding the *new* mesh dictates —
  a checkpoint written on mesh A restores on mesh B (tested).
* Crash safety: an interrupted save leaves only a ``.tmp`` dir; ``latest``
  ignores it; ``clean()`` removes stale tmp dirs.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["Checkpointer", "save_checkpoint", "load_checkpoint",
           "latest_step"]


def _leaf_files(leaf: np.ndarray, n_shards: int) -> List[np.ndarray]:
    if leaf.ndim == 0 or leaf.shape[0] < n_shards or n_shards == 1:
        return [leaf]
    return np.array_split(leaf, n_shards, axis=0)


def _np_dtype(name: str) -> np.dtype:
    """Resolve extended dtypes (bfloat16 etc.) via ml_dtypes."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def save_checkpoint(directory: str, step: int, tree: Any,
                    extras: Optional[Dict] = None, n_shards: int = 1) -> str:
    """Synchronous save. Returns the committed checkpoint path."""
    leaves, treedef = jax.tree.flatten(tree)
    leaves = [np.asarray(l) for l in leaves]
    tmp = os.path.join(directory, f"ckpt_{step}.tmp")
    final = os.path.join(directory, f"ckpt_{step}")
    os.makedirs(tmp, exist_ok=True)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "extras": extras or {},
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        shards = _leaf_files(leaf, n_shards)
        manifest["leaves"].append({
            "id": i, "dtype": str(leaf.dtype), "shape": list(leaf.shape),
            "n_shards": len(shards),
            "shard_shapes": [list(sh.shape) for sh in shards],
        })
        for k, sh in enumerate(shards):
            # raw bytes: robust to extended dtypes (bfloat16) npy can't load
            raw = np.frombuffer(np.ascontiguousarray(sh).tobytes(), np.uint8)
            with open(os.path.join(tmp, f"leaf{i}.s{k}.npy"), "wb") as f:
                np.save(f, raw)
                f.flush()
                os.fsync(f.fileno())
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(final, "COMMIT"), "w") as f:
        f.write("ok")
    return final


def latest_step(directory: str) -> Optional[int]:
    """Newest *committed* checkpoint step, or None."""
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("ckpt_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "COMMIT")):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, tree_like: Any,
                    shardings: Optional[Any] = None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``tree_like``; optional re-mesh.

    ``shardings``: pytree of jax.sharding.Sharding (or None leaves) matching
    ``tree_like`` — leaves are device_put with them (the re-mesh path).
    Returns (tree, extras).
    """
    path = os.path.join(directory, f"ckpt_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = jax.tree.flatten(tree_like)
    if len(leaves_like) != manifest["n_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, "
            f"restore target has {len(leaves_like)}")
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(leaves_like))
    out = []
    for i, (like, info) in enumerate(zip(leaves_like, manifest["leaves"])):
        dt = _np_dtype(info["dtype"])
        parts = []
        for k in range(info["n_shards"]):
            raw = np.load(os.path.join(path, f"leaf{i}.s{k}.npy"))
            parts.append(np.frombuffer(raw.tobytes(), dt)
                         .reshape(info["shard_shapes"][k]))
        arr = parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
        if list(arr.shape) != info["shape"]:
            raise ValueError(f"leaf {i} shape mismatch")
        if shard_leaves[i] is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return treedef.unflatten(out), manifest["extras"]


class Checkpointer:
    """Async wrapper: snapshot on the caller thread, write in background."""

    def __init__(self, directory: str, keep: int = 3, n_shards: int = 1):
        self.directory = directory
        self.keep = keep
        self.n_shards = n_shards
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)
        self.clean()

    def clean(self) -> None:
        for name in os.listdir(self.directory):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.directory, name),
                              ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any, extras: Optional[Dict] = None,
             blocking: bool = False) -> None:
        self.wait()
        # consistent host snapshot before training mutates the arrays
        snapshot = jax.tree.map(lambda l: np.asarray(l), tree)

        def work():
            save_checkpoint(self.directory, step, snapshot, extras,
                            self.n_shards)
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def _gc(self) -> None:
        steps = sorted(s for s in (
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("ckpt_") and not n.endswith(".tmp")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"ckpt_{s}"),
                          ignore_errors=True)

    def restore_latest(self, tree_like: Any, shardings: Optional[Any] = None):
        step = latest_step(self.directory)
        if step is None:
            return None, None, {}
        tree, extras = load_checkpoint(self.directory, step, tree_like,
                                       shardings)
        return step, tree, extras
