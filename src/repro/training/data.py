"""Synthetic data pipeline: deterministic, shardable, infinite.

Sequences are generated from a per-shard PRNG keyed by (seed, step, shard),
so any host can regenerate exactly its shard of any step — the property the
checkpoint/restart path relies on (restart mid-epoch without data state).
A Zipf token distribution keeps embedding-gather access patterns realistic,
and for MoE archs a topic-mixture structure gives the router non-trivial,
stable expert specialization (mirroring serving/workload.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from repro.configs.base import ArchConfig

__all__ = ["DataConfig", "synthetic_batch", "data_stream"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 0
    zipf_a: float = 1.2
    n_topics: int = 16


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    p = 1.0 / np.arange(1, vocab + 1) ** a
    return p / p.sum()


_PROB_CACHE: Dict[tuple, np.ndarray] = {}


def synthetic_batch(cfg: ArchConfig, data: DataConfig, step: int,
                    shard: int = 0, n_shards: int = 1) -> Dict[str, np.ndarray]:
    """One (possibly host-sharded) batch for the given step."""
    rng = np.random.default_rng(
        np.random.SeedSequence([data.seed, step, shard]))
    b = data.global_batch // n_shards
    s = data.seq_len
    key = (cfg.vocab, data.zipf_a)
    if key not in _PROB_CACHE:
        _PROB_CACHE[key] = _zipf_probs(cfg.vocab, data.zipf_a)
    probs = _PROB_CACHE[key]
    # topic-tilted sampling: each sequence draws a topic that biases a slice
    # of the vocab, giving the MoE router stable structure to specialize on
    topics = rng.integers(0, data.n_topics, size=b)
    tokens = np.empty((b, s), np.int32)
    for i in range(b):
        tilt = np.ones(cfg.vocab)
        lo = (topics[i] * cfg.vocab) // data.n_topics
        hi = ((topics[i] + 1) * cfg.vocab) // data.n_topics
        tilt[lo:hi] = 4.0
        p = probs * tilt
        tokens[i] = rng.choice(cfg.vocab, size=s, p=p / p.sum())
    labels = np.roll(tokens, -1, axis=1)
    out: Dict[str, np.ndarray] = {"tokens": tokens, "labels": labels}
    if cfg.frontend == "audio":
        out = {"feats": rng.normal(0, 1, (b, s, cfg.frontend_dim))
               .astype(np.float32),
               "labels": rng.integers(0, cfg.vocab, (b, s)).astype(np.int32)}
    elif cfg.frontend == "vision":
        text = s - cfg.n_patches
        out = {"tokens": tokens[:, :text],
               "labels": labels[:, :text],
               "patches": rng.normal(0, 1, (b, cfg.n_patches,
                                            cfg.frontend_dim))
               .astype(np.float32)}
    return out


def data_stream(cfg: ArchConfig, data: DataConfig, start_step: int = 0,
                shard: int = 0, n_shards: int = 1) -> Iterator[Dict]:
    step = start_step
    while True:
        yield synthetic_batch(cfg, data, step, shard, n_shards)
        step += 1
