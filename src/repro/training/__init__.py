# Training substrate: optimizer, synthetic data pipeline, sharded
# checkpointing with async save + re-mesh restore, and elastic/fault-
# tolerance utilities that compose ViBE with rank loss.
from .checkpoint import (Checkpointer, latest_step, load_checkpoint,
                         save_checkpoint)
from .data import DataConfig, data_stream, synthetic_batch
from .elastic import StragglerDetector, elastic_targets, replan_after_loss
from .optimizer import (AdamWConfig, OptState, adamw_init, adamw_update,
                        cosine_lr, global_norm)

__all__ = [
    "Checkpointer", "latest_step", "load_checkpoint", "save_checkpoint",
    "DataConfig", "data_stream", "synthetic_batch",
    "StragglerDetector", "elastic_targets", "replan_after_loss",
    "AdamWConfig", "OptState", "adamw_init", "adamw_update", "cosine_lr",
    "global_norm",
]
