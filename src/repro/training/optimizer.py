"""AdamW with fp32 master/moment state, built for sharded training.

State mirrors the parameter pytree, so whatever PartitionSpec a parameter
carries applies leaf-wise to its moments and master copy — ZeRO-style
sharding falls out of the param sharding rules for free (launch/sharding).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "global_norm", "cosine_lr"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    master_fp32: bool = True     # keep fp32 master weights (bf16 params)


class OptState(NamedTuple):
    step: jnp.ndarray            # scalar i32
    mu: Any                      # first moments (fp32)
    nu: Any                      # second moments (fp32)
    master: Optional[Any]        # fp32 master weights (or None)


def adamw_init(params, cfg: AdamWConfig = AdamWConfig()) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
              if cfg.master_fp32 else None)
    return OptState(jnp.zeros((), jnp.int32),
                    jax.tree.map(zeros, params),
                    jax.tree.map(zeros, params),
                    master)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def cosine_lr(cfg: AdamWConfig, step, warmup: int = 100,
              total: int = 10_000) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < warmup, warm, 0.1 + 0.9 * cos)


def adamw_update(grads, state: OptState, params,
                 cfg: AdamWConfig = AdamWConfig(),
                 lr: Optional[jnp.ndarray] = None) -> Tuple[Any, OptState]:
    """One AdamW step. Returns (new params, new state)."""
    step = state.step + 1
    if lr is None:
        lr = jnp.asarray(cfg.lr, jnp.float32)
    # global-norm clip
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(g, mu, nu, p, master):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1.0 - cfg.b1) * g
        nu = cfg.b2 * nu + (1.0 - cfg.b2) * g * g
        upd = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        base = master if master is not None else p.astype(jnp.float32)
        decay = cfg.weight_decay * base if cfg.weight_decay else 0.0
        new_master = base - lr * (upd + decay)
        return new_master.astype(p.dtype), mu, nu, new_master

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    flat_ma = (treedef.flatten_up_to(state.master)
               if state.master is not None else [None] * len(flat_p))
    out = [upd(g, mu, nu, p, ma) for g, mu, nu, p, ma
           in zip(flat_g, flat_mu, flat_nu, flat_p, flat_ma)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    new_ma = (treedef.unflatten([o[3] for o in out])
              if state.master is not None else None)
    return new_p, OptState(step, new_mu, new_nu, new_ma)
