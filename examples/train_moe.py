"""Train a ~100M-param MoE LM for a few hundred steps on this host,
with checkpoints, restart, and the routing statistics that seed ViBE.

    PYTHONPATH=src python examples/train_moe.py [--steps 300]
"""

import argparse
import dataclasses
import tempfile


from repro.configs import get_smoke
import repro.configs.qwen3_moe_235b as q3
from repro.launch.train import train

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    # ~100M-param qwen3-family MoE (scaled-up smoke config)
    cfg = dataclasses.replace(
        get_smoke("qwen3-moe-235b-a22b"), name="qwen3-moe-100m",
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        n_experts=16, top_k=4, moe_d_ff=512, vocab=16384)
    import repro.configs as C
    # register so the driver can resolve it
    C._MODULES["qwen3-moe-100m"] = "qwen3_moe_235b"
    q3.SMOKE_100M = cfg
    orig = C.get_smoke
    C.get_smoke = lambda n: cfg if n == "qwen3-moe-100m" else orig(n)
    import repro.launch.train as T
    T.get_smoke = C.get_smoke

    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="vibe_train_")
    params, opt, losses, tallies = train(
        "qwen3-moe-100m", smoke=True, steps=args.steps, seq_len=128,
        batch=8, ckpt_dir=ckpt, ckpt_every=50, log_every=20)
    print(f"loss: {losses[0]:.3f} → {losses[-1]:.3f} over {args.steps} steps")
    if tallies is not None:
        per_expert = tallies.sum(0)
        print("router specialization: expert load max/min = "
              f"{per_expert.max() / max(per_expert.min(), 1):.2f} "
              "(this matrix seeds ViBE's Phase 2 placement)")
    print(f"checkpoints in {ckpt} (restartable: rerun with --ckpt-dir)")
