"""End-to-end serving: a real JAX MoE model under the continuous-batching
engine — paged KV cache, SLO-aware scheduling and chunked prefill — with
ViBE placement, drift detection and live weight migration.

    PYTHONPATH=src python examples/serve_moe.py [--policy eplb]
    PYTHONPATH=src python examples/serve_moe.py --scheduler slo_edf \\
        --workload bursty --prefill-chunk 12
"""

import argparse

from repro.core import registered_policies
from repro.launch.serve import serve
from repro.serving import TRACES, WORKLOADS, registered_schedulers, summarize

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="vibe",
                    choices=list(registered_policies()))
    ap.add_argument("--arch", default="qwen3-moe-235b-a22b")
    ap.add_argument("--scheduler", default="fcfs",
                    choices=list(registered_schedulers()))
    ap.add_argument("--workload", default="sharegpt",
                    choices=sorted(WORKLOADS) + sorted(TRACES))
    ap.add_argument("--prefill-chunk", type=int, default=0)
    args = ap.parse_args()

    engine, records, _ = serve(args.arch, policy=args.policy, n_requests=8,
                            qps=30.0, workload=args.workload, max_batch=4,
                            max_seq=96, scheduler=args.scheduler,
                            prefill_chunk=args.prefill_chunk)
    s = summarize(records)
    st = engine.stats
    print(f"policy={args.policy}: served {s['n']} requests in "
          f"{st.steps} steps ({st.prefill_steps} prefill, "
          f"{st.chunk_steps} chunks, {st.decode_steps} decode)")
    print(f"virtual time {st.virtual_time:.3f}s | "
          f"TTFT p50/p90 {s['ttft_p50'] * 1e3:.1f}/{s['ttft_p90'] * 1e3:.1f}ms"
          f" | TPOT p50 {s['tpot_p50'] * 1e3:.2f}ms")
    print(f"recalibrations {st.migrations}, migrated expert slots "
          f"{st.migrated_slots} ({st.migration_bytes / 2**20:.1f} MiB)")
