"""End-to-end serving: a real JAX MoE model under the continuous-batching
engine, with ViBE placement, drift detection and live weight migration.

    PYTHONPATH=src python examples/serve_moe.py [--policy eplb]
"""

import argparse

from repro.core import registered_policies
from repro.launch.serve import serve
from repro.serving import summarize

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="vibe",
                    choices=list(registered_policies()))
    ap.add_argument("--arch", default="qwen3-moe-235b-a22b")
    args = ap.parse_args()

    engine, records = serve(args.arch, policy=args.policy, n_requests=8,
                            qps=30.0, workload="sharegpt", max_batch=4,
                            max_seq=96)
    s = summarize(records)
    st = engine.stats
    print(f"policy={args.policy}: served {s['n']} requests in "
          f"{st.steps} steps ({st.prefill_steps} prefill, "
          f"{st.decode_steps} decode)")
    print(f"virtual time {st.virtual_time:.3f}s | "
          f"TTFT p50/p90 {s['ttft_p50'] * 1e3:.1f}/{s['ttft_p90'] * 1e3:.1f}ms"
          f" | TPOT p50 {s['tpot_p50'] * 1e3:.2f}ms")
    print(f"recalibrations {st.migrations}, migrated expert slots "
          f"{st.migrated_slots} ({st.migration_bytes / 2**20:.1f} MiB)")
