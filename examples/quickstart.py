"""Quickstart: ViBE in 80 lines — profile, place (every registered
placement policy), drift, recalibrate.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (DriftConfig, SolveContext, ViBEConfig,
                        ViBEController, get_policy, layer_latency_span,
                        make_cluster, registered_policies)
from repro.serving import WORKLOADS, routing_profile

# A ground-truth 8-device cluster in the paper's MI325X regime: nominally
# identical devices, up to ~7% kernel-time spread under power-limited load.
cluster = make_cluster(8, "mi325x", d_model=7168, d_ff=2048,
                       experts_per_rank=32)

# Phase 1a — profile each device once: token count → fused-MoE latency.
perf_models = cluster.fit_models()
print("device speeds @stress:",
      np.round([m.speed(3 * cluster.n_tdp) for m in perf_models], 2))

# Phase 1b — profile expert activation on a representative workload.
L, E, TOP_K, TOKENS = 61, 256, 8, 16_384
W = routing_profile(WORKLOADS["sonnet"], L, E) * TOKENS * TOP_K

# Phase 2 — placement. Policies are plugins: every entry in the registry
# (vLLM-style contiguous, EPLB, GEM-style greedy, HarMoEny-style redundant
# sharding, ViBE, ViBE-R) solves the same SolveContext; capability flags
# say what each solve consumes. Register your own policy and it shows up
# here — and in `launch/serve.py --policy` and the benchmark sweeps.
ctx = SolveContext(w=W, n_ranks=8, perf_models=perf_models)
for name in registered_policies():
    pol = get_policy(name)
    caps = pol.capabilities
    pl = pol.solve(ctx if caps.needs_perf_models
                   else SolveContext(w=W, n_ranks=8))
    span = layer_latency_span(pl, W, perf_models)
    print(f"{name:>10}: predicted layer latency "
          f"max {span[:, 0].mean() * 1e3:.3f}ms"
          f"  span {(span[:, 0] - span[:, 2]).mean() * 1e3:.3f}ms"
          f"  (max copies {int(pl.n_copies().max())})")

# Phase 3 — serve with drift-aware recalibration.
ctl = ViBEController(
    L, E, 8, perf_models,
    ViBEConfig(policy="vibe", adaptive=True,
               drift=DriftConfig(window=50, interval=10, cooldown=20),
               expert_bytes=3 * 7168 * 2048 * 2),
    initial_w=W)

rng = np.random.default_rng(0)
W_drifted = routing_profile(WORKLOADS["sharegpt"], L, E) * TOKENS * TOP_K
for step in range(200):
    w_now = (W if step < 80 else W_drifted) * rng.uniform(0.97, 1.03)
    upd = ctl.observe(w_now, tokens=TOKENS)
    if upd is not None:
        print(f"step {step}: drift {upd.event.kind} "
              f"(cos d={upd.event.max_cos_distance:.3f}) → "
              f"recalibrated, moved {upd.moved_experts} expert slots "
              f"({upd.migration_bytes / 2**20:.0f} MiB) "
              f"{'full re-solve' if upd.full_resolve else 'incremental'}")
print(f"total recalibrations: {len(ctl.updates)}")
