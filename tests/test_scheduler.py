"""Scheduler registry + built-in policy semantics (serving/scheduler.py).

The engine and the simulator's scheduled loop both trust three contracts
pinned here: registry lookups are closed over registered names, chunk
packing respects the token budget and admission limits, and ``slo_edf``
never starves decode past its configured bound.
"""

import dataclasses

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving import (Action, Chunk, RequestView, SchedulerConfig,
                           SchedulerContext, UnknownSchedulerError,
                           get_scheduler, register_scheduler,
                           registered_schedulers)
from repro.serving.scheduler import _REGISTRY


def _ctx(waiting=(), prefilling=(), n_running=0, prefill_streak=0,
         can_start=4, chunk_budget=64, prefill_chunk=0,
         decode_starvation_bound=4, ttft_slo=0.35):
    return SchedulerContext(
        now=0.0,
        config=SchedulerConfig(prefill_chunk=prefill_chunk,
                               decode_starvation_bound=decode_starvation_bound,
                               ttft_slo=ttft_slo),
        waiting=list(waiting), prefilling=list(prefilling),
        n_running=n_running, prefill_streak=prefill_streak,
        can_start=can_start, chunk_budget=chunk_budget)


def _req(req_id, arrival=0.0, prompt=8, output=4, prefilled=0, slo=None):
    return RequestView(req_id=req_id, arrival=arrival, prompt_len=prompt,
                       output_len=output, prefilled=prefilled, ttft_slo=slo)


class TestRegistry:
    def test_builtins_registered(self):
        assert {"fcfs", "slo_edf", "decode_priority"} <= \
            set(registered_schedulers())

    def test_unknown_name_lists_registry(self):
        with pytest.raises(UnknownSchedulerError, match="fcfs"):
            get_scheduler("nope")

    def test_duplicate_and_replace(self):
        class Dummy:
            name = "fcfs"

            def schedule(self, ctx):
                return Action("idle")

        with pytest.raises(ValueError, match="already registered"):
            register_scheduler(Dummy)
        orig = get_scheduler("fcfs")
        try:
            register_scheduler(Dummy, replace=True)
            assert isinstance(get_scheduler("fcfs"), Dummy)
        finally:
            _REGISTRY["fcfs"] = orig

    def test_protocol_enforced(self):
        class NoSchedule:
            name = "broken"

        with pytest.raises(TypeError):
            register_scheduler(NoSchedule)


class TestChunkPacking:
    def test_whole_prompt_mode_one_chunk_each(self):
        ctx = _ctx(waiting=[_req(0, prompt=8), _req(1, prompt=8)],
                   chunk_budget=64)
        chunks = ctx.build_chunks(ctx.waiting)
        assert [(c.req_id, c.n_tokens) for c in chunks] == [(0, 8), (1, 8)]

    def test_first_chunk_always_taken_over_budget(self):
        # a prompt larger than the budget still gets its chunk — otherwise
        # a big request at the head of the queue would deadlock the loop
        ctx = _ctx(waiting=[_req(0, prompt=100)], chunk_budget=16)
        assert ctx.build_chunks(ctx.waiting) == (Chunk(0, 100),)

    def test_budget_stops_later_chunks(self):
        ctx = _ctx(waiting=[_req(0, prompt=10), _req(1, prompt=10)],
                   chunk_budget=12)
        chunks = ctx.build_chunks(ctx.waiting)
        assert [c.req_id for c in chunks] == [0]

    def test_can_start_gates_new_but_not_midprefill(self):
        mid = _req(0, prompt=12, prefilled=4)
        new = _req(1, prompt=8)
        ctx = _ctx(waiting=[new], prefilling=[mid], can_start=0,
                   prefill_chunk=4)
        chunks = ctx.build_chunks([mid, new])
        assert [c.req_id for c in chunks] == [0]
        assert chunks[0].n_tokens == 4           # chunked: min(4, remaining)

    def test_chunked_sizes_clamped_to_remaining(self):
        mid = _req(0, prompt=10, prefilled=8)
        ctx = _ctx(prefilling=[mid], prefill_chunk=4)
        assert ctx.build_chunks([mid])[0].n_tokens == 2


class TestBuiltins:
    def test_fcfs_prefers_prefill_in_arrival_order(self):
        s = get_scheduler("fcfs")
        a = s.schedule(_ctx(waiting=[_req(1, arrival=0.1),
                                     _req(0, arrival=0.0)], n_running=2))
        assert a.kind == "prefill"
        assert a.chunks[0].req_id == 1           # list order, not sorted

    def test_fcfs_decode_when_no_prefill(self):
        s = get_scheduler("fcfs")
        assert s.schedule(_ctx(n_running=2)).kind == "decode"
        assert s.schedule(_ctx()).kind == "idle"

    def test_edf_orders_by_deadline_with_tenant_slo(self):
        s = get_scheduler("slo_edf")
        # req 5 arrives later but its tight tenant SLO makes it urgent
        a = s.schedule(_ctx(waiting=[_req(3, arrival=0.0, slo=0.5),
                                     _req(5, arrival=0.1, slo=0.05)]))
        assert a.kind == "prefill"
        assert a.chunks[0].req_id == 5

    def test_edf_forces_decode_at_starvation_bound(self):
        s = get_scheduler("slo_edf")
        ctx = _ctx(waiting=[_req(0)], n_running=1, prefill_streak=4,
                   decode_starvation_bound=4)
        assert s.schedule(ctx).kind == "decode"
        # but not when nothing is decoding — forcing decode would idle
        ctx2 = _ctx(waiting=[_req(0)], n_running=0, prefill_streak=9)
        assert s.schedule(ctx2).kind == "prefill"

    def test_decode_priority_extreme(self):
        s = get_scheduler("decode_priority")
        assert s.schedule(_ctx(waiting=[_req(0)], n_running=1)).kind \
            == "decode"
        assert s.schedule(_ctx(waiting=[_req(0)])).kind == "prefill"

    @settings(max_examples=40, deadline=None)
    @given(streak=st.integers(0, 12), bound=st.integers(1, 8),
           n_running=st.integers(0, 8), n_waiting=st.integers(0, 6))
    def test_edf_starvation_bound_property(self, streak, bound, n_running,
                                           n_waiting):
        """slo_edf never returns prefill once the streak reaches the bound
        while sequences are decoding — TPOT starvation is bounded."""
        s = get_scheduler("slo_edf")
        ctx = _ctx(waiting=[_req(i, arrival=i * 0.01)
                            for i in range(n_waiting)],
                   n_running=n_running, prefill_streak=streak,
                   decode_starvation_bound=bound)
        a = s.schedule(ctx)
        if n_running > 0 and streak >= bound:
            assert a.kind == "decode"
        assert isinstance(a, Action)


class TestViewInvariants:
    def test_views_frozen(self):
        v = _req(0)
        with pytest.raises(dataclasses.FrozenInstanceError):
            v.prefilled = 3

    def test_deadline_falls_back_to_config_slo(self):
        assert _req(0, arrival=1.0).deadline(0.35) == pytest.approx(1.35)
        assert _req(0, arrival=1.0, slo=0.1).deadline(0.35) \
            == pytest.approx(1.1)

    def test_action_validation(self):
        with pytest.raises(ValueError):
            Action("prefill")                    # needs chunks
        with pytest.raises(ValueError):
            Action("nonsense")
