"""Golden regression fixture for the ViBE-R solver + validation coverage.

The placement, per-copy traffic shares, and predicted max-layer latency for
a fixed small fixture are checked in verbatim: a solver refactor that
changes tie-breaking, share computation, or the slot layout — even while
still "optimal" — fails here and must update the goldens *deliberately*.
Perf models are synthetic affine curves (not cluster-calibrated) so the
fixture is immune to profiling-harness changes.
"""

import numpy as np
import pytest

from repro.core import (PerfModel, ReplicatedPlacement,
                        predicted_rank_latencies, vibe_r_placement)


def affine_perf(slopes, base=5e-4):
    return [PerfModel(knots=np.array([0.0, 1e6]),
                      lat=np.array([base, base + s * 1e6]), device_id=g)
            for g, s in enumerate(slopes)]


GOLDEN_W = np.array([
    [4000., 2500., 150., 900., 300., 80., 60., 10.],
    [120., 40., 5000., 700., 2200., 350., 90., 500.],
])
GOLDEN_SLOPES = [1e-8, 2e-8, 4e-8, 8e-8]
# Goldens regenerated when the reweighted-refill pass was folded into
# _replicated_solve: layer 0's refill found a strictly better layout
# (predicted straggler latency 0.0006051 → 0.0005859), layer 1 kept the
# single-pass solve (refill did not improve it) — shares there are
# unchanged to the last digit.
GOLDEN_SLOT_EXPERT = np.array([
    [0, 1, 7, 0, 1, 3, 0, 4, 5, 0, 2, 6],
    [2, 4, 6, 1, 2, 4, 2, 3, 5, 0, 2, 7],
], dtype=np.int32)
GOLDEN_SHARE = np.array([
    [0.2741683909, 0.5094339623, 1.0, 0.2640140060, 0.4905660377, 1.0,
     0.2458061436, 1.0, 1.0, 0.2160114595, 1.0, 1.0],
    [0.2768019609, 0.5105386417, 1.0, 1.0, 0.2653743570, 0.4894613583,
     0.2451339400, 1.0, 1.0, 1.0, 0.2126897420, 1.0],
])
GOLDEN_MAX_LATENCY = np.array([0.0005859237, 0.0006346759])


def test_vibe_r_solver_golden_fixture():
    perf = affine_perf(GOLDEN_SLOPES)
    rp = vibe_r_placement(GOLDEN_W, perf, slots_per_rank=3)
    np.testing.assert_array_equal(rp.slot_expert, GOLDEN_SLOT_EXPERT)
    np.testing.assert_allclose(rp.share, GOLDEN_SHARE, atol=1e-9)
    lat = predicted_rank_latencies(rp, GOLDEN_W, perf)
    np.testing.assert_allclose(lat.max(1), GOLDEN_MAX_LATENCY, rtol=1e-6)


def test_golden_fixture_is_share_skewed():
    """Sanity on the fixture itself: it must exercise non-uniform shares
    (otherwise it can't catch a regression in the share computation)."""
    replicated = GOLDEN_SHARE[GOLDEN_SHARE < 1.0]
    assert replicated.size > 0
    assert replicated.max() / replicated.min() > 2.0


# ---------------------------------------------------------------------------
# ReplicatedPlacement.__post_init__ validation error paths
# ---------------------------------------------------------------------------

class TestReplicatedPlacementValidation:
    def _ok(self):
        # 2 experts on 2 ranks, expert 0 replicated into the spare slots
        se = np.array([[0, 1, 0, 1]])
        sh = np.array([[0.75, 1.0, 0.25, 0.0]])
        return se, sh

    def test_valid_baseline(self):
        se, sh = self._ok()
        rp = ReplicatedPlacement(se, sh, n_ranks=2, n_experts=2)
        np.testing.assert_array_equal(rp.n_copies(), [[2, 2]])

    def test_shares_must_sum_to_one(self):
        se, sh = self._ok()
        for bad in (sh * 0.5, sh * 2.0, sh + 0.01):
            with pytest.raises(ValueError,
                               match="copy shares must sum to 1"):
                ReplicatedPlacement(se, bad, n_ranks=2, n_experts=2)

    def test_negative_share_rejected(self):
        se = np.array([[0, 1, 0, 1]])
        sh = np.array([[1.25, 1.0, -0.25, 0.0]])
        with pytest.raises(ValueError, match="negative copy share"):
            ReplicatedPlacement(se, sh, n_ranks=2, n_experts=2)

    def test_shape_mismatch_rejected(self):
        with pytest.raises(ValueError, match="!= share"):
            ReplicatedPlacement(np.array([[0, 1]]),
                                np.array([[0.5, 0.25, 0.25]]),
                                n_ranks=2, n_experts=2)

    def test_slot_count_must_divide_ranks(self):
        with pytest.raises(ValueError, match="not divisible"):
            ReplicatedPlacement(np.array([[0, 1, 0]]),
                                np.array([[0.5, 1.0, 0.5]]),
                                n_ranks=2, n_experts=2)

    def test_expert_ids_in_range(self):
        # ids strictly beyond the phantom sentinel (== n_experts) are out
        # of range; the sentinel itself marks a budget-padding phantom slot
        with pytest.raises(ValueError, match="outside"):
            ReplicatedPlacement(np.array([[0, 3]]), np.array([[1.0, 1.0]]),
                                n_ranks=2, n_experts=2)

    def test_phantom_slots_carry_no_share(self):
        se = np.array([[0, 1, 2, 1]])          # slot 2 is a phantom (id == E)
        sh = np.array([[1.0, 0.5, 0.0, 0.5]])
        rp = ReplicatedPlacement(se, sh, n_ranks=2, n_experts=2)
        np.testing.assert_array_equal(rp.n_copies(), [[1, 2]])
        np.testing.assert_array_equal(rp.rank_slot_budget(), [[2, 1]])
        with pytest.raises(ValueError, match="phantom"):
            ReplicatedPlacement(se, np.array([[1.0, 0.5, 0.25, 0.25]]),
                                n_ranks=2, n_experts=2)

    def test_every_expert_needs_a_slot(self):
        with pytest.raises(ValueError, match="no physical slot"):
            ReplicatedPlacement(np.array([[0, 0]]), np.array([[0.5, 0.5]]),
                                n_ranks=2, n_experts=2)

    def test_copy_shares_r_max_too_small(self):
        se, sh = self._ok()
        rp = ReplicatedPlacement(se, sh, n_ranks=2, n_experts=2)
        with pytest.raises(ValueError, match="r_max"):
            rp.copy_shares(r_max=1)
