"""Seed-determinism sweep across the registered policy × scheduler matrix.

Running serve's core loop twice with the same seed must be *bit-identical*:
any unseeded RNG, dict-order iteration, wall-clock read, or accumulation-
order drift anywhere in the stack (controller solve, scheduler decisions,
simulated loads, virtual-clock pricing, work stealing) shows up here as a
record or stat mismatch. The matrix is registry-driven, so newly registered
policies and schedulers are swept automatically.
"""

import pytest

from repro.configs import get_smoke
from repro.core import (StealConfig, ViBEConfig, ViBEController,
                        get_policy, make_cluster, registered_policies)
from repro.serving import (EPSimulator, Engine, EngineConfig,
                           SchedulerConfig, SimConfig, WORKLOADS,
                           registered_schedulers, routing_profile,
                           sample_requests)

POLICIES = registered_policies()
SCHEDULERS = registered_schedulers()


def _record_key(r):
    return (r.req_id, r.arrival, r.prompt_len, r.output_len,
            r.first_token_at, r.finished_at)


def _build(policy, sched):
    cfg = get_smoke("qwen3-moe-235b-a22b")
    cluster = make_cluster(4, "mi325x", d_model=cfg.d_model,
                           d_ff=cfg.moe_d_ff,
                           experts_per_rank=cfg.n_experts // 4)
    L, E = cfg._n_moe_layers(), cfg.n_experts
    wl = WORKLOADS["sharegpt"]
    W = routing_profile(wl, L, E) * 4096 * cfg.top_k
    # replication-capable policies also exercise the steal path, so the
    # sweep covers the responsive-share machinery too
    steal = (StealConfig(headroom=0.0, smoothing=1.0)
             if get_policy(policy).capabilities.supports_replication
             else None)
    ctl = ViBEController(L, E, 4, cluster.fit_models(),
                         ViBEConfig(policy=policy, steal=steal),
                         initial_w=W)
    return cfg, cluster, wl, ctl, sched


def _sim_once(policy, sched):
    cfg, cluster, wl, ctl, sched = _build(policy, sched)
    sim = EPSimulator(cfg, cluster, wl,
                      SimConfig(ep_degree=4, seed=5,
                                max_prefill_tokens=4096,
                                scheduler=SchedulerConfig(name=sched)),
                      controller=ctl)
    recs = sim.run(sample_requests(wl, 20, qps=30.0, seed=6),
                   phase="prefill")
    rs = ctl.rescheduler
    return (tuple(_record_key(r) for r in recs),
            (sim.steps, sim.now, sim.total_layer_time,
             sim.total_barrier_idle, sim.dropped_assignments,
             sim.steal_updates, len(ctl.updates),
             rs.steals if rs is not None else -1,
             rs.share_moved if rs is not None else -1.0))


@pytest.mark.parametrize("sched", SCHEDULERS)
@pytest.mark.parametrize("policy", POLICIES)
def test_simulator_run_bit_identical_across_reruns(policy, sched):
    recs_a, stats_a = _sim_once(policy, sched)
    recs_b, stats_b = _sim_once(policy, sched)
    assert recs_a == recs_b
    assert stats_a == stats_b


@pytest.mark.slow
@pytest.mark.parametrize("sched", SCHEDULERS)
def test_engine_run_bit_identical_across_reruns(sched):
    """The real JAX engine loop, one representative policy (vibe_r with
    stealing — the most state-carrying configuration) per scheduler."""

    def once():
        cfg, cluster, wl, ctl, name = _build("vibe_r", sched)
        eng = Engine(cfg, EngineConfig(
            max_batch=2, max_seq=48, seed=0,
            scheduler=SchedulerConfig(name=name, prefill_chunk=16)),
            controller=ctl, cluster=cluster)
        reqs = sample_requests(wl, 3, qps=100.0, seed=1)
        reqs = [type(r)(r.req_id, r.arrival, 8, 6) for r in reqs]
        eng.submit(reqs)
        recs = eng.run(max_steps=200)
        st = eng.stats
        return (tuple(_record_key(r) for r in recs),
                (st.decode_steps, st.prefill_steps, st.virtual_time,
                 st.steal_updates, ctl.rescheduler.steals))

    ra, sa = once()
    rb, sb = once()
    assert ra == rb
    assert sa == sb
