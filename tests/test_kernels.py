"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.moe_ffn import fused_moe_ffn_pallas
from repro.kernels.router import router_topk_pallas


def _rand_ffn(key, E, C, D, F, dtype):
    ks = jax.random.split(key, 4)
    toks = jax.random.normal(ks[0], (E, C, D)).astype(dtype)
    w1 = (jax.random.normal(ks[1], (E, D, F)) / np.sqrt(D)).astype(dtype)
    w3 = (jax.random.normal(ks[2], (E, D, F)) / np.sqrt(D)).astype(dtype)
    w2 = (jax.random.normal(ks[3], (E, F, D)) / np.sqrt(F)).astype(dtype)
    return toks, w1, w3, w2


SHAPES = [
    (1, 8, 64, 128),      # single expert
    (4, 64, 128, 256),    # aligned
    (2, 100, 96, 192),    # unaligned C (pad path)
    (8, 16, 256, 512),    # many experts, small capacity
    (3, 33, 160, 130),    # everything unaligned
]


@pytest.mark.parametrize("E,C,D,F", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_moe_ffn_shape_dtype_sweep(E, C, D, F, dtype):
    toks, w1, w3, w2 = _rand_ffn(jax.random.PRNGKey(E * 7 + C), E, C, D, F,
                                 dtype)
    y_ref = np.asarray(ref.moe_ffn_ref(w1, w3, w2, toks), np.float32)
    y = np.asarray(fused_moe_ffn_pallas(w1, w3, w2, toks, bm=32, bf=64,
                                        interpret=True), np.float32)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(y, y_ref, atol=tol, rtol=tol)


def test_moe_ffn_zero_rows_stay_zero():
    """Capacity-bucket semantics: padded rows in, zeros out."""
    toks, w1, w3, w2 = _rand_ffn(jax.random.PRNGKey(0), 2, 16, 64, 128,
                                 jnp.bfloat16)
    toks = toks.at[:, 8:].set(0)
    y = np.asarray(fused_moe_ffn_pallas(w1, w3, w2, toks, interpret=True))
    assert np.abs(y[:, 8:]).max() == 0.0


def test_moe_ffn_block_size_invariance():
    toks, w1, w3, w2 = _rand_ffn(jax.random.PRNGKey(1), 2, 64, 128, 256,
                                 jnp.float32)
    outs = [np.asarray(fused_moe_ffn_pallas(w1, w3, w2, toks, bm=bm, bf=bf,
                                            interpret=True))
            for bm, bf in [(16, 64), (64, 128), (64, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("T,E,K", [(64, 16, 4), (100, 8, 2), (300, 128, 8),
                                   (7, 4, 1), (513, 40, 8)])
def test_router_topk_sweep(T, E, K):
    logits = jax.random.normal(jax.random.PRNGKey(T + E), (T, E),
                               jnp.float32)
    w_ref, i_ref = ref.router_topk_ref(logits, K)
    w, i = router_topk_pallas(logits, K, bt=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref),
                               rtol=1e-5, atol=1e-6)


def test_router_weights_normalized():
    logits = jax.random.normal(jax.random.PRNGKey(9), (50, 32))
    w, _ = ops.router_topk(logits, 4)
    np.testing.assert_allclose(np.asarray(w).sum(-1), 1.0, rtol=1e-5)


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_moe_ffn_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    E = int(rng.integers(1, 5))
    C = int(rng.integers(4, 48))
    D = int(rng.integers(1, 5)) * 32
    F = int(rng.integers(1, 5)) * 32
    toks, w1, w3, w2 = _rand_ffn(jax.random.PRNGKey(seed), E, C, D, F,
                                 jnp.float32)
    y_ref = np.asarray(ref.moe_ffn_ref(w1, w3, w2, toks))
    y = np.asarray(fused_moe_ffn_pallas(w1, w3, w2, toks, bm=16, bf=32,
                                        interpret=True))
    np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)


def test_ops_wrapper_picks_valid_blocks():
    bm, bf = ops.pick_blocks(8192, 24576)
    resident = bm * 8192 * 2 + bm * 8192 * 4 + 3 * 8192 * bf * 2 + bm * bf * 4
    assert resident <= 14 * 1024 * 1024
    assert bm % 128 == 0 and bf % 128 == 0


def test_kernel_is_dispatch_compatible():
    """ops.fused_moe_ffn drops into the EP dispatch's ffn slot."""
    from repro.models.moe import expert_ffn_ref
    toks, w1, w3, w2 = _rand_ffn(jax.random.PRNGKey(3), 2, 32, 64, 128,
                                 jnp.bfloat16)
    a = np.asarray(expert_ffn_ref(w1, w3, w2, toks), np.float32)
    b = np.asarray(ops.fused_moe_ffn(w1, w3, w2, toks), np.float32)
    np.testing.assert_allclose(a, b, atol=5e-2, rtol=5e-2)
