"""Property tests for share-weighted replica selection (models/moe.py).

Runs under real hypothesis in CI and under tests/_hypothesis_fallback.py in
containers without it (conftest registers the shim). Properties:

* inverse-CDF selection is a pure function — deterministic for fixed inputs;
* it matches an independent numpy searchsorted reference;
* it degenerates to the singleton path when ``r_max == 1``;
* realized per-copy traffic converges to the solver's shares (bounded TV
  distance, shrinking with token count — heavy sweep marked ``slow``).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import PerfModel, reweight_shares_by_speed, vibe_r_placement
from repro.models.moe import _assignment_uniforms, _select_slots


def affine_perf(slopes, base=5e-4):
    return [PerfModel(knots=np.array([0.0, 1e6]),
                      lat=np.array([base, base + s * 1e6]), device_id=g)
            for g, s in enumerate(slopes)]


def random_tables(rng, E, r_max):
    """Random slots_of / n_copies / copy_cdf with skewed per-copy shares."""
    n_copies = rng.integers(1, r_max + 1, size=E).astype(np.int32)
    slots_of = np.zeros((E, r_max), np.int32)
    slot = 0
    for e in range(E):
        for r in range(int(n_copies[e])):
            slots_of[e, r] = slot
            slot += 1
        slots_of[e, n_copies[e]:] = slots_of[e, 0]
    shares = rng.dirichlet(np.full(r_max, 0.5), size=E)
    cdf = np.ones((E, r_max), np.float32)
    for e in range(E):
        c = int(n_copies[e])
        s = shares[e, :c] / shares[e, :c].sum()
        cdf[e, :c] = np.cumsum(s)
        cdf[e, c - 1:] = 1.0
    return slots_of, n_copies, cdf


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), E=st.sampled_from([4, 8, 16]),
       r_max=st.integers(2, 4))
def test_selection_matches_searchsorted_reference(seed, E, r_max):
    """The jnp inverse-CDF pick equals a literal numpy searchsorted over the
    same deterministic uniforms — independent reimplementation check."""
    rng = np.random.default_rng(seed)
    slots_of, n_copies, cdf = random_tables(rng, E, r_max)
    t, K = 512, 2
    idx = rng.integers(0, E, size=(t, K)).astype(np.int32)
    got = np.asarray(_select_slots(jnp.asarray(idx), jnp.asarray(slots_of),
                                   jnp.asarray(n_copies), jnp.asarray(cdf)))
    u = np.asarray(_assignment_uniforms(t, K))
    copy = np.empty((t, K), np.int64)
    for i in range(t):
        for k in range(K):
            copy[i, k] = np.searchsorted(cdf[idx[i, k]], u[i, k],
                                         side="right")
    copy = np.minimum(copy, n_copies[idx] - 1)
    np.testing.assert_array_equal(got, slots_of[idx, copy])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_selection_deterministic(seed):
    rng = np.random.default_rng(seed)
    slots_of, n_copies, cdf = random_tables(rng, 8, 3)
    idx = rng.integers(0, 8, size=(256, 4)).astype(np.int32)
    args = (jnp.asarray(idx), jnp.asarray(slots_of), jnp.asarray(n_copies),
            jnp.asarray(cdf))
    np.testing.assert_array_equal(np.asarray(_select_slots(*args)),
                                  np.asarray(_select_slots(*args)))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), E=st.sampled_from([4, 16]))
def test_singleton_degenerates_to_direct_lookup(seed, E):
    """r_max == 1: weighted, uniform-hash, and direct lookup all coincide."""
    rng = np.random.default_rng(seed)
    slots_of = rng.permutation(E).astype(np.int32)[:, None]
    n_copies = np.ones(E, np.int32)
    cdf = np.ones((E, 1), np.float32)
    idx = rng.integers(0, E, size=(128, 2)).astype(np.int32)
    want = slots_of[:, 0][idx]
    for c in (jnp.asarray(cdf), None):
        got = np.asarray(_select_slots(jnp.asarray(idx),
                                       jnp.asarray(slots_of),
                                       jnp.asarray(n_copies), c))
        np.testing.assert_array_equal(got, want)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000))
def test_realized_copy_traffic_converges_to_shares(seed):
    """Bounded TV distance: the realized per-copy split of each expert's
    traffic lands within a few sigma of the share table."""
    rng = np.random.default_rng(seed)
    E, r_max = 8, 4
    slots_of, n_copies, cdf = random_tables(rng, E, r_max)
    t, K = 20_000, 2
    idx = rng.integers(0, E, size=(t, K)).astype(np.int32)
    slots = np.asarray(_select_slots(jnp.asarray(idx), jnp.asarray(slots_of),
                                     jnp.asarray(n_copies),
                                     jnp.asarray(cdf)))
    counts = np.bincount(slots.ravel(), minlength=int(n_copies.sum()))
    for e in range(E):
        c = int(n_copies[e])
        if c == 1:
            continue
        got = counts[slots_of[e, :c]].astype(float)
        n = got.sum()
        share = np.diff(np.concatenate([[0.0], cdf[e, :c]]))
        tv = 0.5 * np.abs(got / n - share / share.sum()).sum()
        assert tv < 0.03, (e, tv)


@pytest.mark.slow
def test_convergence_sweep_tv_shrinks_with_tokens():
    """The heavy sweep: TV distance to the share table decays as the token
    count grows (hash equidistribution, not luck)."""
    rng = np.random.default_rng(0)
    E, r_max = 8, 4
    slots_of, n_copies, cdf = random_tables(rng, E, r_max)
    share = np.diff(np.concatenate([np.zeros((E, 1)), cdf], axis=1), axis=1)

    def worst_tv(t):
        idx = rng.integers(0, E, size=(t, 2)).astype(np.int32)
        slots = np.asarray(_select_slots(
            jnp.asarray(idx), jnp.asarray(slots_of),
            jnp.asarray(n_copies), jnp.asarray(cdf)))
        counts = np.bincount(slots.ravel(),
                             minlength=int(n_copies.sum())).astype(float)
        tvs = []
        for e in range(E):
            c = int(n_copies[e])
            if c == 1:
                continue
            got = counts[slots_of[e, :c]]
            sh = share[e, :c] / share[e, :c].sum()
            tvs.append(0.5 * np.abs(got / got.sum() - sh).sum())
        return max(tvs)

    tv = [worst_tv(t) for t in (2_000, 16_000, 128_000)]
    assert tv[-1] < tv[0], tv
    assert tv[-1] < 0.01, tv


def test_route_seed_converges_decode_sized_batches():
    """The decode regime: a handful of assignments per step. With a fixed
    seed the same uniforms replay forever and the realized split stays
    quantized; a per-step seed re-draws them, so traffic aggregated across
    steps converges to the share table."""
    import jax

    slots_of = np.array([[0, 1], [2, 3]], np.int32)
    n_copies = np.array([2, 2], np.int32)
    cdf = np.array([[0.8, 1.0], [0.7, 1.0]], np.float32)
    rng = np.random.default_rng(0)
    idx = rng.integers(0, 2, size=(4, 2)).astype(np.int32)   # 8 assignments
    sel = jax.jit(_select_slots)

    def run(seed):
        return np.bincount(np.asarray(sel(
            jnp.asarray(idx), jnp.asarray(slots_of), jnp.asarray(n_copies),
            jnp.asarray(cdf), jnp.int32(seed))).ravel(), minlength=4)

    steps = 400
    varying = sum(run(s) for s in range(steps))
    fixed = sum(run(0) for _ in range(steps))
    # fixed seed: every step replays step 0 exactly — no convergence
    np.testing.assert_array_equal(fixed, steps * run(0))
    # varying seed: expert 0's copy split approaches its 0.8 / 0.2 shares
    share0 = varying[0] / (varying[0] + varying[1])
    assert abs(share0 - 0.8) < 0.05, share0
    share1 = varying[2] / (varying[2] + varying[3])
    assert abs(share1 - 0.7) < 0.05, share1


# ---------------------------------------------------------------------------
# share reweighting after incremental swaps
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 500))
def test_reweight_shares_by_speed_invariants(seed):
    """Reweighting re-proportions shares to the ranks copies sit on: sums
    stay 1 per expert, the slot table is untouched, and within an expert
    the share ordering follows rank speed."""
    rng = np.random.default_rng(seed)
    G, E, L = 4, 16, 2
    perf = affine_perf([1e-8, 2e-8, 4e-8, 8e-8])
    w = rng.random((L, E)) * 50_000 + 1
    rp = vibe_r_placement(w, perf, slots_per_rank=6)
    rw = reweight_shares_by_speed(rp, w, perf)
    np.testing.assert_array_equal(rw.slot_expert, rp.slot_expert)
    np.testing.assert_array_equal(rw.n_copies(), rp.n_copies())
    rank_of = np.arange(rp.n_slots) // rp.slots_per_rank
    for l in range(L):
        for e in range(E):
            slots = np.flatnonzero(rw.slot_expert[l] == e)
            if slots.size < 2:
                continue
            sh = rw.share[l, slots]
            # affine f_g with increasing slope → rank 0 fastest: the copy on
            # the lower-slope rank must carry the larger share
            order = np.argsort(rank_of[slots])
            assert (np.diff(sh[order]) <= 1e-12).all(), (l, e, sh)


def _replicated_objective(pl, w, perf):
    from repro.core.incremental import _replicated_objective
    return _replicated_objective(pl, w, perf)


def test_incremental_update_reweight_opt_in():
    """reweight_shares=True returns a placement whose shares ARE the
    speed-reweighted shares of its own slot table (the folded search keeps
    the reweight invariant at every step), with replica counts preserved."""
    from repro.core import incremental_update_replicated

    rng = np.random.default_rng(4)
    perf = affine_perf([1e-8, 2e-8, 4e-8, 8e-8])
    w0 = rng.random((3, 16)) * 50_000 + 1
    rp = vibe_r_placement(w0, perf, slots_per_rank=6)
    w1 = np.roll(w0, 5, axis=1)
    res = incremental_update_replicated(rp, w1, perf, reweight_shares=True)
    new = res.placement
    np.testing.assert_array_equal(new.n_copies(), rp.n_copies())
    want = reweight_shares_by_speed(new, w1, perf)
    np.testing.assert_allclose(new.share, want.share, atol=1e-12)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 500))
def test_incremental_folded_reweight_never_worse_than_posthoc(seed):
    """ISSUE 4 satellite: scoring swaps under post-reweight shares must
    never end up worse (Σ_l max_g f_g under reweighted shares) than the
    historical carried-share search + post-hoc reweight."""
    from repro.core import incremental_update_replicated
    from repro.core.incremental import _replicated_swap_run

    rng = np.random.default_rng(seed)
    perf = affine_perf([1e-8, 2e-8, 4e-8, 8e-8])
    w0 = rng.random((2, 16)) * 50_000 + 1
    rp = vibe_r_placement(w0, perf, slots_per_rank=6)
    w1 = np.stack([rng.permutation(w0[l]) for l in range(w0.shape[0])])
    folded = incremental_update_replicated(rp, w1, perf,
                                           reweight_shares=True)
    posthoc = reweight_shares_by_speed(
        _replicated_swap_run(rp, w1, perf, 0.03, 64).placement, w1, perf)
    obj_folded = _replicated_objective(folded.placement, w1, perf)
    obj_posthoc = _replicated_objective(posthoc, w1, perf)
    assert obj_folded <= obj_posthoc + 1e-12, (obj_folded, obj_posthoc)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 500))
def test_full_solve_reweighted_refill_never_worse(seed):
    """ISSUE 7 satellite: the reweighted-refill pass folded into the full
    ViBE-R solve must never worsen the predicted straggler objective
    Σ_l max_g f_g vs the single-pass solve it replaced — the mirror of
    test_incremental_folded_reweight_never_worse_than_posthoc for
    _replicated_solve."""
    from repro.core.placement import (_replicated_solve, _speed_targets,
                                      normalize_slot_budget)

    rng = np.random.default_rng(seed)
    perf = affine_perf([1e-8, 2e-8, 4e-8, 8e-8])
    w = rng.random((2, 16)) * 50_000 + 1
    budget = normalize_slot_budget(6, 16, 4)
    speeds, targets = _speed_targets(w, perf, "rank")
    single = _replicated_solve(w, speeds, targets, 4, budget)
    folded = _replicated_solve(w, speeds, targets, 4, budget,
                               perf_models=perf)
    obj_folded = _replicated_objective(folded, w, perf)
    obj_single = _replicated_objective(single, w, perf)
    assert obj_folded <= obj_single + 1e-12, (obj_folded, obj_single)
    # replica counts are a refill invariant (only the fill moved) and the
    # public entry point IS the folded solve
    np.testing.assert_array_equal(folded.n_copies(), single.n_copies())
    np.testing.assert_allclose(
        vibe_r_placement(w, perf, slots_per_rank=6).share,
        folded.share, atol=1e-12)
