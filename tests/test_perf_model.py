"""Device performance models: fitting, monotonicity, profiling interface."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (DeviceProfile, PerfModel, fit_perf_model,
                        make_cluster, profile_device)


def test_perf_model_interp_and_extrapolation():
    m = PerfModel(np.array([0.0, 100.0, 200.0]),
                  np.array([1e-3, 2e-3, 4e-3]))
    assert m(0) == pytest.approx(1e-3)
    assert m(50) == pytest.approx(1.5e-3)
    assert m(300) == pytest.approx(6e-3)          # final-slope extrapolation
    assert m.speed(100) == pytest.approx(500.0)


def test_perf_model_validation():
    with pytest.raises(ValueError):
        PerfModel(np.array([0.0]), np.array([1e-3]))
    with pytest.raises(ValueError):
        PerfModel(np.array([0.0, 0.0]), np.array([1e-3, 2e-3]))
    with pytest.raises(ValueError):
        PerfModel(np.array([0.0, 1.0]), np.array([1e-3, -1.0]))


def test_fit_is_monotone_even_on_noisy_data():
    rng = np.random.default_rng(0)
    tc = np.repeat([64, 256, 1024, 4096, 16384], 3).astype(float)
    true = 1e-4 + tc * 2e-7
    lat = true * (1 + rng.normal(0, 0.05, tc.size))
    m = fit_perf_model(DeviceProfile(0, tc, lat))
    grid = np.linspace(0, 20000, 200)
    pred = m(grid)
    assert np.all(np.diff(pred) >= -1e-12)         # monotone non-decreasing


def test_profile_device_median_of_repeats():
    calls = []
    def latency_fn(g, n):
        calls.append(n)
        return 1e-3 + n * 1e-7
    prof = profile_device(latency_fn, 0, token_counts=(10, 100), repeats=3)
    assert len(calls) == 6
    assert prof.latencies[1] > prof.latencies[0]


def test_cluster_profiles_recover_speed_ordering():
    """ViBE only sees profiled samples; the fitted models must still rank
    devices correctly at stressed loads (the paper's Phase 1 requirement)."""
    cluster = make_cluster(8, "mi325x", d_model=1024, d_ff=512,
                           experts_per_rank=8)
    models = cluster.fit_models()
    n_stress = 3 * cluster.n_tdp
    fitted = np.array([m(n_stress) for m in models])
    truth = np.array([cluster.latency(g, n_stress) for g in range(8)])
    assert np.corrcoef(fitted, truth)[0, 1] > 0.9


def test_stress_dependence_matches_paper_fig5():
    """Variability is latent at low load (decode) and expressed at high
    load (prefill) — paper Fig 5."""
    cluster = make_cluster(8, "mi325x", d_model=1024, d_ff=512,
                           experts_per_rank=8)
    lo = np.array([cluster.latency(g, 32) for g in range(8)])
    hi = np.array([cluster.latency(g, 4 * cluster.n_tdp) for g in range(8)])
    assert lo.std() / lo.mean() < 0.01
    assert hi.std() / hi.mean() > 0.01


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n_knots=st.integers(2, 12))
def test_property_fit_never_negative_and_callable(seed, n_knots):
    rng = np.random.default_rng(seed)
    tc = np.sort(rng.integers(1, 100_000, size=12)).astype(float)
    tc = np.unique(tc)
    if tc.size < 2:
        tc = np.array([1.0, 2.0])
    lat = np.abs(rng.normal(1e-3, 5e-4, tc.size)) + 1e-6
    m = fit_perf_model(DeviceProfile(0, tc, lat), n_knots=n_knots)
    probe = m(rng.uniform(0, 2e5, size=16))
    assert np.all(probe > 0)


def test_fit_local_regression_exact_on_affine_sweeps():
    """The per-knot estimator answers "latency AT the knot": on exactly
    affine data every knot value must reproduce the truth line regardless
    of how samples sit inside their bins. The pre-fix bin *mean* answered
    "average latency NEAR the knot" and lands at the bin centroid instead —
    off by slope × (centroid − knot) whenever sampling is asymmetric."""
    base, slope = 2e-3, 5e-7
    # asymmetric clusters: samples pile up on one side of each knot
    tc = np.array([100., 110., 120., 400.,
                   1000., 1040., 1080., 1800.,
                   5000., 5100., 5200., 8000.], dtype=float)
    lat = base + slope * tc                        # noiseless affine truth
    m = fit_perf_model(DeviceProfile(0, tc, lat), n_knots=4)
    inner = m.knots[m.knots > 0]                   # skip the 0-anchor
    np.testing.assert_allclose(m(inner), base + slope * inner, rtol=1e-9)
    # tripwire: the bin-mean estimator is measurably biased on this fixture
    knots = np.unique(np.quantile(tc, np.linspace(0, 1, 4)))
    idx = np.abs(tc[:, None] - knots[None, :]).argmin(axis=1)
    means = np.array([lat[idx == i].mean() for i in range(knots.size)])
    bias = np.abs(means - (base + slope * knots)) / (base + slope * knots)
    assert bias.max() > 0.02, "fixture no longer discriminates mean vs fit"


def test_fit_knee_bias_removed():
    """Regression for the documented ~10% stress-knee bias: on a flat-then-
    steep profile whose knee bin straddles the kink, the local-regression
    knot value must sit far closer to the true knee latency than the old
    bin mean did (PerfDriftConfig.delta_perf thresholds below 0.10 rely on
    this)."""
    knee, base, slope = 2048.0, 1e-3, 2e-6
    def truth(n):
        return base + slope * np.maximum(np.asarray(n, dtype=float)
                                         - knee, 0.0)
    # dense sweep with samples on both sides of the knee
    tc = np.array([64., 256., 512., 1024., 1536., 1900., 2000.,
                   2100., 2300., 2700., 3500., 4096., 6144., 8192.])
    lat = truth(tc)
    m = fit_perf_model(DeviceProfile(0, tc, lat), n_knots=8)
    inner = m.knots[m.knots > 0]
    fit_err = np.abs(m(inner) - truth(inner)) / truth(inner)
    assert fit_err.max() < 0.05, fit_err
    # the old bin-mean estimator on the same knots is an order worse: bins
    # on the steep side average up-slope samples into the knot value
    knots = np.unique(np.quantile(tc, np.linspace(0, 1, 8)))
    idx = np.abs(tc[:, None] - knots[None, :]).argmin(axis=1)
    means = np.array([lat[idx == i].mean() for i in range(knots.size)])
    mean_err = np.abs(means - truth(knots)) / truth(knots)
    assert mean_err.max() > 0.10, mean_err          # the documented bias
    assert mean_err.max() > 10 * fit_err.max()
