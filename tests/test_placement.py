"""Placement solvers: invariants, policy semantics, property tests."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (Placement, PerfModel, contiguous_placement,
                        eplb_placement, layer_latency_span,
                        permutation_to_placement, placement_to_permutation,
                        predicted_layer_latency, solve_model_placement,
                        vibe_placement, make_cluster)


def linear_models(speeds):
    """f_g(n) = n / speed — the EPLB assumption with per-device speeds."""
    return [PerfModel(np.array([0.0, 1e6]),
                      np.array([1e-9, 1e6 / s]), device_id=g)
            for g, s in enumerate(speeds)]


def test_contiguous_matches_vllm_layout():
    pl = contiguous_placement(n_layers=2, n_experts=8, n_ranks=4)
    assert pl.assign.shape == (2, 8)
    np.testing.assert_array_equal(pl.assign[0], [0, 0, 1, 1, 2, 2, 3, 3])


def test_uniform_slot_constraint_enforced():
    with pytest.raises(ValueError):
        Placement(np.array([[0, 0, 0, 1]]), n_ranks=2)  # 3-vs-1 split


def test_eplb_balances_tokens():
    rng = np.random.default_rng(0)
    w = rng.dirichlet(np.full(64, 0.3), size=4) * 10_000
    pl = eplb_placement(w, n_ranks=8)
    loads = pl.rank_loads(w)
    for l in range(4):
        # greedy longest-processing-time bound: a single mega-hot expert
        # cannot be split, so max load ≤ mean + heaviest expert
        assert loads[l].max() <= w[l].sum() / 8 + w[l].max() + 1e-9
        # and strictly better than the contiguous layout
        cont = contiguous_placement(1, 64, 8).rank_loads(w[l:l + 1])
        assert loads[l].max() <= cont.max() + 1e-9


def test_vibe_weights_by_speed():
    speeds = np.array([1.0, 1.0, 1.0, 0.7])     # rank 3 is 30% slower
    models = linear_models(speeds)
    rng = np.random.default_rng(1)
    w = rng.dirichlet(np.full(32, 0.5), size=2) * 8_000
    pl = vibe_placement(w, models)
    loads = pl.rank_loads(w)
    # the slow rank receives measurably fewer tokens
    assert loads[:, 3].mean() < 0.85 * loads[:, :3].mean()
    # and predicted completion times are tighter than EPLB's
    span_v = layer_latency_span(pl, w, models)
    span_e = layer_latency_span(eplb_placement(w, 4), w, models)
    assert span_v[:, 0].mean() <= span_e[:, 0].mean() * 1.001


def test_vibe_reduces_latency_gap_under_skew():
    """Paper Fig 13/14: a 13%-degraded device is routed around."""
    cluster = make_cluster(8, "skewed", d_model=1024, d_ff=512,
                           experts_per_rank=8)
    perf = cluster.fit_models()
    rng = np.random.default_rng(2)
    w = rng.dirichlet(np.full(64, 0.25), size=4) * 60_000
    pv = vibe_placement(w, perf)
    pe = eplb_placement(w, 8)
    gap = lambda pl: np.mean([predicted_layer_latency(pl.assign[l], w[l], perf).max()
                              - predicted_layer_latency(pl.assign[l], w[l], perf).min()
                              for l in range(4)])
    assert gap(pv) < gap(pe)


def test_permutation_roundtrip():
    rng = np.random.default_rng(3)
    w = rng.random((3, 16)) * 100
    pl = eplb_placement(w, n_ranks=4)
    perm = placement_to_permutation(pl.assign, 4)
    back = permutation_to_placement(perm, 4)
    np.testing.assert_array_equal(back, pl.assign)


def test_solve_model_placement_dispatch():
    w = np.ones((2, 8))
    assert solve_model_placement("contiguous", w, 4).n_ranks == 4
    assert solve_model_placement("eplb", w, 4).n_experts == 8
    with pytest.raises(ValueError):
        solve_model_placement("vibe", w, 4)          # needs perf models
    with pytest.raises(ValueError):
        solve_model_placement("nope", w, 4)


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    n_ranks=st.sampled_from([2, 4, 8]),
    e_per=st.integers(1, 6),
    n_layers=st.integers(1, 4),
    seed=st.integers(0, 1000),
)
def test_property_every_policy_uniform_slots(n_ranks, e_per, n_layers, seed):
    """Any solver output satisfies the uniform slots-per-rank constraint
    and covers every expert exactly once (bijectivity)."""
    E = n_ranks * e_per
    rng = np.random.default_rng(seed)
    w = rng.random((n_layers, E)) * 1000
    models = linear_models(1.0 - 0.3 * rng.random(n_ranks))
    for pl in (contiguous_placement(n_layers, E, n_ranks),
               eplb_placement(w, n_ranks),
               vibe_placement(w, models)):
        counts = np.apply_along_axis(np.bincount, 1, pl.assign,
                                     minlength=n_ranks)
        assert (counts == e_per).all()
        perm = pl.perm
        for l in range(n_layers):
            assert sorted(perm[l]) == list(range(E))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_vibe_never_worse_than_eplb_with_true_models(seed):
    """With exact (linear) latency models, ViBE's predicted max latency is
    never materially worse than EPLB's — the objective it optimizes."""
    rng = np.random.default_rng(seed)
    G, E = 4, 32
    speeds = 1.0 - 0.4 * rng.random(G)
    models = linear_models(speeds)
    w = rng.dirichlet(np.full(E, 0.4)) * 10_000
    pv = vibe_placement(w[None], models)
    pe = eplb_placement(w[None], G)
    tv = predicted_layer_latency(pv.assign[0], w, models).max()
    te = predicted_layer_latency(pe.assign[0], w, models).max()
    assert tv <= te * 1.02


def test_moved_experts_counts():
    a = contiguous_placement(2, 8, 4)
    b = contiguous_placement(2, 8, 4)
    assert a.moved_experts(b) == 0
    w = np.random.default_rng(0).random((2, 8))
    c = eplb_placement(w, 4)
    assert a.moved_experts(c) >= 0
