"""Fleet topology: cost-model degenerates, dispatch locality accounting,
the vibe_h two-level solver, and dead-rank masking through the policy
registry."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ClusterTopology, SolveContext, get_policy,
                        inflate_placement, make_cluster, parse_topology,
                        vibe_h_placement, vibe_r_placement)
from repro.core.placement import default_slots_per_rank
from repro.core.topology import DEFAULT_DCN_RATIO


def paper_perf(G, seed=0):
    cluster = make_cluster(G, "mi325x", d_model=1024, d_ff=512,
                           experts_per_rank=8, seed=seed)
    return cluster.fit_models()


def skewed_w(rng, L, E, tokens=100_000.0, alpha=0.3):
    return rng.dirichlet(np.full(E, alpha), size=L) * tokens


# ---------------------------------------------------------------------------
# construction + parsing
# ---------------------------------------------------------------------------

class TestConstruction:
    def test_uniform_shape(self):
        t = ClusterTopology.uniform(2, 4, 1e11)
        assert t.n_ranks == 8 and t.n_nodes == 2 and not t.is_flat
        np.testing.assert_array_equal(t.node_sizes, [4, 4])
        np.testing.assert_array_equal(t.ranks_of(1), [4, 5, 6, 7])
        assert t.dcn_bw == pytest.approx(1e11 / DEFAULT_DCN_RATIO)

    def test_flat_is_flat(self):
        t = ClusterTopology.flat(8, 1e11)
        assert t.is_flat and t.n_nodes == 1
        assert t.dcn_bw == t.ici_bw        # no second link class

    def test_noncontiguous_node_ids_rejected(self):
        with pytest.raises(ValueError, match="contiguous"):
            ClusterTopology(np.array([0, 0, 2, 2]), 1e11, 1e10)

    def test_bad_bandwidth_rejected(self):
        with pytest.raises(ValueError, match="positive"):
            ClusterTopology.flat(4, 0.0)

    def test_parse_topology(self):
        t = parse_topology("2x4", ici_bw=1e11)
        assert t.n_nodes == 2 and t.n_ranks == 8
        assert parse_topology("8", ici_bw=1e11).is_flat
        with pytest.raises(ValueError, match="topology spec"):
            parse_topology("2x4x2", ici_bw=1e11)
        with pytest.raises(ValueError, match="topology spec"):
            parse_topology("lots", ici_bw=1e11)

    def test_mask_relabels_nodes(self):
        t = ClusterTopology.uniform(3, 2, 1e11)
        # kill node 1 entirely plus one device of node 0
        m = t.mask([1, 2, 3])
        assert m.n_ranks == 3 and m.n_nodes == 2
        np.testing.assert_array_equal(m.node_of, [0, 1, 1])
        with pytest.raises(ValueError, match="every rank"):
            t.mask(range(6))


# ---------------------------------------------------------------------------
# cost-model flat degenerates (pin the legacy pricing bit-identical)
# ---------------------------------------------------------------------------

class TestCosts:
    def test_a2a_flat_degenerate(self):
        G, bw, nb = 8, 1e11, 1e9
        t = ClusterTopology.flat(G, bw)
        assert t.a2a_cost(nb) == pytest.approx(nb * (G - 1) / G / bw)

    def test_migration_flat_degenerate(self):
        t = ClusterTopology.flat(8, 1e11)
        assert t.migration_cost(1e9) == pytest.approx(1e9 / 1e11)
        # the simulator stripes over G parallel links
        assert t.migration_cost(1e9, parallel_links=8) \
            == pytest.approx(1e9 / (8 * 1e11))

    def test_broadcast_flat_degenerate(self):
        t = ClusterTopology.flat(8, 1e11)
        assert t.broadcast_cost(4096) == pytest.approx(4096 / 1e11)

    def test_two_level_costs_slower_than_flat(self):
        flat = ClusterTopology.flat(8, 1e11)
        two = ClusterTopology.uniform(2, 4, 1e11)
        assert two.a2a_cost(1e9) > flat.a2a_cost(1e9)
        assert two.migration_cost(1e9) > flat.migration_cost(1e9)
        assert two.broadcast_cost(1e9) > flat.broadcast_cost(1e9)
        assert 0.0 < two.cross_fraction() < 1.0
        assert flat.cross_fraction() == 0.0

    def test_xfer_cost_link_classes(self):
        t = ClusterTopology.uniform(2, 2, 1e11, dcn_bw=1e10,
                                    ici_latency=1e-6, dcn_latency=1e-5)
        assert t.xfer_cost(0, 0, 1e6) == 0.0
        assert t.xfer_cost(0, 1, 1e6) == pytest.approx(1e6 / 1e11 + 1e-6)
        assert t.xfer_cost(0, 2, 1e6) == pytest.approx(1e6 / 1e10 + 1e-5)


# ---------------------------------------------------------------------------
# dispatch locality accounting
# ---------------------------------------------------------------------------

class TestNodeSplitLoads:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 500), n_nodes=st.sampled_from([2, 4]))
    def test_conservation(self, seed, n_nodes):
        """local + cross token arrivals equal the dispatched loads."""
        G, E, L = 8, 32, 3
        rng = np.random.default_rng(seed)
        w = skewed_w(rng, L, E)
        pl = vibe_r_placement(w, paper_perf(G, seed))
        topo = ClusterTopology.uniform(n_nodes, G // n_nodes, 1e11)
        local, cross = topo.node_split_loads(pl, w)
        np.testing.assert_allclose((local + cross).sum(1), w.sum(1),
                                   rtol=1e-9)

    def test_flat_no_cross_traffic(self):
        G, E, L = 8, 32, 3
        rng = np.random.default_rng(0)
        w = skewed_w(rng, L, E)
        pl = vibe_r_placement(w, paper_perf(G))
        topo = ClusterTopology.flat(G, 1e11)
        local, cross = topo.node_split_loads(pl, w)
        np.testing.assert_allclose(cross, 0.0)
        np.testing.assert_allclose(local, pl.rank_loads(w), rtol=1e-9)


# ---------------------------------------------------------------------------
# vibe_h two-level solver
# ---------------------------------------------------------------------------

class TestVibeH:
    def test_flat_delegates_to_vibe_r(self):
        """On a flat (or absent) topology vibe_h IS vibe_r, bit for bit."""
        G, E, L = 8, 32, 3
        perf = paper_perf(G)
        w = skewed_w(np.random.default_rng(1), L, E)
        base = vibe_r_placement(w, perf)
        for topo in (None, ClusterTopology.flat(G, 1e11)):
            pl = vibe_h_placement(w, perf, topo)
            np.testing.assert_array_equal(pl.slot_expert, base.slot_expert)
            np.testing.assert_array_equal(pl.share, base.share)

    def test_valid_replicated_placement(self):
        G, E, L = 16, 64, 4
        perf = paper_perf(G, seed=3)
        w = skewed_w(np.random.default_rng(3), L, E)
        topo = ClusterTopology.uniform(4, 4, 1e11)
        pl = vibe_h_placement(w, perf, topo)
        # ReplicatedPlacement.__post_init__ already pins coverage + share
        # normalization; check the engine-facing geometry too
        assert pl.n_ranks == G and pl.n_experts == E
        assert pl.slots_per_rank == default_slots_per_rank(E, G)
        np.testing.assert_allclose(pl.rank_loads(w).sum(1), w.sum(1),
                                   rtol=1e-9)

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_cuts_cross_node_traffic_vs_vibe_r(self, seed):
        """The PR's core claim at test scale: on a 2-level topology the
        node-aware solve sends fewer tokens over the DCN than the
        topology-blind vibe_r, at comparable predicted tail latency."""
        from repro.core import predicted_rank_latencies
        G, E, L, K = 16, 64, 4, 4
        cluster = make_cluster(G, "mi325x", d_model=1024, d_ff=512,
                               experts_per_rank=E // G, seed=seed)
        perf = cluster.fit_models()
        w = skewed_w(np.random.default_rng(seed), L, E)
        topo = ClusterTopology.uniform(K, G // K, cluster.ici_bw)
        pr = vibe_r_placement(w, perf)
        ph = vibe_h_placement(w, perf, topo)
        cross_r = topo.node_split_loads(pr, w)[1].sum()
        cross_h = topo.node_split_loads(ph, w)[1].sum()
        assert cross_h < cross_r
        lat_r = predicted_rank_latencies(pr, w, perf).max(1).sum()
        lat_h = predicted_rank_latencies(ph, w, perf).max(1).sum()
        assert lat_h <= lat_r * 1.25

    def test_respects_slot_budget(self):
        G, E, L = 16, 64, 2
        perf = paper_perf(G, seed=5)
        w = skewed_w(np.random.default_rng(5), L, E)
        topo = ClusterTopology.uniform(4, 4, 1e11)
        budget = np.full(G, 6)
        budget[:4] = 4
        pl = vibe_h_placement(w, perf, topo, slots_per_rank=budget)
        s_max = pl.slots_per_rank
        real = (pl.slot_expert < E).reshape(L, G, s_max).sum(2)
        assert (real <= budget[None, :]).all()


# ---------------------------------------------------------------------------
# dead-rank masking through the registry + inflate_placement
# ---------------------------------------------------------------------------

class TestDeadRankMasking:
    # replication-capable policies survive any dead set; singleton
    # policies only when E still divides the survivor count
    @pytest.mark.parametrize("policy,dead", [
        ("vibe_r", (3,)), ("vibe_h", (3,)), ("vibe_r", (1, 6)),
        ("vibe", (4, 5, 6, 7)), ("eplb", (4, 5, 6, 7))])
    def test_masked_solve_zeroes_dead_ranks(self, policy, dead):
        G, E, L = 8, 32, 3
        perf = paper_perf(G)
        w = skewed_w(np.random.default_rng(2), L, E)
        pol = get_policy(policy)
        pl = pol.solve(SolveContext(
            w=w, n_ranks=G,
            perf_models=perf if pol.capabilities.needs_perf_models else None,
            topology=ClusterTopology.uniform(2, 4, 1e11),
            dead_ranks=dead))
        loads = pl.rank_loads(w)
        np.testing.assert_allclose(loads[:, list(dead)], 0.0)
        # survivors still serve everything
        np.testing.assert_allclose(loads.sum(1), w.sum(1), rtol=1e-9)

    def test_singleton_policy_rejects_ragged_survivors(self):
        w = skewed_w(np.random.default_rng(2), 3, 32)
        with pytest.raises(ValueError, match="replication-capable"):
            get_policy("eplb").solve(
                SolveContext(w=w, n_ranks=8, dead_ranks=(3,)))

    def test_dead_ranks_validation(self):
        w = skewed_w(np.random.default_rng(0), 2, 16)
        with pytest.raises(ValueError):
            SolveContext(w=w, n_ranks=4, dead_ranks=(4,))
        with pytest.raises(ValueError):
            SolveContext(w=w, n_ranks=4, dead_ranks=(0, 1, 2, 3))
        # empty tuple normalizes to None (no mask)
        assert SolveContext(w=w, n_ranks=4, dead_ranks=()).dead_ranks is None

    def test_inflate_placement_validation(self):
        G, E, L = 4, 8, 2
        perf = paper_perf(G)
        w = skewed_w(np.random.default_rng(1), L, E)
        sub = vibe_r_placement(w, perf[:3])
        with pytest.raises(ValueError):
            inflate_placement(sub, survivors=np.array([0, 1]), n_ranks=G)
        with pytest.raises(ValueError):
            inflate_placement(sub, survivors=np.array([0, 1, 9]), n_ranks=G)
        out = inflate_placement(sub, survivors=np.array([0, 1, 3]), n_ranks=G)
        assert out.n_ranks == G
        np.testing.assert_allclose(out.rank_loads(w)[:, 2], 0.0)

    def test_topology_rank_mismatch_rejected(self):
        w = skewed_w(np.random.default_rng(0), 2, 16)
        with pytest.raises(ValueError, match="topology"):
            SolveContext(w=w, n_ranks=4,
                         topology=ClusterTopology.flat(8, 1e11))
