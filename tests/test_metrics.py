"""serving/metrics.py: summarize, goodput, and per-tenant TTFT
aggregation (including the permutation-invariance property)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.serving import SLO, goodput, per_tenant_ttft, summarize
from repro.serving.metrics import RequestRecord


def _rec(i, ttft, tenant="", n_out=5, tpot=0.01):
    r = RequestRecord(i, 0.0, 10, n_out, tenant=tenant)
    r.first_token_at = ttft
    r.finished_at = ttft + (n_out - 1) * tpot
    return r


class TestSummarize:
    def test_percentiles_and_counts(self):
        recs = [_rec(i, ttft=0.1 * (i + 1)) for i in range(10)]
        s = summarize(recs)
        assert s["n"] == 10
        assert s["ttft_p50"] == pytest.approx(0.55)
        assert s["ttft_p90"] == pytest.approx(np.percentile(
            [0.1 * (i + 1) for i in range(10)], 90))
        assert s["tpot_p50"] == pytest.approx(0.01)

    def test_unfinished_requests_excluded_from_tails(self):
        recs = [_rec(i, ttft=0.1) for i in range(4)]
        recs.append(RequestRecord(99, 0.0, 10, 5))      # never started
        s = summarize(recs)
        assert s["n"] == 5
        # the unstarted request's NaN must not poison the percentiles
        assert np.isfinite(s["ttft_p90"])
        assert s["ttft_p50"] == pytest.approx(0.1)


class TestPerTenantTTFT:
    def test_groups_by_tenant(self):
        recs = ([_rec(i, 0.1, tenant="chat") for i in range(5)]
                + [_rec(10 + i, 0.8, tenant="batch") for i in range(5)])
        out = per_tenant_ttft(recs)
        assert set(out) == {"chat", "batch"}
        assert out["chat"] == pytest.approx(0.1)
        assert out["batch"] == pytest.approx(0.8)

    def test_unstarted_requests_excluded(self):
        recs = [_rec(0, 0.2, tenant="a"), RequestRecord(1, 0.0, 10, 5,
                                                        tenant="a")]
        out = per_tenant_ttft(recs)
        assert out["a"] == pytest.approx(0.2)

    def test_tenant_with_no_finished_requests_absent(self):
        recs = [_rec(0, 0.2, tenant="a"),
                RequestRecord(1, 0.0, 10, 5, tenant="ghost")]
        assert set(per_tenant_ttft(recs)) == {"a"}

    def test_percentile_parameter(self):
        recs = [_rec(i, float(i), tenant="t") for i in range(11)]
        assert per_tenant_ttft(recs, percentile=50.0)["t"] \
            == pytest.approx(5.0)

    @settings(max_examples=30, deadline=None)
    @given(ttfts=st.lists(st.floats(0.001, 10.0), min_size=1, max_size=24),
           tenant_ids=st.lists(st.integers(0, 3), min_size=1, max_size=24),
           seed=st.integers(0, 1000))
    def test_permutation_invariant(self, ttfts, tenant_ids, seed):
        """Aggregation must not depend on record arrival order: shuffling
        the record list leaves every tenant's percentile unchanged."""
        n = min(len(ttfts), len(tenant_ids))
        recs = [_rec(i, ttfts[i], tenant=f"t{tenant_ids[i]}")
                for i in range(n)]
        base = per_tenant_ttft(recs)
        rng = np.random.default_rng(seed)
        shuffled = [recs[j] for j in rng.permutation(n)]
        out = per_tenant_ttft(shuffled)
        assert set(out) == set(base)
        for t in base:
            assert out[t] == pytest.approx(base[t], rel=1e-12)


class TestGoodput:
    def test_both_slo_arms_enforced(self):
        recs = [_rec(0, 0.1, tpot=0.01), _rec(1, 0.9, tpot=0.01),
                _rec(2, 0.1, tpot=0.5)]
        assert goodput(recs, SLO(ttft=0.5, tpot=0.05)) \
            == pytest.approx(1 / 3)
        assert goodput(recs, SLO(ttft=1e9, tpot=1e9)) == 1.0

    def test_empty_records(self):
        assert goodput([], SLO(ttft=1.0, tpot=1.0)) == 0.0

class TestSingleTokenTpot:
    """output_len == 1: zero decode steps, so tpot is 0 by definition —
    never a 0/0. Regression guard for the prefill-only request shape."""

    def test_tpot_zero_not_nan(self):
        r = _rec(0, ttft=0.1, n_out=1)
        assert r.tpot == 0.0
        assert np.isfinite(r.tpot)

    def test_meets_and_goodput_count_it(self):
        r = _rec(0, ttft=0.1, n_out=1)
        assert r.meets(SLO(ttft=0.5, tpot=1e-9))   # tpot arm trivially met
        assert goodput([r], SLO(ttft=0.5, tpot=0.01)) == 1.0

    def test_summarize_stays_finite(self):
        s = summarize([_rec(0, ttft=0.1, n_out=1)])
        assert s["tpot_p50"] == 0.0 and np.isfinite(s["tpot_p99"])
