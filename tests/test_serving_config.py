"""The unified ServingConfig hierarchy (serving/config.py).

Pins the API-redesign contract: configs are frozen value objects, the
legacy ``Engine(**kwargs)`` surface maps onto ``EngineConfig.from_kwargs``
with a DeprecationWarning, and — the load-bearing guarantee — a default
``EngineConfig`` reproduces the legacy engine loop bit-for-bit.
"""

import dataclasses
import warnings

import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import (DriftConfig, ViBEConfig, ViBEController,
                        make_cluster)
from repro.models import moe_perm_shape
from repro.serving import (Engine, EngineConfig, KVCacheConfig,
                           SchedulerConfig, SimConfig, WORKLOADS,
                           sample_requests)


class TestConfigObjects:
    def test_frozen(self):
        for cfg in (KVCacheConfig(), SchedulerConfig(), EngineConfig(),
                    SimConfig()):
            with pytest.raises(dataclasses.FrozenInstanceError):
                cfg.seed_or_block = 1

    def test_blocks_for(self):
        kv = KVCacheConfig(block_size=16, n_blocks=8)
        assert kv.blocks_for(1) == 1
        assert kv.blocks_for(16) == 1
        assert kv.blocks_for(17) == 2
        assert kv.blocks_for(0) == 1     # every sequence owns >= 1 block

    def test_chunk_must_divide_max_seq(self):
        EngineConfig(max_seq=48, scheduler=SchedulerConfig(prefill_chunk=12))
        with pytest.raises(ValueError, match="divide"):
            EngineConfig(max_seq=48,
                         scheduler=SchedulerConfig(prefill_chunk=7))

    def test_resolve_fills_defaults(self):
        cfg = EngineConfig(max_batch=3, max_seq=48).resolve()
        assert cfg.scheduler == SchedulerConfig()
        # default pool exactly covers the dense lanes: the paged cache
        # never rejects what the legacy lane-count admission accepted
        assert cfg.kv.n_blocks == 3 * -(-48 // cfg.kv.block_size)
        # resolve is idempotent and keeps explicit sub-configs
        explicit = EngineConfig(kv=KVCacheConfig(n_blocks=7)).resolve()
        assert explicit.kv.n_blocks == 7

    def test_from_kwargs_deprecation_and_unknown(self):
        with pytest.warns(DeprecationWarning, match="EngineConfig"):
            cfg = EngineConfig.from_kwargs(max_batch=2, max_seq=32)
        assert cfg.max_batch == 2 and cfg.max_seq == 32
        with pytest.raises(TypeError, match="bogus"):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                EngineConfig.from_kwargs(bogus=1)


class TestLegacyShim:
    def _parts(self, seed=0):
        cfg = get_smoke("qwen3-moe-235b-a22b")
        n_moe, n_slots = moe_perm_shape(cfg, None, "train")
        cluster = make_cluster(4, "mi325x", d_model=cfg.d_model,
                               d_ff=cfg.moe_d_ff,
                               experts_per_rank=n_slots // 4, seed=seed)
        ctl = ViBEController(
            n_moe, n_slots, 4, cluster.fit_models(),
            ViBEConfig(policy="vibe", adaptive=True,
                       drift=DriftConfig(window=8, interval=4, cooldown=4),
                       expert_bytes=3 * cfg.d_model * cfg.moe_d_ff * 2))
        return cfg, ctl, cluster

    def test_legacy_kwargs_bit_identical_to_config(self):
        """Engine(**legacy) and Engine(cfg, EngineConfig(...)) drive the
        same virtual clock, the same recalibrations, the same records."""
        reqs = sample_requests(WORKLOADS["sharegpt"], 3, qps=100.0, seed=0)
        reqs = [dataclasses.replace(r, prompt_len=8, output_len=5)
                for r in reqs]
        cfg, ctl, cluster = self._parts()
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            e1 = Engine(cfg, controller=ctl, cluster=cluster,
                        max_batch=2, max_seq=48, seed=0)
        e1.submit(list(reqs))
        r1 = e1.run(max_steps=200)

        cfg2, ctl2, cluster2 = self._parts()
        e2 = Engine(cfg2, EngineConfig(max_batch=2, max_seq=48, seed=0),
                    controller=ctl2, cluster=cluster2)
        e2.submit(list(reqs))
        r2 = e2.run(max_steps=200)

        assert e1.stats == e2.stats
        for a, b in zip(r1, r2):
            assert a.req_id == b.req_id
            np.testing.assert_array_equal(
                [a.first_token_at, a.finished_at],
                [b.first_token_at, b.finished_at])

    def test_config_plus_legacy_kwargs_rejected(self):
        cfg, ctl, cluster = self._parts()
        with pytest.raises(TypeError, match="both"):
            Engine(cfg, EngineConfig(), controller=ctl, cluster=cluster,
                   max_batch=2)
