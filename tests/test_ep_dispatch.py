"""Multi-device EP dispatch correctness (8 fake devices via subprocess).

The main pytest process must keep seeing 1 device (jax locks device count
on first init), so every multi-device check runs in a subprocess with
XLA_FLAGS set. One subprocess executes the whole battery to amortize
startup cost.
"""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import sys
sys.path.insert(0, os.environ['REPRO_SRC'])
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.models.sharding import ShardingRules, build_copy_cdf, \
    build_slots_of
from repro.models import moe as MOE

set_mesh = compat.use_mesh
mesh = compat.make_mesh((2, 4), ('data', 'model'))
E, D, F, K = 16, 64, 128, 4
p = MOE.moe_init(jax.random.PRNGKey(0), d=D, f=F, n_experts=E, n_slots=E)
B, S = 4, 8
x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D)).astype(jnp.bfloat16)
y_ref, tally_ref, aux_ref = MOE.moe_layer(p, x, top_k=K, n_experts=E,
                                          rules=None)

def check(tag, y, tally, tol=1e-6):
    err = float(jnp.abs(y_ref.astype(jnp.float32)
                        - y.astype(jnp.float32)).max())
    assert err <= tol, f'{tag}: max err {err}'
    assert np.allclose(np.asarray(tally_ref), np.asarray(tally)), \
        f'{tag}: tally mismatch'
    print(f'{tag}: OK (err={err:.2e})')

# 1. a2a dispatch == dense oracle
rules = ShardingRules(mesh=mesh, dp=('data',), ep=('model',), fsdp=None,
                      capacity_factor=8.0)
with set_mesh(mesh):
    y, t, _ = jax.jit(lambda p, x: MOE.moe_layer(
        p, x, top_k=K, n_experts=E, rules=rules, phase='train'))(p, x)
check('a2a', y, t)

# 2. a2a + FSDP weight sharding
rules_f = ShardingRules(mesh=mesh, dp=('data',), ep=('model',), fsdp='data',
                        capacity_factor=8.0)
with set_mesh(mesh):
    y, t, _ = jax.jit(lambda p, x: MOE.moe_layer(
        p, x, top_k=K, n_experts=E, rules=rules_f, phase='train'))(p, x)
check('a2a+fsdp', y, t)

# 3. replicated decode (all-axes slots, round-robin duplication)
rules_r = ShardingRules(mesh=mesh, dp=('data',), ep=('model',),
                        ep_all=('data', 'model'), fsdp=None,
                        moe_dispatch='replicated', capacity_factor=8.0)
with set_mesh(mesh):
    y, t, _ = jax.jit(lambda p, x: MOE.moe_layer(
        p, x, top_k=K, n_experts=E, rules=rules_r, phase='decode'))(p, x)
check('replicated', y, t)

# 4. expert-TP decode (F sliced over data, partial-sum combine)
rules_tp = ShardingRules(mesh=mesh, dp=('data',), ep=('model',),
                         ep_all=('data', 'model'), fsdp=None,
                         moe_dispatch='replicated', capacity_factor=8.0,
                         decode_expert_tp=True)
with set_mesh(mesh):
    y, t, _ = jax.jit(lambda p, x: MOE.moe_layer(
        p, x, top_k=K, n_experts=E, rules=rules_tp, phase='decode'))(p, x)
check('expert-tp', y, t, tol=2e-2)   # different reduction order (bf16)

# 5. gradients flow through a2a (+aux)
def loss(p, x):
    y, t, a = MOE.moe_layer(p, x, top_k=K, n_experts=E, rules=rules_f,
                            phase='train')
    return (y.astype(jnp.float32) ** 2).mean() + 0.01 * a
with set_mesh(mesh):
    g = jax.jit(jax.grad(loss))(p, x)
for k, v in g.items():
    n = float(jnp.linalg.norm(v.astype(jnp.float32)))
    assert n > 0, f'zero grad for {k}'
print('grads: OK')

# 6. ViBE permutation: migrated weights + slot tables == identity semantics
rng = np.random.default_rng(0)
perm = rng.permutation(E).astype(np.int32)[None, :]
migrated, moved = MOE.apply_placement(
    {k: v[None] for k, v in p.items() if k != 'router'},
    np.arange(E)[None], perm)
p2 = dict(p, **{k: migrated[k][0] for k in ('w1', 'w2', 'w3')})
slots_of, n_copies = build_slots_of(perm, E, E)
with set_mesh(mesh):
    y, t, _ = jax.jit(lambda p2, x: MOE.moe_layer(
        p2, x, top_k=K, n_experts=E, rules=rules,
        slots_of=jnp.asarray(slots_of[0]), n_copies=jnp.asarray(n_copies[0]),
        phase='train'))(p2, x)
check('permuted', y, t)
assert moved > 0

# 7. phantom padding (E=6 experts on 4 EP ranks → 8 slots)
E2 = 6
ns = MOE.n_slots_a2a(E2, 4)
assert ns == 8
p3 = MOE.moe_init(jax.random.PRNGKey(2), d=D, f=F, n_experts=E2, n_slots=ns)
perm3 = MOE.default_perm_a2a(1, E2, 4)
so3, nc3 = build_slots_of(perm3, E2, ns)
y_ref3, t_ref3, _ = MOE.moe_layer(p3, x, top_k=2, n_experts=E2, rules=None,
                                  slots_of=jnp.asarray(so3[0]),
                                  n_copies=jnp.asarray(nc3[0]))
with set_mesh(mesh):
    y3, t3, _ = jax.jit(lambda p3, x: MOE.moe_layer(
        p3, x, top_k=2, n_experts=E2, rules=rules,
        slots_of=jnp.asarray(so3[0]), n_copies=jnp.asarray(nc3[0]),
        phase='train'))(p3, x)
err = float(jnp.abs(y_ref3.astype(jnp.float32) - y3.astype(jnp.float32)).max())
assert err < 1e-6, f'phantom: {err}'
print('phantom padding: OK')

# 8. share-weighted replica routing == dense oracle on both production paths
# 24 slots: experts 0..15 plus replicas of 0..7 with skewed 0.25/0.75 shares
ns8 = 24
perm8 = np.concatenate([np.arange(E), np.arange(8)])[None, :].astype(np.int32)
p8 = {k: (v if k == 'router' else v[perm8[0]]) for k, v in p.items()}
share8 = np.ones((1, ns8))
share8[0, :8] = 0.25
share8[0, 16:] = 0.75
so8, nc8 = build_slots_of(perm8, E, ns8)
cdf8 = build_copy_cdf(perm8, E, ns8, share=share8)
with set_mesh(mesh):
    y8, t8, _ = jax.jit(lambda p8, x: MOE.moe_layer(
        p8, x, top_k=K, n_experts=E, rules=rules,
        slots_of=jnp.asarray(so8[0]), n_copies=jnp.asarray(nc8[0]),
        copy_cdf=jnp.asarray(cdf8[0]), phase='train'))(p8, x)
check('a2a+weighted', y8, t8)
rules8r = ShardingRules(mesh=mesh, dp=('data',), ep=('model',),
                        ep_all=('data', 'model'), fsdp=None,
                        moe_dispatch='replicated', capacity_factor=8.0)
with set_mesh(mesh):
    y8r, t8r, _ = jax.jit(lambda p8, x: MOE.moe_layer(
        p8, x, top_k=K, n_experts=E, rules=rules8r,
        slots_of=jnp.asarray(so8[0]), n_copies=jnp.asarray(nc8[0]),
        copy_cdf=jnp.asarray(cdf8[0]), phase='decode'))(p8, x)
check('replicated+weighted', y8r, t8r)

# 9. capacity drops surface in the tally's final column (a2a, starved cf;
# long sequence so per-device buckets can exceed the rounded-up capacity).
# moe_impl pinned: the ragged default is dropless by construction.
x9 = jax.random.normal(jax.random.PRNGKey(3), (4, 32, D)).astype(jnp.bfloat16)
rules9 = ShardingRules(mesh=mesh, dp=('data',), ep=('model',), fsdp=None,
                       capacity_factor=0.25, moe_impl='capacity')
with set_mesh(mesh):
    _, t9, _ = jax.jit(lambda p, x: MOE.moe_layer(
        p, x, top_k=K, n_experts=E, rules=rules9, phase='train'))(p, x9)
assert float(t9[-1]) > 0, 'starved capacity produced no drops'
assert float(jnp.sum(t9[:E])) == x9.shape[0] * x9.shape[1] * K
print(f'capacity drop column: OK ({float(t9[-1]):.0f} dropped)')

# 10. capacity baseline still == dense oracle at generous cf (checks 1-8 run
# the ragged default; this keeps the legacy bucketed path covered too)
rules10 = ShardingRules(mesh=mesh, dp=('data',), ep=('model',), fsdp=None,
                        capacity_factor=8.0, moe_impl='capacity')
with set_mesh(mesh):
    y10, t10, _ = jax.jit(lambda p, x: MOE.moe_layer(
        p, x, top_k=K, n_experts=E, rules=rules10, phase='train'))(p, x)
check('a2a capacity baseline', y10, t10)

# 11. ragged dispatch is dropless where the same cf starves the buckets:
# full dense-oracle agreement AND a zero drop column on both paths
y9_ref, t9_ref, _ = MOE.moe_layer(p, x9, top_k=K, n_experts=E, rules=None)
rules11 = ShardingRules(mesh=mesh, dp=('data',), ep=('model',), fsdp=None,
                        capacity_factor=0.25, moe_impl='ragged')
with set_mesh(mesh):
    y11, t11, _ = jax.jit(lambda p, x: MOE.moe_layer(
        p, x, top_k=K, n_experts=E, rules=rules11, phase='train'))(p, x9)
err11 = float(jnp.abs(y9_ref.astype(jnp.float32)
                      - y11.astype(jnp.float32)).max())
# bf16 output: summation order differs from the dense combine by
# up to one bf16 ULP on long sequences
assert err11 <= 1e-3, f'ragged@starved-cf: max err {err11}'
assert float(t11[-1]) == 0, 'ragged path reported drops'
assert np.allclose(np.asarray(t11), np.asarray(t9_ref))
rules11r = ShardingRules(mesh=mesh, dp=('data',), ep=('model',),
                         ep_all=('data', 'model'), fsdp=None,
                         moe_dispatch='replicated', capacity_factor=0.25,
                         moe_impl='ragged')
with set_mesh(mesh):
    y11r, t11r, _ = jax.jit(lambda p, x: MOE.moe_layer(
        p, x, top_k=K, n_experts=E, rules=rules11r, phase='decode'))(p, x9)
err11r = float(jnp.abs(y9_ref.astype(jnp.float32)
                       - y11r.astype(jnp.float32)).max())
assert err11r <= 1e-3, f'ragged-replicated@starved-cf: max err {err11r}'
assert float(t11r[-1]) == 0, 'ragged replicated path reported drops'
print('ragged dropless @ starved cf: OK')

print('ALL_EP_CHECKS_PASSED')
"""


@pytest.mark.slow
def test_ep_dispatch_battery():
    env = dict(os.environ)
    env["REPRO_SRC"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "ALL_EP_CHECKS_PASSED" in res.stdout, \
        f"stdout:\n{res.stdout}\nstderr:\n{res.stderr[-3000:]}"
