"""Trace-driven workload generation: arrival processes + tenant mixes.

The arrival statistics are load-bearing for the fig8 sweep and the P90
TTFT acceptance test: bursty must actually be overdispersed relative to
Poisson, diurnal must actually swing, and tenants must carry their
length distributions and TTFT SLOs through to the sampled requests.
"""

import numpy as np
import pytest

from repro.serving import (ArrivalSpec, TRACES, TenantSpec, TraceSpec,
                           WORKLOADS, sample_arrivals, sample_trace)


def _dispersion(arrivals, window=1.0):
    """Index of dispersion of per-window counts (Poisson → ~1)."""
    edges = np.arange(0.0, arrivals[-1], window)
    counts, _ = np.histogram(arrivals, bins=edges)
    return counts.var() / counts.mean()


class TestArrivalProcesses:
    def test_poisson_rate_and_dispersion(self):
        rng = np.random.default_rng(0)
        a = sample_arrivals(ArrivalSpec("poisson"), 4000, 10.0, rng)
        assert len(a) / a[-1] == pytest.approx(10.0, rel=0.1)
        assert _dispersion(a) == pytest.approx(1.0, abs=0.25)

    def test_bursty_overdispersed_same_mean_rate(self):
        rng = np.random.default_rng(0)
        spec = ArrivalSpec("bursty", burst_factor=4.0, burst_fraction=0.2,
                           sojourn=2.0)
        a = sample_arrivals(spec, 4000, 10.0, rng)
        # long-run mean rate preserved...
        assert len(a) / a[-1] == pytest.approx(10.0, rel=0.15)
        # ...but counts are overdispersed (the MMPP burst structure)
        assert _dispersion(a) > 2.0

    def test_diurnal_rate_swings(self):
        rng = np.random.default_rng(0)
        spec = ArrivalSpec("diurnal", amplitude=0.8, period=60.0)
        a = sample_arrivals(spec, 6000, 10.0, rng)
        # per-second rate near the sinusoid's crest vs trough
        phase = (a % 60.0)
        crest = np.sum((phase > 10) & (phase < 20))   # sin ≈ +1 at t=15
        trough = np.sum((phase > 40) & (phase < 50))  # sin ≈ -1 at t=45
        assert crest > 3 * trough

    def test_arrivals_sorted_and_positive(self):
        rng = np.random.default_rng(1)
        for proc in ("poisson", "bursty", "diurnal"):
            a = sample_arrivals(ArrivalSpec(proc), 500, 25.0, rng)
            assert (np.diff(a) >= 0).all()
            assert a[0] > 0

    def test_spec_validation(self):
        with pytest.raises(ValueError, match="process"):
            ArrivalSpec("fractal")
        with pytest.raises(ValueError, match="negative"):
            ArrivalSpec("bursty", burst_factor=10.0, burst_fraction=0.2)


class TestTraces:
    def test_registry_contents(self):
        assert {"flat", "bursty", "diurnal"} <= set(TRACES)

    def test_bursty_tenant_mix_and_slos(self):
        reqs = sample_trace(TRACES["bursty"], 2000, qps=20.0, seed=0)
        assert len(reqs) == 2000
        tenants = {r.tenant for r in reqs}
        assert tenants == {"chat", "longctx"}
        frac_chat = np.mean([r.tenant == "chat" for r in reqs])
        assert frac_chat == pytest.approx(0.85, abs=0.03)
        for r in reqs:
            assert r.ttft_slo == (0.25 if r.tenant == "chat" else 0.60)
        # tenant length distributions follow their workload families
        chat_in = np.mean([r.prompt_len for r in reqs
                           if r.tenant == "chat"])
        long_in = np.mean([r.prompt_len for r in reqs
                           if r.tenant == "longctx"])
        assert long_in > 5 * chat_in

    def test_deterministic_given_seed(self):
        a = sample_trace(TRACES["bursty"], 64, qps=20.0, seed=3)
        b = sample_trace(TRACES["bursty"], 64, qps=20.0, seed=3)
        assert a == b
        c = sample_trace(TRACES["bursty"], 64, qps=20.0, seed=4)
        assert a != c

    def test_trace_spec_validation(self):
        with pytest.raises(ValueError, match="tenant"):
            TraceSpec("empty", ArrivalSpec("poisson"), ())
        with pytest.raises(ValueError, match="unknown workload"):
            TraceSpec("bad", ArrivalSpec("poisson"),
                      (TenantSpec("t", "nope", 1.0),))

    def test_primary_workload_drives_routing(self):
        assert TRACES["bursty"].primary is WORKLOADS["sharegpt"]
