import os
import sys

# tests run with PYTHONPATH=src; make that robust when invoked otherwise
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
