import os
import sys

# tests run with PYTHONPATH=src; make that robust when invoked otherwise
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property tests prefer real hypothesis (installed in CI); containers
# without it get the deterministic fallback so the tests still collect
# and run (see tests/_hypothesis_fallback.py).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback

    sys.modules["hypothesis"] = _hypothesis_fallback
    sys.modules["hypothesis.strategies"] = _hypothesis_fallback.strategies


def pytest_configure(config):
    # registered in pyproject.toml as well; kept here so ad-hoc invocations
    # (pytest path/to/test.py from any cwd) never warn on unknown marks
    config.addinivalue_line(
        "markers", "slow: heavy multi-process/e2e tests (skipped on the CI "
        "fast lane via -m 'not slow')")
