"""Training substrate: optimizer, data, checkpoint/restart, elasticity."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import init_params, loss_fn, make_moe_tables
from repro.training import (AdamWConfig, Checkpointer, DataConfig,
                            StragglerDetector, adamw_init, adamw_update,
                            cosine_lr, elastic_targets, global_norm,
                            latest_step, load_checkpoint, replan_after_loss,
                            save_checkpoint, synthetic_batch)
from repro.core import make_cluster


def test_loss_decreases_on_moe_arch():
    cfg = get_smoke("qwen3-moe-235b-a22b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    mt = make_moe_tables(cfg, None)
    lossf = loss_fn(cfg)
    dc = DataConfig(seq_len=16, global_batch=4)

    @jax.jit
    def step(params, opt, batch, mt):
        (loss, _), grads = jax.value_and_grad(lossf, has_aux=True)(
            params, batch, mt)
        params, opt = adamw_update(grads, opt, params)
        return params, opt, loss

    losses = []
    for s in range(10):
        b = {k: jnp.asarray(v)
             for k, v in synthetic_batch(cfg, dc, s).items()}
        params, opt, loss = step(params, opt, b, mt)
        losses.append(float(loss))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))


def test_adamw_grad_clip_and_lr():
    cfg = AdamWConfig(grad_clip=1.0)
    p = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    g = {"w": jnp.full((4, 4), 100.0, jnp.bfloat16)}     # huge grads
    opt = adamw_init(p, cfg)
    p2, opt2 = adamw_update(g, opt, p, cfg)
    delta = np.abs(np.asarray(p2["w"], np.float32) - 1.0).max()
    assert delta < 0.01                                   # clipped update
    assert float(cosine_lr(cfg, jnp.int32(0), warmup=10)) == 0.0
    assert float(cosine_lr(cfg, jnp.int32(10), warmup=10)) == \
        pytest.approx(cfg.lr, rel=1e-5)


def test_data_determinism_and_sharding():
    cfg = get_smoke("smollm-360m")
    dc = DataConfig(seq_len=32, global_batch=8, seed=7)
    a = synthetic_batch(cfg, dc, step=3)
    b = synthetic_batch(cfg, dc, step=3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synthetic_batch(cfg, dc, step=4)
    assert not np.array_equal(a["tokens"], c["tokens"])
    s0 = synthetic_batch(cfg, dc, step=3, shard=0, n_shards=2)
    s1 = synthetic_batch(cfg, dc, step=3, shard=1, n_shards=2)
    assert s0["tokens"].shape[0] == 4
    assert not np.array_equal(s0["tokens"], s1["tokens"])


class TestCheckpoint:
    def test_roundtrip_bf16_and_shards(self):
        tree = {"a": jnp.arange(24, dtype=jnp.bfloat16).reshape(6, 4),
                "b": {"c": jnp.float32(3.5),
                      "d": jnp.arange(5, dtype=jnp.int32)}}
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 7, tree, extras={"k": 1}, n_shards=3)
            assert latest_step(d) == 7
            out, extras = load_checkpoint(d, 7, tree)
            assert extras == {"k": 1}
            for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
                np.testing.assert_array_equal(np.asarray(x, np.float32),
                                              np.asarray(y, np.float32))

    def test_async_save_and_gc(self):
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d, keep=2, n_shards=2)
            tree = {"w": jnp.ones((8, 8))}
            for s in (1, 2, 3, 4):
                ck.save(s, tree)
            ck.wait()
            ck._gc()
            steps = sorted(int(n.split("_")[1]) for n in os.listdir(d)
                           if n.startswith("ckpt_"))
            assert steps == [3, 4]

    def test_uncommitted_tmp_ignored(self):
        with tempfile.TemporaryDirectory() as d:
            os.makedirs(os.path.join(d, "ckpt_9.tmp"))
            assert latest_step(d) is None
            save_checkpoint(d, 3, {"w": jnp.zeros(2)})
            assert latest_step(d) == 3

    def test_restore_with_remesh_subprocess(self):
        """Checkpoint written on 1 device restores under an 8-device mesh
        with explicit NamedShardings (mesh A → mesh B)."""
        import subprocess, sys
        with tempfile.TemporaryDirectory() as d:
            tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
            save_checkpoint(d, 1, tree, n_shards=4)
            script = f"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import sys; sys.path.insert(0, {repr(os.path.join(os.path.dirname(__file__), '..', 'src'))})
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import compat
from repro.training import load_checkpoint
mesh = compat.make_mesh((2, 4), ('data', 'model'))
like = {{'w': jnp.zeros((8, 8), jnp.float32)}}
sh = {{'w': NamedSharding(mesh, P('data', 'model'))}}
tree, _ = load_checkpoint({repr(d)}, 1, like, shardings=sh)
assert tree['w'].sharding.is_equivalent_to(sh['w'], 2)
np.testing.assert_array_equal(np.asarray(tree['w']).ravel(),
                              np.arange(64, dtype=np.float32))
print('REMESH_OK')
"""
            res = subprocess.run([sys.executable, "-c", script],
                                 capture_output=True, text=True, timeout=300)
            assert "REMESH_OK" in res.stdout, res.stderr[-2000:]

    @pytest.mark.slow
    def test_train_resume_matches_uninterrupted(self):
        """Fault tolerance: crash+restart reproduces the uninterrupted run
        exactly (deterministic data + full state in the checkpoint)."""
        from repro.launch.train import train
        with tempfile.TemporaryDirectory() as d:
            _, _, losses_a, _ = train("smollm-360m", steps=6, seq_len=16,
                                      batch=2, ckpt_dir="", log_every=100)
            train("smollm-360m", steps=3, seq_len=16, batch=2,
                  ckpt_dir=d, ckpt_every=3, log_every=100)
            _, _, losses_b, _ = train("smollm-360m", steps=6, seq_len=16,
                                      batch=2, ckpt_dir=d, ckpt_every=100,
                                      log_every=100)
            np.testing.assert_allclose(losses_a[3:], losses_b,
                                       rtol=1e-5, atol=1e-6)


class TestElastic:
    def test_straggler_detection(self):
        det = StragglerDetector(8, min_steps=5)
        flags = {}
        for _ in range(10):
            flags = det.observe(np.array([1.0] * 7 + [1.2]))
        assert flags["soft"] == [7] and flags["hard"] == []
        for _ in range(30):
            flags = det.observe(np.array([1.0] * 7 + [2.0]))
        assert flags["hard"] == [7]

    def test_replan_after_loss(self):
        cluster = make_cluster(8, "mi325x", d_model=512, d_ff=256,
                               experts_per_rank=8)
        perf = cluster.fit_models()
        rng = np.random.default_rng(0)
        w = rng.dirichlet(np.full(56, 0.3), size=4) * 10_000  # 56 = 7×8
        pl, rank_map = replan_after_loss(w, perf, lost_ranks=[3])
        assert pl.n_ranks == 7
        assert 3 not in rank_map
        counts = np.apply_along_axis(np.bincount, 1, pl.assign, minlength=7)
        assert (counts == 8).all()

    def test_elastic_targets_speed_weighted(self):
        cluster = make_cluster(4, "skewed", d_model=512, d_ff=256,
                               experts_per_rank=4)
        perf = cluster.fit_models()
        t = elastic_targets(perf, total_items=1000, n_ref=3 * cluster.n_tdp)
        assert t.sum() == 1000
        assert t[0] < t[1:].mean()       # degraded device 0 gets less work


def test_global_norm():
    t = {"a": jnp.ones(4), "b": jnp.ones((2, 2)) * 2}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(4 + 16))
