"""Chunked prefill: model-level bit-identity + engine/serve integration.

The virtual clock prices every chunk, so correctness rests on the chunk
path being *exactly* the whole-prompt computation re-sliced: masked tail
rows contribute exact zeros to attention and tallies (flash kernel's
``exp(_NEG - m)`` underflow), so logits, cache state and MoE tallies are
bit-identical across chunk widths — pinned here, not approximated.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import (DriftConfig, ViBEConfig, ViBEController,
                        make_cluster)
from repro.models import (init_cache, init_params, make_moe_tables,
                          moe_perm_shape, prefill_chunk_fn, prefill_fn)
from repro.serving import (Engine, EngineConfig, RejectReason,
                           SchedulerConfig, WORKLOADS, Request,
                           sample_requests, summarize)

ARCH = "qwen3-moe-235b-a22b"


def _chunked_run(cfg, params, cache, prompt, chunk, lane, mt):
    """Drive prefill_chunk_fn over ``prompt`` exactly as the engine does:
    fixed-width buffers, n_valid tail masking, offset = tokens done."""
    fn = jax.jit(prefill_chunk_fn(cfg))
    P = prompt.shape[1]
    tallies = None
    logits = None
    done = 0
    while done < P:
        n_valid = min(chunk, P - done)
        buf = np.zeros((1, chunk), dtype=prompt.dtype)
        buf[0, :n_valid] = prompt[0, done:done + n_valid]
        logits, cache, t = fn(params, jnp.asarray(buf), cache, lane, done,
                              n_valid, mt)
        tallies = t if tallies is None else tallies + t
        done += n_valid
    return logits, cache, tallies


class TestModelLevel:
    def setup_method(self):
        self.cfg = get_smoke(ARCH)
        self.params = init_params(self.cfg, jax.random.PRNGKey(0))
        self.mt = make_moe_tables(self.cfg, None)
        rng = np.random.default_rng(3)
        self.prompt = rng.integers(0, self.cfg.vocab, size=(1, 10))
        # dirty cache: masking bugs show up as garbage leaking into
        # attention instead of silently reading zeros
        self.S_max = 16
        zero = init_cache(self.cfg, 2, self.S_max)
        self.cache = jax.tree.map(
            lambda c: jnp.asarray(
                np.random.default_rng(7).normal(size=c.shape), c.dtype),
            zero)

    def test_bit_identical_across_chunk_widths(self):
        lg_a, cache_a, tal_a = _chunked_run(self.cfg, self.params,
                                            self.cache, self.prompt, 5, 0,
                                            self.mt)
        lg_b, cache_b, tal_b = _chunked_run(self.cfg, self.params,
                                            self.cache, self.prompt, 2, 0,
                                            self.mt)
        assert np.array_equal(np.asarray(lg_a), np.asarray(lg_b))
        assert np.array_equal(np.asarray(tal_a), np.asarray(tal_b))
        for a, b in zip(jax.tree.leaves(cache_a), jax.tree.leaves(cache_b)):
            assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_matches_whole_prompt_prefill(self):
        lg_w, _, tal_w = prefill_fn(self.cfg)(
            self.params, {"tokens": jnp.asarray(self.prompt)}, self.mt)
        lg_c, _, tal_c = _chunked_run(self.cfg, self.params, self.cache,
                                      self.prompt, 4, 1, self.mt)
        np.testing.assert_allclose(np.asarray(lg_c), np.asarray(lg_w),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(tal_c), np.asarray(tal_w),
                                   atol=0)

    def test_other_lane_untouched(self):
        _, cache, _ = _chunked_run(self.cfg, self.params, self.cache,
                                   self.prompt, 4, 0, self.mt)
        # cache leaves are (layers, lane, seq, kv_heads, head_dim)
        for before, after in zip(jax.tree.leaves(self.cache),
                                 jax.tree.leaves(cache)):
            assert np.array_equal(np.asarray(before)[:, 1],
                                  np.asarray(after)[:, 1])

    def test_ssm_mixers_rejected(self):
        with pytest.raises(NotImplementedError, match="recurrent"):
            prefill_chunk_fn(get_smoke("xlstm-350m"))


def _engine(config, seed=0):
    cfg = get_smoke(ARCH)
    n_moe, n_slots = moe_perm_shape(cfg, None, "train")
    cluster = make_cluster(4, "mi325x", d_model=cfg.d_model,
                           d_ff=cfg.moe_d_ff,
                           experts_per_rank=n_slots // 4, seed=seed)
    ctl = ViBEController(
        n_moe, n_slots, 4, cluster.fit_models(),
        ViBEConfig(policy="vibe", adaptive=True,
                   drift=DriftConfig(window=8, interval=4, cooldown=4),
                   expert_bytes=3 * cfg.d_model * cfg.moe_d_ff * 2))
    return Engine(cfg, config, controller=ctl, cluster=cluster)


class TestEngineChunked:
    def test_chunked_engine_serves_and_frees_kv(self):
        eng = _engine(EngineConfig(
            max_batch=2, max_seq=48, seed=0,
            scheduler=SchedulerConfig(name="slo_edf", prefill_chunk=8)))
        reqs = sample_requests(WORKLOADS["sharegpt"], 4, qps=100.0, seed=0)
        reqs = [dataclasses.replace(r, prompt_len=20, output_len=6)
                for r in reqs]
        eng.submit(reqs)
        records = eng.run(max_steps=300)
        done = [r for r in records if np.isfinite(r.finished_at)]
        assert len(done) == 4
        assert eng.stats.chunk_steps >= 4 * 3     # 20 tokens = 3 chunks of 8
        assert eng.kv.n_seqs == 0                 # every reservation freed
        assert eng.kv.used_blocks == 0
        assert eng.kv.peak_blocks > 0

    def test_oversized_prompt_rejected_at_submit(self):
        # typed rejection, not an exception: submit returns the rejected
        # records and tags them TOO_LONG (chaos invariant: every request
        # finishes or carries a typed RejectReason)
        eng = _engine(EngineConfig(max_batch=2, max_seq=48, seed=0))
        rejected = eng.submit([Request(0, 0.0, 100, 4)])
        assert len(rejected) == 1
        assert rejected[0].reject_reason is RejectReason.TOO_LONG
        assert eng.records[0].rejected
        assert eng.stats.rejected == {"too_long": 1}
        assert not eng.waiting                    # never queued
        records = eng.run(max_steps=10)
        assert summarize(records)["n_rejected"] == 1


@pytest.mark.slow
class TestSloAcceptance:
    def test_chunked_edf_beats_whole_prompt_fcfs_p90_ttft(self):
        """ISSUE 6 acceptance: on a saturating bursty mix — a burst of
        long-context requests hogging the lanes ahead of tight-SLO chat
        traffic — chunked prefill + slo_edf improves the chat tenant's
        P90 TTFT by >= 25% over the legacy whole-prompt FCFS loop: EDF
        admits chats ahead of the queued long-context backlog as lanes
        free, instead of draining the backlog in arrival order."""
        def mix():
            longs = [Request(i, 0.0, 24, 30, tenant="longctx",
                             ttft_slo=10.0) for i in range(4)]
            chats = [Request(10 + i, 0.001 + i * 1e-4, 8, 4, tenant="chat",
                             ttft_slo=0.05) for i in range(8)]
            return longs + chats

        def chat_p90(records):
            return summarize([r for r in records
                              if r.req_id >= 10])["ttft_p90"]

        legacy = _engine(EngineConfig(max_batch=2, max_seq=48, seed=0))
        legacy.submit(mix())
        p90_legacy = chat_p90(legacy.run(max_steps=2000))

        chunked = _engine(EngineConfig(
            max_batch=2, max_seq=48, seed=0,
            scheduler=SchedulerConfig(name="slo_edf", prefill_chunk=12)))
        chunked.submit(mix())
        p90_chunked = chat_p90(chunked.run(max_steps=2000))

        assert p90_chunked <= 0.75 * p90_legacy, \
            f"chat p90 TTFT {p90_chunked:.6f}s vs legacy {p90_legacy:.6f}s"

    def test_serve_e2e_thermal_ramp_with_scheduler(self):
        """vibe_r recalibration keeps recovering goodput with the full
        serving core on: slo_edf + chunked prefill + bursty trace +
        thermal-ramp hardware drift + perf-model refresh."""
        from repro.launch.serve import serve
        engine, records, _ = serve(
            ARCH, policy="vibe_r", n_requests=8, workload="bursty",
            scheduler="slo_edf", prefill_chunk=12, max_seq=96,
            variability_scenario="thermal-ramp", scenario_start=0.0,
            scenario_duration=1.0, perf_drift_delta=0.15, seed=0)
        done = [r for r in records if np.isfinite(r.finished_at)]
        assert len(done) == 8
        assert engine.stats.migrations > 0        # recalibration fired
        assert engine.stats.chunk_steps > 0
        assert engine.kv.used_blocks == 0
