"""End-to-end system behaviour + paper-claim sanity checks."""

import numpy as np
import pytest

from repro.configs import ALL_ARCHS, SHAPES, get, shape_applicable
from repro.core import (make_cluster, solve_model_placement,
                        incremental_update, vibe_placement)
from repro.launch.hlo_analysis import parse_hlo
from repro.serving import WORKLOADS, routing_profile


def test_paper_claim_incremental_vs_full_transfer_volume():
    """Paper §4.2.4: incremental solver converges in 5–30 swaps/layer vs
    >200 slot reassignments for a full re-solve (256 experts, 8 ranks)."""
    model = get("deepseek-v3-671b")
    cluster = make_cluster(8, "mi325x", d_model=model.d_model,
                           d_ff=model.moe_d_ff, experts_per_rank=32)
    perf = cluster.fit_models()
    L, E = model._n_moe_layers(), model.n_experts
    w0 = routing_profile(WORKLOADS["sonnet"], L, E) * 16384 * model.top_k
    w1 = routing_profile(WORKLOADS["sharegpt"], L, E) * 16384 * model.top_k
    pl = vibe_placement(w0, perf)
    res = incremental_update(pl, w1, perf)
    full = vibe_placement(w1, perf)
    swaps_per_layer = res.per_layer_swaps.mean()
    full_moves_per_layer = full.moved_experts(pl) / L
    assert swaps_per_layer <= 35
    assert full_moves_per_layer > 150
    # >10× transfer-volume saving (paper: "over an order of magnitude")
    assert res.moved_expert_count() * 10 < full.moved_experts(pl)


def test_paper_claim_latency_gap_reduction():
    """Paper Fig 10a: token redistribution (EPLB) removes most of the gap;
    ViBE removes a further slice. Checked at the layer-latency level."""
    from repro.serving.simulator import rank_latency_matrix
    model = get("deepseek-v3-671b")
    cluster = make_cluster(8, "mi325x", d_model=model.d_model,
                           d_ff=model.moe_d_ff, experts_per_rank=32)
    perf = cluster.fit_models()
    L, E = model._n_moe_layers(), model.n_experts
    W = routing_profile(WORKLOADS["sonnet"], L, E) * 16384 * model.top_k
    gaps = {}
    for policy in ("contiguous", "eplb", "vibe"):
        pl = solve_model_placement(
            policy, W, 8, perf_models=perf if policy == "vibe" else None)
        rt = rank_latency_matrix(cluster, pl.rank_loads(W))
        gaps[policy] = float(np.median(rt.max(1) - rt.min(1)))
    assert gaps["eplb"] < 0.5 * gaps["contiguous"]      # paper: −63.9%
    assert gaps["vibe"] < gaps["eplb"]                  # paper: −19.6% more


def test_skip_matrix_is_exactly_the_assignment():
    """40 cells − 8 documented skips = 32 runnable cells."""
    runnable, skipped = 0, []
    for arch in ALL_ARCHS:
        cfg = get(arch)
        for shape in SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            if ok:
                runnable += 1
            else:
                skipped.append((arch, shape.name, why))
    assert runnable == 32, skipped
    long_skips = [s for s in skipped
                  if s[1] == "long_500k" and "full-attention" in s[2]]
    dec_skips = [s for s in skipped if s[0] == "hubert-xlarge"]
    assert len(long_skips) == 6        # pure full-attention archs
    assert len(dec_skips) == 2         # encoder-only: both decode shapes


def test_hlo_parser_trip_count_exact():
    """Roofline provenance: parse_hlo scales with lax.scan trip count
    (cost_analysis counts while bodies once — verified here)."""
    import jax
    import jax.numpy as jnp

    def make(L):
        w = jnp.zeros((L, 128, 128), jnp.float32)

        def f(w, x):
            def body(x, wl):
                return jnp.tanh(x @ wl), None
            y, _ = jax.lax.scan(body, x, w)
            return y.sum()
        x = jax.ShapeDtypeStruct((32, 128), jnp.float32)
        return jax.jit(f).lower(w, x).compile()

    for L in (2, 5):
        c = make(L)
        costs = parse_hlo(c.as_text())
        expect = 2 * 32 * 128 * 128 * L
        assert costs.flops == pytest.approx(expect, rel=1e-6)
        from repro.compat import cost_analysis_dict
        ca = cost_analysis_dict(c)   # list-of-dicts on 0.4.x, dict on newer
        # rel=0.05 absorbs elementwise-op flops; a trip-count-multiplying
        # XLA would be off by ~L×, far outside this tolerance
        assert ca["flops"] == pytest.approx(2 * 32 * 128 * 128, rel=0.05), \
            "XLA started multiplying while bodies — update the roofline!"


def test_serve_driver_end_to_end():
    from repro.launch.serve import serve
    engine, records, _ = serve("qwen3-moe-235b-a22b", policy="vibe",
                            n_requests=3, qps=100.0, max_batch=2,
                            max_seq=48)
    done = [r for r in records if np.isfinite(r.finished_at)]
    assert len(done) == 3
    assert engine.stats.virtual_time > 0


def test_vibe_beats_eplb_on_skewed_system_e2e():
    """Paper Fig 14: on the skewed system (one device −13%), ViBE holds a
    clear SLO edge over EPLB at stress."""
    from repro.serving import (EPSimulator, SimConfig, goodput,
                               sample_requests, PAPER_SLOS)
    model = get("deepseek-v3-671b")
    wl = WORKLOADS["sonnet"]
    cluster = make_cluster(8, "skewed", d_model=model.d_model,
                           d_ff=model.moe_d_ff, experts_per_rank=32)
    perf = cluster.fit_models()
    L, E = model._n_moe_layers(), model.n_experts
    W = routing_profile(wl, L, E) * 16384 * model.top_k
    slo = PAPER_SLOS[("sonnet", "deepseek-v3-671b")]
    gps = {}
    for policy in ("eplb", "vibe"):
        pl = solve_model_placement(
            policy, W, 8, perf_models=perf if policy == "vibe" else None)
        sim = EPSimulator(model, cluster, wl,
                          SimConfig(ep_degree=8, seed=1,
                                    max_prefill_tokens=16384),
                          placement=pl)
        recs = sim.run(sample_requests(wl, 150, qps=20.0, seed=2),
                       phase="prefill")
        gps[policy] = goodput(recs, slo)
    assert gps["vibe"] >= gps["eplb"]
