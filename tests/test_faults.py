"""Fault injection, chaos drill, overload protection, elastic grow.

Pins the PR's robustness invariants:

* the fault DSL / default schedule are validated and seed-deterministic;
* a full chaos drill (fail → stall → DCN brownout → recover) on a live
  engine holds the four chaos invariants and ends with a healthy fleet;
* elastic grow (``recover_rank``) restores the full rank set, and a
  mask→unmask round trip is bit-identical to the healthy solve for every
  replication-capable policy (hypothesis property);
* overload protection is typed: watermark shedding rejects with
  ``RejectReason.SHED``, decode preemption is bounded per request, and
  admission-infeasible requests carry ``NEVER_FITS`` — none of it raises;
* the token-conservation ledger holds on clean and chaotic runs alike;
* the simulator's injection path applies the same schedule vocabulary.
"""


import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_smoke
from repro.core import (ClusterTopology, ViBEConfig, ViBEController,
                        get_policy, make_cluster, registered_policies)
from repro.serving import (Engine, EngineConfig, EPSimulator, FaultInjector,
                           FaultSchedule, FaultSpec, KVCacheConfig,
                           RejectReason, SchedulerConfig, SimConfig, SLO,
                           WORKLOADS, fail_rank, goodput, recover_rank,
                           run_chaos, sample_requests, summarize)
from repro.serving.workload import Request


# ---------------------------------------------------------------------------
# FaultSpec / FaultSchedule: validation, DSL, determinism
# ---------------------------------------------------------------------------

class TestFaultSpecValidation:
    def test_unknown_kind_raises(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultSpec("power_surge", 3)

    def test_fail_without_rank_raises(self):
        with pytest.raises(ValueError, match="needs a target rank"):
            FaultSpec("rank_fail", 3)

    def test_recover_without_rank_raises(self):
        with pytest.raises(ValueError, match="needs a target rank"):
            FaultSpec("rank_recover", 3)

    def test_negative_step_raises(self):
        with pytest.raises(ValueError, match="at_step"):
            FaultSpec("rank_fail", -1, rank=0)

    def test_stall_magnitude_bounds(self):
        with pytest.raises(ValueError, match="magnitude"):
            FaultSpec("transient_stall", 3, magnitude=1.5)
        with pytest.raises(ValueError, match="magnitude"):
            FaultSpec("dcn_degrade", 3, magnitude=0.0)

    def test_stall_duration_positive(self):
        with pytest.raises(ValueError, match="duration"):
            FaultSpec("transient_stall", 3, duration=0.0)


class TestScheduleParse:
    def test_dsl_round_trip(self):
        sched = FaultSchedule.parse(
            "fail@4:1,stall@6:2x0.4+0.5,dcn@7x0.5+0.8,recover@9:1",
            n_ranks=4)
        kinds = [f.kind for f in sched.faults]
        assert kinds == ["rank_fail", "transient_stall", "dcn_degrade",
                         "rank_recover"]
        stall = sched.faults[1]
        assert (stall.at_step, stall.rank) == (6, 2)
        assert stall.magnitude == pytest.approx(0.4)
        assert stall.duration == pytest.approx(0.5)

    def test_schedule_sorted_by_step(self):
        sched = FaultSchedule.parse("recover@9:1,fail@4:1", n_ranks=4)
        assert [f.at_step for f in sched.faults] == [4, 9]

    def test_bad_item_raises(self):
        with pytest.raises(ValueError, match="bad fault spec"):
            FaultSchedule.parse("fail@4:1,bogus", n_ranks=4)

    def test_empty_raises(self):
        with pytest.raises(ValueError, match="empty"):
            FaultSchedule.parse(" , ", n_ranks=4)

    def test_default_is_seed_deterministic(self):
        a = FaultSchedule.default(8, seed=3)
        b = FaultSchedule.default(8, seed=3)
        assert a.faults == b.faults
        assert FaultSchedule.parse("default:3", 8).faults == a.faults
        assert FaultSchedule.default(8, seed=4).faults != a.faults

    def test_default_shape(self):
        """Fail early, recover the same victim later, stall elsewhere."""
        for seed in range(8):
            s = FaultSchedule.default(4, seed=seed)
            by_kind = {f.kind: f for f in s.faults}
            assert set(by_kind) == {"rank_fail", "rank_recover",
                                    "transient_stall", "dcn_degrade"}
            assert by_kind["rank_recover"].rank == by_kind["rank_fail"].rank
            assert by_kind["rank_recover"].at_step \
                > by_kind["rank_fail"].at_step
            assert by_kind["transient_stall"].rank != by_kind["rank_fail"].rank

    def test_default_needs_two_ranks(self):
        with pytest.raises(ValueError, match=">= 2 ranks"):
            FaultSchedule.default(1)


# ---------------------------------------------------------------------------
# engine chaos drill (module-scoped: construction jits the smoke model)
# ---------------------------------------------------------------------------

TOPO = ClusterTopology.uniform(2, 2, 50e9)   # 4 ranks on 2 nodes


def _engine(policy="vibe_r", topology=None, **cfg_kw):
    cfg = get_smoke("qwen3-moe-235b-a22b")
    from repro.models import moe_perm_shape
    n_moe, n_slots = moe_perm_shape(cfg, None, "train")
    cluster = make_cluster(4, "mi325x", d_model=cfg.d_model,
                           d_ff=cfg.moe_d_ff, experts_per_rank=n_slots // 4)
    ctl = ViBEController(
        n_moe, n_slots, 4, cluster.fit_models(),
        ViBEConfig(policy=policy, adaptive=False,
                   expert_bytes=3 * cfg.d_model * cfg.moe_d_ff * 2,
                   topology=topology))
    eng = Engine(cfg, EngineConfig(max_batch=2, max_seq=48, seed=0,
                                   topology=topology, **cfg_kw),
                 controller=ctl, cluster=cluster)
    return eng


def _short_requests(n, start_id=0, seed=0):
    reqs = sample_requests(WORKLOADS["sharegpt"], n, qps=100.0, seed=seed)
    return [Request(start_id + i, r.arrival, 8, 6)
            for i, r in enumerate(reqs)]


@pytest.fixture(scope="module")
def chaos():
    """One full drill — every fault kind fires mid-traffic on a 2-node
    topology (dcn_degrade needs ``EngineConfig.topology``)."""
    eng = _engine(topology=TOPO)
    sched = FaultSchedule.parse(
        "fail@3:1,stall@5:0x0.4+0.5,dcn@6x0.5+0.3,recover@8:1", n_ranks=4)
    report = run_chaos(eng, _short_requests(8), sched)
    return eng, report


class TestChaosDrill:
    def test_invariants_hold(self, chaos):
        _, report = chaos
        assert report.ok, report.violations

    def test_every_fault_applied(self, chaos):
        _, report = chaos
        assert not report.skipped
        assert [s.kind for s, _ in report.applied] == [
            "rank_fail", "transient_stall", "dcn_degrade", "rank_recover"]

    def test_fleet_healthy_after_drill(self, chaos):
        eng, _ = chaos
        assert eng.controller.dead_ranks == ()
        assert eng.kv.used_blocks == 0 and eng.kv.n_seqs == 0

    def test_dcn_bandwidth_restored(self, chaos):
        eng, _ = chaos
        assert eng.config.topology.dcn_bw == pytest.approx(TOPO.dcn_bw)

    def test_stall_composed_into_variability(self, chaos):
        eng, _ = chaos
        injected = [e for e in eng.cluster.events if e.kind == "transient"
                    and e.magnitude == pytest.approx(0.4)]
        assert len(injected) == 1 and injected[0].device == 0

    def test_all_requests_complete(self, chaos):
        _, report = chaos
        assert len(report.records) == 8
        assert all(np.isfinite(r.finished_at) for r in report.records)
        assert goodput(report.records, SLO(ttft=1e9, tpot=1e9)) == 1.0

    def test_fail_and_recover_recorded_on_controller(self, chaos):
        eng, _ = chaos
        kinds = [u.kind for u in eng.controller.updates]
        assert kinds.count("fail") == 1 and kinds.count("recover") == 1

    def test_infeasible_faults_skipped_not_raised(self, chaos):
        """Re-running a schedule the fleet state makes infeasible logs
        skips; chaos never crashes the system it stresses. (Runs last on
        the shared engine; leaves it healthy.)"""
        eng, _ = chaos
        cur = eng.stats.steps
        sched = FaultSchedule((
            FaultSpec("rank_recover", cur + 1, rank=2),     # not dead
            FaultSpec("rank_fail", cur + 2, rank=0),
            FaultSpec("rank_fail", cur + 3, rank=0),        # already dead
            FaultSpec("rank_recover", cur + 4, rank=0),
        ))
        report = run_chaos(eng, _short_requests(4, start_id=100), sched)
        assert report.ok, report.violations
        reasons = {s.kind: why for s, why in report.skipped}
        assert "not dead" in reasons["rank_recover"]
        assert "already dead" in reasons["rank_fail"]
        assert [s.kind for s, _ in report.applied] == ["rank_fail",
                                                       "rank_recover"]
        assert eng.controller.dead_ranks == ()

    def test_flush_applies_late_faults_on_drain(self, chaos):
        """A recover scheduled past the last step must still fire — the
        drill flushes pending faults when the queue drains, so a drill
        never strands the fleet degraded."""
        eng, _ = chaos
        cur = eng.stats.steps
        sched = FaultSchedule((
            FaultSpec("rank_fail", cur + 2, rank=3),
            FaultSpec("rank_recover", cur + 10_000, rank=3),
        ))
        report = run_chaos(eng, _short_requests(4, start_id=200), sched)
        assert report.ok, report.violations
        assert not report.skipped
        assert eng.controller.dead_ranks == ()


class TestInjectorGuards:
    def test_last_survivor_never_killed(self):
        """FaultInjector refuses to take down the whole fleet even when
        the schedule asks for it."""
        eng = _engine()
        sched = FaultSchedule(tuple(
            FaultSpec("rank_fail", 2 + g, rank=g) for g in range(4)))
        report = run_chaos(eng, _short_requests(4), sched)
        assert report.ok, report.violations
        assert len(eng.controller.dead_ranks) == 3
        assert [why for _, why in report.skipped] \
            == ["would kill the last survivor"]

    def test_controllerless_engine_skips_rank_faults(self):
        cfg = get_smoke("qwen3-moe-235b-a22b")
        eng = Engine(cfg, EngineConfig(max_batch=2, max_seq=48, seed=0))
        sched = FaultSchedule.parse("fail@1:0,recover@2:0", n_ranks=4)
        report = run_chaos(eng, _short_requests(2), sched)
        assert report.ok, report.violations
        assert not report.applied
        assert all(why == "no controller" for _, why in report.skipped)

    def test_dcn_without_topology_skipped(self):
        inj = FaultInjector(FaultSchedule.parse("dcn@0x0.5+0.5", 4))
        eng = _engine()                       # no topology configured
        inj.poll(eng)
        assert [why for _, why in inj.skipped] \
            == ["no fleet topology (flat pricing)"]


# ---------------------------------------------------------------------------
# elastic grow: fail → recover round trip on a live engine
# ---------------------------------------------------------------------------

class TestRecoverRank:
    @pytest.fixture(scope="class")
    def roundtrip(self):
        eng = _engine()
        eng.submit(_short_requests(6))
        for _ in range(3):
            eng.step()
        fail = fail_rank(eng, 2)
        rec = recover_rank(eng, 2)
        records = eng.run(max_steps=400)
        return eng, fail, rec, records

    def test_reports(self, roundtrip):
        _, fail, rec, _ = roundtrip
        assert fail.rank == rec.rank == 2
        assert rec.dead_after == ()
        assert rec.migration_bytes >= 0

    def test_all_requests_complete_after_grow(self, roundtrip):
        eng, _, _, records = roundtrip
        assert all(np.isfinite(r.finished_at) for r in records)
        assert eng.kv.used_blocks == 0

    def test_recovered_rank_carries_traffic_again(self, roundtrip):
        eng, _, _, _ = roundtrip
        pl = eng.controller.placement
        loads = pl.rank_loads(np.ones((eng.controller.L, eng.controller.E)))
        assert loads[:, 2].sum() > 0.0

    def test_token_ledger_balances_through_fail_recover(self, roundtrip):
        eng, _, _, _ = roundtrip
        st = eng.stats
        assert st.prefill_tokens + st.decode_tokens \
            == st.useful_tokens + st.lost_tokens

    def test_recover_live_rank_raises(self, roundtrip):
        eng, _, _, _ = roundtrip
        with pytest.raises(ValueError, match="not dead"):
            recover_rank(eng, 1)

    def test_recover_out_of_range_raises(self, roundtrip):
        eng, _, _, _ = roundtrip
        with pytest.raises(ValueError, match="outside"):
            recover_rank(eng, 9)


# mask→unmask must restore the healthy placement bit-identically for every
# replication-capable policy (the elastic-grow correctness property: a
# recovered fleet serves exactly the placement a never-failed fleet would)
REPLICATION_POLICIES = sorted(
    p for p in registered_policies()
    if get_policy(p).capabilities.supports_replication)


def test_replication_policy_roster():
    assert REPLICATION_POLICIES == ["harmoeny", "vibe_h", "vibe_r"]


@settings(max_examples=12, deadline=None)
@given(policy=st.sampled_from(REPLICATION_POLICIES),
       rank=st.integers(min_value=0, max_value=3))
def test_mask_unmask_restores_healthy_placement(policy, rank):
    cluster = make_cluster(4, "mi325x", seed=0)
    ctl = ViBEController(2, 8, 4, cluster.fit_models(),
                         ViBEConfig(policy=policy, adaptive=False,
                                    topology=TOPO))
    healthy = ctl.placement
    ctl.mask_ranks((rank,))
    masked = ctl.placement
    spr = masked.slots_per_rank
    np.testing.assert_allclose(
        masked.share[:, rank * spr:(rank + 1) * spr], 0.0)
    ctl.unmask_ranks((rank,))
    assert ctl.dead_ranks == ()
    np.testing.assert_array_equal(ctl.placement.slot_expert,
                                  healthy.slot_expert)
    np.testing.assert_array_equal(ctl.placement.share, healthy.share)


# ---------------------------------------------------------------------------
# overload protection: typed rejection, shedding, bounded preemption
# ---------------------------------------------------------------------------

def _tiny_engine(n_blocks, **sched_kw):
    """Controllerless engine with a deliberately starved KV pool (the
    virtual clock still advances via the trivial-fallback pricing)."""
    cfg = get_smoke("qwen3-moe-235b-a22b")
    return Engine(cfg, EngineConfig(
        max_batch=2, max_seq=48, seed=0,
        kv=KVCacheConfig(block_size=16, n_blocks=n_blocks),
        scheduler=SchedulerConfig(**sched_kw)))


class TestTypedRejection:
    def test_never_fits_at_submit(self):
        eng = _tiny_engine(2)
        rejected = eng.submit([Request(0, 0.0, 16, 31),     # 3 blocks > 2
                               Request(1, 0.0, 8, 4)])
        assert [r.req_id for r in rejected] == [0]
        assert rejected[0].reject_reason is RejectReason.NEVER_FITS
        assert eng.stats.rejected == {"never_fits": 1}
        records = eng.run(max_steps=200)
        assert summarize(records)["n_rejected"] == 1
        assert np.isfinite(eng.records[1].finished_at)

    def test_shed_rejects_lapsed_waiters_under_pressure(self):
        eng = _tiny_engine(4, shed_watermark=0.5)
        # A occupies 3/4 blocks (utilization 0.75 >= watermark); B can't
        # admit behind it and its TTFT deadline lapses immediately
        eng.submit([Request(0, 0.0, 16, 31),
                    Request(1, 0.0, 16, 4, ttft_slo=1e-6)])
        records = eng.run(max_steps=400)
        shed = eng.records[1]
        assert shed.reject_reason is RejectReason.SHED
        assert not np.isfinite(shed.finished_at)
        assert eng.stats.rejected == {"shed": 1}
        assert np.isfinite(eng.records[0].finished_at)
        assert summarize(records)["n_rejected"] == 1
        assert eng.kv.used_blocks == 0

    def test_no_shedding_below_watermark(self):
        """Identical traffic with an un-breached watermark sheds nothing —
        the protection is load-gated, not deadline-gated."""
        eng = _tiny_engine(8, shed_watermark=0.99)
        eng.submit([Request(0, 0.0, 16, 31),
                    Request(1, 0.0, 16, 4, ttft_slo=1e-6)])
        eng.run(max_steps=400)
        assert eng.stats.rejected == {}
        assert all(np.isfinite(r.finished_at)
                   for r in eng.records.values())


class TestPreemption:
    def test_preemption_breaks_kv_deadlock(self):
        """Two requests that can never coexist in the pool: without
        preemption the waiter starves; with it both complete, each evicted
        at most ``max_preemptions`` times."""
        eng = _tiny_engine(4, preempt_decodes=True, max_preemptions=2)
        eng.submit([Request(0, 0.0, 16, 31), Request(1, 0.0, 16, 31)])
        records = eng.run(max_steps=2_000)
        assert all(np.isfinite(r.finished_at) for r in records)
        assert eng.stats.preemptions >= 1
        for r in records:
            assert r.preemptions <= 2
        st = eng.stats
        assert st.preemptions == sum(r.preemptions for r in records)
        assert st.lost_tokens > 0
        assert st.prefill_tokens + st.decode_tokens \
            == st.useful_tokens + st.lost_tokens
        assert eng.kv.used_blocks == 0

    def test_preemption_off_by_default(self):
        eng = _tiny_engine(8)
        eng.submit([Request(0, 0.0, 16, 15), Request(1, 0.0, 16, 15)])
        eng.run(max_steps=400)
        assert eng.stats.preemptions == 0


class TestTokenLedger:
    def test_clean_run_conserves_tokens(self):
        eng = _tiny_engine(8)
        eng.submit([Request(0, 0.0, 16, 8), Request(1, 0.0, 8, 4)])
        eng.run(max_steps=400)
        st = eng.stats
        assert st.lost_tokens == 0
        assert st.prefill_tokens + st.decode_tokens == st.useful_tokens
        assert st.useful_tokens > 0


# ---------------------------------------------------------------------------
# simulator fault injection (same schedule vocabulary, discrete-event side)
# ---------------------------------------------------------------------------

class TestSimulatorFaults:
    def test_full_drill_applies_and_recovers(self):
        cfg = get_smoke("qwen3-moe-235b-a22b")
        from repro.models import moe_perm_shape
        n_moe, n_slots = moe_perm_shape(cfg, None, "train")
        cluster = make_cluster(4, "mi325x", d_model=cfg.d_model,
                               d_ff=cfg.moe_d_ff,
                               experts_per_rank=n_slots // 4, seed=0)
        ctl = ViBEController(
            n_moe, n_slots, 4, cluster.fit_models(),
            ViBEConfig(policy="vibe_r", adaptive=False,
                       expert_bytes=3 * cfg.d_model * cfg.moe_d_ff * 2))
        sim = EPSimulator(cfg, cluster, WORKLOADS["sharegpt"],
                          SimConfig(ep_degree=4, seed=1, topology=TOPO),
                          controller=ctl)
        sim.inject_faults(FaultSchedule.parse(
            "fail@2:1,stall@3:0x0.4+0.3,dcn@4x0.5+0.3,recover@6:1",
            n_ranks=4))
        reqs = sample_requests(WORKLOADS["sharegpt"], 20, qps=50.0, seed=3)
        recs = sim.run(reqs, phase="prefill")
        applied = [s.kind for s, why in sim.fault_log if why == "applied"]
        assert applied == ["rank_fail", "transient_stall", "dcn_degrade",
                           "rank_recover"]
        assert sim.controller.dead_ranks == ()
        assert sim.cfg.topology.dcn_bw == pytest.approx(TOPO.dcn_bw)
        assert all(np.isfinite(r.finished_at) for r in recs)
        fails = [u for u in ctl.updates if u.kind == "fail"]
        recovers = [u for u in ctl.updates if u.kind == "recover"]
        assert len(fails) == 1 and len(recovers) == 1
