"""Per-arch smoke tests (assignment requirement) + mixer correctness.

Every assigned architecture instantiates its REDUCED config and runs one
forward/train step on CPU asserting output shapes + no NaNs; decoder archs
also run prefill + decode.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, EXTRA_ARCHS, get_smoke
from repro.models import (block_layout, decode_fn, init_cache, init_params,
                          loss_fn, make_moe_tables, prefill_fn)
from repro.models import ssm
from repro.models.flash import flash_attention, flash_decode
from repro.training import adamw_init, adamw_update


def _smoke_batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.frontend == "audio":
        return {"feats": jnp.asarray(rng.normal(0, 1, (B, S, cfg.frontend_dim)),
                                     jnp.bfloat16),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                      jnp.int32)}
    if cfg.frontend == "vision":
        st = S - cfg.n_patches
        return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, st)),
                                      jnp.int32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, st)),
                                      jnp.int32),
                "patches": jnp.asarray(rng.normal(0, 1, (B, cfg.n_patches,
                                                         cfg.frontend_dim)),
                                       jnp.bfloat16)}
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                  jnp.int32)}


@pytest.mark.parametrize(
    "arch", [pytest.param(a, marks=pytest.mark.slow)
             if a == "jamba-1.5-large-398b" else a
             for a in ALL_ARCHS + EXTRA_ARCHS])
def test_arch_smoke_train_step(arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    mt = make_moe_tables(cfg, None)
    batch = _smoke_batch(cfg)
    lossf = loss_fn(cfg)

    (loss, (tallies, aux)), grads = jax.value_and_grad(
        lossf, has_aux=True)(params, batch, mt)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    gleaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in gleaves)
    if cfg.is_moe:
        nb, specs = block_layout(cfg)
        n_moe = nb * sum(1 for s in specs if s.ffn == "moe")
        # logical-expert counts + capacity-dropped-assignment column
        assert tallies.shape == (n_moe, cfg.n_experts + 1)
        assert (np.asarray(tallies)[:, -1] == 0).all()   # dense never drops
        # every token routed top_k times per MoE layer
        t = batch.get("tokens", batch.get("feats"))
        logical = np.asarray(tallies)[:, :cfg.n_experts]
        np.testing.assert_allclose(logical.sum(1),
                                   t.shape[0] * t.shape[1] * cfg.top_k
                                   if "tokens" in batch else logical.sum(1))
    # one optimizer step runs
    opt = adamw_init(params)
    new_params, _ = adamw_update(grads, opt, params)
    assert jax.tree.structure(new_params) == jax.tree.structure(params)


@pytest.mark.parametrize(
    "arch", [pytest.param(a, marks=pytest.mark.slow)
             if a == "jamba-1.5-large-398b" else a
             for a in ALL_ARCHS])
def test_arch_smoke_prefill_decode(arch):
    cfg = get_smoke(arch)
    if not cfg.is_decoder:
        pytest.skip("encoder-only: no decode step")
    params = init_params(cfg, jax.random.PRNGKey(0))
    mt = make_moe_tables(cfg, None)
    B, S = 2, 16
    batch = _smoke_batch(cfg, B, S)
    batch.pop("labels", None)
    logits, cache, tallies = prefill_fn(cfg)(params, batch, mt)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()

    dcache = init_cache(cfg, B, 32)
    tok = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.array([3, 7], jnp.int32)               # per-sequence positions
    lg, ncache, _ = decode_fn(cfg)(params, tok, dcache, pos, mt)
    assert lg.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(lg)).all()


def test_decode_matches_prefill_logits():
    """Teacher-forcing a prompt through decode reproduces prefill logits."""
    cfg = get_smoke("smollm-360m")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 8
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    logits_p, _, _ = prefill_fn(cfg)(params, {"tokens": tokens}, None)
    cache = init_cache(cfg, B, S + 1)
    df = decode_fn(cfg)
    for t in range(S):
        logits_d, cache, _ = df(params, tokens[:, t:t + 1], cache,
                                jnp.full((B,), t, jnp.int32), None)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(logits_p),
                               atol=0.75, rtol=0.05)  # bf16 path tolerance


def test_gemma3_window_pattern():
    cfg = get_smoke("gemma3-4b")
    from repro.models.model import _windows
    win = _windows(cfg)
    assert win is not None
    flat = win.reshape(-1)
    assert (flat == 0).sum() == cfg.n_layers // cfg.global_every
    assert (flat[flat > 0] == cfg.window).all()


def test_jamba_block_structure():
    cfg = get_smoke("jamba-1.5-large-398b")
    nb, specs = block_layout(cfg)
    assert len(specs) == 8
    assert specs[0].mixer == "attn"
    assert all(s.mixer == "mamba" for s in specs[1:])
    assert sum(1 for s in specs if s.ffn == "moe") == 4


def test_xlstm_block_structure():
    cfg = get_smoke("xlstm-350m")
    nb, specs = block_layout(cfg)
    assert specs[0].mixer == "slstm"
    assert all(s.mixer == "mlstm" for s in specs[1:])


# -- mixer correctness: chunked/parallel forms vs step recurrence ----------

def test_mamba_chunked_equals_step():
    B, S, D = 2, 24, 32
    p = ssm.mamba_init(jax.random.PRNGKey(0), D)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D)).astype(jnp.bfloat16)
    y_full, st_full = ssm.mamba_seq(p, x, chunk=8)
    st = ssm.mamba_state_init(B, D)
    ys = []
    for t in range(S):
        y, st = ssm.mamba_step(p, x[:, t:t + 1], st)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full, np.float32),
                               np.asarray(y_step, np.float32),
                               atol=2e-2, rtol=2e-2)
    np.testing.assert_allclose(np.asarray(st_full["h"]), np.asarray(st["h"]),
                               atol=1e-4, rtol=1e-3)


def test_mlstm_chunked_equals_step():
    B, S, D, H = 2, 16, 32, 2
    p = ssm.mlstm_init(jax.random.PRNGKey(0), D, n_heads=H)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D)).astype(jnp.bfloat16)
    y_full, stf = ssm.mlstm_seq(p, x, chunk=4)
    st = None
    ys = []
    for t in range(S):
        y, st = ssm.mlstm_step(p, x[:, t:t + 1], st)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full, np.float32),
                               np.asarray(y_step, np.float32),
                               atol=3e-2, rtol=3e-2)


def test_slstm_chunk_size_invariance():
    B, S, D, H = 2, 16, 32, 2
    p = ssm.slstm_init(jax.random.PRNGKey(0), D, n_heads=H)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D)).astype(jnp.bfloat16)
    y1, _ = ssm.slstm_seq(p, x, chunk=4)
    y2, _ = ssm.slstm_seq(p, x, chunk=16)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32),
                               atol=1e-3, rtol=1e-3)


# -- flash attention -------------------------------------------------------

def _quad_ref(q, k, v, causal, window, hd):
    S = q.shape[1]
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k) / np.sqrt(hd)
    qp, kp = jnp.arange(S)[:, None], jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= (qp - kp) < window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    return jnp.einsum("bkgqs,bskh->bqkgh", jax.nn.softmax(scores, -1), v)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 7), (False, 0)])
@pytest.mark.parametrize("chunks", [(16, 8), (64, 64), (11, 5)])
def test_flash_vs_quadratic(causal, window, chunks):
    B, S, KV, G, hd = 2, 64, 2, 3, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, KV, G, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    ref = _quad_ref(q, k, v, causal, window, hd)
    out = flash_attention(q, k, v, causal=causal,
                          window=jnp.int32(window) if window else None,
                          q_chunk=chunks[0], kv_chunk=chunks[1])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_decode_per_sequence_positions():
    B, S_max, KV, G, hd = 3, 32, 2, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd))
    kc = jax.random.normal(ks[1], (B, S_max, KV, hd))
    vc = jax.random.normal(ks[2], (B, S_max, KV, hd))
    pos = jnp.array([5, 17, 31])
    out = flash_decode(q, kc, vc, pos, kv_chunk=8)
    for b in range(B):
        sc = jnp.einsum("kgh,skh->kgs", q[b], kc[b]) / np.sqrt(hd)
        sc = jnp.where((jnp.arange(S_max) <= pos[b])[None, None], sc, -1e30)
        ref = jnp.einsum("kgs,skh->kgh", jax.nn.softmax(sc, -1), vc[b])
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)
