"""Placement-policy registry: protocol, capabilities, shim parity, budgets.

Covers the registry API surface end to end: unknown-name errors list what
is registered, the deprecated ``solve_model_placement`` shim reproduces the
old string-dispatch paths bit for bit, capability flags (not name
comparisons) gate the controller's incremental path, and per-rank
(non-uniform) slot budgets are first-class through ``SolveContext``.
"""

import numpy as np
import pytest

from repro.core import (DriftConfig, Placement, PerfModel,
                        PolicyCapabilities, ReplicatedPlacement,
                        SolveContext, UnknownPolicyError, ViBEConfig,
                        ViBEController, contiguous_placement, eplb_placement,
                        gem_placement, get_policy, harmoeny_placement,
                        incremental_update_replicated, make_cluster,
                        predicted_rank_latencies, register_policy,
                        registered_policies, reweight_shares_by_speed,
                        solve_model_placement, vibe_placement,
                        vibe_r_placement)
from repro.core import policy as policy_mod


def linear_models(speeds):
    """f_g(n) = n / speed — exact linear latency curves per device."""
    return [PerfModel(np.array([0.0, 1e6]),
                      np.array([1e-9, 1e6 / s]), device_id=g)
            for g, s in enumerate(speeds)]


@pytest.fixture
def fixture():
    rng = np.random.default_rng(11)
    G, E, L = 4, 16, 3
    w = rng.dirichlet(np.full(E, 0.3), size=L) * 20_000
    perf = linear_models([1.0, 0.9, 1.1, 0.6])
    return G, E, L, w, perf


# ---------------------------------------------------------------------------
# registry surface
# ---------------------------------------------------------------------------

def test_builtin_family_registered():
    names = registered_policies()
    for expected in ("contiguous", "eplb", "gem", "harmoeny", "vibe",
                     "vibe_r"):
        assert expected in names
    assert names == tuple(sorted(names))


def test_every_registered_policy_satisfies_protocol():
    from repro.core.policy import PlacementPolicy
    for name in registered_policies():
        pol = get_policy(name)
        assert isinstance(pol, PlacementPolicy)
        assert pol.name == name
        assert isinstance(pol.capabilities, PolicyCapabilities)


def test_unknown_policy_error_lists_registered_names():
    with pytest.raises(UnknownPolicyError) as ei:
        get_policy("nope")
    msg = str(ei.value)
    for name in registered_policies():
        assert name in msg
    assert isinstance(ei.value, ValueError)       # legacy except-clauses work


def test_register_custom_policy_and_duplicate_rejection(fixture):
    G, E, L, w, perf = fixture

    class RotatePolicy:
        name = "_test_rotate"
        capabilities = PolicyCapabilities(workload_aware=False)

        def solve(self, ctx):
            e_loc = ctx.n_experts // ctx.n_ranks
            row = ((np.arange(ctx.n_experts) // e_loc + 1)
                   % ctx.n_ranks).astype(np.int32)
            return ReplicatedPlacement.from_singleton(
                Placement(np.tile(row, (ctx.n_layers, 1)), ctx.n_ranks))

    register_policy(RotatePolicy)
    try:
        assert "_test_rotate" in registered_policies()
        pl = get_policy("_test_rotate").solve(SolveContext(w=w, n_ranks=G))
        assert isinstance(pl, ReplicatedPlacement)
        assert pl.n_copies().max() == 1
        with pytest.raises(ValueError, match="already registered"):
            register_policy(RotatePolicy)
        register_policy(RotatePolicy, replace=True)   # explicit override ok
    finally:
        policy_mod._REGISTRY.pop("_test_rotate", None)


def test_register_rejects_non_conforming_objects():
    class NoSolve:
        name = "_test_nosolve"
        capabilities = PolicyCapabilities()

    with pytest.raises(TypeError, match="protocol"):
        register_policy(NoSolve)
    assert "_test_nosolve" not in registered_policies()

    class NoRefine:
        name = "_test_norefine"
        capabilities = PolicyCapabilities(supports_incremental=True)

        def solve(self, ctx):
            raise NotImplementedError

    # advertising supports_incremental without refine must fail at
    # registration, not as an AttributeError mid-serving
    with pytest.raises(TypeError, match="refine"):
        register_policy(NoRefine)
    assert "_test_norefine" not in registered_policies()


# ---------------------------------------------------------------------------
# deprecation shim: bit-identical to the historical string-dispatch paths
# ---------------------------------------------------------------------------

def test_shim_golden_parity_all_legacy_policies(fixture):
    G, E, L, w, perf = fixture
    legacy = {
        "contiguous": contiguous_placement(L, E, G),
        "eplb": eplb_placement(w, G),
        "vibe": vibe_placement(w, perf),
        "vibe_r": vibe_r_placement(w, perf),
    }
    for name, ref in legacy.items():
        with pytest.warns(DeprecationWarning, match="deprecated"):
            got = solve_model_placement(
                name, w, G,
                perf_models=perf if name in ("vibe", "vibe_r") else None)
        assert type(got) is type(ref)
        if isinstance(ref, ReplicatedPlacement):
            np.testing.assert_array_equal(got.slot_expert, ref.slot_expert)
            np.testing.assert_array_equal(got.share, ref.share)
        else:
            np.testing.assert_array_equal(got.assign, ref.assign)


def test_shim_preserves_legacy_error_behaviour(fixture):
    G, E, L, w, perf = fixture
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="requires perf_models"):
            solve_model_placement("vibe", w, G)
        with pytest.raises(ValueError, match="per rank"):
            solve_model_placement("vibe", w, G + 1, perf_models=perf)
        with pytest.raises(ValueError):
            solve_model_placement("nope", w, G)
        # historical leniency: slots_per_rank silently ignored when the
        # policy's capabilities don't accept a budget
        pl = solve_model_placement("eplb", w, G, slots_per_rank=7)
        assert isinstance(pl, Placement)


# ---------------------------------------------------------------------------
# unified placement representation
# ---------------------------------------------------------------------------

def test_registry_solves_are_unified_replicated(fixture):
    G, E, L, w, perf = fixture
    for name in registered_policies():
        pol = get_policy(name)
        ctx = SolveContext(
            w=w, n_ranks=G,
            perf_models=perf if pol.capabilities.needs_perf_models else None)
        pl = pol.solve(ctx)
        assert isinstance(pl, ReplicatedPlacement), name
        lat = predicted_rank_latencies(pl, w, perf)
        assert np.isfinite(lat).all(), name
        if not pol.capabilities.supports_replication:
            assert int(pl.n_copies().max()) == 1, name
            # singleton degenerate: assign/to_singleton round-trip
            single = pl.to_singleton()
            np.testing.assert_array_equal(pl.assign, single.assign)
            back = ReplicatedPlacement.from_singleton(single)
            np.testing.assert_array_equal(back.slot_expert, pl.slot_expert)


def test_to_singleton_rejects_genuine_replication(fixture):
    G, E, L, w, perf = fixture
    rp = vibe_r_placement(w, perf, slots_per_rank=E // G + 1)
    with pytest.raises(ValueError, match="replicated"):
        rp.to_singleton()
    with pytest.raises(ValueError, match="replicated"):
        rp.assign


# ---------------------------------------------------------------------------
# ViBEConfig capability validation
# ---------------------------------------------------------------------------

def test_config_rejects_budget_for_non_budget_policies():
    for name in ("vibe", "eplb", "contiguous", "gem"):
        with pytest.raises(ValueError, match="accepts_slot_budget"):
            ViBEConfig(policy=name, slot_budget=3)
    ViBEConfig(policy="vibe_r", slot_budget=3)            # fine
    ViBEConfig(policy="harmoeny", slot_budget=[3, 2, 3, 2])


def test_config_rejects_reweight_without_refine_path():
    # the reweight only acts on the incremental refine path, so singleton
    # policies AND replication-capable ones without refine (harmoeny) must
    # reject it instead of accepting a silently inert flag
    for name in ("vibe", "eplb", "contiguous", "gem", "harmoeny"):
        with pytest.raises(ValueError, match="reweight_shares"):
            ViBEConfig(policy=name, reweight_shares=True)
    ViBEConfig(policy="vibe_r", reweight_shares=True)     # fine


def test_config_rejects_unknown_policy():
    with pytest.raises(UnknownPolicyError, match="registered"):
        ViBEConfig(policy="definitely_not_registered")


def test_config_legacy_slots_per_rank_kwarg_still_constructs():
    """The published pre-registry kwarg keeps working as an alias."""
    cfg = ViBEConfig(policy="vibe_r", slots_per_rank=6)
    assert cfg.slot_budget == 6
    assert cfg.slots_per_rank == 6
    cfg = ViBEConfig(policy="vibe_r", slot_budget=[3, 2, 3, 2])
    assert list(cfg.slots_per_rank) == [3, 2, 3, 2]
    with pytest.raises(ValueError, match="not conflicting both"):
        ViBEConfig(policy="vibe_r", slot_budget=6, slots_per_rank=7)
    with pytest.raises(ValueError, match="accepts_slot_budget"):
        ViBEConfig(policy="vibe", slots_per_rank=6)


def test_context_validates_budget_feasibility_at_boundary(fixture):
    """Infeasible budgets fail when the SolveContext is built — before any
    policy (including third-party ones) can read them."""
    G, E, L, w, perf = fixture
    with pytest.raises(ValueError, match="cannot hold"):
        SolveContext(w=w, n_ranks=G, slot_budget=[1, 1, 1, 1])   # Σ < E
    with pytest.raises(ValueError, match="at least 1"):
        SolveContext(w=w, n_ranks=G, slot_budget=[0, 8, 8, 8])
    ctx = SolveContext(w=w, n_ranks=G, slot_budget=5)            # scalar → (G,)
    np.testing.assert_array_equal(ctx.slot_budget, np.full(G, 5))


# ---------------------------------------------------------------------------
# capability flags gate the controller's recalibration path
# ---------------------------------------------------------------------------

def _drive_to_drift(policy, **cfg_kw):
    cluster = make_cluster(4, "mi325x", d_model=256, d_ff=128,
                           experts_per_rank=4)
    rng = np.random.default_rng(5)
    w0 = rng.dirichlet(np.full(16, 0.3), size=3) * 20_000
    ctl = ViBEController(
        3, 16, 4, cluster.fit_models(),
        ViBEConfig(policy=policy, adaptive=True, expert_bytes=10,
                   drift=DriftConfig(window=10, interval=5, cooldown=5),
                   **cfg_kw))
    for _ in range(30):
        assert ctl.observe(w0 * rng.uniform(0.97, 1.03)) is None
    w1 = np.roll(w0, 6, axis=1)
    for _ in range(40):
        upd = ctl.observe(w1)
        if upd is not None:
            return ctl, upd
    raise AssertionError(f"no drift update fired for {policy}")


def test_supports_incremental_selects_refine_path():
    for policy in ("vibe", "vibe_r"):
        ctl, upd = _drive_to_drift(policy)
        assert get_policy(policy).capabilities.supports_incremental
        assert not upd.full_resolve
        assert upd.swaps_per_layer is not None
        assert upd.moved_experts == upd.migration_bytes // 10
        assert isinstance(upd.placement, ReplicatedPlacement)


def test_no_incremental_capability_means_full_resolve():
    for policy in ("eplb", "harmoeny", "gem"):
        ctl, upd = _drive_to_drift(policy)
        assert not get_policy(policy).capabilities.supports_incremental
        assert upd.full_resolve
        assert upd.swaps_per_layer is None


def test_static_policy_never_recalibrates():
    cluster = make_cluster(4, "mi325x", d_model=256, d_ff=128,
                           experts_per_rank=4)
    ctl = ViBEController(2, 8, 4, cluster.fit_models(),
                         ViBEConfig(policy="contiguous", adaptive=True))
    assert not get_policy("contiguous").capabilities.workload_aware
    rng = np.random.default_rng(7)
    for i in range(60):
        w = rng.dirichlet(np.full(8, 0.3), size=2) * 1000 * (1 + i)
        assert ctl.observe(w) is None


# ---------------------------------------------------------------------------
# the two related-work baselines
# ---------------------------------------------------------------------------

def test_gem_routes_around_slow_rank(fixture):
    G, E, L, w, perf = fixture                   # rank 3 is 40% slower
    pl = gem_placement(w, perf)
    loads = pl.rank_loads(w)
    assert loads[:, 3].mean() < 0.85 * loads[:, :3].mean()
    # uniform slot constraint + bijectivity hold
    counts = np.apply_along_axis(np.bincount, 1, pl.assign, minlength=G)
    assert (counts == E // G).all()
    # variability-aware greedy beats the oblivious layouts it baselines
    lat_gem = predicted_rank_latencies(pl, w, perf).max(1).mean()
    lat_cont = predicted_rank_latencies(
        contiguous_placement(L, E, G), w, perf).max(1).mean()
    assert lat_gem < lat_cont


def test_harmoeny_replicates_hot_expert_load_balance_only():
    G, E, L = 4, 16, 2
    w = np.full((L, E), 100.0)
    w[:, 0] = 20_000.0                           # one mega-hot expert
    rp = harmoeny_placement(w, G, slots_per_rank=E // G + 2)
    assert rp.n_copies()[:, 0].min() >= 2        # hot expert got copies
    # shares are uniform over copies (hardware-oblivious by construction)
    cs = rp.copy_shares()
    nc = rp.n_copies()
    expect = np.where(np.arange(cs.shape[-1])[None, None, :] < nc[..., None],
                      1.0 / nc[..., None], 0.0)
    np.testing.assert_allclose(cs, expect, atol=1e-12)
    # replication splits the hot expert below the singleton bound
    singleton_max = eplb_placement(w, G).rank_loads(w).max()
    assert rp.rank_loads(w).max() < 0.7 * singleton_max


def test_harmoeny_ignores_hardware(fixture):
    """Same solve whatever the perf models say — it never reads them."""
    G, E, L, w, perf = fixture
    a = harmoeny_placement(w, G)
    ctx = SolveContext(w=w, n_ranks=G, perf_models=perf)  # carried, unread
    b = get_policy("harmoeny").solve(ctx)
    np.testing.assert_array_equal(a.slot_expert, b.slot_expert)
    np.testing.assert_array_equal(a.share, b.share)


# ---------------------------------------------------------------------------
# per-rank (non-uniform) slot budgets
# ---------------------------------------------------------------------------

def test_non_uniform_slot_budget_solve(fixture):
    G, E, L, w, perf = fixture
    budget = np.array([6, 4, 5, 4])              # memory-headroom driven
    ctx = SolveContext(w=w, n_ranks=G, perf_models=perf, slot_budget=budget)
    rp = get_policy("vibe_r").solve(ctx)
    # physical layout: uniform s_max slots per rank, phantoms pad the tail
    assert rp.slots_per_rank == 6
    assert rp.n_slots == 24
    np.testing.assert_array_equal(rp.rank_slot_budget(),
                                  np.tile(budget, (L, 1)))
    nc = rp.n_copies()
    assert (nc >= 1).all()
    assert int(nc.sum()) == int(budget.sum()) * L
    # phantom slots carry no expert and no share
    phantom = rp.slot_expert == E
    assert int(phantom.sum()) == (6 * G - int(budget.sum())) * L
    assert np.all(rp.share[phantom] == 0.0)
    # traffic conservation through fractional and realized splits
    from repro.serving.simulator import realized_rank_loads
    np.testing.assert_allclose(rp.rank_loads(w).sum(1), w.sum(1))
    realized = realized_rank_loads(rp, np.round(w))
    np.testing.assert_allclose(realized.sum(1), np.round(w).sum(1))
    assert np.isfinite(predicted_rank_latencies(rp, w, perf)).all()


def test_non_uniform_budget_harmoeny(fixture):
    G, E, L, w, perf = fixture
    rp = harmoeny_placement(w, G, slots_per_rank=[5, 4, 4, 5])
    np.testing.assert_array_equal(rp.rank_slot_budget(),
                                  np.tile([5, 4, 4, 5], (L, 1)))
    np.testing.assert_allclose(rp.rank_loads(w).sum(1), w.sum(1))


def test_non_uniform_budget_incremental_and_reweight(fixture):
    """Swap-based refinement + share reweighting preserve per-rank budgets
    (phantom slots never move — they are missing memory, not capacity)."""
    G, E, L, w, perf = fixture
    budget = np.array([6, 4, 5, 4])
    rp = vibe_r_placement(w, perf, slots_per_rank=budget)
    w2 = np.roll(w, 5, axis=1)
    res = incremental_update_replicated(rp, w2, perf)
    np.testing.assert_array_equal(res.placement.rank_slot_budget(),
                                  rp.rank_slot_budget())
    np.testing.assert_array_equal(res.placement.n_copies().sum(1),
                                  rp.n_copies().sum(1))
    rw = reweight_shares_by_speed(res.placement, w2, perf)
    assert np.all(rw.share[rw.slot_expert == E] == 0.0)
    np.testing.assert_allclose(rw.rank_loads(w2).sum(1), w2.sum(1))


def test_budget_validation_errors(fixture):
    G, E, L, w, perf = fixture
    with pytest.raises(ValueError, match="cannot hold"):
        vibe_r_placement(w, perf, slots_per_rank=[1, 1, 1, 1])   # sum < E
    with pytest.raises(ValueError, match="at least 1"):
        vibe_r_placement(w, perf, slots_per_rank=[0, 8, 8, 8])
    with pytest.raises(ValueError, match="full .*expert set"):
        vibe_r_placement(w, perf, slots_per_rank=[E + 1, 5, 5, 5])
    with pytest.raises(ValueError, match="shape"):
        SolveContext(w=w, n_ranks=G, perf_models=perf,
                     slot_budget=[3, 3, 3])                      # wrong G
    # budget offered to a policy that can't honour it → loud error
    with pytest.raises(ValueError, match="accepts_slot_budget"):
        get_policy("vibe").solve(
            SolveContext(w=w, n_ranks=G, perf_models=perf, slot_budget=5))


def test_uniform_array_budget_matches_scalar(fixture):
    """A constant (G,) budget array is exactly the scalar path — no phantom
    padding, bit-identical layout."""
    G, E, L, w, perf = fixture
    a = vibe_r_placement(w, perf, slots_per_rank=5)
    b = vibe_r_placement(w, perf, slots_per_rank=np.full(G, 5))
    np.testing.assert_array_equal(a.slot_expert, b.slot_expert)
    np.testing.assert_array_equal(a.share, b.share)
    assert not np.any(a.slot_expert == E)
